"""Online-admission front-door tests: traffic models, the batch-full-or-
deadline policy (driven deterministically on a virtual clock), shape
bucketing, the engine's depth-k in-flight window + protocol submit/drain
API, and the warmup-aware stats split."""

import time

import jax
import numpy as np
import pytest

from repro.configs import base as cbase
from repro.models import nvsa
from repro.serve import frontdoor as fd
from repro.serve.reason import ReasonConfig, ReasonRequest, requests_from_batch


class VirtualClock:
    """Deterministic clock + sleep pair for driving the serve loop."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def sleep(self, dt: float):
        assert dt >= 0
        self.t += dt


def _oracle_engine(model="nvsa", batch_size=4, buckets=(2, 4),
                   max_inflight=1, schedule="overlap", d=64):
    """Cheap symbolic-stream-only engine (no CNN params needed)."""
    cfg = cbase.REASON_WORKLOADS[model].make_config(d=d)
    consts = {"params": None,
              "books": nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))}
    eng = cbase.reason_engine(
        model, cfg,
        ReasonConfig(batch_size=batch_size, buckets=buckets,
                     max_inflight=max_inflight, schedule=schedule),
        consts=consts, variants=("oracle",), trace_graph=False)
    return cfg, consts, eng


def _oracle_requests(cfg, n, seed=3):
    from repro.data import raven

    return requests_from_batch(raven.generate_batch(cfg.raven, seed=seed,
                                                    n=n))


# -- traffic models ----------------------------------------------------------


def test_pow2_buckets():
    assert fd.pow2_buckets(8) == (2, 4, 8)
    assert fd.pow2_buckets(6) == (2, 4, 6)
    assert fd.pow2_buckets(2) == (2,)
    assert fd.pow2_buckets(1) == (1,)
    assert fd.pow2_buckets(8, min_bucket=1) == (1, 2, 4, 8)
    with pytest.raises(ValueError):
        fd.pow2_buckets(0)


def test_poisson_arrivals_rate_and_determinism():
    reqs = [ReasonRequest(uid=i) for i in range(400)]
    a = list(fd.poisson_arrivals("m", reqs, rate_rps=50.0, seed=7))
    b = list(fd.poisson_arrivals("m", reqs, rate_rps=50.0, seed=7))
    assert [x.t for x in a] == [x.t for x in b]  # seeded => reproducible
    gaps = np.diff([0.0] + [x.t for x in a])
    assert (gaps > 0).all()
    assert 1 / 50.0 * 0.8 < gaps.mean() < 1 / 50.0 * 1.2
    with pytest.raises(ValueError, match="rate_rps"):
        next(fd.poisson_arrivals("m", reqs, rate_rps=0.0))


def test_poisson_arrivals_pull_requests_lazily():
    pulled = []

    def stream():
        for i in range(5):
            pulled.append(i)
            yield ReasonRequest(uid=i)

    it = fd.poisson_arrivals("m", stream(), rate_rps=10.0)
    assert pulled == []          # nothing rendered before the first pull
    next(it)
    assert len(pulled) == 1


def test_trace_arrivals_validation():
    reqs = [ReasonRequest(uid=i) for i in range(2)]
    out = list(fd.trace_arrivals("m", [0.1, 0.4], reqs))
    assert [a.t for a in out] == [0.1, 0.4]
    with pytest.raises(ValueError, match="nondecreasing"):
        list(fd.trace_arrivals("m", [0.4, 0.1], reqs))
    with pytest.raises(ValueError, match="more times"):
        list(fd.trace_arrivals("m", [0.1, 0.2, 0.3], reqs))


def test_merge_arrivals_orders_streams():
    r = lambda u: ReasonRequest(uid=u)
    s1 = fd.trace_arrivals("a", [0.0, 0.3], [r(0), r(1)])
    s2 = fd.trace_arrivals("b", [0.1, 0.2], [r(0), r(1)])
    merged = list(fd.merge_arrivals(s1, s2))
    assert [(a.model, a.t) for a in merged] == \
        [("a", 0.0), ("b", 0.1), ("b", 0.2), ("a", 0.3)]


def test_merge_arrivals_tie_break_is_stable():
    """Equal timestamps across models must preserve per-stream FIFO order
    AND earlier-argument stream priority: heapq.merge is stable, and the
    admission policy (which model's group a simultaneous arrival joins
    first) depends on that.  Pinned so a future reimplementation (e.g. a
    naive sort on t alone) cannot silently reorder simultaneous traffic."""
    r = lambda u: ReasonRequest(uid=u)
    # all four arrivals of each stream collide pairwise at t=0.0/0.1/0.1/0.2
    times = [0.0, 0.1, 0.1, 0.2]
    s1 = fd.trace_arrivals("a", times, [r(0), r(1), r(2), r(3)])
    s2 = fd.trace_arrivals("b", times, [r(0), r(1), r(2), r(3)])
    merged = [(a.model, a.request.uid, a.t) for a in
              fd.merge_arrivals(s1, s2)]
    # ties: stream "a" (first argument) wins, each stream stays FIFO
    assert merged == [
        ("a", 0, 0.0), ("b", 0, 0.0),
        ("a", 1, 0.1), ("a", 2, 0.1), ("b", 1, 0.1), ("b", 2, 0.1),
        ("a", 3, 0.2), ("b", 3, 0.2),
    ]
    for model in ("a", "b"):
        uids = [u for m, u, _ in merged if m == model]
        assert uids == sorted(uids)      # per-stream FIFO preserved


# -- the admission policy (virtual clock) ------------------------------------


def test_admission_full_deadline_flush_and_buckets():
    """4 back-to-back arrivals close `full`; a pair closes at the 20ms
    deadline through the bucket-2 shape; stream-end flushes the tail."""
    cfg, consts, eng = _oracle_engine(batch_size=4, buckets=(2, 4))
    reqs = _oracle_requests(cfg, 9)
    times = [0.0, 0.001, 0.002, 0.003,      # -> full group of 4
             0.05, 0.051,                   # -> deadline group of 2
             0.2, 0.21, 0.22]               # -> flush group of 3
    clock = VirtualClock()
    door = fd.FrontDoor({"nvsa": eng}, fd.FrontDoorConfig(deadline_s=0.02),
                        clock=clock, sleep=clock.sleep)
    rep = door.serve(fd.trace_arrivals("nvsa", times, reqs))

    assert eng.clock is time.perf_counter  # serve restored the engine clock
    assert [(g.size, g.bucket, g.close_reason) for g in rep.groups] == \
        [(4, 4, "full"), (2, 2, "deadline"), (3, 4, "flush")]
    assert len(rep.latencies) == 9
    assert all(l.queue_s >= -1e-9 and l.service_s >= -1e-9
               for l in rep.latencies)
    # the deadline group's first (oldest) request waited exactly the deadline
    dl = [l for l in rep.latencies if l.close_reason == "deadline"]
    assert max(l.queue_s for l in dl) == pytest.approx(0.02, abs=1e-6)
    # full group dispatched immediately on the closing arrival
    full = [l for l in rep.latencies if l.close_reason == "full"]
    assert max(l.queue_s for l in full) <= 0.004 + 1e-6
    # answers match the offline engine run bit-exactly
    offline = eng.run(_oracle_requests(cfg, 9), variant="oracle")
    for uid, res in rep.results["nvsa"].items():
        np.testing.assert_array_equal(res.answer_logprobs,
                                      offline[uid].answer_logprobs)


def test_frontdoor_multiplexes_models():
    """nvsa + prae behind one front-door: per-model groups, per-model
    results, one time-ordered feed."""
    ncfg, nconsts, neng = _oracle_engine("nvsa")
    pcfg = cbase.REASON_WORKLOADS["prae"].make_config(d=64)
    pconsts = {"params": None, "books": None}
    peng = cbase.reason_engine(
        "prae", pcfg, ReasonConfig(batch_size=4, buckets=(2, 4)),
        consts=pconsts, variants=("oracle",), trace_graph=False)
    clock = VirtualClock()
    door = fd.FrontDoor({"nvsa": neng, "prae": peng},
                        fd.FrontDoorConfig(deadline_s=0.01),
                        clock=clock, sleep=clock.sleep)
    streams = [
        fd.poisson_arrivals("nvsa", _oracle_requests(ncfg, 6, seed=5),
                            rate_rps=300.0, seed=0),
        fd.poisson_arrivals("prae", _oracle_requests(pcfg, 5, seed=6),
                            rate_rps=300.0, seed=1),
    ]
    rep = door.serve(fd.merge_arrivals(*streams))
    assert sorted(rep.results) == ["nvsa", "prae"]
    assert len(rep.results["nvsa"]) == 6 and len(rep.results["prae"]) == 5
    assert {g.model for g in rep.groups} == {"nvsa", "prae"}
    assert rep.throughput_rps() > 0
    # NSAI rows report in problems: one work unit per request
    assert rep.work_unit("nvsa") == "prob"
    assert rep.work_per_s("nvsa") == pytest.approx(rep.throughput_rps("nvsa"))
    assert rep.summary()  # renders without blowing up
    p = rep.percentiles("queue_s", "prae")
    assert set(p) == {"p50", "p95", "p99"} and p["p50"] <= p["p99"]


def test_frontdoor_empty_stream_well_formed_report():
    """An empty arrival stream must return a well-formed empty report, not
    crash or hang: per-model result dicts present, no latencies/groups,
    NaN percentiles, zero throughput, empty summary."""
    cfg, consts, eng = _oracle_engine()
    clock = VirtualClock()
    door = fd.FrontDoor({"nvsa": eng}, clock=clock, sleep=clock.sleep)
    rep = door.serve(iter([]))
    assert rep.results == {"nvsa": {}}
    assert rep.latencies == [] and rep.groups == []
    assert rep.wall_time_s >= 0 and np.isfinite(rep.wall_time_s)
    assert rep.throughput_rps() == 0.0 and rep.work_per_s() == 0.0
    assert all(np.isnan(v) for v in rep.percentiles().values())
    assert rep.bucket_histogram() == {}
    assert rep.summary() == ""
    assert eng.inflight == 0


def test_frontdoor_validation_errors():
    cfg, consts, eng = _oracle_engine()
    with pytest.raises(ValueError, match="at least one engine"):
        fd.FrontDoor({})
    with pytest.raises(ValueError, match="deadline_s"):
        fd.FrontDoor({"nvsa": eng}, fd.FrontDoorConfig(deadline_s=-1.0))
    clock = VirtualClock()
    door = fd.FrontDoor({"nvsa": eng}, clock=clock, sleep=clock.sleep)
    reqs = _oracle_requests(cfg, 2)
    with pytest.raises(ValueError, match="unknown model"):
        door.serve(fd.trace_arrivals("mystery", [0.0], reqs[:1]))
    with pytest.raises(ValueError, match="not time-ordered"):
        door.serve(iter([fd.ArrivalRequest(0.5, "nvsa", reqs[0]),
                         fd.ArrivalRequest(0.1, "nvsa", reqs[1])]))


def test_frontdoor_rejects_duplicate_uid_across_whole_serve():
    """Engines allow uid reuse after a drain, so the front-door must
    guard serve-lifetime uniqueness itself: a duplicate arriving after
    its predecessor was already served would otherwise silently
    overwrite the earlier answer in the report's results dict."""
    cfg, consts, eng = _oracle_engine(batch_size=2, buckets=(2,))
    reqs = _oracle_requests(cfg, 4)
    dup = reqs[:2] + reqs[:1]           # uid 0 arrives again much later
    clock = VirtualClock()
    door = fd.FrontDoor({"nvsa": eng}, fd.FrontDoorConfig(deadline_s=0.01),
                        clock=clock, sleep=clock.sleep)
    with pytest.raises(ValueError, match="duplicate request uid"):
        door.serve(fd.trace_arrivals("nvsa", [0.0, 0.001, 5.0], dup))


# -- engine group-level API (the runtime protocol) ---------------------------


def test_engine_inflight_window_depth():
    """max_inflight=2: the third submit must drain the first group."""
    cfg, consts, eng = _oracle_engine(batch_size=2, buckets=(2,),
                                      max_inflight=2)
    reqs = _oracle_requests(cfg, 6)
    r1 = eng.submit(reqs[0:2])
    r2 = eng.submit(reqs[2:4])
    assert eng.inflight == 2 and r1.done_t is None and r2.done_t is None
    r3 = eng.submit(reqs[4:6])
    assert r1.done_t is not None          # drained to make room
    assert eng.inflight == 2              # r2, r3 still resident
    results = eng.drain_all()
    assert sorted(results) == list(range(6))
    assert all(r.done_t >= r.dispatch_t for r in (r1, r2, r3))


def test_engine_drain_ready_nonblocking():
    cfg, consts, eng = _oracle_engine(batch_size=2, buckets=(2,),
                                      max_inflight=4)
    reqs = _oracle_requests(cfg, 4)
    eng.submit(reqs[:2])
    eng.submit(reqs[2:])
    results = {}
    deadline = time.time() + 30
    while eng.inflight and time.time() < deadline:
        results.update(eng.drain_ready())
        time.sleep(0.005)
    results.update(eng.drain_all())  # collect stragglers deterministically
    assert eng.inflight == 0 and len(results) == 4


def test_engine_submit_rejections():
    cfg, consts, eng = _oracle_engine(batch_size=2, buckets=(2,))
    reqs = _oracle_requests(cfg, 4)
    with pytest.raises(ValueError, match="empty admission group"):
        eng.submit([])
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(reqs[:3])
    with pytest.raises(ValueError, match="duplicate request uid"):
        eng.submit([reqs[0], reqs[0]])     # duplicate inside one group
    eng.submit(reqs[:2])
    with pytest.raises(ValueError, match="duplicate request uid"):
        eng.submit(reqs[:2])      # still in flight
    with pytest.raises(ValueError, match="undrained in-flight"):
        eng.run(reqs[2:])
    undrained = eng.drain_all()
    assert sorted(undrained) == [0, 1]
    with pytest.raises(ValueError, match="max_inflight"):
        cbase.reason_engine(
            "nvsa", cfg, ReasonConfig(max_inflight=0),
            consts=consts, variants=("oracle",), trace_graph=False)
    with pytest.raises(ValueError, match="largest compiled bucket"):
        cbase.reason_engine(
            "nvsa", cfg, ReasonConfig(batch_size=8, buckets=(2, 4)),
            consts=consts, variants=("oracle",), trace_graph=False)
    nc, _, unbound = _oracle_engine(batch_size=2, buckets=(2,))
    unbound.consts = None
    with pytest.raises(ValueError, match="no consts bound"):
        unbound.submit(reqs[:2])


def test_covering_bucket():
    cfg, consts, eng = _oracle_engine(batch_size=4, buckets=(2, 4))
    sched = eng.schedules["oracle"]
    assert sched.batch_buckets == (2, 4)
    assert [sched.covering_bucket(n) for n in (1, 2, 3, 4)] == [2, 2, 4, 4]
    with pytest.raises(ValueError, match="exceeds the largest"):
        sched.covering_bucket(5)


class CountingClock:
    """Monotone counter: every read advances, so stamp *ordering* is the
    observable (no wall-time ambiguity)."""

    def __init__(self):
        self.n = 0.0

    def __call__(self) -> float:
        self.n += 1.0
        return self.n


@pytest.mark.parametrize("drain_stage", [0, 1])
def test_window_block_never_charged_to_new_group_service(drain_stage):
    """Regression: with a full in-flight window, the new group's
    ``dispatch_t`` must be stamped BEFORE the engine blocks draining the
    oldest group — the window wait is queueing, never the new group's
    service time.  Earlier revisions drained mid-pipeline at the
    schedule's ``drain_stage``, which reordered the stamps whenever
    ``drain_stage > 0``; the ordering must now be independent of it."""
    cfg, consts, eng = _oracle_engine(batch_size=2, buckets=(2,),
                                      max_inflight=1)
    eng.schedules["oracle"].drain_stage = drain_stage
    eng.clock = CountingClock()
    reqs = _oracle_requests(cfg, 4)
    r1 = eng.submit(reqs[:2])
    r2 = eng.submit(reqs[2:])  # window full: dispatch r2, THEN drain r1
    assert r1.done_t is not None          # drained to keep the window at 1
    assert r2.done_t is None
    assert r2.dispatch_t < r1.done_t      # dispatched before the block
    eng.drain_all()
    assert r2.done_t > r2.dispatch_t


def test_protocol_path_accumulates_measured_stats():
    """Regression: engines driven purely through submit/drain (the
    front-door path — ``run()`` never called) used to accumulate zero
    measured requests/wall time, so ``problems_per_s()`` reported the
    warmup-fallback rate forever.  Groups are now accounted at collect
    time, keyed off each group's own cold flag."""
    cfg, consts, eng = _oracle_engine(batch_size=2, buckets=(2,),
                                      max_inflight=1)
    reqs = _oracle_requests(cfg, 8)
    for lo in range(0, 8, 2):
        eng.submit(reqs[lo:lo + 2])
    results = eng.drain_all()
    assert len(results) == 8
    assert eng.stats["warmup"]["requests"] == 2    # the one cold group
    assert eng.stats["measured"]["requests"] == 6  # warm groups measured
    assert eng.stats["measured"]["work"] == 6
    assert eng.stats["measured"]["wall_time_s"] > 0
    assert eng.problems_per_s() > 0


def test_drain_ready_probe_is_conservative():
    """A buffer leaf with no ``is_ready()`` that is not host-side data
    must probe NOT ready — ``drain_ready`` skips the group instead of
    vacuously treating it as finished and then blocking in collect."""
    from repro.serve.reason import ReasonEngine

    class OpaqueLeaf:  # e.g. a donated-buffer surrogate
        pass

    class FakeArray:
        def __init__(self, ready):
            self._ready = ready

        def is_ready(self):
            return self._ready

    assert ReasonEngine._leaf_ready(np.zeros(2))
    assert ReasonEngine._leaf_ready(1.5) and ReasonEngine._leaf_ready(3)
    assert ReasonEngine._leaf_ready(FakeArray(True))
    assert not ReasonEngine._leaf_ready(FakeArray(False))
    assert not ReasonEngine._leaf_ready(OpaqueLeaf())

    # an in-flight group whose buffers are opaque must not drain
    cfg, consts, eng = _oracle_engine(batch_size=2, buckets=(2,),
                                      max_inflight=4)
    reqs = _oracle_requests(cfg, 4)
    eng.submit(reqs[:2])
    group, bufs, rec, sched, cold, t0 = eng._inflight[0]
    eng._inflight[0] = (group, {"x": OpaqueLeaf()}, rec, sched, cold, t0)
    assert eng.drain_ready() == {}
    assert eng.inflight == 1
    eng._inflight[0] = (group, bufs, rec, sched, cold, t0)
    out = eng.drain_all()
    assert sorted(out) == [0, 1]


def test_drain_ready_under_fused_schedule():
    """The fused (one-jit, donation-eligible) pipeline serves through the
    same non-blocking probe loop the front-door drives."""
    cfg, consts, eng = _oracle_engine(batch_size=2, buckets=(2,),
                                      max_inflight=4, schedule="fused")
    assert eng.schedules["oracle"].fused_ok
    reqs = _oracle_requests(cfg, 4)
    eng.submit(reqs[:2])
    eng.submit(reqs[2:])
    results = {}
    deadline = time.time() + 30
    while eng.inflight and time.time() < deadline:
        results.update(eng.drain_ready())
        time.sleep(0.005)
    results.update(eng.drain_all())
    assert sorted(results) == list(range(4))
    assert eng.stats["fused_groups"] == 2
    assert eng.stats["dispatches"] == 2            # one launch per group


# -- stats: warmup split + per-variant stage keys ----------------------------


def test_stats_warmup_split_and_per_run_records():
    cfg, consts, eng = _oracle_engine(batch_size=2, buckets=(2,))
    reqs = _oracle_requests(cfg, 4)
    eng.run(reqs[:2])
    assert eng.last_run["warmup"] is True          # compiled bucket 2
    assert eng.stats["warmup"]["requests"] == 2
    assert eng.stats["measured"]["requests"] == 0
    warm_pps = eng.problems_per_s()                # warmup-only fallback
    assert warm_pps > 0
    eng.run(reqs[2:])
    assert eng.last_run["warmup"] is False
    assert eng.stats["measured"]["requests"] == 2
    # now measured-only: compile time no longer in the denominator
    assert eng.problems_per_s() > warm_pps
    # warmup wall time stays out of the measured throughput denominator
    assert eng.stats["measured"]["wall_time_s"] < \
        eng.stats["warmup"]["wall_time_s"]
    assert [r["warmup"] for r in eng.runs] == [True, False]
    # reset zeroes totals but remembers compiled shapes
    eng.reset_stats()
    assert eng.runs == [] and eng.problems_per_s() == 0.0
    eng.run(_oracle_requests(cfg, 2, seed=9))
    assert eng.last_run["warmup"] is False


def test_stage_times_do_not_collide_across_variants():
    """Both nvsa variants end in a stage named `symbolic`; per-variant
    nesting keeps oracle and cnn timings separate."""
    cfg = cbase.REASON_WORKLOADS["nvsa"].make_config(d=64)
    consts = cbase.REASON_WORKLOADS["nvsa"].make_consts(
        cfg, jax.random.PRNGKey(0))
    eng = cbase.reason_engine("nvsa", cfg, ReasonConfig(batch_size=2),
                              consts=consts, trace_graph=False)
    reqs = _oracle_requests(cfg, 2)
    eng.run(reqs, schedule="sequential", variant="cnn")
    eng.run(_oracle_requests(cfg, 2, seed=9),
            schedule="sequential", variant="oracle")
    st = eng.stats["stage_time_s"]
    assert set(st["cnn"]) == {"frontend", "symbolic"}
    assert set(st["oracle"]) == {"oracle", "symbolic"}
    assert st["cnn"]["symbolic"] != st["oracle"]["symbolic"]
    assert eng.last_run["stage_time_s"].keys() == st["oracle"].keys()
