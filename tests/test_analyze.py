"""Preflight static-analysis suite.

Three layers, mirroring the analyzer's contract:

* **golden known-bad fixtures** — one seeded offender per rule family
  (NSF001–NSF007 artifact/registry rules, NSF101–NSF104 lint rules),
  each asserting *exactly* its rule fires, so a rule that silently stops
  matching shows up as a failed golden rather than a quiet pass;
* **clean passes** — the real serving sources lint clean (the raw
  ``time.perf_counter()`` regression), the real registry is consistent,
  and every NSAI workload's compiled schedule clears the full artifact +
  retrace pass across its buckets;
* **integration** — the CLI entry point, ``deploy()``'s preflight gate
  (error raises, warn records), and the injectable ``wall`` clock the
  lint forced into the engines.
"""

import dataclasses
import json
import textwrap
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analyze import (AnalysisReport, PreflightError, RULES, finding,
                           lint_file, lint_tree, preflight)
from repro.analyze import artifacts, registry_check, retrace
from repro.backend import registry
from repro.configs import base as cbase

# -- fixture scaffolding ------------------------------------------------------

_SPECS = {"x": jax.ShapeDtypeStruct((4, 8), jnp.float32)}


def _cpu_plan():
    return registry.negotiate(platform="cpu", override="")


@dataclasses.dataclass
class _Stage:
    name: str
    stream: str
    fn: object


class _FakeSched:
    """Just enough StagedSchedule surface for the artifact/retrace checks."""

    def __init__(self, stages, input_specs=None, plan=None, buckets=(),
                 jit_fused=None):
        self.stages = list(stages)
        self.input_specs = _SPECS if input_specs is None else input_specs
        self.consts_spec = {}
        self.plan = plan or _cpu_plan()
        self.batch_buckets = tuple(buckets)
        self.jit_fused = jit_fused
        self.workload = "fixture"
        self.variant = "bad"

    def covering_bucket(self, n):
        for b in self.batch_buckets:
            if b >= n:
                return b
        raise ValueError(f"no bucket covers {n}")


def _rules_of(report):
    return sorted({f.rule for f in report.findings})


# -- golden fixtures: artifact rules (NSF001-NSF004) --------------------------


def test_nsf001_downcast_below_declared_int_precision():
    """f32 -> bf16 inside a vsa stage declared int8 is a precision error."""
    def fn(consts, bufs):
        return {"x": bufs["x"].astype(jnp.bfloat16).astype(jnp.float32)}

    cfg = types.SimpleNamespace(nn_precision="fp32", symb_precision="int8")
    sched = _FakeSched([_Stage("symbolic", "vsa", fn)])
    rep = artifacts.check_schedule(sched, cfg=cfg)
    assert _rules_of(rep) == ["NSF001"]
    assert not rep.ok


def test_nsf001_ignores_downcast_under_float_precision():
    """The same cast under declared fp32 symbolic precision is legal."""
    def fn(consts, bufs):
        return {"x": bufs["x"].astype(jnp.bfloat16).astype(jnp.float32)}

    cfg = types.SimpleNamespace(nn_precision="fp32", symb_precision="fp32")
    sched = _FakeSched([_Stage("symbolic", "vsa", fn)])
    assert artifacts.check_schedule(sched, cfg=cfg).ok


def test_nsf001_f64_upcast():
    from jax.experimental import enable_x64

    def fn(consts, bufs):
        wide = jax.lax.convert_element_type(bufs["x"], jnp.float64)
        return {"x": wide.astype(jnp.float32)}

    sched = _FakeSched([_Stage("drift", "nn", fn)])
    with enable_x64():
        rep = artifacts.check_schedule(sched)
    assert "NSF001" in _rules_of(rep)
    assert any("float64" in f.message for f in rep.findings)


def test_nsf002_mixed_amax_axes():
    """Global + per-problem amax scales in one stage = admission-group
    dependent numerics (warning, not error)."""
    def fn(consts, bufs):
        x = bufs["x"]
        global_scale = jnp.max(jnp.abs(x))
        per_problem = jnp.max(jnp.abs(x), axis=1, keepdims=True)
        return {"x": x / global_scale + x / per_problem}

    rep = artifacts.check_schedule(_FakeSched([_Stage("quant", "vsa", fn)]))
    assert _rules_of(rep) == ["NSF002"]
    assert rep.ok  # warning severity: reported, never fails preflight


def test_nsf003_host_callback_in_stage():
    def fn(consts, bufs):
        x = bufs["x"]
        y = jax.pure_callback(lambda a: a,
                              jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return {"x": y}

    rep = artifacts.check_schedule(_FakeSched([_Stage("leak", "nn", fn)]))
    assert _rules_of(rep) == ["NSF003"]
    assert not rep.ok


def test_nsf004_off_cpu_fused_without_donation():
    def fused(consts, bufs):
        return {"x": bufs["x"] * 2.0}

    sched = _FakeSched([], plan=registry.negotiate(platform="tpu",
                                                   override=""),
                       jit_fused=jax.jit(fused))
    rep = artifacts.check_schedule(sched)
    assert _rules_of(rep) == ["NSF004"]
    assert not rep.ok


def test_nsf004_cpu_fused_with_donation_warns():
    def fused(consts, bufs):
        return {"x": bufs["x"] * 2.0}

    sched = _FakeSched([], jit_fused=jax.jit(fused, donate_argnums=(1,)))
    rep = artifacts.check_schedule(sched)
    assert _rules_of(rep) == ["NSF004"]
    assert rep.ok  # CPU-side donation is a warning (XLA:CPU just ignores it)


def test_nsf004_clean_cpu_fused():
    def fused(consts, bufs):
        return {"x": bufs["x"] * 2.0}

    rep = artifacts.check_schedule(_FakeSched([], jit_fused=jax.jit(fused)))
    assert rep.findings == []
    assert rep.coverage["fused_donation"] == 1


# -- golden fixtures: retrace hazards (NSF005) --------------------------------


def test_nsf005_bucket_closure_hole():
    class _Leaky(_FakeSched):
        def covering_bucket(self, n):
            return n  # 1 and 3 are not declared buckets

    rep = retrace.check_retrace(_Leaky([], buckets=(2, 4)))
    assert _rules_of(rep) == ["NSF005"]
    assert len(rep.findings) == 2  # n=1 and n=3 both escape the bucket set


def test_nsf005_group_size_leaks_into_nonbatch_axis():
    entry = types.SimpleNamespace(input_specs=lambda cfg, b, v: {
        "x": jax.ShapeDtypeStruct((b, b + 7), jnp.float32)})
    out = retrace.check_bucket_specs(entry, None, None, (2, 4), "fixture")
    assert sorted({f.rule for f in out}) == ["NSF005"]
    assert any("non-batch" in f.message for f in out)


def test_nsf005_nondeterministic_stage_trace():
    counter = iter(range(100))

    def fn(consts, bufs):
        return {"x": bufs["x"] + float(next(counter))}

    sched = _FakeSched([_Stage("drift", "nn", fn)], buckets=(4,))
    rep = retrace.check_retrace(sched, double_trace=True)
    assert "NSF005" in _rules_of(rep)
    assert any("traces differently" in f.message for f in rep.findings)


def test_nsf005_clean_on_deterministic_stage():
    def fn(consts, bufs):
        return {"x": bufs["x"] * 2.0}

    sched = _FakeSched([_Stage("ok", "nn", fn)], buckets=(2, 4))
    rep = retrace.check_retrace(sched, double_trace=True)
    assert rep.findings == []
    assert rep.coverage == {"bucket_closure": 1, "double_trace": 1}


# -- golden fixtures: registry rules (NSF006/NSF007) --------------------------


def test_nsf006_registry_entry_without_kernel_package(monkeypatch):
    monkeypatch.setitem(registry.KERNELS, "ghost_kernel",
                        registry.KERNELS["qmatmul"])
    rep = registry_check.check_static()
    assert [f.rule for f in rep.findings] == ["NSF006"]
    assert "ghost_kernel" in rep.findings[0].where


def test_nsf006_twin_predicate_drift(monkeypatch):
    """A shape-predicate fix applied to circ_conv but not its circulant
    twin unbind_classify must fire the twin check."""
    spec = registry.KERNELS["unbind_classify"]
    pallas = spec.by_name("pallas")
    lows = tuple(dataclasses.replace(low, min_size=16)
                 if low is pallas else low for low in spec.lowerings)
    monkeypatch.setitem(registry.KERNELS, "unbind_classify",
                        dataclasses.replace(spec, lowerings=lows))
    rep = registry_check.check_static()
    assert [f.rule for f in rep.findings] == ["NSF006"]
    assert "circ_conv+unbind_classify" in rep.findings[0].where


def test_nsf007_floor_without_dispatch_site(monkeypatch):
    spec = registry.KERNELS["qmatmul"]
    assert spec.dispatch_min_size == 0  # precondition: floorless today
    monkeypatch.setitem(registry.KERNELS, "qmatmul",
                        dataclasses.replace(spec, dispatch_min_size=64))
    rep = registry_check.check_dispatch_floors()
    assert [f.rule for f in rep.findings] == ["NSF007"]
    assert "dead policy" in rep.findings[0].message


def test_nsf007_dispatch_site_without_floor(monkeypatch):
    spec = registry.KERNELS["circ_conv"]
    assert spec.dispatch_min_size > 0  # precondition: floored today
    monkeypatch.setitem(registry.KERNELS, "circ_conv",
                        dataclasses.replace(spec, dispatch_min_size=0))
    rep = registry_check.check_dispatch_floors()
    assert [f.rule for f in rep.findings] == ["NSF007"]
    assert "no-op" in rep.findings[0].message


# -- golden fixtures: serving lint (NSF101-NSF104) ----------------------------


def _lint(tmp_path, src, name="fixture.py"):
    """Write a fixture under a serve/ dir so path routing applies.

    ``name`` matters to NSF105's clock half, which keys on control-plane
    basenames (control.py / slo.py / sim.py).
    """
    p = tmp_path / "serve" / name
    p.parent.mkdir(exist_ok=True)
    p.write_text(textwrap.dedent(src))
    return AnalysisReport(list(lint_file(str(p))))


def test_nsf101_raw_clock_call(tmp_path):
    rep = _lint(tmp_path, """
        import time

        def measure():
            t0 = time.perf_counter()
            return time.perf_counter() - t0
        """)
    assert _rules_of(rep) == ["NSF101"]
    assert len(rep.findings) == 2


def test_nsf101_injectable_clock_default_is_clean(tmp_path):
    rep = _lint(tmp_path, """
        import time

        def measure(clock=time.perf_counter, wall=time.perf_counter):
            return wall() - clock()
        """)
    assert rep.findings == []


def test_nsf102_host_materialization_in_jit(tmp_path):
    rep = _lint(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            return np.asarray(x) + 1
        """)
    assert _rules_of(rep) == ["NSF102"]


def test_nsf102_host_materialization_outside_jit_is_clean(tmp_path):
    rep = _lint(tmp_path, """
        import jax
        import numpy as np

        def collect(x):
            return np.asarray(x) + 1
        """)
    assert rep.findings == []


def test_nsf103_prngkey_without_fold_in(tmp_path):
    rep = _lint(tmp_path, """
        import jax

        def make_stream(seed):
            return jax.random.PRNGKey(seed)
        """)
    assert _rules_of(rep) == ["NSF103"]


def test_nsf103_fold_in_derivation_is_clean(tmp_path):
    rep = _lint(tmp_path, """
        import jax

        def make_stream(seed, i):
            root = jax.random.PRNGKey(seed)
            return jax.random.fold_in(root, i)
        """)
    assert rep.findings == []


def test_nsf104_blocks_before_stamping(tmp_path):
    rep = _lint(tmp_path, """
        import jax

        class BadEngine:
            def submit(self, group):
                out = jax.block_until_ready(self.fn(group))
                rec = self.record(group)
                rec.dispatch_t = self.clock()
                return rec
        """)
    assert _rules_of(rep) == ["NSF104"]


def test_nsf104_never_stamps(tmp_path):
    rep = _lint(tmp_path, """
        class WorseEngine:
            def submit(self, group):
                return list(group)
        """)
    assert _rules_of(rep) == ["NSF104"]


def test_nsf104_stamp_then_block_is_clean(tmp_path):
    rep = _lint(tmp_path, """
        import jax

        class GoodEngine:
            def submit(self, group):
                rec = self.record(group)
                rec.dispatch_t = self.clock()
                jax.block_until_ready(self.fn(group))
                return rec
        """)
    assert rep.findings == []


def test_nsf105_unbounded_queue_append(tmp_path):
    # method named enqueue (not submit) so NSF104 doesn't co-fire
    rep = _lint(tmp_path, """
        class Router:
            def __init__(self):
                self.pending = []

            def enqueue(self, item):
                self.pending.append(item)
        """)
    assert _rules_of(rep) == ["NSF105"]
    assert "bound check" in rep.findings[0].message


def test_nsf105_bounded_queue_append_is_clean(tmp_path):
    rep = _lint(tmp_path, """
        class Router:
            def __init__(self, depth):
                self.pending = []
                self.depth = depth

            def enqueue(self, item):
                if len(self.pending) >= self.depth:
                    return False
                self.pending.append(item)
                return True
        """)
    assert rep.findings == []


def test_nsf105_closure_bound_check_does_not_dominate(tmp_path):
    # the check lives in a nested function — the outer append is still
    # unbounded, so the closure must not satisfy the rule
    rep = _lint(tmp_path, """
        class Router:
            def enqueue(self, item):
                def bounded():
                    return len(self.pending) < self.depth
                self.pending.append(item)
                return bounded
        """)
    assert _rules_of(rep) == ["NSF105"]


def test_nsf105_non_queue_append_is_clean(tmp_path):
    rep = _lint(tmp_path, """
        def collect(rows):
            out = []
            for r in rows:
                out.append(r)
            return out
        """)
    assert rep.findings == []


def test_nsf105_time_reference_in_control_plane_module(tmp_path):
    # attribute *reference* (no call) — NSF101 only flags calls, so this
    # would slip through without the control-plane clause
    rep = _lint(tmp_path, """
        import dataclasses
        import time


        @dataclasses.dataclass
        class ControlConfig:
            clock: object = time.monotonic
        """, name="control.py")
    assert _rules_of(rep) == ["NSF105"]
    assert len(rep.findings) == 2  # the import and the reference
    assert "control-plane" in rep.findings[0].message


def test_nsf105_time_reference_outside_control_plane_is_clean(tmp_path):
    rep = _lint(tmp_path, """
        import dataclasses
        import time


        @dataclasses.dataclass
        class Cfg:
            clock: object = time.monotonic
        """, name="helpers.py")
    assert rep.findings == []


# -- clean passes over the real stack -----------------------------------------


def test_serving_sources_lint_clean():
    """Regression for the raw time.perf_counter() offenders the lint
    originally flagged in serve/ — the tree must stay clean."""
    import repro.serve as serve_pkg

    rep = lint_tree(serve_pkg.__path__[0])
    assert rep.findings == [], rep.render()
    assert rep.coverage["lint_files"] >= 8


def test_whole_package_lint_clean():
    import repro

    rep = lint_tree(repro.__path__[0])
    assert rep.findings == [], rep.render()


def test_registry_static_consistency_clean():
    rep = registry_check.check_registry(probe=False)
    assert rep.findings == [], rep.render()
    assert rep.coverage["registry_static"] == len(registry.KERNELS)
    assert rep.coverage["dispatch_floors"] == len(registry.KERNELS)


@pytest.mark.slow
def test_registry_probes_clean():
    """Empirical interpret-vs-reference probes (the check that demoted the
    registry's over-strict non-pow2 claim) find nothing today."""
    rep = registry_check.check_probes()
    assert rep.findings == [], rep.render()
    assert rep.coverage["kernel_probes"] >= 10


@pytest.mark.parametrize("model", sorted(cbase.REASON_WORKLOADS))
def test_clean_pass_real_workload(model):
    """Every NSAI workload's compiled schedule clears the full artifact +
    retrace pass across its buckets (abstract consts — no params)."""
    entry = cbase.REASON_WORKLOADS[model]
    cfg = entry.make_config(d=32)
    variant = entry.variants[0]
    sched = cbase.compile_reason_schedule(model, cfg, variant,
                                          batch_size=(1, 2, 4),
                                          trace_graph=False,
                                          plan=_cpu_plan())
    rep = preflight([(sched, cfg, entry, variant)], double_trace=True)
    assert rep.ok, rep.render()
    assert rep.coverage["schedules"] == 1
    assert rep.coverage["stage_jaxprs"] >= 1
    assert rep.coverage["bucket_specs"] == 1
    assert rep.coverage["double_trace"] == 1


# -- findings / report datatypes ----------------------------------------------


def test_finding_validates_rule_and_severity():
    with pytest.raises(ValueError):
        finding("NSF999", "x", "no such rule")
    with pytest.raises(ValueError):
        finding("NSF001", "x", "bad severity", severity="fatal")
    f = finding("NSF002", "here", "msg")
    assert f.severity == RULES["NSF002"][0] == "warning"


def test_report_merge_and_verdict():
    a = AnalysisReport([finding("NSF003", "a", "err")], {"c": 1})
    b = AnalysisReport([finding("NSF002", "b", "warn")], {"c": 2, "d": 1})
    a.merge(b)
    assert not a.ok and len(a.errors) == 1 and len(a.warnings) == 1
    assert a.coverage == {"c": 3, "d": 1}
    assert set(a.by_rule()) == {"NSF002", "NSF003"}
    assert "preflight FAIL: 1 error(s), 1 warning(s)" in a.render()
    round_trip = json.loads(a.to_json())
    assert round_trip["ok"] is False and len(round_trip["findings"]) == 2


# -- CLI ----------------------------------------------------------------------


def test_cli_lint_and_registry_only(tmp_path, capsys):
    from repro.analyze.__main__ import main

    out = tmp_path / "results" / "ANALYZE.json"
    rc = main(["--workload", "none", "--format", "json", "--out", str(out),
               "--no-probe", "--no-double-trace"])
    assert rc == 0
    data = json.loads(out.read_text())
    assert data["ok"] is True
    assert data["coverage"]["lint_files"] >= 1
    assert data["coverage"]["registry_static"] == len(registry.KERNELS)
    assert json.loads(capsys.readouterr().out) == data


def test_cli_rejects_unknown_workload():
    from repro.analyze.__main__ import main

    with pytest.raises(SystemExit):
        main(["--workload", "not_a_workload"])


# -- deploy() preflight gate --------------------------------------------------


def _seeded_failure(subjects, **kw):
    rep = AnalysisReport()
    rep.findings.append(finding("NSF003", "fixture/stage", "seeded error"))
    return rep


def test_deploy_preflight_gate(monkeypatch):
    import importlib

    # the package re-exports the preflight *function*, which shadows the
    # submodule on attribute access — resolve the module explicitly
    pf = importlib.import_module("repro.analyze.preflight")
    from repro.serve.deploy import Budget, deploy

    opts = {"nvsa": {"d": 32}}
    monkeypatch.setattr(pf, "preflight", _seeded_failure)
    # warn: the failing report is recorded, deploy still succeeds
    dep = deploy(["nvsa"], options=opts, budget=Budget(max_batch=2),
                 preflight="warn")
    rec = dep.report()["analysis"]
    assert rec["ok"] is False and rec["errors"] == 1
    assert "preflight FAIL: 1 error(s)" in dep.summary()
    # error (the default): same findings abort the deploy
    with pytest.raises(PreflightError) as ei:
        deploy(["nvsa"], options=opts, budget=Budget(max_batch=2))
    assert [f.rule for f in ei.value.report.findings] == ["NSF003"]
    # off: nothing runs, nothing recorded
    monkeypatch.setattr(pf, "preflight", _boom)
    dep = deploy(["nvsa"], options=opts, budget=Budget(max_batch=2),
                 preflight="off")
    assert dep.report()["analysis"] is None
    with pytest.raises(ValueError, match="preflight"):
        deploy(["nvsa"], options=opts, preflight="bogus")


def _boom(*a, **kw):  # preflight="off" must never reach the analyzer
    raise AssertionError("preflight ran despite preflight='off'")


# -- injectable wall clock (the NSF101 fix) -----------------------------------


class _Ticker:
    """Deterministic fake wall: each read advances a huge step, so any
    accounting it feeds is unmistakably not real time."""

    def __init__(self, step=1000.0):
        self.t, self.step = 0.0, step

    def __call__(self):
        self.t += self.step
        return self.t


def test_reason_engine_wall_is_injectable():
    from repro.models import nvsa
    from repro.serve.reason import ReasonConfig, requests_from_batch

    cfg = cbase.REASON_WORKLOADS["nvsa"].make_config(d=32)
    consts = {"params": None,
              "books": nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))}
    eng = cbase.reason_engine(
        "nvsa", cfg, ReasonConfig(batch_size=2, schedule="sequential"),
        consts=consts, variants=("oracle",), trace_graph=False)
    eng.wall = _Ticker()

    from repro.data import raven

    def reqs(seed):
        return requests_from_batch(raven.generate_batch(cfg.raven,
                                                        seed=seed, n=2))

    eng.run(reqs(3), variant="oracle")          # cold run -> warmup bucket
    assert eng.stats["warmup"]["wall_time_s"] >= 1000.0
    eng.run(reqs(4), variant="oracle")          # steady state -> measured
    assert eng.stats["measured"]["wall_time_s"] >= 1000.0
    # the measured rate reads the fake wall, not the real clock
    assert 0 < eng.problems_per_s() < 1.0


def test_lm_engine_wall_is_injectable():
    from repro.configs import ARCHS
    from repro.serve.engine import Engine, ServeConfig

    arch = ARCHS["llama3.2-3b"]
    mcfg = arch.make_smoke()
    from repro.nn import init as nninit

    params = nninit.materialize(cbase.model_spec(arch, mcfg),
                                jax.random.PRNGKey(0))
    step, init_caches = cbase.serve_fns(arch, mcfg, max_len=32)
    eng = Engine(step, init_caches,
                 ServeConfig(max_new_tokens=4, max_slots=2, max_len=32,
                             decode_block=2),
                 params=params, wall=_Ticker())
    prompts = np.random.default_rng(0).integers(
        0, mcfg.vocab, (2, 6)).astype(np.int32)
    eng.generate(prompts)
    assert eng.stats["decode_time_s"] >= 1000.0


def test_replica_pool_wall_delegates_and_falls_back():
    import time

    from repro.serve.replica import ReplicaPool

    ticker = _Ticker()
    with_wall = types.SimpleNamespace(admission_cap=4, wall=ticker)
    pool = ReplicaPool([with_wall])
    assert pool.wall is ticker
    legacy = types.SimpleNamespace(admission_cap=4)  # pre-`wall` engine
    assert ReplicaPool([legacy]).wall is time.perf_counter
