"""Per-kernel allclose vs the pure-jnp oracles, with shape/dtype sweeps and
hypothesis property tests on the VSA algebra invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.circ_conv import kernel as ck, ops as cops, ref as cref
from repro.kernels.qmatmul import ops as qops, ref as qref
from repro.kernels.simd_fused import kernel as sk, ref as sref
from repro.vsa import fpe, ops as vsa


# -- circ_conv ----------------------------------------------------------------


@pytest.mark.parametrize("d", [8, 16, 64, 128,
                               pytest.param(256, marks=pytest.mark.slow)])
@pytest.mark.parametrize("mode", ["conv", "corr"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_circ_elem_matches_ref(d, mode, dtype):
    key = jax.random.PRNGKey(d)
    x = jax.random.normal(key, (5, 3, d)).astype(dtype)
    y = jax.random.normal(jax.random.fold_in(key, 1), (5, 3, d)).astype(dtype)
    out = ck.circ_elem(x, y, mode=mode, interpret=True)
    ref = cref.circ_elem_ref(x, y, mode)
    tol = 1e-4 if dtype == jnp.float32 else 0.25
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n,m,d", [(4, 3, 32), (9, 7, 64),
                                   pytest.param(130, 2, 128, marks=pytest.mark.slow)])
def test_circ_dict_matches_ref(n, m, d):
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (n, 2, d))
    dic = jax.random.normal(jax.random.fold_in(key, 1), (m, 2, d))
    out = ck.circ_dict(x, dic, mode="conv", interpret=True)
    ref = cref.circ_dict_ref(x, dic, "conv")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


def test_circ_conv_matches_fft():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (4, 2, 128))
    b = jax.random.normal(jax.random.fold_in(key, 1), (4, 2, 128))
    np.testing.assert_allclose(np.asarray(vsa.bind(a, b)),
                               np.asarray(vsa.circ_conv_fft(a, b)),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       d=st.sampled_from([16, 32, 64]),
       blocks=st.integers(1, 4))
def test_vsa_commutativity(seed, d, blocks):
    """bind(a, b) == bind(b, a) — circular convolution commutes."""
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (2, blocks, d))
    b = jax.random.normal(jax.random.fold_in(key, 1), (2, blocks, d))
    np.testing.assert_allclose(np.asarray(vsa.bind(a, b)),
                               np.asarray(vsa.bind(b, a)), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.sampled_from([16, 64]))
def test_vsa_associativity(seed, d):
    key = jax.random.PRNGKey(seed)
    a, b, c = (jax.random.normal(jax.random.fold_in(key, i), (1, 2, d))
               for i in range(3))
    left = vsa.bind(vsa.bind(a, b), c)
    right = vsa.bind(a, vsa.bind(b, c))
    np.testing.assert_allclose(np.asarray(left), np.asarray(right),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_unitary_unbind_inverts_bind(seed):
    key = jax.random.PRNGKey(seed)
    a = vsa.random_codebook(key, 3, 2, 64)
    u = vsa.unitary_codebook(jax.random.fold_in(key, 1), 3, 2, 64)
    rec = vsa.unbind(u, vsa.bind(a, u))
    np.testing.assert_allclose(np.asarray(rec), np.asarray(a), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       va=st.integers(0, 4), vb=st.integers(0, 4))
def test_fpe_binding_adds_values(seed, va, vb):
    """bind(u^a, u^b) == u^(a+b) — FPE phase arithmetic."""
    phase = fpe.fpe_base_phase(jax.random.PRNGKey(seed), 2, 32)
    book = fpe.fpe_codebook(phase, 10, 32)
    out = vsa.bind(book[va][None], book[vb][None])[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(book[va + vb]),
                               atol=1e-4)


def test_bind_distributes_over_bundle():
    key = jax.random.PRNGKey(0)
    a, b, c = (jax.random.normal(jax.random.fold_in(key, i), (1, 2, 64))
               for i in range(3))
    left = vsa.bind(a, b + c)
    right = vsa.bind(a, b) + vsa.bind(a, c)
    np.testing.assert_allclose(np.asarray(left), np.asarray(right), atol=1e-4)


def test_circulant_precompute_equals_bind():
    """codebook_circulant (the TPU static-dictionary trick) == bind."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (5, 2, 64))
    dic = jax.random.normal(jax.random.fold_in(key, 1), (3, 2, 64))
    cmat = vsa.codebook_circulant(dic, "conv")  # (3, 2, 64, 64)
    via_mat = jnp.einsum("xbk,mbnk->xmbn", x, cmat)
    via_kernel = cops.circ_bind_dict(x, dic, "conv")
    np.testing.assert_allclose(np.asarray(via_mat), np.asarray(via_kernel),
                               atol=1e-3, rtol=1e-3)


# -- qmatmul ------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(7, 33, 11), (64, 128, 64),
                                   pytest.param(130, 100, 53, marks=pytest.mark.slow)])
@pytest.mark.parametrize("int4", [False, True])
def test_qmatmul_matches_ref(m, k, n, int4):
    key = jax.random.PRNGKey(m * n)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    xq, xs = qops.quantize_rows(x)
    bits = 4 if int4 else 8
    wq, ws = qops.quantize_cols(w, bits)
    if int4:
        wq = qops.pack_int4(wq)
        if n % 2:
            ws = jnp.pad(ws, (0, 1))
    out_k = qops.qmatmul(xq, wq, xs, ws, int4=int4, bm=32, bn=32, bk=32)
    out_r = qref.qmatmul_ref(xq, wq, xs, ws, int4=int4)
    np.testing.assert_allclose(np.asarray(out_k)[:, :n],
                               np.asarray(out_r)[:, :n], atol=1e-3, rtol=1e-3)


def test_qdense_quantization_error_scales_with_bits():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
    exact = np.asarray(x @ w)
    err8 = np.abs(np.asarray(qops.qdense(x, w, bits_w=8), np.float32) - exact).mean()
    err4 = np.abs(np.asarray(qops.qdense(x, w, bits_w=4), np.float32) - exact).mean()
    assert err8 < err4 < 16 * err8 + 1e-3


def test_pack_unpack_roundtrip_exhaustive():
    vals = jnp.arange(-8, 8, dtype=jnp.int8)
    q = jnp.tile(vals, (4, 2))  # (4, 32)
    packed = qops.pack_int4(q)
    unpacked = qref.unpack_int4_ref(packed)
    np.testing.assert_array_equal(np.asarray(unpacked), np.asarray(q))


# -- simd_fused ---------------------------------------------------------------


@pytest.mark.parametrize("n,m,d,temp", [
    (5, 3, 32, 1.0), (40, 7, 128, 0.1),
    pytest.param(128, 16, 64, 0.5, marks=pytest.mark.slow)])
def test_fused_match_prob_matches_ref(n, m, d, temp):
    key = jax.random.PRNGKey(n)
    q = vsa.random_codebook(key, n, 4, d)
    dic = vsa.random_codebook(jax.random.fold_in(key, 1), m, 4, d)
    out = sk.fused_match_prob(q, dic, temp, interpret=True, tile_n=16)
    ref = sref.fused_match_prob_ref(q, dic, temp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_fused_match_prob_rows_sum_to_one():
    key = jax.random.PRNGKey(2)
    q = vsa.random_codebook(key, 17, 2, 64)
    dic = vsa.random_codebook(jax.random.fold_in(key, 1), 5, 2, 64)
    out = np.asarray(sk.fused_match_prob(q, dic, 0.3, interpret=True, tile_n=8))
    np.testing.assert_allclose(out.sum(-1), np.ones(17), atol=1e-5)


def test_kernel_vjps_match_ref_autodiff():
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (3, 2, 32))
    b = jax.random.normal(jax.random.fold_in(key, 1), (3, 2, 32))
    for f_k, f_r in [
        (lambda a, b: vsa.bind(a, b, use_kernel=True), vsa.circ_conv_ref),
        (lambda a, b: vsa.unbind(a, b, use_kernel=True), vsa.circ_corr_ref),
    ]:
        gk = jax.grad(lambda a, b: jnp.sum(jnp.cos(f_k(a, b))), (0, 1))(a, b)
        gr = jax.grad(lambda a, b: jnp.sum(jnp.cos(f_r(a, b))), (0, 1))(a, b)
        for x, y in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-4, rtol=1e-4)


# -- flash attention ----------------------------------------------------------


@pytest.mark.parametrize("sq,skv,hd,bq,bk,causal",
                         [(64, 64, 32, 16, 16, True),
                          (40, 40, 16, 16, 16, True),
                          (32, 40, 32, 16, 16, False),
                          pytest.param(128, 128, 64, 64, 32, True,
                                       marks=pytest.mark.slow)])
def test_flash_attention_matches_ref(sq, skv, hd, bq, bk, causal):
    from repro.kernels.flash_attn import kernel as fk, ref as fr
    key = jax.random.PRNGKey(sq)
    q = jax.random.normal(key, (2, sq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, skv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, skv, hd))
    o_k = fk.flash_attention(q, k, v, scale=0.2, causal=causal, bq=bq, bk=bk,
                             interpret=True)
    o_r = fr.flash_attention_ref(q, k, v, scale=0.2, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4)


def test_flash_mha_wrapper_matches_full_attention():
    from repro.kernels.flash_attn import ops as fo
    from repro.nn import attention as att
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (2, 48, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 48, 4, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 48, 4, 16))
    flash = fo.flash_mha(q, k, v, 0.25)
    full = att.attend_full(q, k, v, att.causal_mask(48, 48), 0.25)
    np.testing.assert_allclose(np.asarray(flash, np.float32),
                               np.asarray(full, np.float32), atol=1e-3)
