"""Serve-path tensor parallelism: TP decode must be token-for-token
identical to single-device decode.

Runs in a subprocess so we can request 4 host devices without polluting
the main test session's device count.  Covers the preferred-axis TP rules
(stablelm smoke: heads/kv/mlp all divide 2- and 4-way meshes) and the
FALLBACK_TP_AXES path (llama smoke: n_kv_heads=2 does not divide the
4-way model axis, so the kv projection re-shards its embed dim), plus the
tp-exceeds-devices error naming the XLA_FLAGS escape hatch.
"""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro.configs import base as cbase
from repro.serve.engine import Request, ServeConfig

assert jax.device_count() == 4


def toks(arch, tp):
    scfg = ServeConfig(max_new_tokens=8, max_slots=2, max_len=64,
                       decode_block=4)
    eng, cfg = cbase.lm_engine(arch, scfg, tp=tp)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, (12,))
                    .astype(np.int32)) for i in range(4)]
    res = eng.run(reqs)
    return {u: res[u].tokens.tolist() for u in res}


# preferred-axis TP: every sharded dim divides the 2- and 4-way meshes
ref = toks("stablelm-3b", 1)
assert any(len(t) for t in ref.values())
for tp in (2, 4):
    assert toks("stablelm-3b", tp) == ref, f"stablelm-3b tp={tp} diverged"
    print(f"stablelm-3b tp{tp}: token stream identical")

# FALLBACK_TP_AXES: llama smoke's kv axis (2 heads) does not divide the
# 4-way model axis -> spec_to_pspec re-shards the embed dim instead
from repro.distributed import sharding_rules as sr
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(1, 4)
ps = sr.spec_to_pspec(("embed", "kv", "hd"), (64, 2, 16), mesh,
                      sr.TP_RULES, min_shard_elems=0)
assert tuple(ps) == ("model",), f"fallback did not engage: {tuple(ps)}"
ref = toks("llama3.2-3b", 1)
assert toks("llama3.2-3b", 4) == ref, "llama3.2-3b tp=4 (fallback) diverged"
print("llama3.2-3b tp4: fallback-sharded token stream identical")

# tp beyond the device pool fails with the escape hatch in the message
try:
    cbase.lm_engine("stablelm-3b", tp=8)
except ValueError as e:
    assert "xla_force_host_platform_device_count" in str(e), e
else:
    raise AssertionError("tp=8 on 4 devices should have raised")
print("SERVE_TP_OK")
"""


def test_serve_tp_token_identity_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "SERVE_TP_OK" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
