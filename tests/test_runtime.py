"""Unified serving-runtime tests: the traffic-class registry, the
request/result work-unit envelope, DSE-driven ``deploy()`` (serving knobs
selected from ``core.dse.explore`` output, not hand-set fields), and the
acceptance regression: one FrontDoor serving interleaved LM + NSAI
arrivals with answers bit-identical to the per-stack offline paths."""

import numpy as np
import pytest

from repro.serve import runtime as rt


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, dt):
        assert dt >= 0
        self.t += dt


# -- registry + envelope -----------------------------------------------------


def test_traffic_class_registry_and_resolve():
    assert set(rt.TRAFFIC_CLASSES) == {"lm", "reason", "frontdoor"}
    lm = rt.TRAFFIC_CLASSES["lm"].models()
    reason = rt.TRAFFIC_CLASSES["reason"].models()
    assert "llama3.2-3b" in lm and "stablelm-3b" in lm
    assert "internvl2-26b" not in lm          # vlm kinds are not servable
    assert set(reason) == {"nvsa", "prae", "mimonet", "lvrf"}
    # the mixed class serves the union
    both = rt.TRAFFIC_CLASSES["frontdoor"].models()
    assert set(both) == set(lm) | set(reason)
    assert rt.resolve_models("frontdoor", ["stablelm-3b", "nvsa"]) == \
        ("stablelm-3b", "nvsa")
    with pytest.raises(KeyError, match="unknown workload"):
        rt.resolve_models("warp", ["nvsa"])
    with pytest.raises(ValueError, match="unknown models"):
        rt.resolve_models("reason", ["stablelm-3b"])   # LM id, NSAI class
    with pytest.raises(ValueError, match="unknown models"):
        rt.resolve_models("frontdoor", ["mystery"])


def test_work_units_envelope():
    from repro.serve.engine import Result
    from repro.serve.reason import ReasonResult

    lm = Result(uid=0, tokens=np.arange(5, dtype=np.int32), prompt_len=3,
                finished_by_eos=False, slot=0)
    ns = ReasonResult(uid=1, answer=2, answer_logprobs=np.zeros(8), batch=0)
    assert rt.work_units(lm) == 5          # generated tokens
    assert rt.work_units(ns) == 1          # one problem
    assert rt.work_unit_name([lm]) == "tok"
    assert rt.work_unit_name([ns]) == "prob"
    assert rt.work_unit_name([]) == "prob"


def test_measured_rate_fallback():
    stats = rt.fresh_split_stats()
    assert rt.measured_rate(stats) == 0.0
    stats["warmup"].update(work=10, wall_time_s=2.0)
    assert rt.measured_rate(stats) == 5.0      # warmup-only fallback
    stats["measured"].update(work=30, wall_time_s=2.0)
    assert rt.measured_rate(stats) == 15.0     # measured wins when present


# -- serving_plan: DSE point -> runtime knobs --------------------------------


def test_serving_plan_maps_design_to_knobs():
    from repro.core.dse import DesignConfig, serving_plan

    para = DesignConfig(H=8, W=8, N=16, mode="parallel", n_l=[8], n_v=[8],
                        nl_bar=8, nv_bar=8, t_para=100, t_seq=250,
                        t_phase1=100)
    plan = serving_plan(para, max_batch=8, inflight_cap=4)
    assert plan.schedule == "overlap"
    assert plan.batch_size == 8            # pow2 floor of N=16, capped at 8
    assert plan.buckets == (2, 4, 8)
    assert plan.max_inflight == 2          # round(250/100), capped
    assert plan.design is para
    seq = DesignConfig(H=8, W=8, N=3, mode="sequential", n_l=[3], n_v=[3],
                       nl_bar=3, nv_bar=3, t_para=100, t_seq=90,
                       t_phase1=90)
    plan = serving_plan(seq, max_batch=8)
    assert plan.schedule == "sequential" and plan.max_inflight == 1
    assert plan.batch_size == 2 and plan.buckets == (2,)  # pow2 floor of 3
    # the inflight cap binds
    deep = serving_plan(para, max_batch=4, inflight_cap=1)
    assert deep.max_inflight == 1 and deep.batch_size == 4


def test_deploy_selects_serving_config_from_dse(monkeypatch):
    """deploy() must configure the NSAI engine from core.dse.explore
    output — not hand-set ReasonConfig fields.  Asserted two ways: the
    engine's compiled knobs equal serving_plan(explored design), and a
    monkeypatched explore() visibly steers the engine's buckets."""
    from repro.core import dse
    from repro.serve import Budget, deploy

    d = deploy(["nvsa"], budget=Budget(max_pes=1024, max_batch=4),
               options={"nvsa": {"variant": "oracle", "d": 64}})
    design, plan = d.designs["nvsa"], d.plans["nvsa"]
    assert design.searched_points > 0          # explore actually ran
    expect = dse.serving_plan(design, max_batch=4, inflight_cap=4)
    assert (plan.batch_size, plan.buckets, plan.max_inflight,
            plan.schedule) == (expect.batch_size, expect.buckets,
                               expect.max_inflight, expect.schedule)
    eng = d.engines["nvsa"]
    assert eng.cfg.batch_size == plan.batch_size
    assert eng.cfg.buckets == plan.buckets
    assert eng.cfg.max_inflight == plan.max_inflight
    # deploy() upgrades a DSE "overlap" choice to the one-dispatch fused
    # schedule when the fused negotiation came out exact; every other
    # DSE choice stands as-is
    upgraded = plan.schedule == "overlap" and eng.schedules["oracle"].fused_ok
    assert eng.cfg.schedule == ("fused" if upgraded else plan.schedule)
    assert eng.schedules["oracle"].batch_buckets == plan.buckets
    # the report records which DSE point serves (bench provenance)
    rec = d.report()["nvsa"]
    assert rec["design"] == design.summary()
    assert rec["serving"]["buckets"] == plan.buckets

    forced = dse.DesignConfig(H=4, W=4, N=2, mode="parallel", n_l=[1],
                              n_v=[1], nl_bar=1, nv_bar=1, t_para=50,
                              t_seq=100, t_phase1=50, searched_points=7)
    monkeypatch.setattr(dse, "explore", lambda *a, **k: forced)
    d2 = deploy(["nvsa"], budget=Budget(max_pes=1024, max_batch=4),
                options={"nvsa": {"variant": "oracle", "d": 64}})
    assert d2.engines["nvsa"].cfg.buckets == (2,)      # pow2 floor of N=2
    # the DSE chose "overlap"; nvsa's fused trace negotiates exact, so
    # the deployment serves the one-dispatch fused schedule in its place
    # (the recorded DSE plan keeps the original choice)
    assert d2.plans["nvsa"].schedule == "overlap"
    assert d2.engines["nvsa"].cfg.schedule == "fused"
    assert d2.engines["nvsa"].cfg.max_inflight == 2    # t_seq/t_para


# -- the acceptance regression: mixed LM + NSAI through one front-door -------


def test_mixed_lm_nsai_frontdoor_bit_identical():
    """One FrontDoor instance serves interleaved LM + NSAI arrivals in a
    single run; the served LM tokens and NSAI answers are bit-identical
    to the respective pre-redesign single-stack offline paths."""
    from repro.serve import Budget, Traffic, deploy
    from repro.serve import frontdoor as fd

    clock = VirtualClock()
    d = deploy(["stablelm-3b", "nvsa"],
               traffic=Traffic(rate_rps=50.0, deadline_s=0.01),
               budget=Budget(max_pes=1024, max_batch=4, max_slots=2,
                             max_len=64, max_new_tokens=6),
               options={"nvsa": {"variant": "oracle", "d": 64}},
               clock=clock, sleep=clock.sleep)
    n = 5
    streams, truths = d._streams(n, seed=42)
    lm_reqs = list(streams["stablelm-3b"])
    ns_reqs = list(streams["nvsa"])
    arrivals = fd.merge_arrivals(
        fd.poisson_arrivals("stablelm-3b", lm_reqs, 50.0, seed=1),
        fd.poisson_arrivals("nvsa", ns_reqs, 50.0, seed=2))
    rep = d.serve(arrivals)
    # interleaved service through ONE front-door, both classes in ONE report
    assert sorted(rep.results) == ["nvsa", "stablelm-3b"]
    assert len(rep.results["stablelm-3b"]) == n
    assert len(rep.results["nvsa"]) == n
    assert {g.model for g in rep.groups} == {"nvsa", "stablelm-3b"}
    assert rep.work_unit("stablelm-3b") == "tok"
    assert rep.work_unit("nvsa") == "prob"
    for field in ("queue_s", "service_s"):
        for m in ("stablelm-3b", "nvsa"):
            p = rep.percentiles(field, m)
            assert np.isfinite(p["p50"]) and np.isfinite(p["p95"])
    # single-stack offline regressions (sampling is (seed, uid, token)-
    # keyed and NSAI answers admission-group independent, so the same
    # engines replay the same uids bit-identically)
    lm_offline = d.engines["stablelm-3b"].run(lm_reqs)
    for uid, res in rep.results["stablelm-3b"].items():
        np.testing.assert_array_equal(res.tokens, lm_offline[uid].tokens)
    ns_offline = d.engines["nvsa"].run(ns_reqs)
    for uid, res in rep.results["nvsa"].items():
        np.testing.assert_array_equal(res.answer_logprobs,
                                      ns_offline[uid].answer_logprobs)
        assert res.answer == ns_offline[uid].answer
    # NSAI accuracy is intact through the mixed path
    from repro.configs import base as cbase

    acc = cbase.REASON_WORKLOADS["nvsa"].score(rep.results["nvsa"],
                                               truths["nvsa"]())
    assert acc == 1.0              # oracle variant is exact


def test_deployment_warmup_and_synthetic_traffic():
    from repro.serve import Budget, deploy

    d = deploy(["nvsa"], budget=Budget(max_pes=256, max_batch=2),
               options={"nvsa": {"variant": "oracle", "d": 64}})
    d.warmup()
    # warmup compiled every bucket: serving now is measured, not warmup
    eng = d.engines["nvsa"]
    assert eng.stats["warmup"]["requests"] > 0
    arrivals, truths = d.synthetic_traffic(4)
    rep = d.serve(arrivals)
    assert len(rep.results["nvsa"]) == 4
    assert set(truths) == {"nvsa"}
