"""ReplicaPool tests: protocol mechanics (least-inflight routing, merged
stats and drains, admission-cap validation), replica-count invariance of
answers — 4 replicas behind the front-door must serve bit-identical
results to 1 — and work conservation of the merged accounting."""

import jax
import numpy as np
import pytest

from repro.configs import base as cbase
from repro.models import nvsa
from repro.serve import frontdoor as fd
from repro.serve import work_units
from repro.serve.reason import ReasonConfig
from repro.serve.replica import ReplicaPool, _merge_stats
from tests.test_frontdoor import (VirtualClock, _oracle_engine,
                                  _oracle_requests)


def _oracle_pool(replicas, batch_size=4, buckets=(2, 4), max_inflight=2,
                 d=64):
    """An oracle-variant nvsa pool (always a ReplicaPool, even at 1)."""
    cfg = cbase.REASON_WORKLOADS["nvsa"].make_config(d=d)
    consts = {"params": None,
              "books": nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))}
    eng = cbase.reason_engine_pool(
        "nvsa", cfg,
        ReasonConfig(batch_size=batch_size, buckets=buckets,
                     max_inflight=max_inflight, schedule="overlap"),
        consts=consts, variants=("oracle",), replicas=replicas,
        trace_graph=False)
    if not isinstance(eng, ReplicaPool):
        eng = ReplicaPool([eng])
    return cfg, eng


# -- construction + validation ----------------------------------------------


def test_pool_rejects_empty_and_mismatched_caps():
    with pytest.raises(ValueError, match="at least one"):
        ReplicaPool([])
    _, _, e2 = _oracle_engine(batch_size=2, buckets=(2,))
    _, _, e4 = _oracle_engine(batch_size=4, buckets=(2, 4))
    with pytest.raises(ValueError, match="admission_cap"):
        ReplicaPool([e2, e4])


def test_reason_engine_pool_unwraps_single_replica():
    cfg = cbase.REASON_WORKLOADS["nvsa"].make_config(d=64)
    consts = {"params": None,
              "books": nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))}
    rcfg = ReasonConfig(batch_size=4, schedule="overlap")
    one = cbase.reason_engine_pool("nvsa", cfg, rcfg, consts=consts,
                                   variants=("oracle",), replicas=1,
                                   trace_graph=False)
    assert not isinstance(one, ReplicaPool)
    three = cbase.reason_engine_pool("nvsa", cfg, rcfg, consts=consts,
                                     variants=("oracle",), replicas=3,
                                     trace_graph=False)
    assert isinstance(three, ReplicaPool) and len(three) == 3
    # replicas share the compiled StagedSchedules (jit caches are shared)
    assert all(r.schedules["oracle"] is three.replicas[0].schedules["oracle"]
               for r in three.replicas)
    with pytest.raises(ValueError, match="replicas"):
        cbase.reason_engine_pool("nvsa", cfg, rcfg, consts=consts,
                                 replicas=0)


def test_merge_stats_sums_trees():
    a = {"n": 1, "nested": {"x": 2.0}, "lst": [1, 2], "flag": True,
         "name": "a"}
    b = {"n": 3, "nested": {"x": 0.5, "y": 7}, "lst": [10, 20],
         "flag": True, "name": "b"}
    m = _merge_stats([a, b])
    assert m["n"] == 4 and m["nested"]["x"] == 2.5 and m["nested"]["y"] == 7
    assert m["lst"] == [11, 22]
    assert m["flag"] is True and m["name"] == "a"


# -- routing + protocol surface ---------------------------------------------


def test_least_inflight_routing_spreads_groups():
    cfg, pool = _oracle_pool(replicas=3, max_inflight=2)
    reqs = _oracle_requests(cfg, 12)
    recs = [pool.submit(reqs[i:i + 4]) for i in (0, 4, 8)]
    # back-to-back submits with nothing drained round-robin across idle
    # replicas (ties break to the lowest index)
    assert [r.replica for r in recs] == [0, 1, 2]
    assert pool.inflight == 3
    results = pool.drain_all()
    assert pool.inflight == 0 and len(results) == 12
    assert pool.dispatched_groups == [1, 1, 1]
    assert pool.dispatched_requests == [4, 4, 4]
    split = pool.per_replica()
    assert [r["groups"] for r in split] == [1, 1, 1]
    assert sum(r["work"] for r in split) == 12


def test_pool_run_merges_results_and_conserves_work():
    cfg, p1 = _oracle_pool(replicas=1)
    cfg4, p4 = _oracle_pool(replicas=4)
    reqs = _oracle_requests(cfg, 12)
    r1 = p1.run(list(reqs))
    r4 = p4.run(list(reqs))
    assert set(r1) == set(r4) == {r.uid for r in reqs}
    # answers are bit-identical whichever replica served them
    for u in r1:
        assert np.array_equal(np.asarray(r1[u].answer),
                              np.asarray(r4[u].answer))
    # merged accounting conserves work: same totals whatever the count
    for p in (p1, p4):
        s = p.stats
        assert s["measured"]["work"] + s["warmup"]["work"] == 12
    assert sum(work_units(r) for r in r4.values()) == \
        sum(work_units(r) for r in r1.values()) == 12
    # and the routing counters account for every dispatched request
    assert sum(p4.dispatched_requests) == 12
    p4.reset_stats()
    assert p4.stats["measured"]["work"] == 0
    assert p4.dispatched_groups == [0] * 4


# -- front-door: replica-count determinism ----------------------------------


def _serve(pool, cfg, n=12, deadline_s=0.05):
    clock = VirtualClock()
    door = fd.FrontDoor({"nvsa": pool},
                        fd.FrontDoorConfig(deadline_s=deadline_s),
                        clock=clock, sleep=clock.sleep)
    reqs = _oracle_requests(cfg, n)
    arrivals = fd.poisson_arrivals("nvsa", reqs, rate_rps=200.0, seed=11)
    return door.serve(arrivals)


def test_frontdoor_answers_invariant_under_replica_count():
    cfg, p1 = _oracle_pool(replicas=1)
    _, p4 = _oracle_pool(replicas=4)
    rep1 = _serve(p1, cfg)
    rep4 = _serve(p4, cfg)
    assert set(rep1.results["nvsa"]) == set(rep4.results["nvsa"])
    for u, res in rep1.results["nvsa"].items():
        assert np.array_equal(np.asarray(res.answer),
                              np.asarray(rep4.results["nvsa"][u].answer))
    # same merged arrival trace => same admission groups, so total
    # dispatched work matches too (conservation across the pool boundary)
    w1 = sum(work_units(r) for r in rep1.results["nvsa"].values())
    w4 = sum(work_units(r) for r in rep4.results["nvsa"].values())
    assert w1 == w4 == 12


def test_frontdoor_report_carries_replica_breakdown():
    cfg, p4 = _oracle_pool(replicas=4)
    rep = _serve(p4, cfg)
    bd = rep.replica_breakdown("nvsa")
    assert bd is not None and set(bd) <= {0, 1, 2, 3}
    assert sum(r["requests"] for r in bd.values()) == 12
    assert abs(sum(r["share"] for r in bd.values()) - 1.0) < 1e-9
    assert all(r["busy_s"] >= 0 for r in bd.values())
    assert "replicas r" in rep.summary()
    # a bare (unpooled) engine reports no breakdown
    cfg1, _, bare = _oracle_engine(max_inflight=2)
    clock = VirtualClock()
    door = fd.FrontDoor({"nvsa": bare}, fd.FrontDoorConfig(deadline_s=0.05),
                        clock=clock, sleep=clock.sleep)
    rep1 = door.serve(fd.poisson_arrivals(
        "nvsa", _oracle_requests(cfg1, 4), rate_rps=200.0, seed=11))
    assert rep1.replica_breakdown("nvsa") is None


def test_pool_clock_fans_out_to_replicas():
    cfg, pool = _oracle_pool(replicas=2)
    clock = VirtualClock()
    pool.clock = clock
    assert all(r.clock is clock for r in pool.replicas)
    assert pool.clock is clock


# -- launcher validation -----------------------------------------------------


def test_launcher_mesh_flags_name_the_escape_hatch():
    from repro.launch.serve import _require_devices

    _require_devices(jax.device_count(), "--replicas")  # fits: no raise
    n = jax.device_count() + 1
    with pytest.raises(SystemExit,
                       match="xla_force_host_platform_device_count"):
        _require_devices(n, "--replicas")
    with pytest.raises(SystemExit, match="--tp"):
        _require_devices(n, "--tp")
