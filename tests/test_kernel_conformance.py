"""Kernel/oracle conformance suite: every Pallas kernel against its pure-jnp
ref in interpret mode, swept over dtypes, degenerate shapes, dispatch
boundaries (non-power-of-two d -> gather fallback), and int4 edge nibbles.

``test_kernels.py`` covers the happy-path sizes; this suite is the
adversarial sweep the serving pipeline relies on — the ReasonEngine routes
symbolic traffic through whichever path ``vsa.ops`` dispatches to, so the
kernel and the fallback must agree everywhere the dispatcher can land.
Property tests run through ``_hypothesis_compat`` (real hypothesis when
installed, fixed deterministic samples otherwise).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.backend import registry
from repro.kernels.circ_conv import kernel as ck, ops as cops, ref as cref
from repro.kernels.qmatmul import kernel as qk, ops as qops, ref as qref
from repro.kernels.simd_fused import kernel as sk, ref as sref
from repro.vsa import ops as vsa


# -- circ_conv: kernel == gather ref == FFT oracle ---------------------------


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.sampled_from([8, 16, 32, 64]),
       blocks=st.integers(1, 3), conv=st.booleans(), bf16=st.booleans())
def test_circ_elem_conformance(seed, d, blocks, conv, bf16):
    mode = "conv" if conv else "corr"
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (3, blocks, d)).astype(dtype)
    y = jax.random.normal(jax.random.fold_in(key, 1), (3, blocks, d)).astype(dtype)
    out = ck.circ_elem(x, y, mode=mode, interpret=True)
    ref = cref.circ_elem_ref(x, y, mode)
    tol = 0.25 if bf16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)
    if not bf16:  # cross-validate the gather ref itself against the FFT oracle
        fft = vsa.circ_conv_fft(x, y) if conv else vsa.circ_corr_fft(x, y)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(fft),
                                   atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("d", [12, 20, 33])
def test_nonpow2_d_routes_to_gather_fallback(d):
    """Below the dispatch floor vsa.bind prefers the exact gather ref
    under any plan (the kernel wins nothing at small d); the FFT oracle
    cross-checks the fallback numerics here."""
    assert vsa.dispatch_path(d) == "gather"
    key = jax.random.PRNGKey(d)
    a = jax.random.normal(key, (2, 2, d))
    b = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, d))
    np.testing.assert_allclose(np.asarray(vsa.bind(a, b)),
                               np.asarray(vsa.circ_conv_fft(a, b)),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(vsa.unbind(a, b)),
                               np.asarray(vsa.circ_corr_fft(a, b)),
                               atol=1e-4, rtol=1e-4)
    # the kernel-ops layer falls back too (circ_bind forced on)
    np.testing.assert_allclose(np.asarray(cops.circ_bind(a, b, "conv")),
                               np.asarray(cref.circ_elem_ref(a, b, "conv")),
                               atol=1e-5, rtol=1e-5)


def test_pow2_d_above_threshold_routes_to_kernel():
    # pin the negotiated plan: routing assertions must hold regardless of
    # any REPRO_BACKEND override in the environment (the forced-fallback
    # CI leg runs this suite under REPRO_BACKEND=xla)
    with registry.use_plan(registry.negotiate(override="")):
        assert vsa.dispatch_path(128) == "kernel"
        assert vsa.dispatch_path(256) == "kernel"
        assert vsa.dispatch_path(64) == "gather"   # below size threshold


def test_nonpow2_d_at_dispatch_floor_routes_to_interpret():
    """Pinned by the registry-vs-kernel consistency check (NSF006): the
    interpreter lowering carries no pow2/min-size predicate, so on CPU a
    non-pow2 d at the dispatch floor serves the kernel path — and its
    output matches the FFT oracle.  Only the compiled Pallas lowering
    (TPU/GPU) keeps the conservative pow2 constraint."""
    with registry.use_plan(registry.negotiate(platform="cpu", override="")):
        assert vsa.dispatch_path(130) == "kernel"
        assert vsa.dispatch_path(192) == "kernel"
        d = 130
        key = jax.random.PRNGKey(d)
        a = jax.random.normal(key, (2, 2, d))
        b = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, d))
        np.testing.assert_allclose(np.asarray(vsa.bind(a, b)),
                                   np.asarray(vsa.circ_conv_fft(a, b)),
                                   atol=1e-4, rtol=1e-4)
    with registry.use_plan(registry.negotiate(platform="tpu", override="")):
        assert vsa.dispatch_path(130) == "gather"  # compiled path: pow2 only
        assert vsa.dispatch_path(128) == "kernel"


# -- registry sweep: every registered lowering of every kernel ---------------
#
# The cases parametrize straight from the lowering registry, so a kernel or
# lowering added there is conformance-tested here automatically.  Each case
# drives the *public ops wrapper* under a plan forcing one lowering and
# compares against the same wrapper under the kernel's exact ``xla``
# reference lowering, with the tolerance the registry declares for its
# equivalence class (0.0 = bit-exact).

_LOWERING_CASES = [(name, low.name)
                   for name, spec in registry.KERNELS.items()
                   for low in spec.lowerings]


def _run_kernel_under(kernel, plan):
    key = jax.random.PRNGKey(42)
    if kernel == "circ_conv":
        a = jax.random.normal(key, (3, 2, 32))
        b = jax.random.normal(jax.random.fold_in(key, 1), (3, 2, 32))
        with registry.use_plan(plan):
            return np.asarray(cops.circ_bind(a, b, "conv"))
    if kernel == "qmatmul":
        x = jax.random.normal(key, (5, 24))
        w = jax.random.normal(jax.random.fold_in(key, 1), (24, 9))
        with registry.use_plan(plan):
            return np.asarray(qops.qdense(x, w, out_dtype=jnp.float32))
    if kernel == "simd_fused":
        from repro.kernels.simd_fused import ops as sops
        q = vsa.random_codebook(key, 6, 2, 32)
        dic = vsa.random_codebook(jax.random.fold_in(key, 1), 4, 2, 32)
        with registry.use_plan(plan):
            return np.asarray(sops.fused_match_prob(q, dic, 0.7))
    if kernel == "unbind_classify":
        from repro.kernels.unbind_classify import ops as uops
        keys = vsa.random_codebook(key, 5, 2, 32)
        x = vsa.random_codebook(jax.random.fold_in(key, 1), 3, 2, 32)
        head = {"w": jax.random.normal(jax.random.fold_in(key, 2), (64, 7)),
                "b": jax.random.normal(jax.random.fold_in(key, 3), (7,))}
        with registry.use_plan(plan):
            return np.asarray(uops.unbind_classify(head, keys, x))
    assert kernel == "flash_attn"
    from repro.kernels.flash_attn import ops as fops
    q = jax.random.normal(key, (2, 12, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 12, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 12, 2, 16))
    with registry.use_plan(plan):
        return np.asarray(fops.flash_mha(q, k, v, scale=0.25))


@pytest.mark.parametrize("kernel,lowering", _LOWERING_CASES)
def test_registry_lowering_conformance(kernel, lowering):
    low = registry.KERNELS[kernel].by_name(lowering)
    out = _run_kernel_under(
        kernel, registry.negotiate(override=f"{kernel}={lowering}"))
    ref = _run_kernel_under(
        kernel, registry.negotiate(override=f"{kernel}=xla"))
    if low.equivalence == "epsilon":
        np.testing.assert_allclose(out, ref, atol=low.epsilon,
                                   rtol=low.epsilon)
    else:
        np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("mode", ["conv", "corr"])
def test_circ_elem_degenerate_single_row_block(mode):
    """1 pair, 1 block — the tile is all padding beyond row 0."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 1, 16))
    y = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 16))
    out = ck.circ_elem(x, y, mode=mode, interpret=True, tile_n=8)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(cref.circ_elem_ref(x, y, mode)),
                               atol=1e-5, rtol=1e-5)


def test_circ_dict_degenerate_single_entry():
    """1 query x 1 dictionary entry (grid collapses to one program)."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1, 1, 16))
    dic = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 16))
    out = ck.circ_dict(x, dic, mode="corr", interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(cref.circ_dict_ref(x, dic, "corr")),
                               atol=1e-5, rtol=1e-5)


# -- qmatmul: int8 / packed-int4 against the integer-exact ref ---------------


def test_qmatmul_int4_edge_nibbles_exact():
    """Every nibble value incl. the extremes (-8, +7) packed/unpacked and
    accumulated exactly: with unit scales the kernel must equal pure int32
    math (the sign bit of the low nibble is where packing goes wrong)."""
    vals = np.arange(-8, 8, dtype=np.int8)          # all 16 nibbles
    w = np.tile(vals, (8, 1))                       # (8, 16)
    x = np.array([[-128, 127, -8, 7, 1, -1, 0, 64]], dtype=np.int8)  # (1, 8)
    exact = x.astype(np.int32) @ w.astype(np.int32)
    packed = qops.pack_int4(jnp.asarray(w))
    ones_m, ones_n = jnp.ones((1,), jnp.float32), jnp.ones((16,), jnp.float32)
    out_k = qk.qmatmul(jnp.asarray(x), packed, ones_m, ones_n, int4=True,
                       interpret=True, bm=8, bn=8, bk=8)
    out_r = qref.qmatmul_ref(jnp.asarray(x), packed, ones_m, ones_n, int4=True)
    np.testing.assert_array_equal(np.asarray(out_k), exact.astype(np.float32))
    np.testing.assert_array_equal(np.asarray(out_r), exact.astype(np.float32))


def test_qmatmul_int8_full_range_exact():
    """int8 extremes (incl. -128) accumulate exactly in int32."""
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, (5, 9)).astype(np.int8)
    w = rng.integers(-128, 128, (9, 7)).astype(np.int8)
    x[0, 0], w[0, 0] = -128, -128  # force the extreme product
    exact = x.astype(np.int32) @ w.astype(np.int32)
    sm, sn = jnp.ones((5,), jnp.float32), jnp.ones((7,), jnp.float32)
    out = qk.qmatmul(jnp.asarray(x), jnp.asarray(w), sm, sn, int4=False,
                     interpret=True, bm=4, bn=4, bk=4)
    np.testing.assert_array_equal(np.asarray(out), exact.astype(np.float32))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 9),
       k=st.integers(1, 17), n=st.integers(1, 9), int4=st.booleans())
def test_qmatmul_property_matches_ref(seed, m, k, n, int4):
    """Random small shapes (incl. 1-row/1-col/1-k degenerates) through the
    quantize helpers: kernel == ref within fp tolerance."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    xq, xs = qops.quantize_rows(x)
    wq, ws = qops.quantize_cols(w, 4 if int4 else 8)
    if int4:
        wq = qops.pack_int4(wq)
        if n % 2:
            ws = jnp.pad(ws, (0, 1))
    out_k = qops.qmatmul(xq, wq, xs, ws, int4=int4, bm=8, bn=8, bk=8)
    out_r = qref.qmatmul_ref(xq, wq, xs, ws, int4=int4)
    np.testing.assert_allclose(np.asarray(out_k)[:, :n],
                               np.asarray(out_r)[:, :n], atol=1e-4, rtol=1e-4)


def test_pack_int4_odd_n_pads_with_zero():
    q = jnp.asarray(np.array([[7, -8, 3]], np.int8).repeat(4, 0))  # n=3 odd
    packed = qops.pack_int4(q)
    unpacked = qref.unpack_int4_ref(packed)
    np.testing.assert_array_equal(np.asarray(unpacked[:, :3]), np.asarray(q))
    assert (np.asarray(unpacked[:, 3]) == 0).all()


# -- simd_fused: fused normalize/dot/softmax chain ---------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 20),
       m=st.integers(1, 6), bf16=st.booleans(),
       temp=st.sampled_from([0.1, 1.0]))
def test_fused_match_prob_conformance(seed, n, m, bf16, temp):
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    key = jax.random.PRNGKey(seed)
    q = vsa.random_codebook(key, n, 2, 32, dtype=dtype)
    dic = vsa.random_codebook(jax.random.fold_in(key, 1), m, 2, 32,
                              dtype=dtype)
    out = sk.fused_match_prob(q, dic, temp, interpret=True, tile_n=8)
    ref = sref.fused_match_prob_ref(q, dic, temp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-2 if bf16 else 1e-5)
    np.testing.assert_allclose(np.asarray(out).sum(-1), np.ones(n), atol=1e-4)


def test_fused_match_prob_single_query_single_entry():
    """n=1, m=1: softmax over one entry must be exactly 1, padded rows cut."""
    q = vsa.random_codebook(jax.random.PRNGKey(0), 1, 1, 16)
    dic = vsa.random_codebook(jax.random.PRNGKey(1), 1, 1, 16)
    out = np.asarray(sk.fused_match_prob(q, dic, 0.5, interpret=True,
                                         tile_n=8))
    assert out.shape == (1, 1)
    np.testing.assert_allclose(out, np.ones((1, 1)), atol=1e-6)


# -- flash attention: degenerate tiles, padding, bf16 ------------------------


@pytest.mark.parametrize("sq,skv,bq,bk,causal", [
    (1, 1, 16, 16, True),      # single position, blocks clamp to 1
    (10, 6, 4, 4, True),       # non-multiple of block in both axes
    (5, 12, 8, 8, False),      # kv longer than q, non-causal
])
def test_flash_attention_degenerate_shapes(sq, skv, bq, bk, causal):
    from repro.kernels.flash_attn import kernel as fk, ref as fr
    key = jax.random.PRNGKey(sq * 31 + skv)
    q = jax.random.normal(key, (2, sq, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, skv, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, skv, 16))
    o_k = fk.flash_attention(q, k, v, scale=0.3, causal=causal, bq=bq, bk=bk,
                             interpret=True)
    o_r = fr.flash_attention_ref(q, k, v, scale=0.3, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4)


# -- unbind_classify: fused symbolic-tail kernel -----------------------------


@pytest.mark.parametrize("n,tile_n", [(1, 8), (5, 8), (13, 8)])
def test_unbind_classify_padded_tiles(n, tile_n):
    """Query counts that leave the last tile mostly padding must still match
    the gather ref exactly after the pad rows are cut."""
    from repro.kernels.unbind_classify import kernel as uk, ref as uref
    key = jax.random.PRNGKey(n)
    keys = vsa.random_codebook(key, 3, 2, 16)
    x = vsa.random_codebook(jax.random.fold_in(key, 1), n, 2, 16)
    w = jax.random.normal(jax.random.fold_in(key, 2), (2, 16, 5))
    b = jax.random.normal(jax.random.fold_in(key, 3), (1, 5))
    out = uk.fused_unbind_classify(keys, x, w, b, interpret=True,
                                   tile_n=tile_n)
    head = {"w": w.reshape(32, 5), "b": b.reshape(5)}
    ref = uref.unbind_classify_ref(head, keys, x)
    assert out.shape == (n, 3, 5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_unbind_classify_custom_vjp_matches_ref_grad():
    """Fused forward, reference backward: head gradients must agree with
    differentiating the pure ref chain."""
    from repro.kernels.unbind_classify import ops as uops, ref as uref
    key = jax.random.PRNGKey(7)
    keys = vsa.random_codebook(key, 2, 2, 16)
    x = vsa.random_codebook(jax.random.fold_in(key, 1), 3, 2, 16)
    head = {"w": jax.random.normal(jax.random.fold_in(key, 2), (32, 4)),
            "b": jax.random.normal(jax.random.fold_in(key, 3), (4,))}
    g_k = jax.grad(
        lambda h: uops.unbind_classify(h, keys, x, use_kernel=True).sum()
    )(head)
    g_r = jax.grad(
        lambda h: uref.unbind_classify_ref(h, keys, x).sum())(head)
    for name in g_r:
        np.testing.assert_allclose(np.asarray(g_k[name]),
                                   np.asarray(g_r[name]),
                                   atol=1e-4, rtol=1e-4)


def test_flash_attention_bf16_io():
    from repro.kernels.flash_attn import kernel as fk, ref as fr
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 24, 16)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 24, 16)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 24, 16)).astype(jnp.bfloat16)
    o_k = fk.flash_attention(q, k, v, scale=0.25, causal=True, bq=8, bk=8,
                             interpret=True)
    o_r = fr.flash_attention_ref(q, k, v, scale=0.25, causal=True)
    assert o_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=3e-2)
