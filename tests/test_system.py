"""End-to-end system behaviour tests for the NSFlow reproduction:
trace -> dataflow -> DSE -> simulate, on the *executable* JAX models
(not just the paper-scale graph builders), plus launch-layer wiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dataflow, dse, simulator, trace, workloads
from repro.data import raven
from repro.models import nvsa


def test_end_to_end_pipeline_on_traced_model():
    """The full frontend pipeline runs on a trace of the real JAX NVSA
    reasoner: extract -> dataflow -> Algorithm 1 -> a valid design."""
    cfg = nvsa.NVSAConfig()
    codebooks = nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
    ctx = [jax.ShapeDtypeStruct((4, 8, n), jnp.float32)
           for n in cfg.raven.attr_sizes]
    cand = [jax.ShapeDtypeStruct((4, 8, n), jnp.float32)
            for n in cfg.raven.attr_sizes]
    # pin the negotiated plan: the vsa-node assertion needs the Pallas
    # circ_conv path, which a REPRO_BACKEND=xla override (the CI
    # forced-fallback leg) would route to gather+dot_general
    from repro.backend import registry
    with registry.use_plan(registry.negotiate(override="")):
        g = trace.extract(lambda c1, c2: nvsa.reason(cfg, codebooks, c1, c2),
                          ctx, cand)
    assert len(g.vsa_nodes()) > 0, "kernel ops must be classified as vsa"
    df = dataflow.build(g)
    design = dse.explore(df, max_pes=16384)
    assert design.H * design.W * design.N <= 16384
    assert design.t_best > 0
    assert design.mem is not None and design.mem.total > 0


@pytest.mark.slow
def test_end_to_end_reasoning_with_kernels():
    """Full NVSA solve on rendered images (untrained frontend -> just checks
    the system runs end-to-end and produces a calibrated distribution)."""
    # d=128 keeps the Pallas kernel path active (d >= 128) at 4x less
    # interpret-mode cost than the default 256
    cfg = nvsa.NVSAConfig(cnn_width=8, cnn_feat=32, d=128)
    batch = raven.generate_batch(cfg.raven, seed=2, n=2)
    from repro.nn import init as nninit
    params = nninit.materialize(nvsa.nvsa_spec(cfg), jax.random.PRNGKey(0))
    codebooks = nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
    logp, rules = nvsa.solve(params, codebooks, cfg,
                             jnp.asarray(batch["context"]),
                             jnp.asarray(batch["candidates"]))
    assert logp.shape == (2, 8)
    np.testing.assert_allclose(np.exp(np.asarray(logp)).sum(-1), 1.0,
                               atol=1e-4)
    assert rules.shape == (3, 2, raven.N_RULES)


def test_simulator_consistency_across_workloads():
    """NSFlow never loses to itself: folding+phase2 <= sequential mode."""
    for name, builder in workloads.WORKLOADS.items():
        g = builder()
        full = simulator.simulate_nsflow(g)
        seq = simulator.simulate_nsflow(g, force_mode="sequential")
        assert full.total <= seq.total * 1.001, name


def test_mesh_dse_analytic():
    from repro.core import meshdse
    # llama3.2-3b-ish train_4k on 256 chips
    pts = meshdse.search(n_params=3.2e9, n_active=3.2e9, d_model=3072,
                         n_layers=28, seq=4096, global_batch=256)
    assert pts, "search must return points"
    top = pts[0]
    assert top.feasible and top.data * top.model == 256
    # deepseek-scale must force model-parallel sharding for feasibility
    pts = meshdse.search(n_params=671e9, n_active=37e9, d_model=7168,
                         n_layers=61, seq=4096, global_batch=256,
                         moment_bytes=2.0)
    feas = [p for p in pts if p.feasible]
    assert feas and feas[0].model >= 8


DRYRUN_SCRIPT = r"""
from repro.launch import dryrun  # sets 512-device XLA flag before jax init
import jax
for arch_id, shape in [("llama3.2-3b", "train_4k"),
                       ("rwkv6-7b", "decode_32k"),
                       ("seamless-m4t-large-v2", "prefill_32k")]:
    fn, args, in_sh, out_sh, donate, meta, mesh, cfg, arch, sh = \
        dryrun.build_cell(arch_id, shape, multi_pod=False)
    assert meta["params"] > 0
    assert jax.tree.structure(args[0]) == jax.tree.structure(in_sh[0])
print("BUILD_CELL_OK")
"""


def test_dryrun_cell_builder_shapes():
    """build_cell wires shardings/specs for every kind (512-dev subprocess,
    no compile)."""
    import subprocess
    import sys
    r = subprocess.run([sys.executable, "-c", DRYRUN_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "BUILD_CELL_OK" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
