"""GPipe pipeline parallelism: equivalence vs sequential execution.

Runs in a subprocess so we can request 4 host devices without polluting the
main test session's device count.
"""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as PS
from repro.distributed import gpipe
from repro.common.util import mesh_context

mesh = jax.make_mesh((4,), ("pod",))
n_stages, n_micro, mb, d = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
# each stage: one dense layer (stacked over stages)
w = jax.random.normal(key, (n_stages, d, d)) / jnp.sqrt(d)
b = jax.random.normal(jax.random.fold_in(key, 1), (n_stages, d)) * 0.1
params = {"w": w, "b": b}
x = jax.random.normal(jax.random.fold_in(key, 2), (n_micro, mb, d))

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

# sequential reference
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ w[s] + b[s])

piped = gpipe.make_pipelined_fn(stage_fn, n_stages, mesh, "pod")
with mesh_context(mesh):
    out = jax.jit(piped)(params, x)
err = float(jnp.max(jnp.abs(out - ref)))
print("fwd err:", err)
assert err < 1e-5, err

# gradient flows through the schedule
def loss(params, x):
    return jnp.sum(piped(params, x) ** 2)

def loss_ref(params, x):
    h = x
    for s in range(n_stages):
        h = jnp.tanh(h @ params["w"][s] + params["b"][s])
    return jnp.sum(h ** 2)

with mesh_context(mesh):
    g1 = jax.jit(jax.grad(loss))(params, x)
g2 = jax.grad(loss_ref)(params, x)
gerr = max(float(jnp.max(jnp.abs(a - b))) for a, b in
           zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
print("grad err:", gerr)
assert gerr < 1e-4, gerr
print("bubble:", gpipe.bubble_fraction(n_stages, n_micro))
print("GPIPE_OK")
"""


def test_gpipe_equivalence_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "GPIPE_OK" in r.stdout, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
