"""Golden-trace record -> replay round trip (the serving-side analogue of
NSFlow's golden-vector RTL validation).

One nvsa deployment at d=128 — large enough that the default CPU plan
actually engages the Pallas interpret lowerings (d below the registry's
``dispatch_min_size`` would route everything to the gather reference and
the cross-plan leg would compare xla against itself).  The recorded trace
must replay bit-exact under the same plan and within the registry-declared
epsilon under the forced all-XLA fallback plan.
"""

import json

import numpy as np
import pytest

from repro.backend import registry
from repro.serve import Budget, GoldenTrace, Traffic, deploy, record
from repro.serve import trace as trace_mod

N_REQUESTS = 6


@pytest.fixture(scope="module")
def golden(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("trace") / "golden.jsonl")
    # pin the recorded plan to the pure platform negotiation: the
    # cross-plan leg below must stay meaningful even when the suite runs
    # under a REPRO_BACKEND override (the forced-fallback CI leg)
    dep = deploy(["nvsa"], Traffic(rate_rps=500.0, deadline_s=0.004),
                 Budget(max_batch=2, inflight_cap=2), seed=3,
                 options={"nvsa": {"d": 128}},
                 backend=registry.negotiate(override=""))
    arrivals, _ = dep.synthetic_traffic(N_REQUESTS, seed=11)
    report, trace = record(dep, arrivals, path)
    return dep, report, trace, path


def test_record_covers_everything_served(golden):
    dep, report, trace, path = golden
    served = {(m, uid) for m, res in report.results.items() for uid in res}
    assert len(served) == N_REQUESTS
    assert set(trace.requests) == served == set(trace.results)
    assert [tuple(g["uids"]) for g in trace.groups] == \
        [tuple(g.uids) for g in report.groups]
    # the default CPU plan must exercise a non-ref circ_conv path at d=128,
    # otherwise the cross-plan leg below is vacuous
    assert trace.recorded_tags == dep.backend.tags()
    assert not dep.backend.select("circ_conv", size=128,
                                  dispatch=True).is_ref


def test_trace_file_is_loadable_and_digests_hold(golden):
    _, _, trace, path = golden
    loaded = GoldenTrace.load(path)
    assert loaded.header["deploy"]["workloads"] == ["nvsa"]
    assert loaded.recorded_tags == trace.recorded_tags
    for key, line in loaded.requests.items():
        arrays = {k: trace_mod._dec_array(v)
                  for k, v in line["arrays"].items()}
        assert trace_mod._digest(arrays) == line["digest"], key


def test_replay_same_plan_is_bit_exact(golden):
    dep, _, trace, _ = golden
    # same engines, same jit caches — the strictest same-plan replay
    diff = trace.diff(trace.replay(deployment=dep))
    assert diff.tolerance == 0.0
    assert diff.n_compared == N_REQUESTS
    assert diff.ok, diff.describe()
    assert diff.max_abs_err == 0.0


def test_replay_fresh_deployment_same_plan_is_bit_exact(golden):
    _, _, trace, path = golden
    # re-deploy from the recorded spec: consts regenerate from the seed,
    # schedules recompile — answers must still be bit-identical
    diff = GoldenTrace.load(path).replay_and_diff(
        backend=registry.negotiate(override=""))
    assert diff.tolerance == 0.0
    assert diff.ok, diff.describe()


def test_replay_forced_xla_plan_within_registry_epsilon(golden):
    _, _, trace, path = golden
    diff = GoldenTrace.load(path).replay_and_diff(backend="xla")
    assert diff.replayed_tags == {k: "xla" for k in registry.KERNELS}
    # tolerance comes from the registry's equivalence classes, not a
    # hand-picked constant
    expected = registry.replay_tolerance(trace.recorded_tags,
                                         diff.replayed_tags)
    assert diff.tolerance == pytest.approx(expected) and expected > 0.0
    assert diff.n_compared == N_REQUESTS
    assert diff.ok, diff.describe()
    # integer answers survive the lowering change exactly
    assert not any(f.field == "answer" for f in diff.failures)


def test_diff_flags_corrupted_answer(golden):
    dep, _, trace, _ = golden
    rep = trace.replay(deployment=dep)
    key = next(iter(rep.results))
    rep.results[key].answer = int(rep.results[key].answer) + 1
    diff = trace.diff(rep)
    assert not diff.ok
    assert any(f.field == "answer" and f.exact_mismatch
               for f in diff.failures)


def test_header_records_deploy_spec(golden):
    _, _, _, path = golden
    with open(path) as f:
        header = json.loads(f.readline())
    assert header["kind"] == "header"
    assert header["backend"]["platform"]
    assert header["deploy"]["seed"] == 3
    assert header["deploy"]["options"] == {"nvsa": {"d": 128}}
    assert header["models"]["nvsa"]["class"] == "reason"
