"""Paper-core tests: trace extraction, dataflow graph, Algorithm 1 DSE,
analytical models, simulator, mesh folding."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import analytical as ana
from repro.core import dataflow as dfl
from repro.core import dse, simulator, trace, workloads
from repro.core.opgraph import OpGraph, OpNode, format_trace


# -- analytical models (Eq. 1-5) ----------------------------------------------


def test_eq1_literal():
    # t_l = (2H + W + d1 - 2) * ceil(ceil(d2/N)/H) * ceil(d3/W)
    assert ana.t_layer(32, 16, 14, 100, 64, 576) == \
        (64 + 16 + 100 - 2) * 1 * 36


def test_eq3_eq4_literal():
    H, W, n_v, nvec, d = 32, 16, 2, 384, 256
    T = 3 * H + d - 1
    assert ana.t_vsa_spatial(H, W, n_v, nvec, d) == nvec * 1 * T
    assert ana.t_vsa_temporal(H, W, n_v, nvec, d) == 24 * 4 * T


@settings(max_examples=30, deadline=None)
@given(h=st.sampled_from([4, 8, 16, 32]), w=st.sampled_from([4, 8, 16, 32]),
       n=st.integers(1, 16), m=st.integers(1, 4096), k=st.integers(1, 4096))
def test_more_subarrays_never_slower(h, w, n, m, k):
    """Monotonicity: adding sub-arrays to a layer can't increase Eq. 1."""
    t1 = ana.t_layer(h, w, n, m, 256, k)
    t2 = ana.t_layer(h, w, n + 1, m, 256, k)
    assert t2 <= t1


@settings(max_examples=30, deadline=None)
@given(nvec=st.integers(1, 2048), d=st.sampled_from([128, 256, 512]),
       n=st.integers(1, 8))
def test_vsa_runtime_positive_and_monotone(nvec, d, n):
    t_n = ana.t_vsa_temporal(32, 16, n, nvec, d)
    t_n1 = ana.t_vsa_temporal(32, 16, n + 1, nvec, d)
    assert 0 < t_n1 <= t_n


# -- trace extraction ---------------------------------------------------------


def test_trace_classifies_kernels():
    from repro.backend import registry
    from repro.vsa import ops as vsa

    def f(a, b, w):
        bound = vsa.bind(a, b)              # pallas circ_conv -> vsa
        y = jnp.einsum("nbd,de->nbe", bound, w)  # dot_general -> nn
        return jax.nn.softmax(jnp.sum(y, axis=-1))  # simd

    a = jax.ShapeDtypeStruct((4, 2, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 2, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    # pin the negotiated plan: the point of this test is classifying the
    # *Pallas* circ_conv path, which a REPRO_BACKEND=xla override (the
    # forced-fallback CI leg) would otherwise route to gather+dot_general
    with registry.use_plan(registry.negotiate(override="")):
        g = trace.extract(f, a, b, w)
    kinds = {n.kind for n in g}
    assert "vsa" in kinds and "nn" in kinds and "simd" in kinds
    vsa_nodes = g.vsa_nodes()
    assert vsa_nodes and vsa_nodes[0].dims["d"] == 128
    # Listing-1-style rendering works
    txt = format_trace(g, 5)
    assert "args" in txt


def test_trace_scan_records_repeat():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    g = trace.extract(f, jax.ShapeDtypeStruct((4, 16), jnp.float32),
                      jax.ShapeDtypeStruct((16, 16), jnp.float32))
    nn = g.nn_nodes()
    assert nn and nn[0].dims["repeat"] == 7
    assert nn[0].flops == 2 * 4 * 16 * 16 * 7


# -- dataflow graph -----------------------------------------------------------


def test_dataflow_critical_path_and_groups():
    g = workloads.nvsa_graph()
    df = dfl.build(g)
    # critical path is a real dependency chain
    for a, b in zip(df.critical_path, df.critical_path[1:]):
        assert a in g.nodes[b].deps or g.nodes[b].deps == []
    # every off-path node attached to some critical-path anchor
    for n in g:
        if not n.on_critical_path:
            assert n.attached_to in g.nodes


def test_interloop_overlap_pipeline_formula():
    df = dfl.build(workloads.nvsa_graph())
    r = dfl.interloop_overlap(df, t_nn_stream=100, t_vsa_stream=50, n_loops=4)
    assert r["pipelined"] == 100 + 3 * 100 + 50
    assert r["sequential"] == 4 * 150
    assert r["speedup"] > 1.3
    # unbalanced steady state idles the shorter stream 25% of 2*stage
    assert r["bubble"] == pytest.approx(0.25)


def test_interloop_overlap_bubble_degenerate_cases():
    """A single iteration has no pipeline slots, hence no bubble; balanced
    streams pipeline bubble-free; the bubble stays clamped to [0, 1]."""
    df = dfl.build(workloads.nvsa_graph())
    one = dfl.interloop_overlap(df, t_nn_stream=100, t_vsa_stream=50,
                                n_loops=1)
    assert one["bubble"] == 0.0
    assert one["pipelined"] == one["sequential"] == 150  # no overlap at n=1
    assert one["speedup"] == 1.0
    balanced = dfl.interloop_overlap(df, t_nn_stream=70, t_vsa_stream=70,
                                     n_loops=8)
    assert balanced["bubble"] == 0.0
    assert balanced["speedup"] == pytest.approx(2 * 8 / 9)
    for n in (2, 3, 16):
        r = dfl.interloop_overlap(df, t_nn_stream=1, t_vsa_stream=10 ** 6,
                                  n_loops=n)
        assert 0.0 <= r["bubble"] <= 1.0


# -- two-phase DSE (Algorithm 1) ----------------------------------------------


def test_phase1_respects_pe_budget_and_partition():
    df = dfl.build(workloads.nvsa_graph())
    cfg = dse.phase1(df, max_pes=16384)
    assert cfg.H * cfg.W * cfg.N <= 16384
    if cfg.mode == "parallel":
        assert cfg.nl_bar + cfg.nv_bar == cfg.N
        assert 1 <= cfg.nl_bar < cfg.N


def test_phase2_never_regresses():
    df = dfl.build(workloads.nvsa_graph())
    c1 = dse.phase1(df, max_pes=16384)
    c2 = dse.phase2(df, c1, iter_max=8)
    assert c2.t_para <= c1.t_para


def test_sequential_fallback_when_no_symbolic():
    g = OpGraph()
    workloads.resnet18_graph(g)  # NN only
    df = dfl.build(g)
    cfg = dse.explore(df, max_pes=16384)
    assert cfg.mode == "sequential"


def test_search_space_reduction_magnitude():
    g = workloads.nvsa_graph()
    n_nodes = len(g.nn_nodes()) + len(g.vsa_nodes())
    s = dse.search_space(10, n_nodes, 8, len(g.nn_nodes()))
    # paper Tab. II: ~10^300 -> ~10^3; our workload gives >= 20 orders
    assert s["reduction_log10"] > 20
    assert s["dag_total_points"] < 10_000


def test_memory_plan_fields():
    g = workloads.nvsa_graph()
    mem = ana.memory_plan(g, t_parallel=10 ** 6)
    assert mem.mem_a1 > 0 and mem.mem_a2 > 0 and mem.mem_c > 0
    assert mem.cache == 2 * (mem.mem_a + mem.mem_b + mem.mem_c)
    assert mem.simd_lanes in (16, 32, 64, 128, 256)


# -- simulator (Fig. 5 / Fig. 6 claims) ---------------------------------------


def test_nsflow_beats_tpu_like_on_nvsa():
    g = workloads.nvsa_graph()
    ns = simulator.simulate_nsflow(g)
    tpu = simulator.simulate_tpu_like(g)
    assert tpu.total / ns.total > 2.0  # paper: up to 8x


def test_speedup_grows_with_symbolic_share():
    speedups = []
    for scale in (8, 48, 192):
        g = workloads.nvsa_graph(symbolic_scale=scale)
        ns = simulator.simulate_nsflow(g)
        tpu = simulator.simulate_tpu_like(g)
        speedups.append(tpu.total / ns.total)
    assert speedups[0] < speedups[1] < speedups[2]  # Fig. 6 trend


def test_phase2_gain_visible_at_balanced_mix():
    g = workloads.nvsa_graph(symbolic_scale=96)
    full = simulator.simulate_nsflow(g, phase2_enabled=True)
    p1 = simulator.simulate_nsflow(g, phase2_enabled=False)
    assert full.total <= p1.total


# -- mesh folding -------------------------------------------------------------

FOLD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import folding
from repro.common.util import mesh_context

mesh = jax.make_mesh((8,), ("model",))
n_l = 6
nn_x = jax.random.normal(jax.random.PRNGKey(0), (12, 16))
vsa_x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
w = jax.random.normal(jax.random.PRNGKey(2), (16, 16))

nn_fn = lambda x: jnp.tanh(x @ w)
vsa_fn = lambda x: jnp.roll(x, 1, axis=-1) * 2.0

f = folding.make_folded_fn(mesh, "model", n_l, nn_fn, vsa_fn,
                           (12, 16), (4, 16))
with mesh_context(mesh):
    nn_out, vsa_out = jax.jit(f)(nn_x, vsa_x)
e1 = float(jnp.max(jnp.abs(nn_out - nn_fn(nn_x))))
e2 = float(jnp.max(jnp.abs(vsa_out - vsa_fn(vsa_x))))
print(e1, e2)
assert e1 < 1e-5 and e2 < 1e-5
print("FOLD_OK")
"""


def test_mesh_folding_subprocess():
    r = subprocess.run([sys.executable, "-c", FOLD_SCRIPT], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "FOLD_OK" in r.stdout, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
