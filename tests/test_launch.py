"""Launch-layer unit tests: roofline HLO parsing, memory planning tiles,
mesh DSE sanity (no compiles — the dry-run itself runs out-of-band)."""

import jax.numpy as jnp
import numpy as np

from repro.core import memplan, workloads
from repro.core.analytical import memory_plan
from repro.launch import roofline as rl


SYNTH_HLO = """
HloModule jit_train_step

%region_1.100 (a: f32[16,1024]) -> f32[16,1024] {
  %p = f32[16,1024]{1,0} parameter(0)
  %ar = f32[16,1024]{1,0} all-reduce(f32[16,1024]{1,0} %p), replica_groups={}
  ROOT %r = f32[16,1024]{1,0} add(%ar, %ar)
}

ENTRY %main (x: bf16[8,512]) -> bf16[8,512] {
  %x = bf16[8,512]{1,0} parameter(0)
  %ag = bf16[64,512]{1,0} all-gather(bf16[8,512]{1,0} %x), dimensions={0}
  %w = s32[] while(s32[] %c), condition=%cond.1, body=%region_1.100
  %cp = bf16[8,512]{1,0} collective-permute(bf16[8,512]{1,0} %x), source_target_pairs={{0,1}}
  ROOT %out = bf16[8,512]{1,0} add(%cp, %x)
}
"""


def test_parse_collectives_counts_and_trips():
    bytes_, counts = rl.parse_collectives(SYNTH_HLO, default_trips=7)
    # all-gather operand: 8*512*2 bytes in entry (trips 1)
    assert bytes_["all-gather"] == 8 * 512 * 2
    # all-reduce lives inside the while body -> scaled by 7
    assert bytes_["all-reduce"] == 16 * 1024 * 4 * 7
    assert counts["all-reduce"] == 7
    assert bytes_["collective-permute"] == 8 * 512 * 2
    assert bytes_["reduce-scatter"] == 0.0


def test_roofline_terms_dominance():
    t = rl.roofline_terms(flops_per_device=197e12, bytes_per_device=0,
                          collective_bytes_total=0, chips=1)
    assert abs(t["compute_s"] - 1.0) < 1e-9 and t["dominant"] == "compute"
    t = rl.roofline_terms(0, 819e9, 0, 1)
    assert abs(t["memory_s"] - 1.0) < 1e-9 and t["dominant"] == "memory"
    t = rl.roofline_terms(0, 0, 200e9 * 4, 4)
    assert t["dominant"] == "collective"


def test_memplan_tiles_fit_vmem():
    g = workloads.nvsa_graph()
    mem = memory_plan(g, t_parallel=10**6)
    tiles = memplan.plan_tiles(mem, d=256)
    assert tiles.circ_elem_tile_n >= 1
    # circulant working set within the VMEM budget
    assert tiles.circ_elem_tile_n * 256 * 256 * 4 * 2 <= tiles.vmem_budget
    assert tiles.qmm_bm % 128 == 0
    merged = memplan.plan_tiles(mem, d=256, concurrent=False)
    assert merged.circ_elem_tile_n >= tiles.circ_elem_tile_n  # A1/A2 merge


def test_shape_bytes_parser():
    assert rl._shape_bytes("f32[4,4]") == 64
    assert rl._shape_bytes("bf16[2,3] , s8[10]") == 12 + 10
    assert rl._shape_bytes("pred[]") == 1  # scalar: empty dims
