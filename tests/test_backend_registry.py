"""Backend negotiation regression suite.

The load-bearing case is the platform predicate: the pre-registry
dispatchers tested ``jax.default_backend() != "tpu"`` and so forced GPUs
into Pallas *interpret* mode (a silent orders-of-magnitude slowdown).
Negotiation is pure given a platform string, so every platform's plan is
asserted here without needing the hardware.
"""

import numpy as np
import pytest

from repro.backend import registry


# -- platform predicates (the GPU mis-dispatch regression) -------------------


@pytest.mark.parametrize("platform,head,interpret", [
    ("tpu", "pallas", False),
    ("gpu", "pallas", False),   # regression: used to get interpret mode
    ("cpu", "interpret", True),
])
def test_negotiate_per_platform(platform, head, interpret):
    plan = registry.negotiate(platform=platform, override="")
    assert plan.platform == platform
    for kernel in registry.KERNELS:
        low = plan.lowering(kernel)
        assert low.name == head, (kernel, low)
        assert low.interpret is interpret
        # every chain ends in the universally-feasible exact reference
        assert plan.chains[kernel][-1].is_ref


def test_negotiate_unknown_platform_falls_back_to_xla():
    plan = registry.negotiate(platform="metal", override="")
    assert all(low.is_ref for low in
               (plan.lowering(k) for k in registry.KERNELS))


def test_gpu_plan_never_interprets():
    """No lowering a GPU plan can select runs in interpret mode."""
    plan = registry.negotiate(platform="gpu", override="")
    for kernel in registry.KERNELS:
        for low in plan.chains[kernel]:
            assert not low.interpret
            assert not plan.run_interpret(low)


def test_cpu_run_interpret_degrades_forced_pallas():
    """Forcing the compiled-pallas lowering on CPU must not hand Mosaic a
    CPU compile: run_interpret() degrades it to interpret mode."""
    plan = registry.negotiate(platform="cpu", override="pallas")
    low = plan.lowering("circ_conv")
    assert low.name == "pallas" and not low.interpret
    assert plan.run_interpret(low)


# -- capability predicates within a chain ------------------------------------


def test_select_nonpow2_falls_through_to_ref():
    plan = registry.negotiate(platform="tpu", override="")
    assert plan.select("circ_conv", size=33).is_ref
    assert plan.select("circ_conv", size=4).is_ref      # below min_size
    assert not plan.select("circ_conv", size=32).is_ref


def test_select_unknown_size_is_conservative():
    """A shape-constrained lowering is infeasible when the call site
    cannot state its size."""
    plan = registry.negotiate(platform="tpu", override="")
    assert plan.select("circ_conv").is_ref
    assert not plan.select("qmatmul").is_ref  # unconstrained kernel: fine


def test_dispatch_threshold_only_applies_with_dispatch_flag():
    plan = registry.negotiate(platform="cpu", override="")
    # vsa-level dispatch: small-but-feasible d routes to the exact ref
    assert plan.select("circ_conv", size=64, dispatch=True).is_ref
    assert not plan.select("circ_conv", size=128, dispatch=True).is_ref
    # kernel-wrapper level: an explicit kernel call at d=64 stays a kernel
    assert not plan.select("circ_conv", size=64).is_ref


# -- overrides ---------------------------------------------------------------


def test_override_global_and_per_kernel():
    plan = registry.negotiate(platform="tpu", override="xla")
    assert all(plan.lowering(k).is_ref for k in registry.KERNELS)
    plan = registry.negotiate(platform="tpu",
                              override="circ_conv=xla,qmatmul=interpret")
    assert plan.lowering("circ_conv").is_ref
    assert plan.lowering("qmatmul").name == "interpret"
    assert plan.lowering("simd_fused").name == "pallas"  # untouched
    assert plan.source == "override:circ_conv=xla,qmatmul=interpret"


def test_override_env(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "xla")
    plan = registry.negotiate(platform="cpu")
    assert plan.source == "env:xla"
    assert all(plan.lowering(k).is_ref for k in registry.KERNELS)
    # the lazily-negotiated default plan re-negotiates on env change
    assert registry.get_plan().lowering("circ_conv").is_ref
    monkeypatch.delenv("REPRO_BACKEND")
    assert not registry.get_plan().lowering("circ_conv").is_ref \
        or registry.get_plan().platform not in ("cpu", "gpu", "tpu")


@pytest.mark.parametrize("bad", ["nope", "circ_conv=nope", "bogus=xla"])
def test_override_rejects_unknown_names(bad):
    with pytest.raises((KeyError, ValueError)):
        registry.negotiate(platform="cpu", override=bad)


def test_forced_nonref_keeps_ref_fallback():
    """A forced compiled-Pallas lowering still degrades to the exact
    reference when the call-site shape is infeasible (non-pow2 d must
    never crash the Mosaic build); the forced *interpreter* carries no
    shape predicate and serves the call itself."""
    plan = registry.negotiate(platform="cpu", override="pallas")
    assert plan.select("circ_conv", size=33).is_ref
    plan = registry.negotiate(platform="cpu", override="interpret")
    assert plan.select("circ_conv", size=33).name == "interpret"


# -- active-plan scoping -----------------------------------------------------


def test_use_plan_stacks_and_restores():
    base = registry.get_plan()
    forced = registry.negotiate(platform="cpu", override="xla")
    with registry.use_plan(forced):
        assert registry.get_plan() is forced
        assert registry.active("circ_conv", size=128).is_ref
        inner = registry.negotiate(platform="tpu", override="")
        with registry.use_plan(inner):
            assert registry.get_plan() is inner
        assert registry.get_plan() is forced
    assert registry.get_plan() is base


# -- replay tolerance (what serve.trace diffs against) -----------------------


def test_replay_tolerance_identical_tags_is_bit_exact():
    tags = registry.negotiate(platform="cpu", override="").tags()
    assert registry.replay_tolerance(tags, dict(tags)) == 0.0


def test_replay_tolerance_changed_kernels_take_max_epsilon():
    a = registry.negotiate(platform="cpu", override="").tags()
    b = dict(a, circ_conv="xla")
    tol = registry.replay_tolerance(a, b)
    eps = registry.KERNELS["circ_conv"].by_name("interpret").epsilon
    assert tol == pytest.approx(eps)
    assert registry.replay_tolerance(b, a) == pytest.approx(eps)


# -- registry invariants -----------------------------------------------------


def test_every_kernel_has_exact_ref_lowering():
    for spec in registry.KERNELS.values():
        refs = [low for low in spec.lowerings if low.is_ref]
        assert len(refs) == 1
        assert refs[0].equivalence == "exact"
        assert refs[0].platforms == registry.PLATFORMS


def test_plan_tags_and_tag_rendering():
    plan = registry.negotiate(platform="cpu", override="")
    assert set(plan.tags()) == set(registry.KERNELS)
    assert plan.tag() == "cpu/interpret"   # uniform plans render compactly
    mixed = registry.negotiate(platform="cpu", override="circ_conv=xla")
    assert "circ_conv:xla" in mixed.tag()


# -- deploy() integration (cheap: report shape only) -------------------------


def test_deployment_report_records_backend(tmp_path):
    from repro.serve import Budget, Traffic, deploy

    dep = deploy(["nvsa"], Traffic(), Budget(max_batch=2), seed=0,
                 options={"nvsa": {"d": 16}})
    rec = dep.report()["nvsa"]["backend"]
    assert rec is not None
    assert set(rec["lowerings"]) == set(registry.KERNELS)
    assert rec["platform"] == dep.backend.platform
    assert "backend=" in dep.summary()
    # explicit override is honored and recorded
    dep2 = deploy(["nvsa"], Traffic(), Budget(max_batch=2), seed=0,
                  options={"nvsa": {"d": 16}}, backend="xla")
    rec2 = dep2.report()["nvsa"]["backend"]
    assert all(v == "xla" for v in rec2["lowerings"].values())
    assert rec2["source"] == "override:xla"
