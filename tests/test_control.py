"""Overload control plane tests: SLO vocabulary, bounded priority
queues + shedding policies, the AIMD feedback controller, and the
front-door integration on the deterministic simulated engine — all
driven on a virtual clock, so every assertion (including the two-run
bit-identical one) is exact."""

import numpy as np
import pytest

from repro.serve import frontdoor as fd
from repro.serve import sim
from repro.serve import slo as slo_mod
from repro.serve.control import (ClassQueues, ControlConfig,
                                 OverloadController, ShedRecord)
from repro.serve.slo import SLOEstimator, SLOTarget, slo_targets


class VirtualClock:
    """Deterministic clock + sleep pair for driving the serve loop."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def sleep(self, dt: float):
        assert dt >= 0
        self.t += dt


def _arrival(uid, t=0.0, model="m", priority=None):
    return fd.ArrivalRequest(t=t, model=model,
                             request=sim.SimRequest(uid=uid),
                             priority=priority)


# -- SLO vocabulary ----------------------------------------------------------


def test_validate_priority_named_error():
    assert slo_mod.validate_priority("interactive") == "interactive"
    with pytest.raises(ValueError, match="unknown priority class 'vip'"):
        slo_mod.validate_priority("vip")


def test_slo_targets_scalar_and_mapping():
    t = slo_targets(60.0)
    assert t["interactive"].total_p99_ms == 60.0
    assert t["standard"].total_p99_ms == 240.0   # conventional 4x
    assert "batch" not in t                      # best-effort
    t = slo_targets({"batch": 5000.0})
    assert set(t) == {"batch"}
    assert slo_targets(None) == {}
    with pytest.raises(ValueError, match="unknown priority class"):
        slo_targets({"vip": 1.0})
    with pytest.raises(ValueError, match="total_p99_ms"):
        slo_targets(-1.0)


def test_slo_estimator_windowed_p99():
    est = SLOEstimator(window=100)
    for i in range(150):
        est.observe("m", "standard", total_s=float(i), now=float(i))
    # only the last 100 observations (50..149) are retained
    assert est.count("m", "standard") == 100
    expect = float(np.percentile(np.arange(50, 150), 99)) * 1e3
    assert est.p99_ms("m", "standard") == pytest.approx(expect)
    assert np.isnan(est.p99_ms("m", "interactive"))


def test_slo_estimator_snapshot_against_targets():
    est = SLOEstimator({"interactive": SLOTarget(total_p99_ms=50.0)})
    for _ in range(10):
        est.observe("m", "interactive", total_s=0.01, now=0.0)
    snap = est.snapshot("m")
    assert snap["interactive"]["ok"] is True
    assert snap["interactive"]["target_ms"] == 50.0
    est.observe("m", "interactive", total_s=10.0, now=0.0)
    assert est.snapshot("m")["interactive"]["ok"] is False


def test_attainment_exact_counts():
    targets = {"interactive": SLOTarget(total_p99_ms=50.0,
                                        attainment=0.9)}
    lats = [fd.RequestLatency(uid=i, model="m", arrival_s=0.0,
                              dispatch_s=0.0,
                              done_s=0.01 if i < 9 else 1.0, bucket=1,
                              group_size=1, close_reason="full",
                              priority="interactive")
            for i in range(10)]
    att = slo_mod.attainment(lats, targets)
    row = att["interactive"]
    assert (row["n"], row["met"]) == (10, 9)
    assert row["attainment"] == pytest.approx(0.9)
    assert row["ok"] is True                     # 0.9 >= 0.9


# -- bounded priority queues -------------------------------------------------


def test_class_queues_bound_and_tail_drop():
    q = ClassQueues(depth=2, policy="tail-drop")
    assert q.offer(_arrival(0, t=0.0), "standard", now=0.0) is None
    assert q.offer(_arrival(1, t=0.1), "standard", now=0.1) is None
    rej = q.offer(_arrival(2, t=0.2), "interactive", now=0.2)
    assert isinstance(rej, ShedRecord)
    # tail-drop sheds the arrival itself, even when it outranks the queue
    assert (rej.uid, rej.priority, rej.reason) == (2, "interactive",
                                                  "queue-full")
    assert len(q) == 2 and q.depth_max == 2


def test_class_queues_lowest_priority_pushout():
    q = ClassQueues(depth=2, policy="lowest-priority")
    q.offer(_arrival(0, t=0.0), "standard", now=0.0)
    q.offer(_arrival(1, t=0.1), "batch", now=0.1)
    # an interactive arrival at the bound evicts the newest lowest-class
    # queued request — not itself
    rej = q.offer(_arrival(2, t=0.2), "interactive", now=0.2)
    assert (rej.uid, rej.priority, rej.reason) == (1, "batch", "pushout")
    assert [a.request.uid for a in q.pop(10)] == [2, 0]
    # a bottom-class arrival at the bound sheds itself
    q2 = ClassQueues(depth=1)
    q2.offer(_arrival(0, t=0.0), "batch", now=0.0)
    rej = q2.offer(_arrival(1, t=0.1), "batch", now=0.1)
    assert (rej.uid, rej.reason) == (1, "queue-full")


def test_class_queues_pop_priority_then_fifo():
    q = ClassQueues()
    q.offer(_arrival(0, t=0.0), "batch", now=0.0)
    q.offer(_arrival(1, t=0.1), "interactive", now=0.1)
    q.offer(_arrival(2, t=0.2), "standard", now=0.2)
    q.offer(_arrival(3, t=0.3), "interactive", now=0.3)
    assert q.oldest_t == 0.0
    assert [a.request.uid for a in q.pop(3)] == [1, 3, 2]
    assert [a.request.uid for a in q.pop(3)] == [0]
    with pytest.raises(ValueError, match="unknown priority class"):
        q.offer(_arrival(4), "vip", now=0.0)
    with pytest.raises(ValueError, match="depth bound"):
        ClassQueues(depth=0)


# -- the feedback controller -------------------------------------------------


def test_control_config_validation():
    with pytest.raises(ValueError, match="tick_s"):
        ControlConfig(tick_s=0.0)
    with pytest.raises(ValueError, match="decrease"):
        ControlConfig(decrease=1.5)
    with pytest.raises(ValueError, match="increase"):
        ControlConfig(increase=1.0)
    with pytest.raises(ValueError, match="unknown shed policy"):
        ControlConfig(shed_policy="coin-flip")
    with pytest.raises(ValueError, match="queue_depth"):
        ControlConfig(queue_depth=0)


def test_controller_bind_is_idempotent_and_clamped():
    ctl = OverloadController(slo_targets(60.0))
    ctl.bind("m", deadline_s=10.0, cap=8, buckets=(2, 4, 8))
    assert ctl.deadline_s("m") == ctl.cfg.max_deadline_s  # clamped
    assert ctl.cap("m") == 8
    ctl.bind("m", deadline_s=0.001, cap=2)   # second bind: no-op
    assert ctl.cap("m") == 8
    assert ctl.bound() == {"m"}


def _fed(ctl, model, total_s, n=16, now=0.0):
    for _ in range(n):
        ctl.observe(model, "interactive", total_s, now)


def test_controller_tightens_on_violation_with_shallow_queue():
    ctl = OverloadController(slo_targets(50.0))
    ctl.bind("m", deadline_s=0.02, cap=8, buckets=(2, 4, 8))
    _fed(ctl, "m", total_s=0.5)              # p99 500ms >> 50ms target
    out = ctl.tick(1.0, {"m": {"queue_depth": 0, "inflight": 0}})
    assert [d.action for d in out] == ["tighten"]
    assert ctl.deadline_s("m") == pytest.approx(0.01)   # halved
    assert ctl.cap("m") == 4                            # stepped down


def test_controller_steps_cap_up_on_violation_with_backlog():
    ctl = OverloadController(slo_targets(50.0))
    ctl.bind("m", deadline_s=0.02, cap=8, buckets=(2, 4, 8))
    _fed(ctl, "m", total_s=0.5)
    # first a shallow-queue violation steps the cap down from the DSE
    # point...
    ctl.tick(1.0, {"m": {"queue_depth": 0, "inflight": 0}})
    assert ctl.cap("m") == 4
    # ...then sustained backlog flips the diagnosis to throughput-bound
    # and steps it back up (the DSE cap stays the ceiling)
    _fed(ctl, "m", total_s=0.5)
    out = ctl.tick(2.0, {"m": {"queue_depth": 16, "inflight": 4}})
    assert [d.action for d in out] == ["throughput"]
    assert ctl.cap("m") == 8                 # amortize dispatch overhead


def test_controller_relaxes_back_when_healthy():
    ctl = OverloadController(slo_targets(50.0))
    ctl.bind("m", deadline_s=0.02, cap=8, buckets=(2, 4, 8))
    _fed(ctl, "m", total_s=0.5)
    ctl.tick(1.0, {"m": {"queue_depth": 0, "inflight": 0}})
    assert (ctl.deadline_s("m"), ctl.cap("m")) == (0.01, 4)
    # healthy window: deadline multiplies back up, cap drifts to the
    # DSE point
    _fed(ctl, "m", total_s=0.001, n=ctl.cfg.window)
    out = ctl.tick(2.0, {"m": {"queue_depth": 0, "inflight": 0}})
    assert [d.action for d in out] == ["relax"]
    assert ctl.deadline_s("m") == pytest.approx(0.0125)
    assert ctl.cap("m") == 8


def test_controller_holds_below_min_obs_and_without_targets():
    ctl = OverloadController(slo_targets(50.0))
    ctl.bind("m", deadline_s=0.02, cap=8)
    _fed(ctl, "m", total_s=0.5, n=ctl.cfg.min_obs - 1)
    assert ctl.tick(1.0, {}) == []           # too few observations
    free = OverloadController()              # no objectives: observe-only
    free.bind("m", deadline_s=0.02, cap=8)
    _fed(free, "m", total_s=0.5)
    assert free.tick(1.0, {}) == []


def test_maybe_tick_is_phase_locked():
    ctl = OverloadController(slo_targets(50.0),
                             ControlConfig(tick_s=0.1))
    ctl.bind("m", deadline_s=0.02, cap=8)
    ctl.maybe_tick(0.0, {})                  # arms the cadence
    assert ctl.ticks == 0
    ctl.maybe_tick(0.05, {})
    assert ctl.ticks == 0                    # not due yet
    ctl.maybe_tick(0.11, {})
    assert ctl.ticks == 1
    # a long stall consumes the missed phases but runs ONE tick, and the
    # next boundary stays on the original phase grid
    ctl.maybe_tick(0.55, {})
    assert ctl.ticks == 2
    assert ctl._next_tick == pytest.approx(0.6)


# -- front-door integration on the simulated engine --------------------------


def _sim_serve(n=2000, rate=500.0, slo_ms=60.0, queue_depth=32,
               mix=None, seed=0, deadline_s=0.01, cap=8,
               policy="lowest-priority", controller=True):
    vc = VirtualClock()
    # a shallow in-flight window keeps the service tail inside the 60ms
    # interactive budget; the pending backlog lives in the bounded
    # ClassQueues where it can shed
    eng = sim.SimEngine(vc, vc.sleep, cap=cap, max_inflight=2)
    ctl = None
    if controller:
        ctl = OverloadController(
            slo_targets(slo_ms),
            ControlConfig(queue_depth=queue_depth, shed_policy=policy))
    door = fd.FrontDoor({"sim": eng},
                        fd.FrontDoorConfig(deadline_s=deadline_s),
                        clock=vc, sleep=vc.sleep, controller=ctl)
    times = [i / rate for i in range(n)]
    reqs = sim.sim_requests(n, mix=mix, seed=seed)
    return door.serve(fd.trace_arrivals("sim", times, reqs))


def test_flush_order_tracks_arrival_order_across_models():
    """End-of-stream flush regression: open groups must dispatch oldest
    arrival first ACROSS models, not in engine-dict order."""
    vc = VirtualClock()
    engines = {"a": sim.SimEngine(vc, vc.sleep, cap=4),
               "b": sim.SimEngine(vc, vc.sleep, cap=4)}
    door = fd.FrontDoor(engines, fd.FrontDoorConfig(deadline_s=1.0),
                        clock=vc, sleep=vc.sleep)
    arrivals = fd.merge_arrivals(
        fd.trace_arrivals("b", [0.05], [sim.SimRequest(uid=0)]),
        fd.trace_arrivals("a", [0.06], [sim.SimRequest(uid=1)]))
    rep = door.serve(arrivals)
    assert [g.close_reason for g in rep.groups] == ["flush", "flush"]
    # "b" opened first (0.05 < 0.06) so it must dispatch first, even
    # though "a" precedes it in the engines dict
    assert [g.model for g in rep.groups] == ["b", "a"]
    assert rep.groups[0].dispatch_s <= rep.groups[1].dispatch_s


def test_no_controller_is_legacy_unbounded_no_shed():
    rep = _sim_serve(n=500, rate=2000.0, controller=False)
    assert rep.shed == [] and rep.slo == {} and rep.decisions == []
    assert len(rep.latencies) == 500
    assert rep.offered("sim") == 500


def test_offered_equals_admitted_plus_shed_exactly():
    mix = {"interactive": 0.3, "standard": 0.5, "batch": 0.2}
    rep = _sim_serve(n=3000, rate=1400.0, mix=mix)   # ~2x capacity
    assert rep.offered("sim") == 3000
    assert len(rep.latencies) + len(rep.shed) == 3000
    served = {l.uid for l in rep.latencies}
    shed = {s.uid for s in rep.shed}
    assert not served & shed
    assert served | shed == set(range(3000))
    assert len(rep.shed) > 0                 # 2x load must actually shed


def test_overload_sheds_low_priority_and_protects_interactive():
    mix = {"interactive": 0.3, "standard": 0.5, "batch": 0.2}
    rep = _sim_serve(n=3000, rate=1400.0, mix=mix)
    counts = rep.shed_counts("sim")
    assert sum(counts.values()) > 0
    assert "interactive" not in counts       # shedding confined downward
    att = rep.slo_attainment("sim")
    assert att["interactive"]["ok"] is True  # SLO holds through overload
    # boundedness: the pending queue never outgrew its depth bound
    assert rep.queue_depth_max["sim"] <= 32
    assert 0.0 < rep.shed_rate("sim") < 1.0
    assert "shed" in rep.summary() and "slo attainment" in rep.summary()


def test_at_capacity_no_shedding_and_slo_met():
    mix = {"interactive": 0.3, "standard": 0.5, "batch": 0.2}
    rep = _sim_serve(n=2000, rate=500.0, mix=mix)    # ~0.75x capacity
    assert rep.shed == []
    att = rep.slo_attainment("sim")
    assert att["interactive"]["ok"] is True
    assert att["standard"]["ok"] is True


def test_shedding_and_decisions_are_deterministic():
    mix = {"interactive": 0.3, "standard": 0.5, "batch": 0.2}
    a = _sim_serve(n=2500, rate=1400.0, mix=mix)
    b = _sim_serve(n=2500, rate=1400.0, mix=mix)
    assert a.shed == b.shed                  # frozen dataclass equality
    assert a.latencies == b.latencies
    assert a.decisions == b.decisions
    assert a.queue_depth_max == b.queue_depth_max
    assert a.wall_time_s == b.wall_time_s


def test_controller_adapts_during_serve():
    mix = {"interactive": 0.3, "standard": 0.5, "batch": 0.2}
    rep = _sim_serve(n=3000, rate=1400.0, mix=mix)
    assert rep.decisions                     # the loop actually closed
    assert {d.action for d in rep.decisions} <= {"tighten", "throughput",
                                                 "relax"}
    assert rep.slo["interactive"].total_p99_ms == 60.0


def test_priority_resolution_prefers_arrival_stamp():
    """with_priorities overrides the envelope's own class; bare arrivals
    fall back to it."""
    vc = VirtualClock()
    eng = sim.SimEngine(vc, vc.sleep, cap=4)
    door = fd.FrontDoor({"sim": eng}, fd.FrontDoorConfig(deadline_s=0.01),
                        clock=vc, sleep=vc.sleep)
    reqs = [sim.SimRequest(uid=0, priority="batch"),
            sim.SimRequest(uid=1, priority="batch")]
    stream = fd.trace_arrivals("sim", [0.0, 0.0], reqs)
    rep = door.serve(fd.with_priorities(stream, "interactive"))
    assert {l.priority for l in rep.latencies} == {"interactive"}
    rep2 = door.serve(fd.trace_arrivals(
        "sim", [0.0], [sim.SimRequest(uid=7, priority="batch")]))
    assert [l.priority for l in rep2.latencies] == ["batch"]


def test_with_priorities_mix_is_seeded():
    reqs = [sim.SimRequest(uid=i) for i in range(200)]
    mk = lambda: fd.with_priorities(
        fd.trace_arrivals("m", [0.0] * 200, iter(reqs)),
        {"interactive": 1, "batch": 1}, seed=5)
    a = [x.priority for x in mk()]
    assert a == [x.priority for x in mk()]
    assert set(a) == {"interactive", "batch"}
    with pytest.raises(ValueError, match="unknown priority class"):
        list(fd.with_priorities(iter([]), "vip"))
    with pytest.raises(ValueError, match="weights"):
        list(fd.with_priorities(iter([]), {"batch": 0.0}))


def test_bursty_times_diurnal_and_bursts():
    quiet = sim.bursty_times(500, base_rps=100.0, amp=0.0, seed=1)
    assert quiet == sim.bursty_times(500, base_rps=100.0, amp=0.0, seed=1)
    assert all(b > a for a, b in zip(quiet, quiet[1:]))
    burst = sim.bursty_times(
        500, base_rps=100.0, amp=0.0, seed=1,
        bursts=[sim.Burst(t0_s=0.0, dur_s=1e9, mult=4.0)])
    assert burst[-1] < quiet[-1] / 2         # 4x rate compresses the trace
    r0 = sim.diurnal_rate(0.0, 100.0, amp=0.4, period_s=3600.0)
    r_peak = sim.diurnal_rate(900.0, 100.0, amp=0.4, period_s=3600.0)
    assert r0 == pytest.approx(100.0)
    assert r_peak == pytest.approx(140.0)


def test_sim_engine_protocol_and_capacity():
    vc = VirtualClock()
    eng = sim.SimEngine(vc, vc.sleep, cap=8, max_inflight=2)
    rec = eng.submit([sim.SimRequest(uid=0), sim.SimRequest(uid=1)])
    assert rec.bucket == 2 and rec.dispatch_t == 0.0
    assert eng.accepting
    out = eng.drain_all()
    assert set(out) == {0, 1}
    assert eng.stats["warmup"]["requests"] == 2
    svc = sim.ServiceModel(base_s=0.004, per_item_s=0.001)
    assert svc.group_s(8) == pytest.approx(0.012)
    assert svc.capacity_rps(8) == pytest.approx(8 / 0.012)
    with pytest.raises(ValueError, match="admission cap"):
        eng.submit([sim.SimRequest(uid=i) for i in range(9)])
    with pytest.raises(ValueError, match="empty"):
        eng.submit([])
