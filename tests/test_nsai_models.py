"""NSAI workload tests: symbolic reasoning correctness, quantization
degradation ordering, data-generator invariants, MIMONet superposition."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import raven
from repro.models import lvrf, mimonet, nvsa, prae
from repro.nn import init as nninit


@pytest.fixture(scope="module")
def problem_batch():
    # d=128 keeps the Pallas kernel path active (d >= 128) at 4x less
    # interpret-mode cost than the default 256
    cfg = nvsa.NVSAConfig(d=128)
    return cfg, raven.generate_batch(cfg.raven, seed=5, n=16)


def _oracle(cfg, batch):
    ctx = [jnp.asarray(x) for x in nvsa.oracle_pmfs(
        cfg, jnp.asarray(batch["context_attrs"]))]
    cand = [jnp.asarray(x) for x in nvsa.oracle_pmfs(
        cfg, jnp.asarray(batch["candidate_attrs"]))]
    return ctx, cand


def test_generator_rules_consistent():
    cfg = raven.RavenConfig()
    for seed in range(20):
        p = raven.generate_problem(cfg, seed)
        grid = p["panel_attrs"].reshape(3, 3, 3)
        for ai in range(3):
            rule = int(p["rules"][ai])
            n = cfg.attr_sizes[ai]
            for row in range(3):
                a1, a2, a3 = (int(v) for v in grid[row, :, ai])
                assert raven.N_RULES
                assert a3 == raven._apply_rule(rule, a1, a2, n), \
                    (seed, ai, rule, grid[row, :, ai])
        # answer present exactly once among candidates
        matches = (p["candidate_attrs"] == p["panel_attrs"][8]).all(1).sum()
        assert matches == 1
        assert (p["candidate_attrs"][p["answer"]] == p["panel_attrs"][8]).all()


def test_nvsa_oracle_reasoning_near_perfect(problem_batch):
    cfg, batch = problem_batch
    ctx, cand = _oracle(cfg, batch)
    logp, rules = nvsa.reason(cfg, codebooks=nvsa.nvsa_codebooks(
        cfg, jax.random.PRNGKey(1)), ctx_pmfs=ctx, cand_pmfs=cand)
    acc = float(np.mean(np.argmax(np.asarray(logp), -1) == batch["answer"]))
    assert acc >= 0.95, acc


def test_prae_oracle_reasoning_near_perfect(problem_batch):
    cfg, batch = problem_batch
    ctx, cand = _oracle(cfg, batch)
    acc, racc = prae.accuracy(prae.PrAEConfig(), ctx, cand,
                              jnp.asarray(batch["answer"]), batch["rules"])
    # 16-problem sample: allow one rule-ambiguous miss (e.g. a constant row
    # that a PMF engine also explains as arith-minus with a2=0)
    assert acc >= 0.90, acc
    assert racc >= 0.8, racc


@pytest.mark.slow
def test_nvsa_quantization_monotone_degradation(problem_batch):
    """Tab. IV ordering on the symbolic side: int8/mp ≈ fp32 >> int4-everything
    degrades — with oracle perception so only precision varies."""
    cfg0, batch = problem_batch
    ctx, cand = _oracle(cfg0, batch)
    accs = {}
    for label, sy in [("fp32", "fp32"), ("int8", "int8"), ("int4", "int4")]:
        cfg = dataclasses.replace(cfg0, symb_precision=sy)
        books = nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
        if sy in ("int8", "int4"):
            books = {
                "books": [nvsa.fake_quant(b, sy) for b in books["books"]],
                "shifts": [nvsa.fake_quant(s, sy) for s in books["shifts"]],
                "roles": nvsa.fake_quant(books["roles"], sy),
            }
        logp, _ = nvsa.reason(cfg, books, ctx, cand)
        accs[label] = float(np.mean(np.argmax(np.asarray(logp), -1)
                                    == batch["answer"]))
    assert accs["fp32"] >= 0.95
    assert accs["int8"] >= accs["fp32"] - 0.1   # int8 ~ lossless (Tab. IV)
    assert accs["int4"] <= accs["int8"] + 1e-9  # int4 strictly no better


def test_nvsa_memory_savings_ratio():
    cfg_fp = nvsa.NVSAConfig()
    cfg_mp = dataclasses.replace(cfg_fp, nn_precision="int8",
                                 symb_precision="int4")
    params = nninit.materialize(nvsa.nvsa_spec(cfg_fp), jax.random.PRNGKey(0))
    r = nvsa.nvsa_memory_bytes(cfg_fp, params) / nvsa.nvsa_memory_bytes(cfg_mp, params)
    assert 3.5 < r < 8.5  # paper: 5.8x


@pytest.mark.slow
def test_lvrf_learns_rules_quickly(problem_batch):
    """A few hundred LVRF steps on oracle PMFs beat chance by a wide margin."""
    cfg0, batch = problem_batch
    ctx, cand = _oracle(cfg0, batch)
    # d=64 keeps binds on the fast XLA ref path (kernel itself is
    # covered by test_kernels.py); 60 full-batch steps stay CPU-cheap
    lcfg = lvrf.LVRFConfig(d=64)
    params = nninit.materialize(lvrf.lvrf_spec(lcfg), jax.random.PRNGKey(0))
    books = lvrf.lvrf_codebooks(lcfg, jax.random.PRNGKey(1))
    answers = jnp.asarray(batch["answer"])
    loss_g = jax.jit(jax.value_and_grad(
        lambda p: lvrf.loss_fn(p, books, lcfg, ctx, cand, answers)))
    lr = 0.5
    for _ in range(60):
        loss, g = loss_g(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    acc = lvrf.accuracy(params, books, lcfg, ctx, cand, answers)
    assert acc > 0.5, acc  # chance = 0.125


def test_mimonet_unbinding_separates_channels():
    """With unitary keys, unbinding the superposition recovers per-channel
    codes (before the trunk): the core MIMONet property."""
    cfg = mimonet.MIMONetConfig()
    keys = mimonet.mimonet_keys(cfg, jax.random.PRNGKey(3))
    from repro.vsa import ops as vsa
    codes = vsa.random_codebook(jax.random.PRNGKey(4), cfg.n_channels,
                                cfg.blocks, cfg.d)
    bound = vsa.bind(codes, keys)
    sup = jnp.sum(bound, axis=0, keepdims=True)
    for c in range(cfg.n_channels):
        rec = vsa.unbind(keys[c][None], sup)[0]
        sims = [float(vsa.similarity(rec[None], codes[i][None])[0])
                for i in range(cfg.n_channels)]
        assert np.argmax(sims) == c
        assert sims[c] > 0.6


@settings(max_examples=10, deadline=None)
@given(style=st.sampled_from(["raven", "iraven", "pgm"]),
       seed=st.integers(0, 10_000))
def test_generator_candidates_unique(style, seed):
    cfg = raven.RavenConfig(style=style)
    p = raven.generate_problem(cfg, seed)
    cands = {tuple(c) for c in p["candidate_attrs"]}
    assert len(cands) == 8


# ---------------------------------------------------------------------------
# Served (compiled StagedSchedule) vs offline equivalence + determinism
# ---------------------------------------------------------------------------


def _reason_engine(cfg, batch_size, model="nvsa", consts=None,
                   variants=None, buckets=None):
    from repro.configs import base as cbase
    from repro.serve.reason import ReasonConfig

    # trace_graph=False: these tests exercise execution equivalence; the
    # graph/buffer lowering itself is covered by test_schedule.py
    return cbase.reason_engine(model, cfg,
                               ReasonConfig(batch_size=batch_size,
                                            buckets=buckets),
                               consts=consts, variants=variants,
                               trace_graph=False)


def test_served_nvsa_oracle_matches_offline(problem_batch):
    """Batched served NVSA (oracle variant, 2 pipeline batches) must
    reproduce the offline ``nvsa.reason`` answer distribution exactly and
    hit accuracy 1.0 on unambiguous RAVEN grids."""
    from repro.serve.reason import requests_from_batch

    cfg, batch = problem_batch
    books = nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
    ctx, cand = _oracle(cfg, batch)
    off_logp, _ = nvsa.reason(cfg, books, ctx, cand)
    off_logp = np.asarray(off_logp)

    consts = {"params": None, "books": books}
    eng = _reason_engine(cfg, batch_size=8, consts=consts,
                         variants=("oracle",))
    res = eng.run(requests_from_batch(batch), variant="oracle")
    n = len(batch["answer"])
    served = np.stack([res[i].answer_logprobs for i in range(n)])
    np.testing.assert_allclose(served, off_logp, atol=1e-5)
    answers = np.array([res[i].answer for i in range(n)])
    np.testing.assert_array_equal(answers, np.argmax(off_logp, -1))
    assert float(np.mean(answers == batch["answer"])) == 1.0


def test_served_prae_oracle_accuracy(problem_batch):
    """The PrAE symbolic stream behind the same engine interface."""
    from repro.serve.reason import requests_from_batch

    cfg, batch = problem_batch
    consts = {"params": None, "books": None}
    eng = _reason_engine(cfg, batch_size=8, model="prae", consts=consts,
                         variants=("oracle",))
    res = eng.run(requests_from_batch(batch), variant="oracle")
    n = len(batch["answer"])
    acc = float(np.mean([res[i].answer == batch["answer"][i]
                         for i in range(n)]))
    assert acc >= 0.90, acc  # same floor as the offline PrAE oracle test


@pytest.mark.parametrize("nn,sy,qmm", [("fp32", "fp32", False),
                                       ("int8", "int4", True)])
def test_served_nvsa_cnn_matches_offline(nn, sy, qmm):
    """Full CNN path: the served pipeline must reproduce the offline
    ``nvsa.solve`` answer distributions — also under Tab. IV mixed
    precision with the nn stream on the Pallas qmatmul kernel and the
    symbolic stream at int4.  With eval-mode BN this holds across ragged
    admission groups, not just when the group equals the offline batch."""
    from repro.serve.reason import requests_from_batch

    # d=64 keeps binds on the XLA path (kernel conformance is covered by
    # test_kernel_conformance.py)
    cfg = nvsa.NVSAConfig(d=64, nn_precision=nn, symb_precision=sy,
                          use_qmatmul=qmm)
    params = nninit.materialize(nvsa.nvsa_spec(cfg), jax.random.PRNGKey(0))
    books = nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
    batch = raven.generate_batch(cfg.raven, seed=11, n=6)
    off_logp, _ = nvsa.solve(params, books, cfg,
                             jnp.asarray(batch["context"]),
                             jnp.asarray(batch["candidates"]))
    off_logp = np.asarray(off_logp)

    consts = {"params": params, "books": books}
    # batch_size=4 -> 6 requests split into a full + ragged pipeline batch
    eng = _reason_engine(cfg, batch_size=4, consts=consts,
                         variants=("cnn",))
    res = eng.run(requests_from_batch(batch))
    served = np.stack([res[i].answer_logprobs for i in range(6)])
    np.testing.assert_allclose(served, off_logp, atol=1e-5)
    np.testing.assert_array_equal(
        np.array([res[i].answer for i in range(6)]),
        np.argmax(off_logp, -1))


def test_served_answer_independent_of_admission_group():
    """Eval-mode BN regression (ROADMAP): a request's served answer
    distribution must not depend on which other requests it was admitted
    with — serve a problem alone and inside a mixed group, byte-compare."""
    from repro.serve.reason import requests_from_batch

    cfg = nvsa.NVSAConfig(d=64)
    params = nninit.materialize(nvsa.nvsa_spec(cfg), jax.random.PRNGKey(0))
    books = nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
    consts = {"params": params, "books": books}
    batch = raven.generate_batch(cfg.raven, seed=17, n=5)
    reqs = requests_from_batch(batch)

    eng = _reason_engine(cfg, batch_size=5, consts=consts, variants=("cnn",))
    grouped = eng.run(reqs)
    solo_eng = _reason_engine(cfg, batch_size=1, consts=consts,
                              variants=("cnn",))
    for req in reqs:
        solo = solo_eng.run([req])
        np.testing.assert_allclose(solo[req.uid].answer_logprobs,
                                   grouped[req.uid].answer_logprobs,
                                   atol=1e-5)
        assert solo[req.uid].answer == grouped[req.uid].answer


@pytest.mark.parametrize("model,variant", [
    ("nvsa", "cnn"), ("prae", "oracle"), ("mimonet", "default"),
    ("lvrf", "oracle")])
def test_served_answer_bitwise_invariant_across_buckets(model, variant):
    """Shape-bucketing regression (extends the PR 3 admission-group
    independence test): a request's served answer must be BIT-identical
    whether it arrives in a full batch, a padded partial batch, or any
    compiled bucket size >= 2 — for every registered workload.  (Bucket 1
    is excluded from the default ladder precisely because XLA's
    degenerate-batch lowerings break bit-equality; see
    frontdoor.pow2_buckets.)"""
    from repro.configs import base as cbase

    entry = cbase.REASON_WORKLOADS[model]
    cfg = entry.make_config(d=64)
    consts = {"params": None, "books": None} if (model, variant) == \
        ("prae", "oracle") else entry.make_consts(cfg, jax.random.PRNGKey(0))
    factory, _ = entry.make_requests(cfg, 5, seed=21)
    reqs = list(factory())

    # reference: all 5 requests in one full (unpadded) admission group
    full = _reason_engine(cfg, batch_size=5, model=model, consts=consts,
                          variants=(variant,)).run(reqs,
                                                   variant=variant)
    # bucketed: groups of 4 (bucket 4) and 1 (bucket 2, one padded row)
    eng = _reason_engine(cfg, batch_size=4, model=model, consts=consts,
                         variants=(variant,), buckets=(2, 4))
    bucketed = eng.run(reqs, variant=variant)
    # padded partial at the same bucket: 3 requests ride bucket 4
    partial = eng.run(reqs[:3], variant=variant)
    assert eng.schedules[variant].batch_buckets == (2, 4)
    assert len({r.batch for r in bucketed.values()}) == 2  # two groups

    for uid in range(5):
        np.testing.assert_array_equal(
            full[uid].answer_logprobs, bucketed[uid].answer_logprobs,
            err_msg=f"{model}/{variant} uid {uid} full-vs-bucketed")
        assert np.array_equal(full[uid].answer, bucketed[uid].answer)
    for uid in range(3):
        np.testing.assert_array_equal(
            full[uid].answer_logprobs, partial[uid].answer_logprobs,
            err_msg=f"{model}/{variant} uid {uid} full-vs-padded-partial")
        assert np.array_equal(full[uid].answer, partial[uid].answer)


def test_bn_ema_updates_running_stats():
    """The functional BN-EMA plumbing: one train step's batch statistics
    fold into the running stats (NVSA frontend and MIMONet encoder), so
    eval-mode BN sees trained statistics."""
    from repro.models import mimonet

    cfg = nvsa.NVSAConfig(d=64, cnn_width=8, cnn_feat=32)
    params = nninit.materialize(nvsa.nvsa_spec(cfg), jax.random.PRNGKey(0))
    imgs, attrs = raven.panel_dataset(cfg.raven, seed=1, n_problems=1)
    (loss, stats), _ = jax.value_and_grad(nvsa.frontend_loss, has_aux=True)(
        params, cfg, jnp.asarray(imgs[:8]), jnp.asarray(attrs[:8]))
    assert np.isfinite(float(loss)) and stats
    new = nvsa.frontend_apply_bn_stats(params, stats, momentum=0.5)
    stem_old = params["frontend"]["stem_bn"]
    stem_new = new["frontend"]["stem_bn"]
    assert not np.allclose(stem_new["mean"], stem_old["mean"])
    assert not np.allclose(stem_new["var"], stem_old["var"])
    # scale/bias untouched; deep (list-indexed) paths updated too
    np.testing.assert_array_equal(stem_new["scale"], stem_old["scale"])
    deep_old = params["frontend"]["stages"][1][0]["bn1"]["mean"]
    deep_new = new["frontend"]["stages"][1][0]["bn1"]["mean"]
    assert not np.allclose(deep_new, deep_old)

    mcfg = mimonet.MIMONetConfig(d=32, cnn_width=4)
    mparams = nninit.materialize(mimonet.mimonet_spec(mcfg),
                                 jax.random.PRNGKey(0))
    keys = mimonet.mimonet_keys(mcfg, jax.random.PRNGKey(1))
    mimgs = jnp.asarray(imgs[: 2 * mcfg.n_channels].reshape(
        2, mcfg.n_channels, *imgs.shape[1:]))
    labels = jnp.asarray(attrs[: 2 * mcfg.n_channels, 0].reshape(
        2, mcfg.n_channels))
    mloss, mstats = mimonet.loss_fn(mparams, keys, mcfg, mimgs, labels)
    assert np.isfinite(float(mloss)) and mstats
    mnew = mimonet.apply_bn_stats(mparams, mstats, momentum=0.5)
    assert not np.allclose(mnew["encoder"]["stem_bn"]["mean"],
                           mparams["encoder"]["stem_bn"]["mean"])


def test_reason_pipeline_deterministic_and_order_invariant():
    """The reasoning-pipeline determinism golden test: identical answer
    distributions across two runs and across request submission orders
    (oracle variant — per-problem PMFs carry no cross-batch coupling)."""
    from repro.serve.reason import requests_from_batch

    cfg = nvsa.NVSAConfig(d=64)
    books = nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
    batch = raven.generate_batch(cfg.raven, seed=13, n=10)
    reqs = requests_from_batch(batch)
    consts = {"params": None, "books": books}
    # 10 reqs -> ragged last batch
    eng = _reason_engine(cfg, batch_size=4, consts=consts,
                         variants=("oracle",))
    golden = eng.run(reqs, variant="oracle")
    rerun = eng.run(reqs, variant="oracle")
    shuffled = eng.run(list(reversed(reqs)), variant="oracle")
    for res in (rerun, shuffled):
        assert sorted(res) == sorted(golden)
        for uid in golden:
            np.testing.assert_array_equal(res[uid].answer_logprobs,
                                          golden[uid].answer_logprobs)
            assert res[uid].answer == golden[uid].answer
