"""Per-architecture smoke tests: reduced config, one train + decode step on
CPU, asserting output shapes and finiteness (assignment requirement f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs import base as cbase
from repro.nn import init as nninit


def _smoke_batch(arch, cfg, key, batch=2, seq=16):
    if arch.kind == "vlm":
        return {
            "patch_embeds": jax.random.normal(
                key, (batch, cfg.n_img_tokens, cfg.lm.d_model), jnp.bfloat16),
            "tokens": jax.random.randint(key, (batch, seq), 0, cfg.lm.vocab),
            "targets": jax.random.randint(key, (batch, seq), 0, cfg.lm.vocab),
        }
    if arch.kind == "encdec":
        return {
            "frames": jax.random.normal(key, (batch, seq, cfg.d_model),
                                        jnp.bfloat16),
            "tgt_tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab),
            "tgt_targets": jax.random.randint(key, (batch, seq), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab),
        "targets": jax.random.randint(key, (batch, seq), 0, cfg.vocab),
    }


# compile-heavy architectures (MoE+MLA+MTP, deep local:global patterns,
# recurrent hybrids) push a CPU value_and_grad compile to 5-20s each; their
# train smoke runs under `-m slow` while decode smoke stays in tier-1
_HEAVY = {"deepseek-v3-671b", "gemma3-12b", "recurrentgemma-9b", "rwkv6-7b",
          "seamless-m4t-large-v2", "internvl2-26b", "granite-moe-1b-a400m"}


@pytest.mark.parametrize(
    "arch_id",
    [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
     for a in sorted(ARCHS)])
def test_train_step_smoke(arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.make_smoke()
    key = jax.random.PRNGKey(0)
    params = nninit.materialize(cbase.model_spec(arch, cfg), key)
    batch = _smoke_batch(arch, cfg, key)
    loss_fn = cbase.loss_fn(arch, cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    assert np.isfinite(float(loss)), f"{arch_id} loss not finite"
    gleaves = jax.tree.leaves(grads)
    assert gleaves, f"{arch_id} no grads"
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in gleaves), f"{arch_id} non-finite grads"


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_decode_step_smoke(arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.make_smoke()
    key = jax.random.PRNGKey(0)
    params = nninit.materialize(cbase.model_spec(arch, cfg), key)
    from repro.configs.shapes import ShapeSpec
    shape = ShapeSpec("smoke", "decode", 32, 2)
    cache_specs, tok_spec, _ = cbase.decode_state_specs(arch, cfg, shape)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_specs)
    token = jnp.zeros(tok_spec.shape, tok_spec.dtype)
    step = cbase.decode_fn(arch, cfg)
    new_caches, logits = step(params, caches, token, jnp.int32(0))
    vocab = cfg.lm.vocab if arch.kind == "vlm" else cfg.vocab
    assert logits.shape == (2, vocab), f"{arch_id}: {logits.shape}"
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache structure preserved
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


def test_prefill_smoke_lm():
    arch = ARCHS["llama3.2-3b"]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg),
                                jax.random.PRNGKey(0))
    f = cbase.prefill_fn(arch, cfg)
    tokens = jnp.zeros((2, 16), jnp.int32)
    logits = f(params, tokens)
    assert logits.shape == (2, cfg.vocab)


def test_full_config_dims_match_assignment():
    """Spot-check the full configs against the assignment table."""
    c = ARCHS["deepseek-v3-671b"].make_full()
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (61, 7168, 128, 129280)
    assert c.moe.n_experts == 256 and c.moe.top_k == 8 and c.mtp
    c = ARCHS["gemma3-12b"].make_full()
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (48, 3840, 15360, 262144)
    assert c.pattern.count("local") == 5 and c.pattern.count("global") == 1
    c = ARCHS["rwkv6-7b"].make_full()
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (32, 4096, 14336, 65536)
    c = ARCHS["recurrentgemma-9b"].make_full()
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (38, 4096, 12288, 256000)
    assert c.n_kv_heads == 1
    c = ARCHS["granite-moe-1b-a400m"].make_full()
    assert c.moe.n_experts == 32 and c.moe.top_k == 8 and c.d_ff == 512
    c = ARCHS["internvl2-26b"].make_full()
    assert (c.lm.n_layers, c.lm.d_model, c.lm.n_heads) == (48, 6144, 48)
    c = ARCHS["seamless-m4t-large-v2"].make_full()
    assert (c.d_model, c.vocab) == (1024, 256206)
