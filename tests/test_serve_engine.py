"""Continuous-batching engine tests: fused-scan equivalence with the
lockstep reference, EOS early-stop, sampling determinism, ragged prefill,
slot reuse after retirement, the runtime-protocol submit/drain surface,
and the warmup-aware stats split."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs import base as cbase
from repro.nn import init as nninit
from repro.serve.engine import Engine, LockstepEngine, Request, ServeConfig

MAX_LEN = 64


@pytest.fixture(scope="module")
def llama():
    arch = ARCHS["llama3.2-3b"]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg),
                                jax.random.PRNGKey(0))
    step, init_caches = cbase.serve_fns(arch, cfg, max_len=MAX_LEN)
    return cfg, params, step, init_caches


def _engine(llama, **kw):
    _, params, step, init_caches = llama
    defaults = dict(max_new_tokens=8, max_slots=4, max_len=MAX_LEN,
                    decode_block=4)
    defaults.update(kw)
    return Engine(step, init_caches, ServeConfig(**defaults), params=params)


@pytest.fixture(scope="module")
def greedy_engine(llama):
    """Shared greedy engine — jit caches are per-instance, so reuse."""
    return _engine(llama)


def test_fused_matches_lockstep_reference(llama, greedy_engine):
    """The scan-fused greedy decode must reproduce the per-token loop."""
    cfg, params, step, init_caches = llama
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (4, 12)).astype(np.int32)
    scfg = ServeConfig(max_new_tokens=8, max_slots=4, max_len=MAX_LEN,
                       decode_block=4)
    ref = LockstepEngine(step, init_caches, scfg).generate(params, prompts)
    out = greedy_engine.generate(prompts)
    np.testing.assert_array_equal(out, ref)


def test_eos_early_stop_matches_reference(llama, greedy_engine):
    """Tokens before EOS match the no-EOS run; pads follow; slot retires."""
    cfg, params, _, _ = llama
    prompt = np.random.default_rng(1).integers(
        0, cfg.vocab, (9,)).astype(np.int32)
    full = greedy_engine.generate([prompt])[0]
    # pick an "EOS" token whose FIRST occurrence is mid-sequence (greedy
    # smoke decodes loop, so full[k] may also appear earlier)
    k = next(i for i in range(1, len(full)) if full[i] not in full[:i])
    eos = int(full[k])
    eng = _engine(llama, eos_id=eos, pad_id=0)
    res = eng.run([Request(uid=0, prompt=prompt)])[0]
    assert res.finished_by_eos
    np.testing.assert_array_equal(res.tokens, full[: k + 1])  # EOS included
    out = eng.generate([prompt])[0]
    np.testing.assert_array_equal(out[: k + 1], full[: k + 1])
    assert (out[k + 1:] == 0).all()  # retired slot emits pad after EOS


def test_sampled_decode_deterministic_under_fixed_key(llama, greedy_engine):
    cfg, params, _, _ = llama
    prompts = np.random.default_rng(2).integers(
        0, cfg.vocab, (3, 10)).astype(np.int32)
    eng = _engine(llama, temperature=0.7, top_k=16, seed=11)
    a = eng.generate(prompts)
    b = eng.generate(prompts)  # run() re-seeds from cfg.seed
    np.testing.assert_array_equal(a, b)
    greedy = greedy_engine.generate(prompts)
    assert not np.array_equal(a, greedy)  # temperature is actually live
    c = _engine(llama, temperature=0.7, top_k=16, seed=12).generate(prompts)
    assert not np.array_equal(a, c)  # and keyed by the seed


def test_ragged_batch_matches_single_requests(llama, greedy_engine):
    """3 ragged prompts admitted together == 3 single-request runs."""
    cfg, params, _, _ = llama
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (5, 12, 9)]
    batch = greedy_engine.generate(prompts)
    for i, p in enumerate(prompts):
        single = greedy_engine.generate([p])[0]
        np.testing.assert_array_equal(batch[i], single)


def test_slots_reused_after_retirement(llama, greedy_engine):
    """6 requests through 4 slots: the queue drains into freed slots."""
    cfg, params, _, _ = llama
    rng = np.random.default_rng(4)
    eng = greedy_engine
    before = list(eng.stats["slots_served"])
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, (7,)).astype(np.int32), max_new_tokens=6)
        for i in range(6)]
    results = eng.run(reqs)
    assert sorted(results) == list(range(6))
    assert all(len(r.tokens) == 6 for r in results.values())
    served = [a - b for a, b in zip(eng.stats["slots_served"], before)]
    assert sum(served) == 6
    assert max(served) >= 2  # a freed slot picked up a queued request


def test_per_request_budget_and_validation(llama, greedy_engine):
    cfg, params, _, _ = llama
    rng = np.random.default_rng(5)
    eng = greedy_engine
    short = Request(uid=0, prompt=rng.integers(0, cfg.vocab, (4,)).astype(
        np.int32), max_new_tokens=3)
    res = eng.run([short])[0]
    assert len(res.tokens) == 3 and not res.finished_by_eos
    with pytest.raises(ValueError):  # prompt + budget must fit the slot
        eng.run([Request(uid=1, prompt=rng.integers(
            0, cfg.vocab, (MAX_LEN,)).astype(np.int32))])


def test_duplicate_request_uids_rejected(llama, greedy_engine):
    """results are keyed by uid — a duplicate would silently drop one."""
    cfg, params, _, _ = llama
    rng = np.random.default_rng(8)
    reqs = [Request(uid=7, prompt=rng.integers(0, cfg.vocab, (5,)).astype(
        np.int32)) for _ in range(2)]
    with pytest.raises(ValueError, match="duplicate request uids"):
        greedy_engine.run(reqs)


def test_windowed_ring_cache_padded_prefill_matches_lockstep():
    """Bucketed prefill must not corrupt ring-buffer (sliding-window) KV
    caches: with window=16 a length-20 prompt pads to 32, and unclamped pad
    positions would wrap the ring and clobber real prompt entries. The
    lockstep reference scans exact lengths, so any corruption diverges."""
    arch = ARCHS["starcoder2-3b"]
    cfg = arch.make_smoke()  # window=16 < padded prefill length
    params = nninit.materialize(cbase.model_spec(arch, cfg),
                                jax.random.PRNGKey(0))
    step, init_caches = cbase.serve_fns(arch, cfg, max_len=MAX_LEN)
    scfg = ServeConfig(max_new_tokens=8, max_slots=2, max_len=MAX_LEN,
                       decode_block=4, prefill_bucket=16)
    prompts = np.random.default_rng(7).integers(
        0, cfg.vocab, (2, 20)).astype(np.int32)
    ref = LockstepEngine(step, init_caches, scfg).generate(params, prompts)
    out = Engine(step, init_caches, scfg, params=params).generate(prompts)
    np.testing.assert_array_equal(out, ref)


def test_sampled_run_golden_deterministic_and_order_invariant(llama):
    """Fixed seed + fixed request set => byte-identical token streams across
    Engine.run invocations AND across submission orders: sampling is keyed
    by (seed, uid, token index), so admission order, slot assignment, and
    co-resident requests must not leak into any request's stream."""
    cfg, params, _, _ = llama
    rng = np.random.default_rng(9)
    eng = _engine(llama, temperature=0.9, top_k=24, seed=21, max_slots=3)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, (n,)).astype(np.int32),
                    max_new_tokens=b)
            for i, (n, b) in enumerate([(5, 8), (11, 4), (7, 6), (9, 8),
                                        (4, 5), (13, 7), (6, 8)])]
    golden = eng.run(reqs)
    rerun = eng.run(reqs)
    orders = [list(reversed(reqs)),
              [reqs[i] for i in np.random.default_rng(0).permutation(7)]]
    for results in [rerun] + [eng.run(order) for order in orders]:
        assert sorted(results) == sorted(golden)
        for uid in golden:
            np.testing.assert_array_equal(results[uid].tokens,
                                          golden[uid].tokens)
            assert results[uid].finished_by_eos == golden[uid].finished_by_eos


def test_vector_pos_decode_matches_scalar(llama):
    """attention.decode_step with a uniform (B,) pos == scalar pos."""
    cfg, params, step, init_caches = llama
    caches = init_caches(4)
    tok = jnp.arange(4, dtype=jnp.int32) + 5
    c1, l1 = jax.jit(step)(params, caches, tok, jnp.int32(0))
    c2, l2 = jax.jit(step)(params, init_caches(4), tok,
                           jnp.zeros((4,), jnp.int32))
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=1e-5)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_serve_fns_tag_forces_stateful_prefill():
    """rwkv/griffin served with a default ServeConfig must not silently run
    bucketed pad steps through cumulative state: serve_fns tags init_caches
    and the Engine flips the flag itself."""
    arch = ARCHS["rwkv6-7b"]
    step, init_caches = cbase.serve_fns(arch, arch.make_smoke(),
                                        max_len=MAX_LEN)
    assert init_caches.stateful_prefill
    eng = Engine(step, init_caches, ServeConfig(max_len=MAX_LEN))
    assert eng.cfg.stateful_prefill
    arch = ARCHS["llama3.2-3b"]  # positional KV caches keep bucketed prefill
    step, init_caches = cbase.serve_fns(arch, arch.make_smoke(),
                                        max_len=MAX_LEN)
    assert not init_caches.stateful_prefill
    eng = Engine(step, init_caches, ServeConfig(max_len=MAX_LEN))
    assert not eng.cfg.stateful_prefill


# -- the runtime protocol (submit / drain, the front-door surface) -----------


def test_submit_drain_matches_run_bit_exactly(llama):
    """Serving the same uids through the online submit/drain path must be
    byte-identical to the offline run() loop (the acceptance regression
    for folding the LM engine under the unified protocol)."""
    cfg, params, _, _ = llama
    rng = np.random.default_rng(10)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, (n,)).astype(np.int32),
                    max_new_tokens=5)
            for i, n in enumerate((5, 9, 7, 4, 11, 6))]
    eng = _engine(llama, temperature=0.8, top_k=12, seed=3, max_slots=2)
    offline = eng.run(reqs)
    # online: dribble groups in, pump with drain_ready, finish with drain_all
    recs = [eng.submit(reqs[0:2])]
    got = dict(eng.drain_ready())
    recs.append(eng.submit(reqs[2:4]))
    got.update(eng.drain_ready())
    recs.append(eng.submit(reqs[4:6]))
    got.update(eng.drain_all())
    assert sorted(got) == sorted(offline)
    for uid in offline:
        np.testing.assert_array_equal(got[uid].tokens, offline[uid].tokens)
    for rec in recs:
        assert rec.dispatch_t is not None and rec.done_t is not None
        assert rec.done_t >= rec.dispatch_t
    assert eng.inflight == 0


def test_submit_queues_past_slot_pool(llama):
    """A submit beyond the free slots queues; drain calls admit + decode
    one block at a time (bounded work per call)."""
    eng = _engine(llama, max_slots=2, max_new_tokens=6)
    cfg, params, _, _ = llama
    rng = np.random.default_rng(11)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, (6,)).astype(np.int32)) for i in range(4)]
    r1 = eng.submit(reqs[:2])
    assert r1.dispatch_t is not None      # prefilled immediately
    r2 = eng.submit(reqs[2:])
    assert r2.dispatch_t is None          # pool full: queued, not dispatched
    assert eng.inflight == 2
    blocks0 = eng.stats["decode_blocks"]
    eng.drain_ready()
    assert eng.stats["decode_blocks"] == blocks0 + 1  # exactly one block
    results = eng.drain_all()
    assert sorted(results) == [0, 1, 2, 3]
    assert r2.dispatch_t is not None and r2.done_t is not None
    assert eng.inflight == 0


def test_submit_rejections(llama):
    eng = _engine(llama, max_slots=2)
    cfg, params, _, _ = llama
    rng = np.random.default_rng(12)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, (5,)).astype(np.int32)) for i in range(3)]
    with pytest.raises(ValueError, match="empty admission group"):
        eng.submit([])
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(reqs)                   # 3 > 2-slot pool
    eng.submit(reqs[:2])
    with pytest.raises(ValueError, match="duplicate request uids"):
        eng.submit(reqs[:1])               # still resident
    with pytest.raises(ValueError, match="undrained in-flight"):
        eng.run(reqs[2:])
    eng.drain_all()
    eng.submit(reqs[:1])                   # drained uids may be reused
    eng.drain_all()
    step, init_caches = cbase.serve_fns(ARCHS["llama3.2-3b"],
                                        ARCHS["llama3.2-3b"].make_smoke(),
                                        max_len=MAX_LEN)
    unbound = Engine(step, init_caches, ServeConfig(max_len=MAX_LEN))
    with pytest.raises(ValueError, match="no params bound"):
        unbound.submit(reqs[:1])


# -- stats: warmup split + per-run records (ReasonEngine parity) -------------


def test_stats_warmup_split_and_per_run_records(llama):
    """First run compiles prefill+decode -> warmup; repeat run at the same
    shapes is measured, so tokens_per_s no longer folds jit compile into
    throughput."""
    cfg, params, _, _ = llama
    rng = np.random.default_rng(13)
    eng = _engine(llama, max_slots=2, max_new_tokens=6)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, (6,)).astype(np.int32)) for i in range(2)]
    eng.run(reqs)
    assert eng.last_run["warmup"] is True          # compiled prefill+decode
    assert eng.stats["warmup"]["requests"] == 2
    assert eng.stats["warmup"]["work"] == eng.stats["tokens"]
    assert eng.stats["measured"]["requests"] == 0
    warm_tps = eng.tokens_per_s()                  # warmup-only fallback
    assert warm_tps > 0
    eng.run(reqs)                                  # same shapes: no compile
    assert eng.last_run["warmup"] is False
    assert eng.stats["measured"]["requests"] == 2
    # compile time no longer in the denominator
    assert eng.tokens_per_s() > warm_tps
    assert eng.stats["measured"]["wall_time_s"] < \
        eng.stats["warmup"]["wall_time_s"]
    assert [r["warmup"] for r in eng.runs] == [True, False]
    # a new padded prefill length is a fresh shape -> warmup again
    long_req = [Request(uid=9, prompt=rng.integers(
        0, cfg.vocab, (20,)).astype(np.int32), max_new_tokens=6)]
    eng.run(long_req)
    assert eng.last_run["warmup"] is True
    # reset zeroes totals but remembers compiled shapes
    eng.reset_stats()
    assert eng.runs == [] and eng.tokens_per_s() == 0.0
    eng.run(reqs)
    assert eng.last_run["warmup"] is False


@pytest.mark.slow
def test_stateful_prefill_ragged_rwkv():
    """Cumulative recurrent state needs exact-length prefill scans: a ragged
    batch under stateful_prefill matches exact single-request runs."""
    arch = ARCHS["rwkv6-7b"]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg),
                                jax.random.PRNGKey(0))
    step, init_caches = cbase.serve_fns(arch, cfg, max_len=MAX_LEN)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (5, 12, 9)]
    kw = dict(max_new_tokens=6, max_slots=4, max_len=MAX_LEN, decode_block=4,
              stateful_prefill=True)
    eng = Engine(step, init_caches, ServeConfig(**kw), params=params)
    batch = eng.generate(prompts)
    assert eng.stats["prefills"] == 3  # one exact-length scan per length
    for i, p in enumerate(prompts):
        single = eng.generate([p])[0]
        np.testing.assert_array_equal(batch[i], single)
