"""Schedule-compilation tests: the dataflow-graph -> StagedSchedule lowering
(serve/schedule.py) and the workload registry (configs/base.py).

Covers the tier-1 compilation smoke for all four registered workloads, the
bit-exact equivalence of the compiled NVSA schedule with PR 2's hand-wired
two-stage pipeline, and served-vs-offline equivalence for the two workloads
the refactor newly opened (MIMONet, LVRF)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cbase
from repro.nn import init as nninit
from repro.serve.reason import ReasonConfig, ReasonRequest
from repro.serve.schedule import (STREAMS, StageSpec, StagedSchedule,
                                  _fmt_bytes, compile_schedule,
                                  predicted_overlap)


def test_registry_covers_all_workloads():
    """The four paper workloads serve through one registry; every consumer
    (launcher --model choices, examples, benchmarks) derives its model list
    from it."""
    assert set(cbase.REASON_WORKLOADS) == {"nvsa", "prae", "mimonet", "lvrf"}
    assert cbase.REASON_MODELS == tuple(cbase.REASON_WORKLOADS)
    for name, entry in cbase.REASON_WORKLOADS.items():
        assert entry.name == name
        assert entry.variants, name
        assert entry.describe


def test_schedule_compilation_smoke():
    """Fast tier-1 smoke: every workload's default variant compiles to a
    StagedSchedule with stream-tagged stages, inter-stage buffer specs and
    a traced DataflowGraph (consts shapes only — nothing materialized)."""
    for model, entry in cbase.REASON_WORKLOADS.items():
        cfg = entry.make_config(d=64)
        sched = cbase.compile_reason_schedule(model, cfg, batch_size=2)
        assert isinstance(sched, StagedSchedule)
        assert len(sched.stages) >= 2, model
        assert all(s in STREAMS for s in sched.streams), model
        # input buffer + one output buffer per stage, all sized
        assert len(sched.buffers) == len(sched.stages) + 1, model
        assert all(b.nbytes > 0 for b in sched.buffers), model
        # per-stage traced op statistics (the stream-tag audit)
        assert len(sched.stage_costs) == len(sched.stages), model
        # the composed pipeline traced into the same graph IR the DSE uses
        assert sched.source == "trace" and sched.graph is not None, model
        assert len(sched.graph.graph) > 0 and sched.graph.critical_path
        assert sched.describe()  # human-readable pipeline rendering
        ovl = predicted_overlap(sched, n_batches=4)
        assert ovl["speedup"] >= 1.0, (model, ovl)


def test_nvsa_schedule_has_two_streams():
    """The compiled NVSA pipeline is the paper's two-stream split: an nn
    perception stage feeding a vsa symbolic stage, with the PMF buffer in
    between sized B*8*sum(V)*2*4 bytes."""
    entry = cbase.REASON_WORKLOADS["nvsa"]
    cfg = entry.make_config(d=64)
    b = 4
    sched = cbase.compile_reason_schedule("nvsa", cfg, batch_size=b)
    assert sched.stage_names == ("frontend", "symbolic")
    assert sched.streams == ("nn", "vsa")
    pmf_bytes = 2 * 4 * b * 8 * sum(cfg.raven.attr_sizes)  # ctx+cand f32
    assert sched.buffers[1].nbytes == pmf_bytes
    # the traced graph sees both unit classes of the composed pipeline
    assert sched.graph.graph.nn_nodes(), "conv/matmul nodes"
    assert sched.graph.graph.simd_nodes(), "softmax/similarity chains"


def test_compiled_nvsa_matches_handwired_pipeline_bitexact():
    """The compiled schedule must reproduce PR 2's hand-wired two-stage
    pipeline byte-identically: same stage functions, same jit boundaries,
    same answers."""
    from repro.models import nvsa as nv
    from repro.serve.reason import requests_from_batch
    from repro.data import raven

    cfg = nv.NVSAConfig(d=64)
    params = nninit.materialize(nv.nvsa_spec(cfg), jax.random.PRNGKey(0))
    books = nv.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
    consts = {"params": params, "books": books}
    batch = raven.generate_batch(cfg.raven, seed=23, n=8)

    # PR 2's hand-wired pipeline: jit(neural) then jit(symbolic), one
    # admission group per dispatch
    def neural(params, ctx, cand):
        n, _, h, w, c = ctx.shape
        ctx_p, _ = nv.frontend_pmfs(params, cfg, ctx.reshape(n * 8, h, w, c))
        cand_p, _ = nv.frontend_pmfs(params, cfg,
                                     cand.reshape(n * 8, h, w, c))
        return (tuple(p.reshape(n, 8, -1) for p in ctx_p),
                tuple(p.reshape(n, 8, -1) for p in cand_p))

    def symbolic(codebooks, ctx_pmfs, cand_pmfs):
        codebooks = nv.quantize_codebooks(cfg, codebooks)
        return nv.reason(cfg, codebooks, list(ctx_pmfs), list(cand_pmfs))

    jit_neural, jit_symbolic = jax.jit(neural), jax.jit(symbolic)
    hand = []
    for lo in range(0, 8, 4):
        ctx = jnp.asarray(batch["context"][lo:lo + 4], jnp.float32)
        cand = jnp.asarray(batch["candidates"][lo:lo + 4], jnp.float32)
        logp, _ = jit_symbolic(books, *jit_neural(params, ctx, cand))
        hand.append(np.asarray(logp))
    hand = np.concatenate(hand)

    eng = cbase.reason_engine("nvsa", cfg, ReasonConfig(batch_size=4),
                              consts=consts, variants=("cnn",),
                              trace_graph=False)
    res = eng.run(requests_from_batch(batch))
    served = np.stack([res[i].answer_logprobs for i in range(8)])
    np.testing.assert_array_equal(served, hand)  # bit-exact


def test_mimonet_served_matches_offline():
    """MIMONet's compiled 5-stage pipeline (encode -> superpose -> trunk ->
    unbind -> classify) reproduces the offline single-jit ``forward``."""
    from repro.models import mimonet as mm

    entry = cbase.REASON_WORKLOADS["mimonet"]
    cfg = entry.make_config(d=64)
    consts = entry.make_consts(cfg, jax.random.PRNGKey(0))
    eng = cbase.reason_engine("mimonet", cfg, ReasonConfig(batch_size=3),
                              consts=consts, trace_graph=False)
    factory, _ = entry.make_requests(cfg, 5, seed=0)
    reqs = list(factory())
    res = eng.run(iter(reqs))  # 5 reqs -> full + ragged batch

    imgs = jnp.asarray(np.stack([r.images for r in reqs]), jnp.float32)
    off = np.asarray(mm.forward(consts["params"], consts["keys"], cfg, imgs))
    served_ans = np.stack([res[i].answer for i in range(5)])
    np.testing.assert_array_equal(served_ans, np.argmax(off, -1))
    for i in range(5):
        shifted = off[i] - off[i].max(-1, keepdims=True)
        off_logp = shifted - np.log(np.exp(shifted).sum(-1, keepdims=True))
        np.testing.assert_allclose(res[i].answer_logprobs, off_logp,
                                   atol=1e-5)
    # sequential run exposes the per-stage timing breakdown (per variant)
    eng.run(factory(), schedule="sequential")
    assert set(eng.stats["stage_time_s"]["default"]) == set(
        eng.schedules["default"].stage_names)


def test_lvrf_served_matches_offline(capsys):
    """LVRF's compiled pipeline (frontend/oracle -> abduce -> execute)
    reproduces the offline ``solve_from_pmfs`` on the oracle variant."""
    from repro.data import raven
    from repro.models import lvrf as lv
    from repro.models import nvsa as nv
    from repro.serve.reason import requests_from_batch

    entry = cbase.REASON_WORKLOADS["lvrf"]
    cfg = entry.make_config(d=64)
    consts = entry.make_consts(cfg, jax.random.PRNGKey(0))
    eng = cbase.reason_engine("lvrf", cfg, ReasonConfig(batch_size=4),
                              consts=consts, variants=("oracle",),
                              trace_graph=False)
    batch = raven.generate_batch(cfg.raven, seed=3, n=6)
    res = eng.run(requests_from_batch(batch), variant="oracle")

    ctx = [jnp.asarray(x) for x in nv.oracle_pmfs(
        cfg, jnp.asarray(batch["context_attrs"]))]
    cand = [jnp.asarray(x) for x in nv.oracle_pmfs(
        cfg, jnp.asarray(batch["candidate_attrs"]))]
    off_logp, off_posts = lv.solve_from_pmfs(consts["params"],
                                             consts["books"], cfg, ctx, cand)
    served = np.stack([res[i].answer_logprobs for i in range(6)])
    np.testing.assert_allclose(served, np.asarray(off_logp), atol=1e-5)
    posts = np.stack([res[i].rule_posteriors for i in range(6)], axis=1)
    np.testing.assert_allclose(posts, np.asarray(off_posts), atol=1e-5)


def test_registry_and_engine_errors():
    entry = cbase.REASON_WORKLOADS["nvsa"]
    cfg = entry.make_config(d=64)
    with pytest.raises(KeyError, match="unknown reasoning workload"):
        cbase.compile_reason_schedule("resnetzilla", cfg)
    with pytest.raises(KeyError, match="variant"):
        cbase.compile_reason_schedule("mimonet",
                                      cbase.REASON_WORKLOADS["mimonet"]
                                      .make_config(d=64), variant="oracle")
    # a mimonet request without images fails loudly with the uid
    mcfg = cbase.REASON_WORKLOADS["mimonet"].make_config(d=64)
    mconsts = cbase.REASON_WORKLOADS["mimonet"].make_consts(
        mcfg, jax.random.PRNGKey(0))
    eng = cbase.reason_engine("mimonet", mcfg, ReasonConfig(batch_size=2),
                              consts=mconsts, trace_graph=False)
    with pytest.raises(ValueError, match="request 7"):
        eng.run([ReasonRequest(uid=7)])
    with pytest.raises(ValueError, match="unknown variant"):
        eng.run([], variant="oracle")
    with pytest.raises(ValueError, match="duplicate request uid"):
        eng.run([ReasonRequest(uid=1), ReasonRequest(uid=1)])


def test_compile_schedule_rejects_bad_stages():
    with pytest.raises(ValueError, match="unknown stream"):
        StageSpec("s", "gpu", lambda c, b: b)
    with pytest.raises(ValueError, match="at least one stage"):
        compile_schedule("w", [], lambda r: r, lambda o, i: {})
    dup = [StageSpec("s", "nn", lambda c, b: b),
           StageSpec("s", "vsa", lambda c, b: b)]
    with pytest.raises(ValueError, match="duplicate stage names"):
        compile_schedule("w", dup, lambda r: r, lambda o, i: {})
    one = [StageSpec("s", "nn", lambda c, b: b)]
    for bad in ((4, 2), (2, 2, 4), (0, 2)):
        with pytest.raises(ValueError, match="batch_buckets"):
            compile_schedule("w", one, lambda r: r, lambda o, i: {},
                             batch_buckets=bad)


# -- fused pipeline: one dispatch per admission group ------------------------


@pytest.mark.parametrize("override", ["", "xla"])
@pytest.mark.parametrize("model", sorted(cbase.REASON_WORKLOADS))
def test_fused_schedule_bitexact_vs_staged(model, override):
    """The whole-pipeline fused jit must reproduce the staged schedule
    bit-for-bit for every workload, across batch buckets (full group of 4
    plus the ragged 2) and under the forced-xla backend override.  At d=64
    on CPU every kernel negotiates an exact lowering, so the fused path
    engages for all four workloads; the dispatch counter must drop from K
    per group to 1."""
    from repro.backend import registry

    entry = cbase.REASON_WORKLOADS[model]
    cfg = entry.make_config(d=64)
    consts = entry.make_consts(cfg, jax.random.PRNGKey(0))
    variant = "oracle" if "oracle" in entry.variants else entry.variants[0]
    with registry.use_plan(registry.negotiate(platform="cpu",
                                              override=override)):
        eng = cbase.reason_engine(
            model, cfg,
            ReasonConfig(batch_size=4, buckets=(2, 4), variant=variant),
            consts=consts, variants=(variant,), trace_graph=False)
        sched = eng.schedules[variant]
        assert sched.jit_fused is not None
        assert sched.fused_equivalence == "exact", (
            model, override, sched.fused_lowering_diff)
        assert sched.fused_ok

        factory, _ = entry.make_requests(cfg, 6, seed=11)
        reqs = list(factory())
        staged = eng.run(iter(reqs), schedule="overlap")
        k = len(sched.jit_stages)
        assert eng.stats["dispatches"] == 2 * k       # 2 groups x K stages
        fused = eng.run(iter(reqs), schedule="fused")
        assert eng.stats["dispatches"] == 2 * k + 2   # 2 groups x 1 launch
        assert eng.stats["fused_groups"] == 2
        assert eng.stats["fused_fallback_groups"] == 0

    assert set(staged) == set(fused)
    for uid, r_s in staged.items():
        r_f = fused[uid]
        np.testing.assert_array_equal(np.asarray(r_s.answer),
                                      np.asarray(r_f.answer))
        np.testing.assert_array_equal(r_s.answer_logprobs,
                                      r_f.answer_logprobs)
        if r_s.rule_posteriors is not None:
            np.testing.assert_array_equal(r_s.rule_posteriors,
                                          r_f.rule_posteriors)


def test_fused_epsilon_negotiation_falls_back_stagewise():
    """mimonet at d=128 on CPU: the staged trace routes unbind through the
    circ_conv interpret lowering while the fused trace routes the
    epsilon-class unbind_classify kernel — the negotiation must come out
    epsilon, the executor must refuse the substitution and serve stage by
    stage (counting the fallback), and the answers must stay identical to
    the staged schedule; ``fused=True`` overrides the refusal."""
    from repro.backend import registry

    plan = registry.negotiate(platform="cpu", override="")
    entry = cbase.REASON_WORKLOADS["mimonet"]
    cfg = entry.make_config(d=128)
    consts = entry.make_consts(cfg, jax.random.PRNGKey(0))
    with registry.use_plan(plan):
        eng = cbase.reason_engine("mimonet", cfg, ReasonConfig(batch_size=2),
                                  consts=consts, trace_graph=False)
        sched = eng.schedules["default"]
        assert sched.jit_fused is not None
        assert sched.fused_equivalence == "epsilon"
        assert sched.fused_epsilon > 0
        assert "unbind_classify" in sched.fused_lowering_diff
        assert not sched.fused_ok

        factory, _ = entry.make_requests(cfg, 2, seed=0)
        reqs = list(factory())
        staged = eng.run(iter(reqs), schedule="overlap")
        fused = eng.run(iter(reqs), schedule="fused")
        assert eng.stats["fused_groups"] == 0
        assert eng.stats["fused_fallback_groups"] == 1
    for uid in staged:
        np.testing.assert_array_equal(staged[uid].answer_logprobs,
                                      fused[uid].answer_logprobs)

    # an explicit fused=True accepts the epsilon class
    forced = cbase.compile_reason_schedule("mimonet", cfg, consts=consts,
                                           batch_size=2, trace_graph=False,
                                           plan=plan, fused=True)
    assert forced.fused_forced and forced.fused_ok
    assert forced.fused_equivalence == "epsilon"


def test_fmt_bytes_boundaries():
    """Unit boundaries must never render a value >= 1024 of the smaller
    unit (1048575 bytes is '1.0MB', not '1024.0KB')."""
    assert _fmt_bytes(0) == "0B"
    assert _fmt_bytes(1023) == "1023B"
    assert _fmt_bytes(1024) == "1.0KB"
    assert _fmt_bytes(1048575) == "1.0MB"          # the old '1024.0KB' bug
    assert _fmt_bytes(1048576) == "1.0MB"
    assert _fmt_bytes(1024 ** 3 - 1) == "1.0GB"
    assert _fmt_bytes(1024 ** 3) == "1.0GB"
    assert _fmt_bytes(1536) == "1.5KB"
    # GB is the cap unit: values >= 1024GB stay in GB by design
    assert _fmt_bytes(2 ** 40) == "1024.0GB"
    assert _fmt_bytes(5 * 1024 ** 4) == "5120.0GB"
    # values just inside the rounding window promote instead of rendering
    # "1024.0" of the smaller unit
    assert _fmt_bytes(1048524) == "1023.9KB"        # 1023.949KB: stays KB
    assert _fmt_bytes(1048526) == "1.0MB"           # 1023.951KB: promotes
    assert _fmt_bytes(int(1023.96 * 1024 ** 2)) == "1.0GB"


def test_predicted_overlap_traces_lazily_without_trace_graph():
    """A schedule compiled with input_specs but trace_graph=False used to
    raise a misleading 'compiled without input_specs'; stage costs (and
    the composed-pipeline graph) must instead be traced on first use."""
    entry = cbase.REASON_WORKLOADS["nvsa"]
    cfg = entry.make_config(d=64)
    sched = cbase.compile_reason_schedule("nvsa", cfg, batch_size=2,
                                          trace_graph=False)
    assert sched.stage_costs == () and sched.graph is None
    ovl = predicted_overlap(sched, n_batches=4)
    assert ovl["speedup"] >= 1.0
    # memoized on the schedule, matching an eagerly-traced compile
    assert len(sched.stage_costs) == len(sched.stages)
    assert sched.graph is not None and sched.source == "trace"
    eager = cbase.compile_reason_schedule("nvsa", cfg, batch_size=2)
    assert predicted_overlap(eager, n_batches=4) == ovl
    # no input specs at all is still a (correctly-worded) error
    bare = compile_schedule("w", [StageSpec("s", "nn", lambda c, b: b)],
                            lambda r: r, lambda o, i: {})
    with pytest.raises(ValueError, match="without input_specs"):
        predicted_overlap(bare)
