"""Trainer / checkpoint / fault-tolerance / compression tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.tokens import SyntheticTokens, TokenPipelineConfig
from repro.distributed import compression as comp
from repro.models import lm
from repro.nn import init as nninit
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.trainer import (FailureInjector, Trainer, TrainerConfig,
                                 run_with_restarts)


def _tiny_lm():
    cfg = lm.LMConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
                      remat=False)
    params = nninit.materialize(lm.lm_spec(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _make_trainer(tmp, fail_at=None, seed=0, accum=1, quantized=False):
    cfg, params = _tiny_lm()
    loader = SyntheticTokens(TokenPipelineConfig(
        vocab_size=64, seq_len=16, global_batch=8, seed=seed))
    return Trainer(
        loss_fn=lambda p, b: lm.loss_fn(p, cfg, b),
        params=params,
        tcfg=TrainerConfig(total_steps=12, ckpt_every=4, ckpt_dir=str(tmp),
                           grad_accum=accum),
        ocfg=opt_mod.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=12,
                                 quantized_state=quantized),
        loader=loader,
        injector=FailureInjector(fail_at_step=fail_at) if fail_at else None,
    )


def test_loss_decreases(tmp_path):
    t = _make_trainer(tmp_path)
    hist = t.run(12)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first, (first, last)


def test_checkpoint_roundtrip(tmp_path):
    t = _make_trainer(tmp_path)
    t.run(4)
    t2 = _make_trainer(tmp_path)
    assert t2.try_restore()
    assert t2.step == 4
    for a, b in zip(jax.tree.leaves(t.params), jax.tree.leaves(t2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_restart_bitexact(tmp_path):
    """Uninterrupted run == failure-interrupted run with restarts."""
    ref = _make_trainer(tmp_path / "ref")
    ref.run(12)

    calls = {"n": 0}

    def make():
        calls["n"] += 1
        # fail once at step 6 (only the first incarnation)
        return _make_trainer(tmp_path / "ft", fail_at=6 if calls["n"] == 1 else None)

    t = run_with_restarts(make, total_steps=12)
    assert calls["n"] == 2  # one failure, one restart
    assert t.step == 12
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(t.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_ckpt_atomic_under_midwrite_crash(tmp_path):
    cfg, params = _tiny_lm()
    tree = {"params": params}
    ckpt.save(tmp_path, 1, tree)
    with pytest.raises(RuntimeError):
        ckpt.save(tmp_path, 2, tree, _fail_after_files=3)
    # LATEST still points at the complete step 1
    assert ckpt.latest_step(tmp_path) == 1
    restored, step = ckpt.restore(tmp_path, tree)
    assert step == 1


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint written unsharded restores onto explicit shardings."""
    cfg, params = _tiny_lm()
    ckpt.save(tmp_path, 1, params)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as PS
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, PS()), params)
    restored, _ = ckpt.restore(tmp_path, params, shardings=shardings)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert isinstance(b, jax.Array)


def test_grad_accum_equivalence(tmp_path):
    """accum=2 with half microbatch == accum=1 (same global batch)."""
    t1 = _make_trainer(tmp_path / "a", accum=1)
    t2 = _make_trainer(tmp_path / "b", accum=2)
    h1, h2 = t1.run(3), t2.run(3)
    for a, b in zip(h1, h2):
        assert abs(a["loss"] - b["loss"]) < 2e-2, (a["loss"], b["loss"])


@pytest.mark.slow
def test_quantized_adam_close_to_fp32(tmp_path):
    t1 = _make_trainer(tmp_path / "a")
    t2 = _make_trainer(tmp_path / "b", quantized=True)
    h1, h2 = t1.run(10), t2.run(10)
    # 8-bit moments must still optimize: loss decreases and tracks fp32
    assert h2[-1]["loss"] < h2[0]["loss"]
    assert abs(h1[-1]["loss"] - h2[-1]["loss"]) < 0.5


def test_straggler_hook(tmp_path):
    t = _make_trainer(tmp_path)
    t.tcfg.step_deadline_s = 0.0  # everything is a straggler
    t.run(2)
    assert len(t.straggler_log) == 2
    assert {"step", "latency_s"} <= set(t.straggler_log[0])


# -- compression --------------------------------------------------------------


def test_quantize_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, s = comp.quantize(g)
    err = np.abs(np.asarray(comp.dequantize(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_accumulates_to_truth():
    """Sum of EF-compressed grads converges to sum of true grads."""
    key = jax.random.PRNGKey(1)
    true_sum = np.zeros(64, np.float32)
    ef_sum = np.zeros(64, np.float32)
    res = {"g": jnp.zeros(64)}
    for i in range(50):
        g = {"g": jax.random.normal(jax.random.fold_in(key, i), (64,)) * 0.1}
        payload, res = comp.ef_compress_tree(g, res)
        deq = comp.ef_decompress_tree(payload)
        true_sum += np.asarray(g["g"])
        ef_sum += np.asarray(deq["g"])
    # EF guarantees the *cumulative* quantization error stays bounded by
    # one quantization step, not growing with iterations
    resid = np.abs(np.asarray(res["g"]))
    assert np.abs(true_sum - ef_sum).max() <= resid.max() + 1e-5


def test_data_pipeline_determinism():
    cfg = TokenPipelineConfig(vocab_size=64, seq_len=16, global_batch=8, seed=3)
    l1, l2 = SyntheticTokens(cfg), SyntheticTokens(cfg)
    a, _ = l1.batch(step=7, shard=1, n_shards=2)
    b, _ = l2.batch(step=7, shard=1, n_shards=2)
    np.testing.assert_array_equal(a, b)
    c, _ = l1.batch(step=8, shard=1, n_shards=2)
    assert not np.array_equal(a, c)
