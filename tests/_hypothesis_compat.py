"""Hypothesis shim: real hypothesis when installed, fixed samples otherwise.

The tier-1 container does not ship ``hypothesis``; these tests still want
property-style coverage. When the package is absent, ``@given`` expands each
strategy into a small deterministic sample (seeded per test name) and routes
it through ``pytest.mark.parametrize``, and ``@settings`` becomes a no-op.
With hypothesis installed (the ``[test]`` extra) the real decorators are
re-exported unchanged, so CI with the full env keeps true property testing.

Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import functools
import random
import zlib

import pytest

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    # number of deterministic samples drawn per @given test (kept small:
    # tier-1 must finish fast; real hypothesis explores more in CI)
    FALLBACK_EXAMPLES = 6

    class _Strategy:
        """A draw()-able stand-in for one hypothesis strategy."""

        def __init__(self, draw, edge_cases=()):
            self._draw = draw
            self._edges = tuple(edge_cases)

        def example(self, rng: random.Random, i: int):
            # lead with edge cases (hypothesis shrinks toward these), then
            # pseudo-random draws from the same seeded stream
            if i < len(self._edges):
                return self._edges[i]
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value),
                             edge_cases=(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements),
                             edge_cases=elements[:1])

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value),
                             edge_cases=(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)),
                             edge_cases=(False, True))

    st = _Strategies()

    def settings(*_args, **_kwargs):
        """Ignored in fallback mode (sample count is fixed)."""

        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        """Expand keyword strategies into a fixed parametrize grid."""

        names = sorted(strategies)

        def deco(fn):
            seed = zlib.adler32(fn.__qualname__.encode())
            rng = random.Random(seed)
            cases = [
                tuple(strategies[k].example(rng, i) for k in names)
                for i in range(FALLBACK_EXAMPLES)
            ]
            if len(names) == 1:  # pytest wants scalars for one argname
                cases = [c[0] for c in cases]

            @pytest.mark.parametrize(",".join(names), cases)
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                return fn(*args, **kwargs)

            return wrapper

        return deco
