"""Roofline-term extraction from compiled dry-run artifacts.

compute  = HLO_FLOPs / (chips × peak)        [cost_analysis]
memory   = HLO_bytes / (chips × HBM bw)      [cost_analysis]
collect. = Σ collective operand bytes / (chips × link bw × links)
           [parsed from the partitioned HLO text; collectives inside while
           (scan) bodies are multiplied by the known trip count]
"""

from __future__ import annotations

import re

from repro.launch.mesh import HW

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|s4|u4|pred|c64|c128)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-_]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_BODY_RE = re.compile(r"body=%?([\w\.\-_]+)")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, loop_trips: dict[str, int] | None = None,
                      default_trips: int = 1):
    """Returns {op_kind: bytes} with while-body collectives scaled by trips.

    ``loop_trips`` maps while-body computation name -> trip count; bodies
    not listed use ``default_trips``.
    """
    # map: computation name -> list of (kind, operand bytes)
    per_comp: dict[str, list] = {}
    body_names: set[str] = set()
    current = "__entry__"
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            current = m.group(1)
            per_comp.setdefault(current, [])
            continue
        if "while(" in line or " while " in line:
            for b in _BODY_RE.findall(line):
                body_names.add(b)
        for kind in _COLLECTIVES:
            # match the op use site: "= TYPE[...] all-reduce(OPERANDS...)"
            if f" {kind}(" in line or f"{kind}-start(" in line:
                lhs, _, rhs = line.partition(f"{kind}")
                operands = rhs.partition("(")[2]
                operands = operands.rpartition(")")[0]
                b = _shape_bytes(operands.split("),")[0] if kind ==
                                 "all-to-all" else operands)
                per_comp.setdefault(current, []).append((kind, b))
                break
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for comp, items in per_comp.items():
        trips = 1
        if comp in body_names:
            trips = (loop_trips or {}).get(comp, default_trips)
        for kind, b in items:
            out[kind] += b * trips
            counts[kind] += trips
    return out, counts


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_total: float, chips: int) -> dict:
    """All three terms in seconds (per-device quantities in, seconds out)."""
    compute = flops_per_device / HW["peak_flops_bf16"]
    memory = bytes_per_device / HW["hbm_bw"]
    collective = (collective_bytes_total / chips) / \
        (HW["ici_bw_per_link"] * HW["ici_links"])
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "bound_s": max(compute, memory, collective),
    }


# ---------------------------------------------------------------------------
# Report generation (EXPERIMENTS.md §Roofline)
# ---------------------------------------------------------------------------


def summarize(dryrun_dir=None) -> str:
    """Markdown roofline table from the dry-run JSONs (single-pod cells)."""
    import json
    import pathlib

    d = pathlib.Path(dryrun_dir) if dryrun_dir else \
        pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"
    lines = [
        "| arch | shape | dom | compute | memory | collective | "
        "MODEL/HLO | coll. mix |",
        "|---|---|---|---|---|---|---|---|",
    ]
    multi = ["", "### Multi-pod (2×16×16) deltas", "",
             "| arch | shape | status | compute | collective | note |",
             "|---|---|---|---|---|---|"]
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("tag"):
            continue  # perf A/B variants live in §Perf, not the baseline table
        if r["status"] == "skip":
            if r["mesh"] == "pod16x16":
                lines.append(f"| {r['arch']} | {r['shape']} | SKIP | — | — | — "
                             f"| — | {r['skip_reason'][:40]}… |")
            continue
        if r["status"] != "ok":
            tgt = lines if r["mesh"] == "pod16x16" else multi
            tgt.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | — "
                       f"| {r.get('error','')[:50]} |")
            continue
        t = r["roofline"]
        cb = r["collective_bytes_per_device"]
        mix = ",".join(f"{k.split('-')[-1][:4]}:{v/1e9:.1f}G"
                       for k, v in cb.items() if v > 0) or "none"
        if r["mesh"] == "pod16x16":
            lines.append(
                f"| {r['arch']} | {r['shape']} | **{t['dominant'][:4]}** | "
                f"{t['compute_s']*1e3:.1f}ms | {t['memory_s']*1e3:.1f}ms | "
                f"{t['collective_s']*1e3:.2f}ms | "
                f"{r['useful_flops_ratio']:.2f} | {mix} |")
        else:
            multi.append(
                f"| {r['arch']} | {r['shape']} | ok | "
                f"{t['compute_s']*1e3:.1f}ms | {t['collective_s']*1e3:.2f}ms | "
                f"{t['dominant']} |")
    return "\n".join(lines + multi)


if __name__ == "__main__":
    print(summarize())
