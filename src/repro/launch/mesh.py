"""Production mesh builders.

Functions (not module-level constants) so importing never touches jax
device state — the dry-run sets XLA_FLAGS before first jax init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis
    carries either extra data parallelism (default) or pipeline stages."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host CPU devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


HW = {
    # TPU v5e, per chip
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,        # bytes/s
    "ici_bw_per_link": 50e9,  # bytes/s/link (~ per direction)
    "ici_links": 4,
    "hbm_bytes": 16e9,
    "vmem_bytes": 16 * 2 ** 20,  # usable VMEM planning budget per core
}
