"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Two traffic classes:
- ``--workload lm`` (default): continuous-batching generation with the
  slot-pool engine (smoke-scale models on CPU; the decode_step is the same
  function the dry-run lowers for the 256/512-chip meshes).
- ``--workload reason``: batched RAVEN reasoning through the two-stream
  ReasonEngine (``--model nvsa|prae``), with the overlap/sequential
  schedule and Tab. IV precision knobs exposed.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.configs import base as cbase
from repro.nn import init as nninit
from repro.serve.engine import Engine, Request, ServeConfig


def serve_reason(args):
    from repro.data import raven
    from repro.models import nvsa
    from repro.serve.reason import (ReasonConfig, ReasonEngine,
                                    requests_from_batch)

    cfg = nvsa.NVSAConfig(d=args.d, nn_precision=args.nn_precision,
                          symb_precision=args.symb_precision,
                          use_qmatmul=args.nn_precision in ("int8", "int4"))
    params = nninit.materialize(nvsa.nvsa_spec(cfg), jax.random.PRNGKey(0))
    books = nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
    neural, oracle, symbolic = cbase.reason_fns(args.model, cfg)
    engine = ReasonEngine(
        neural, symbolic,
        ReasonConfig(batch_size=args.batch_size, schedule=args.schedule,
                     perception="oracle" if args.oracle else "cnn"),
        oracle_fn=oracle)

    batch = raven.generate_batch(cfg.raven, seed=0, n=args.requests)
    t0 = time.time()
    results = engine.run(params, books, requests_from_batch(batch))
    dt = time.time() - t0
    acc = np.mean([results[i].answer == batch["answer"][i]
                   for i in range(args.requests)])
    print(f"[serve] model={args.model} schedule={args.schedule} "
          f"perception={'oracle' if args.oracle else 'cnn'} "
          f"precision=nn:{args.nn_precision}/symb:{args.symb_precision}")
    print(f"[serve] {args.requests} problems in {dt:.1f}s "
          f"({args.requests / dt:.1f} problems/s, "
          f"{engine.stats['batches']} batches), accuracy {acc:.3f}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=("lm", "reason"))
    ap.add_argument("--arch", default="llama3.2-3b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--eos-id", type=int, default=None)
    # reasoning workload knobs
    ap.add_argument("--model", default="nvsa", choices=cbase.REASON_MODELS)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--schedule", default="overlap",
                    choices=("overlap", "sequential"))
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--nn-precision", default="fp32",
                    choices=("fp32", "bf16", "int8", "int4"))
    ap.add_argument("--symb-precision", default="fp32",
                    choices=("fp32", "bf16", "int8", "int4"))
    ap.add_argument("--oracle", action="store_true",
                    help="ground-truth perception (symbolic stream only)")
    args = ap.parse_args()

    if args.workload == "reason":
        return serve_reason(args)

    arch = ARCHS[args.arch]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg),
                                jax.random.PRNGKey(0))
    try:
        step, init_caches = cbase.serve_fns(arch, cfg, max_len=args.cache_len)
    except NotImplementedError as e:
        raise SystemExit(str(e))
    engine = Engine(step, init_caches, ServeConfig(
        max_new_tokens=args.max_new, max_slots=args.slots,
        max_len=args.cache_len, decode_block=args.decode_block,
        temperature=args.temperature, top_k=args.top_k, eos_id=args.eos_id))
    # (stateful_prefill for rwkv/griffin is forced by the serve_fns tag)

    vocab = cfg.vocab  # serve_fns already rejected vlm/encdec kinds
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, vocab, (args.prompt_len,)).astype(np.int32))
        for i in range(args.requests)]
    t0 = time.time()
    results = engine.run(params, reqs)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in results.values())
    print(f"[serve] arch={args.arch} requests={args.requests} "
          f"slots={args.slots} prompt={args.prompt_len} new={args.max_new}")
    print(f"[serve] {dt:.1f}s total, {toks/dt:.1f} tok/s, "
          f"slot utilization {engine.utilization():.0%} (CPU smoke config)")
    print(f"[serve] sample output ids: {results[0].tokens[:12].tolist()}")
    return results


if __name__ == "__main__":
    main()
