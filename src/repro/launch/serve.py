"""Serving launcher: ``python -m repro.launch.serve --workload <class>``.

The traffic classes (and their model lists) derive from the serving
runtime registry — ``repro.serve.runtime.TRAFFIC_CLASSES`` — not a
hand-listed tuple; adding a workload/arch there is all it takes to show
up here:

- ``--workload lm`` (default): continuous-batching generation with the
  slot-pool engine (smoke-scale models on CPU; the decode_step is the same
  function the dry-run lowers for the 256/512-chip meshes).
- ``--workload reason``: batched NSAI reasoning through the generic
  N-stage ReasonEngine.  ``--model`` choices derive from the workload
  registry (``configs.base.REASON_WORKLOADS``: nvsa, prae, mimonet, lvrf);
  the pipeline is compiled from the workload's dataflow graph by
  ``serve.schedule``, with the overlap/sequential schedule and Tab. IV
  precision knobs exposed, and a per-stage timing breakdown printed for
  the sequential schedule.
- ``--workload frontdoor``: *online mixed* serving through
  ``repro.serve.deploy`` — any mix of LM archs and NSAI workloads
  (``--models stablelm-3b,nvsa,mimonet``) behind one deadline-batched,
  shape-bucketed front-door fed by per-model Poisson arrival streams at
  ``--rate`` req/s.  The NSAI engines' serving knobs (batch buckets,
  in-flight depth, schedule) are DSE-derived from each workload's traced
  dataflow graph under ``--max-pes``; the report covers both request
  classes (tokens/s for LM rows, problems/s for NSAI rows) plus
  per-model p50/p95/p99 queueing + service latency and bucket usage.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cbase
from repro.serve import runtime as rt


def _require_devices(n: int, what: str):
    """Mesh flags need real (or faked) devices; fail with the escape hatch."""
    have = jax.device_count()
    if n > have:
        raise SystemExit(
            f"{what}={n} needs {n} devices but jax.device_count()={have} — "
            "on CPU, fake a device pool with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}")


def serve_reason(args):
    from repro.serve.reason import ReasonConfig
    from repro.serve.replica import ReplicaPool

    entry = cbase.REASON_WORKLOADS[args.model]
    cfg = entry.make_config(d=args.d, nn_precision=args.nn_precision,
                            symb_precision=args.symb_precision)
    consts = entry.make_consts(cfg, jax.random.PRNGKey(0))
    variant = "oracle" if args.oracle else entry.variants[0]
    if variant not in entry.variants:
        raise SystemExit(f"{args.model} has no {variant!r} variant "
                         f"(available: {entry.variants})")
    engine = cbase.reason_engine_pool(
        args.model, cfg,
        ReasonConfig(batch_size=args.batch_size, schedule=args.schedule,
                     variant=variant),
        consts=consts, variants=(variant,), replicas=args.replicas)
    base = engine.replicas[0] if isinstance(engine, ReplicaPool) else engine
    sched = base.schedules[variant]
    print(f"[serve] {args.model}: {sched.describe()}")
    if args.schedule == "fused":
        print(f"[serve] fused negotiation: ok={sched.fused_ok} "
              f"eq={sched.fused_equivalence} "
              f"lowering_diff={list(sched.fused_lowering_diff) or '-'}")

    stream, truth = entry.make_requests(cfg, args.requests, seed=0)
    t0 = time.time()
    results = engine.run(stream())
    dt = time.time() - t0
    acc = entry.score(results, truth())
    # report the config's *actual* precision — workloads without Tab. IV
    # knobs (mimonet, lvrf) ignore the CLI flags and run fp32
    nn_p = getattr(cfg, "nn_precision", "fp32")
    sy_p = getattr(cfg, "symb_precision", "fp32")
    if (nn_p, sy_p) != (args.nn_precision, args.symb_precision):
        print(f"[serve] note: {args.model} has no precision knobs; "
              f"requested nn:{args.nn_precision}/symb:{args.symb_precision} "
              "ignored")
    print(f"[serve] model={args.model} schedule={args.schedule} "
          f"variant={variant} precision=nn:{nn_p}/symb:{sy_p}")
    print(f"[serve] {args.requests} problems in {dt:.1f}s "
          f"({args.requests / dt:.1f} problems/s, "
          f"{engine.stats['batches']} batches), accuracy {acc:.3f}")
    if isinstance(engine, ReplicaPool):
        split = " ".join(f"r{r['replica']}:{r['groups']}g/{r['requests']}req"
                         for r in engine.per_replica())
        print(f"[serve] {len(engine)} replicas: {split}")
    if args.schedule == "sequential":
        for name, t in engine.stats["stage_time_s"].get(variant, {}).items():
            print(f"[serve]   stage {name:12s} {t:.3f}s")
    return results


def _parse_class_spec(flag: str, spec: str, scalar_ok: bool):
    """Parse ``60`` / ``interactive=60,standard=240`` style flags into a
    float or ``{class: float}`` mapping, with the error naming the flag
    and the offending token (class names validate against
    :data:`repro.serve.slo.PRIORITIES`)."""
    from repro.serve.slo import validate_priority

    spec = spec.strip()
    if "=" not in spec:
        if not scalar_ok:
            raise SystemExit(f"{flag}: expected a priority class or "
                             f"class=weight list, got {spec!r}")
        try:
            return float(spec)
        except ValueError:
            raise SystemExit(f"{flag}: expected a number or a "
                             f"class=value list, got {spec!r}") from None
    out = {}
    for part in spec.split(","):
        name, eq, val = part.partition("=")
        if not eq:
            raise SystemExit(f"{flag}: malformed entry {part!r} "
                             "(expected class=value)")
        try:
            out[validate_priority(name.strip())] = float(val)
        except ValueError as e:
            raise SystemExit(f"{flag}: {e}") from None
    return out


def serve_frontdoor(args):
    from repro.serve import SHED_POLICIES, Budget, Traffic, deploy
    from repro.serve.slo import PRIORITY_RANK

    models = rt.resolve_models(
        "frontdoor", [m.strip() for m in args.models.split(",") if m.strip()])
    nsai = {m for m in models if m in cbase.REASON_WORKLOADS}
    options = {m: {"d": args.d, "nn_precision": args.nn_precision,
                   "symb_precision": args.symb_precision,
                   **({"variant": "oracle"} if args.oracle else {})}
               for m in nsai}
    slo_ms = (None if args.slo_ms is None else
              _parse_class_spec("--slo-ms", args.slo_ms, scalar_ok=True))
    if args.shed_policy not in SHED_POLICIES:
        raise SystemExit(f"--shed-policy: unknown shed policy "
                         f"{args.shed_policy!r} (known: "
                         f"{', '.join(SHED_POLICIES)})")
    if args.queue_depth is not None and args.queue_depth < 1:
        raise SystemExit(f"--queue-depth: must be >= 1, "
                         f"got {args.queue_depth}")
    priorities = None
    if args.priority is not None:
        if "=" in args.priority:
            priorities = _parse_class_spec("--priority", args.priority,
                                           scalar_ok=False)
        elif args.priority in PRIORITY_RANK:
            priorities = args.priority
        else:
            raise SystemExit(f"--priority: unknown priority class "
                             f"{args.priority!r} (known: "
                             f"{', '.join(sorted(PRIORITY_RANK))})")
    deployment = deploy(
        models,
        traffic=Traffic(rate_rps=args.rate,
                        deadline_s=args.deadline_ms / 1e3),
        budget=Budget(max_pes=args.max_pes, max_batch=args.batch_size,
                      inflight_cap=args.max_inflight,
                      max_slots=args.slots, max_len=args.cache_len,
                      decode_block=args.decode_block,
                      max_new_tokens=args.max_new,
                      replicas=args.replicas if args.replicas != 1 else None,
                      tp=args.tp if args.tp != 1 else None,
                      slo_ms=slo_ms, queue_depth=args.queue_depth,
                      shed_policy=args.shed_policy),
        options=options, preflight=args.preflight)
    for line in deployment.summary().splitlines():
        print(f"[deploy] {line}")
    if deployment.analysis is not None:
        for f in deployment.analysis.findings:
            print(f"[preflight] {f.render()}")
    deployment.warmup()  # compile every serving shape before taking latencies
    print(f"[frontdoor] {len(models)} models x {args.requests} requests, "
          f"poisson {args.rate:.1f} req/s each, deadline "
          f"{args.deadline_ms:.0f}ms")
    arrivals, truths = deployment.synthetic_traffic(args.requests,
                                                    priorities=priorities)
    report = deployment.serve(arrivals)
    for line in report.summary().splitlines():
        print(f"[frontdoor] {line}")
    for model in sorted(truths):
        acc = cbase.REASON_WORKLOADS[model].score(report.results[model],
                                                  truths[model]())
        print(f"[frontdoor] {model} accuracy {acc:.3f}")
    return report


def serve_lm(args):
    from repro.serve.engine import Request, ServeConfig

    eng, cfg = cbase.lm_engine_pool(
        args.arch,
        ServeConfig(max_new_tokens=args.max_new, max_slots=args.slots,
                    max_len=args.cache_len, decode_block=args.decode_block,
                    temperature=args.temperature, top_k=args.top_k,
                    eos_id=args.eos_id),
        replicas=args.replicas, tp=args.tp)
    # (stateful_prefill for rwkv/griffin is forced by the serve_fns tag)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, (args.prompt_len,)).astype(np.int32))
        for i in range(args.requests)]
    t0 = time.time()
    results = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in results.values())
    print(f"[serve] arch={args.arch} requests={args.requests} "
          f"slots={args.slots} prompt={args.prompt_len} new={args.max_new}")
    from repro.serve.replica import ReplicaPool
    if isinstance(eng, ReplicaPool):
        util = " ".join(f"r{i}:{e.utilization():.0%}"
                        for i, e in enumerate(eng.replicas))
    else:
        util = f"{eng.utilization():.0%}"
    print(f"[serve] {dt:.1f}s total, {toks/dt:.1f} tok/s, "
          f"slot utilization {util} (CPU smoke config)")
    print(f"[serve] sample output ids: {results[0].tokens[:12].tolist()}")
    return results


def main():
    ap = argparse.ArgumentParser()
    # traffic classes + per-class model lists derive from the runtime
    # registry (repro.serve.runtime.TRAFFIC_CLASSES)
    ap.add_argument("--workload", default="lm",
                    choices=sorted(rt.TRAFFIC_CLASSES))
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=sorted(rt.TRAFFIC_CLASSES["lm"].models()))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--eos-id", type=int, default=None)
    # reasoning workload knobs (--model choices derive from the registry)
    ap.add_argument("--model", default="nvsa",
                    choices=sorted(rt.TRAFFIC_CLASSES["reason"].models()))
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--schedule", default="overlap",
                    choices=("overlap", "sequential", "fused"))
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--nn-precision", default="fp32",
                    choices=("fp32", "bf16", "int8", "int4"))
    ap.add_argument("--symb-precision", default="fp32",
                    choices=("fp32", "bf16", "int8", "int4"))
    ap.add_argument("--oracle", action="store_true",
                    help="ground-truth perception (symbolic stream only)")
    # online front-door knobs (--workload frontdoor, served via deploy())
    ap.add_argument("--models", default="nvsa,mimonet,lvrf",
                    help="comma list of workloads (NSAI and/or LM archs) "
                         "multiplexed behind the front-door")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="per-model Poisson offered load, req/s")
    ap.add_argument("--deadline-ms", type=float, default=20.0,
                    help="admission-group deadline after first arrival")
    ap.add_argument("--max-inflight", type=int, default=4,
                    help="cap on the DSE-derived in-flight window depth")
    ap.add_argument("--max-pes", type=int, default=4096,
                    help="AdArray PE budget handed to the DSE")
    # mesh knobs: data-parallel engine replicas + LM tensor parallelism
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas per model "
                         "(each replica's consts/params on its own device)")
    ap.add_argument("--tp", type=int, default=1,
                    help="LM tensor-parallel degree (params sharded over a "
                         "1 x tp host mesh via distributed.sharding_rules)")
    ap.add_argument("--preflight", default="error",
                    choices=("error", "warn", "off"),
                    help="static-analysis gate before serving: fail the "
                         "deploy on error findings (default), report only, "
                         "or skip")
    # overload control plane (--workload frontdoor; see repro.serve.control)
    ap.add_argument("--slo-ms", default=None,
                    help="total-latency p99 SLO: a scalar (interactive "
                         "target; standard gets 4x, batch best-effort) or "
                         "a class=ms list, e.g. interactive=60,standard=240."
                         "  Attaches the feedback controller")
    ap.add_argument("--queue-depth", type=int, default=None,
                    help="bound each model's pending queue; arrivals "
                         "beyond it shed by --shed-policy instead of "
                         "growing the queue without bound")
    ap.add_argument("--shed-policy", default="lowest-priority",
                    help="lowest-priority (evict newest lowest-class "
                         "queued work) or tail-drop (reject the arrival)")
    ap.add_argument("--priority", default=None,
                    help="traffic-class stamp for synthetic arrivals: one "
                         "class name or a class=weight mix, e.g. "
                         "interactive=3,standard=5,batch=2")
    args = ap.parse_args()

    if args.replicas < 1 or args.tp < 1:
        raise SystemExit(f"--replicas/--tp must be >= 1 "
                         f"(got {args.replicas}/{args.tp})")
    _require_devices(args.replicas, "--replicas")
    _require_devices(args.tp, "--tp")
    if args.workload == "reason":
        return serve_reason(args)
    if args.workload == "frontdoor":
        return serve_frontdoor(args)
    return serve_lm(args)


if __name__ == "__main__":
    main()
