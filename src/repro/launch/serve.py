"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Two traffic classes:
- ``--workload lm`` (default): continuous-batching generation with the
  slot-pool engine (smoke-scale models on CPU; the decode_step is the same
  function the dry-run lowers for the 256/512-chip meshes).
- ``--workload reason``: batched NSAI reasoning through the generic
  N-stage ReasonEngine.  ``--model`` choices derive from the workload
  registry (``configs.base.REASON_WORKLOADS``: nvsa, prae, mimonet, lvrf
  — adding a workload is one registry entry); the pipeline is compiled
  from the workload's dataflow graph by ``serve.schedule``, with the
  overlap/sequential schedule and Tab. IV precision knobs exposed, and a
  per-stage timing breakdown printed for the sequential schedule.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.configs import base as cbase
from repro.nn import init as nninit
from repro.serve.engine import Engine, Request, ServeConfig


def serve_reason(args):
    from repro.serve.reason import ReasonConfig

    entry = cbase.REASON_WORKLOADS[args.model]
    cfg = entry.make_config(d=args.d, nn_precision=args.nn_precision,
                            symb_precision=args.symb_precision)
    consts = entry.make_consts(cfg, jax.random.PRNGKey(0))
    variant = "oracle" if args.oracle else entry.variants[0]
    if variant not in entry.variants:
        raise SystemExit(f"{args.model} has no {variant!r} variant "
                         f"(available: {entry.variants})")
    engine = cbase.reason_engine(
        args.model, cfg,
        ReasonConfig(batch_size=args.batch_size, schedule=args.schedule,
                     variant=variant),
        consts=consts, variants=(variant,))
    sched = engine.schedules[variant]
    print(f"[serve] {args.model}: {sched.describe()}")

    stream, truth = entry.make_requests(cfg, args.requests, seed=0)
    t0 = time.time()
    results = engine.run(consts, stream())
    dt = time.time() - t0
    acc = entry.score(results, truth())
    # report the config's *actual* precision — workloads without Tab. IV
    # knobs (mimonet, lvrf) ignore the CLI flags and run fp32
    nn_p = getattr(cfg, "nn_precision", "fp32")
    sy_p = getattr(cfg, "symb_precision", "fp32")
    if (nn_p, sy_p) != (args.nn_precision, args.symb_precision):
        print(f"[serve] note: {args.model} has no precision knobs; "
              f"requested nn:{args.nn_precision}/symb:{args.symb_precision} "
              "ignored")
    print(f"[serve] model={args.model} schedule={args.schedule} "
          f"variant={variant} precision=nn:{nn_p}/symb:{sy_p}")
    print(f"[serve] {args.requests} problems in {dt:.1f}s "
          f"({args.requests / dt:.1f} problems/s, "
          f"{engine.stats['batches']} batches), accuracy {acc:.3f}")
    if args.schedule == "sequential":
        for name, t in engine.stats["stage_time_s"].items():
            print(f"[serve]   stage {name:12s} {t:.3f}s")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=("lm", "reason"))
    ap.add_argument("--arch", default="llama3.2-3b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--eos-id", type=int, default=None)
    # reasoning workload knobs (--model choices derive from the registry)
    ap.add_argument("--model", default="nvsa",
                    choices=sorted(cbase.REASON_WORKLOADS))
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--schedule", default="overlap",
                    choices=("overlap", "sequential"))
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--nn-precision", default="fp32",
                    choices=("fp32", "bf16", "int8", "int4"))
    ap.add_argument("--symb-precision", default="fp32",
                    choices=("fp32", "bf16", "int8", "int4"))
    ap.add_argument("--oracle", action="store_true",
                    help="ground-truth perception (symbolic stream only)")
    args = ap.parse_args()

    if args.workload == "reason":
        return serve_reason(args)

    arch = ARCHS[args.arch]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg),
                                jax.random.PRNGKey(0))
    try:
        step, init_caches = cbase.serve_fns(arch, cfg, max_len=args.cache_len)
    except NotImplementedError as e:
        raise SystemExit(str(e))
    engine = Engine(step, init_caches, ServeConfig(
        max_new_tokens=args.max_new, max_slots=args.slots,
        max_len=args.cache_len, decode_block=args.decode_block,
        temperature=args.temperature, top_k=args.top_k, eos_id=args.eos_id))
    # (stateful_prefill for rwkv/griffin is forced by the serve_fns tag)

    vocab = cfg.vocab  # serve_fns already rejected vlm/encdec kinds
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, vocab, (args.prompt_len,)).astype(np.int32))
        for i in range(args.requests)]
    t0 = time.time()
    results = engine.run(params, reqs)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in results.values())
    print(f"[serve] arch={args.arch} requests={args.requests} "
          f"slots={args.slots} prompt={args.prompt_len} new={args.max_new}")
    print(f"[serve] {dt:.1f}s total, {toks/dt:.1f} tok/s, "
          f"slot utilization {engine.utilization():.0%} (CPU smoke config)")
    print(f"[serve] sample output ids: {results[0].tokens[:12].tolist()}")
    return results


if __name__ == "__main__":
    main()
