"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Batched greedy generation with the continuous-batching engine (smoke-scale
models on CPU; the decode_step is the same function the dry-run lowers for
the 256/512-chip meshes).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs import base as cbase
from repro.nn import init as nninit
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    arch = ARCHS[args.arch]
    if arch.kind == "vlm":
        raise SystemExit("vlm serving requires patch-embedding inputs — "
                         "see examples/serve_lm.py for the text-LM path")
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg),
                                jax.random.PRNGKey(0))
    from repro.configs.shapes import ShapeSpec
    shape = ShapeSpec("serve", "decode", args.cache_len, args.batch)

    def init_caches(batch):
        specs, _, _ = cbase.decode_state_specs(arch, cfg, shape)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)

    step = cbase.decode_fn(arch, cfg)
    engine = Engine(step, init_caches, ServeConfig(max_new_tokens=args.max_new))
    vocab = cfg.lm.vocab if arch.kind == "vlm" else cfg.vocab
    prompts = np.random.default_rng(0).integers(
        0, vocab, (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(params, prompts)
    dt = time.time() - t0
    tok_s = args.batch * args.max_new / dt
    print(f"[serve] arch={args.arch} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.max_new}")
    print(f"[serve] {dt:.1f}s total, {tok_s:.1f} tok/s (CPU smoke config)")
    print(f"[serve] sample output ids: {out[0][:12].tolist()}")
    return out


if __name__ == "__main__":
    main()
