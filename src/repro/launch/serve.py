"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Three traffic classes:
- ``--workload lm`` (default): continuous-batching generation with the
  slot-pool engine (smoke-scale models on CPU; the decode_step is the same
  function the dry-run lowers for the 256/512-chip meshes).
- ``--workload reason``: batched NSAI reasoning through the generic
  N-stage ReasonEngine.  ``--model`` choices derive from the workload
  registry (``configs.base.REASON_WORKLOADS``: nvsa, prae, mimonet, lvrf
  — adding a workload is one registry entry); the pipeline is compiled
  from the workload's dataflow graph by ``serve.schedule``, with the
  overlap/sequential schedule and Tab. IV precision knobs exposed, and a
  per-stage timing breakdown printed for the sequential schedule.
- ``--workload frontdoor``: *online* NSAI serving — several workload
  engines (``--models nvsa,mimonet,lvrf``) multiplexed behind one
  deadline-batched, shape-bucketed front-door (``serve.frontdoor``) fed
  by per-model Poisson arrival streams at ``--rate`` req/s; reports
  per-model p50/p95/p99 queueing + service latency and bucket usage.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.configs import base as cbase
from repro.nn import init as nninit
from repro.serve.engine import Engine, Request, ServeConfig


def serve_reason(args):
    from repro.serve.reason import ReasonConfig

    entry = cbase.REASON_WORKLOADS[args.model]
    cfg = entry.make_config(d=args.d, nn_precision=args.nn_precision,
                            symb_precision=args.symb_precision)
    consts = entry.make_consts(cfg, jax.random.PRNGKey(0))
    variant = "oracle" if args.oracle else entry.variants[0]
    if variant not in entry.variants:
        raise SystemExit(f"{args.model} has no {variant!r} variant "
                         f"(available: {entry.variants})")
    engine = cbase.reason_engine(
        args.model, cfg,
        ReasonConfig(batch_size=args.batch_size, schedule=args.schedule,
                     variant=variant),
        consts=consts, variants=(variant,))
    sched = engine.schedules[variant]
    print(f"[serve] {args.model}: {sched.describe()}")

    stream, truth = entry.make_requests(cfg, args.requests, seed=0)
    t0 = time.time()
    results = engine.run(consts, stream())
    dt = time.time() - t0
    acc = entry.score(results, truth())
    # report the config's *actual* precision — workloads without Tab. IV
    # knobs (mimonet, lvrf) ignore the CLI flags and run fp32
    nn_p = getattr(cfg, "nn_precision", "fp32")
    sy_p = getattr(cfg, "symb_precision", "fp32")
    if (nn_p, sy_p) != (args.nn_precision, args.symb_precision):
        print(f"[serve] note: {args.model} has no precision knobs; "
              f"requested nn:{args.nn_precision}/symb:{args.symb_precision} "
              "ignored")
    print(f"[serve] model={args.model} schedule={args.schedule} "
          f"variant={variant} precision=nn:{nn_p}/symb:{sy_p}")
    print(f"[serve] {args.requests} problems in {dt:.1f}s "
          f"({args.requests / dt:.1f} problems/s, "
          f"{engine.stats['batches']} batches), accuracy {acc:.3f}")
    if args.schedule == "sequential":
        for name, t in engine.stats["stage_time_s"].get(variant, {}).items():
            print(f"[serve]   stage {name:12s} {t:.3f}s")
    return results


def serve_frontdoor(args):
    from repro.serve import frontdoor as fd
    from repro.serve.reason import ReasonConfig

    models = [m.strip() for m in args.models.split(",") if m.strip()]
    buckets = fd.pow2_buckets(args.batch_size)
    engines, consts, streams, truths = {}, {}, [], {}
    for i, model in enumerate(models):
        entry = cbase.REASON_WORKLOADS[model]
        cfg = entry.make_config(d=args.d, nn_precision=args.nn_precision,
                                symb_precision=args.symb_precision)
        variant = "oracle" if args.oracle else entry.variants[0]
        if variant not in entry.variants:
            raise SystemExit(f"{model} has no {variant!r} variant "
                             f"(available: {entry.variants})")
        c = entry.make_consts(cfg, jax.random.PRNGKey(i))
        eng = cbase.reason_engine(
            model, cfg,
            ReasonConfig(batch_size=args.batch_size, schedule=args.schedule,
                         variant=variant, buckets=buckets,
                         max_inflight=args.max_inflight),
            consts=c, variants=(variant,), trace_graph=False)
        for b in buckets:  # compile every bucket before taking latencies
            warm, _ = entry.make_requests(cfg, b, seed=5000 + b)
            eng.run(c, warm())
        engines[model], consts[model] = eng, c
        stream, truth = entry.make_requests(cfg, args.requests, seed=100 + i)
        truths[model] = truth
        streams.append(fd.poisson_arrivals(model, stream(), args.rate,
                                           seed=i))
        print(f"[frontdoor] {model}/{variant}: "
              f"{eng.schedules[variant].describe()}")
    door = fd.FrontDoor(engines, consts, fd.FrontDoorConfig(
        deadline_s=args.deadline_ms / 1e3, schedule=args.schedule))
    print(f"[frontdoor] {len(models)} models x {args.requests} requests, "
          f"poisson {args.rate:.1f} req/s each, deadline "
          f"{args.deadline_ms:.0f}ms, buckets {buckets}, "
          f"max_inflight={args.max_inflight}")
    report = door.serve(fd.merge_arrivals(*streams))
    for line in report.summary().splitlines():
        print(f"[frontdoor] {line}")
    for model in models:
        acc = cbase.REASON_WORKLOADS[model].score(report.results[model],
                                                  truths[model]())
        print(f"[frontdoor] {model} accuracy {acc:.3f}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm",
                    choices=("lm", "reason", "frontdoor"))
    ap.add_argument("--arch", default="llama3.2-3b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--eos-id", type=int, default=None)
    # reasoning workload knobs (--model choices derive from the registry)
    ap.add_argument("--model", default="nvsa",
                    choices=sorted(cbase.REASON_WORKLOADS))
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--schedule", default="overlap",
                    choices=("overlap", "sequential"))
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--nn-precision", default="fp32",
                    choices=("fp32", "bf16", "int8", "int4"))
    ap.add_argument("--symb-precision", default="fp32",
                    choices=("fp32", "bf16", "int8", "int4"))
    ap.add_argument("--oracle", action="store_true",
                    help="ground-truth perception (symbolic stream only)")
    # online front-door knobs (--workload frontdoor)
    ap.add_argument("--models", default="nvsa,mimonet,lvrf",
                    help="comma list of workloads multiplexed behind the "
                         "front-door")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="per-model Poisson offered load, req/s")
    ap.add_argument("--deadline-ms", type=float, default=20.0,
                    help="admission-group deadline after first arrival")
    ap.add_argument("--max-inflight", type=int, default=1,
                    help="dispatched-but-undrained groups per engine")
    args = ap.parse_args()

    if args.workload == "reason":
        return serve_reason(args)
    if args.workload == "frontdoor":
        return serve_frontdoor(args)

    arch = ARCHS[args.arch]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg),
                                jax.random.PRNGKey(0))
    try:
        step, init_caches = cbase.serve_fns(arch, cfg, max_len=args.cache_len)
    except NotImplementedError as e:
        raise SystemExit(str(e))
    engine = Engine(step, init_caches, ServeConfig(
        max_new_tokens=args.max_new, max_slots=args.slots,
        max_len=args.cache_len, decode_block=args.decode_block,
        temperature=args.temperature, top_k=args.top_k, eos_id=args.eos_id))
    # (stateful_prefill for rwkv/griffin is forced by the serve_fns tag)

    vocab = cfg.vocab  # serve_fns already rejected vlm/encdec kinds
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, vocab, (args.prompt_len,)).astype(np.int32))
        for i in range(args.requests)]
    t0 = time.time()
    results = engine.run(params, reqs)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in results.values())
    print(f"[serve] arch={args.arch} requests={args.requests} "
          f"slots={args.slots} prompt={args.prompt_len} new={args.max_new}")
    print(f"[serve] {dt:.1f}s total, {toks/dt:.1f} tok/s, "
          f"slot utilization {engine.utilization():.0%} (CPU smoke config)")
    print(f"[serve] sample output ids: {results[0].tokens[:12].tolist()}")
    return results


if __name__ == "__main__":
    main()
