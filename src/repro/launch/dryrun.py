import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 host placeholder devices, lowers the appropriate
step (train_step incl. optimizer / prefill / decode) with full shardings,
compiles, and records memory_analysis + cost_analysis + the parsed
collective schedule to JSON for §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod-only]
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs import ARCHS, SHAPES
from repro.configs import base as cbase
from repro.distributed import sharding_rules as rules
from repro.launch import roofline as rl
from repro.common import util
from repro.launch.mesh import make_production_mesh, HW
from repro.nn import init as nninit
from repro.train import optimizer as opt

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _skip_reason(arch, shape) -> str | None:
    if shape.name == "long_500k" and not arch.supports_long:
        return ("skipped: pure full-attention arch at 524k context "
                "(sub-quadratic required; see DESIGN.md §4)")
    return None


def _opt_state_shardings(state_shapes, param_shardings_tree, mesh):
    """Moments inherit the parameter sharding; quantized blocks shard their
    leading (blocks) dim over data when divisible, else replicate."""

    def for_param(ps, mu):
        if "m" in mu:  # fp32 moments: same sharding as the parameter
            return {"m": ps, "v": ps}
        # quantized moments: flat (blocks, qblock) — ZeRO-shard the block dim
        # across as many mesh axes as divide it (data×model when possible)
        nb = mu["m_q"].shape[0]
        axes = [a for a in ("data", "model", "pod") if a in mesh.shape]
        best: tuple = ()
        size = 1
        for a in axes:
            if nb % (size * mesh.shape[a]) == 0:
                best = best + (a,)
                size *= mesh.shape[a]
        spec = PS(best) if best else PS()
        qs = NamedSharding(mesh, spec)
        return {"m_q": qs, "m_s": qs, "v_q": qs, "v_s": qs}

    mu = jax.tree.map(for_param, param_shardings_tree, state_shapes["mu"],
                      is_leaf=lambda x: isinstance(x, NamedSharding))
    return {"mu": mu, "step": NamedSharding(mesh, PS())}


def _batch_shardings(batch_specs, mesh):
    daxes = rules.data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))

    def one(s):
        # batch-1 (long_500k) cells replicate the batch dim (SP shards the
        # cache sequence dim over the model axis instead)
        lead = daxes if (s.shape and s.shape[0] % dsize == 0) else None
        return NamedSharding(mesh, PS(lead, *([None] * (len(s.shape) - 1))))

    return jax.tree.map(one, batch_specs)


def _scale_config(arch, cfg, reps: int):
    """Rebuild the arch config with the scanned body at ``reps`` repetitions
    (calibration for XLA CPU cost_analysis, which counts while bodies once)."""
    import dataclasses as dc
    # scan_unroll >= reps removes the while loop entirely so cost_analysis
    # sees every layer (XLA CPU neither multiplies nor even counts bodies).
    if arch.kind == "vlm":
        return dc.replace(cfg, lm=_scale_config_lm(cfg.lm, reps))
    if arch.kind == "lm":
        return _scale_config_lm(cfg, reps)
    if arch.kind == "rwkv":
        return dc.replace(cfg, n_layers=reps, scan_unroll=max(2, reps))
    if arch.kind == "griffin":
        unit, reps0, tail = cfg.plan()
        return dc.replace(cfg, n_layers=len(unit) * reps + len(tail),
                          scan_unroll=max(2, reps))
    if arch.kind == "encdec":
        return dc.replace(cfg, n_enc_layers=reps, n_dec_layers=reps,
                          scan_unroll=max(2, reps))
    return cfg


def _scale_config_lm(cfg, reps: int):
    import dataclasses as dc
    from repro.models.lm import stage_plan
    plan = stage_plan(cfg)
    n = len(plan.prefix) + len(plan.unit) * reps + len(plan.tail)
    return dc.replace(cfg, n_layers=n, scan_unroll=max(2, reps))


def build_cell(arch_id: str, shape_name: str, multi_pod: bool, cfg=None):
    """Returns (fn, example_args (SDS), in_shardings, out_shardings, meta)."""
    arch = ARCHS[arch_id]
    shape = SHAPES[shape_name]
    cfg = cfg or arch.make_full()
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = cbase.model_spec(arch, cfg)
    param_shapes = nninit.shapes(spec)
    param_shard = rules.param_shardings(spec, mesh, fsdp=arch.fsdp)
    meta = {
        "params": nninit.param_count(spec),
        "active_params": cbase.active_param_count(arch, cfg),
        "param_bytes": nninit.param_bytes(spec),
    }

    if shape.kind == "train":
        ocfg = opt.AdamWConfig(quantized_state=arch.opt_8bit)
        state_shapes = opt.state_shapes(param_shapes, ocfg)
        state_shard = _opt_state_shardings(state_shapes, param_shard, mesh)
        batch_specs = cbase.train_batch_specs(arch, cfg, shape)
        batch_shard = _batch_shardings(batch_specs, mesh)
        loss = cbase.loss_fn(arch, cfg)

        def train_step(params, state, batch):
            lv, grads = jax.value_and_grad(loss)(params, batch)
            params, state, metrics = opt.apply_updates(params, grads, state, ocfg)
            return params, state, {"loss": lv, **metrics}

        fn = train_step
        args = (param_shapes, state_shapes, batch_specs)
        in_sh = (param_shard, state_shard, batch_shard)
        out_sh = (param_shard, state_shard,
                  {"loss": NamedSharding(mesh, PS()),
                   "grad_norm": NamedSharding(mesh, PS()),
                   "lr": NamedSharding(mesh, PS())})
        donate = (0, 1)
    elif shape.kind == "prefill":
        fn = cbase.prefill_fn(arch, cfg)
        inp = cbase.prefill_input_specs(arch, cfg, shape)
        in_sh = (param_shard, *(_batch_shardings(i, mesh) for i in inp))
        args = (param_shapes, *inp)
        out_sh = None
        donate = ()
    else:  # decode
        caches, token, pos = cbase.decode_state_specs(arch, cfg, shape)
        cache_shard = rules.tree_cache_shardings(caches, mesh)
        fn = cbase.decode_fn(arch, cfg)
        args = (param_shapes, caches, token, pos)
        in_sh = (param_shard, cache_shard,
                 _batch_shardings(token, mesh), NamedSharding(mesh, PS()))
        out_sh = (cache_shard, None)
        donate = (1,)
    return fn, args, in_sh, out_sh, donate, meta, mesh, cfg, arch, shape


def _loop_trips(arch, cfg) -> int:
    if arch.kind == "lm" or arch.kind == "vlm":
        from repro.models.lm import stage_plan
        return stage_plan(cfg.lm if arch.kind == "vlm" else cfg).repeats
    if arch.kind == "rwkv":
        return cfg.n_layers
    if arch.kind == "griffin":
        return cfg.plan()[1]
    if arch.kind == "encdec":
        return cfg.n_dec_layers
    return 1


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path = RESULTS_DIR, verbose: bool = True,
             cfg_transform=None, tag: str = "") -> dict:
    """``cfg_transform``: optional fn(cfg) -> cfg applied to the full config
    (perf hillclimbing A/B cells; results tagged with ``tag``)."""
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch_id}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = out_dir / f"{cell}.json"
    arch, shape = ARCHS[arch_id], SHAPES[shape_name]
    reason = _skip_reason(arch, shape)
    record: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                    "status": "skip", "skip_reason": reason, "tag": tag}
    if reason:
        out_dir.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(record, indent=1))
        if verbose:
            print(f"[dryrun] {cell}: SKIP ({reason})")
        return record
    t0 = time.time()
    try:
        cfg0 = ARCHS[arch_id].make_full()
        if cfg_transform is not None:
            cfg0 = cfg_transform(cfg0)
        fn, args, in_sh, out_sh, donate, meta, mesh, cfg, arch, shape = \
            build_cell(arch_id, shape_name, multi_pod, cfg=cfg0)
        chips = int(np.prod(list(mesh.shape.values())))
        with util.mesh_context(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        trips = _loop_trips(arch, cfg)
        coll_bytes, coll_counts = rl.parse_collectives(hlo, default_trips=trips)
        total_coll = sum(coll_bytes.values())
        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
        # XLA CPU cost_analysis counts while (scan) bodies ONCE — calibrate
        # with reps=1 and reps=2 compiles and extrapolate (exact: every
        # scanned quantity is linear in reps).
        calibration = None
        if trips > 1:
            costs = []
            for reps in (1, 2):
                c_cfg = _scale_config(arch, cfg, reps)
                f1, a1, i1, o1, d1, *_ = build_cell(arch_id, shape_name,
                                                    multi_pod, cfg=c_cfg)
                with util.mesh_context(mesh):
                    cal = jax.jit(f1, in_shardings=i1, out_shardings=o1,
                                  donate_argnums=d1).lower(*a1).compile()
                cc = cal.cost_analysis() or {}
                costs.append((float(cc.get("flops", 0.0)),
                              float(cc.get("bytes accessed", 0.0))))
            df = costs[1][0] - costs[0][0]
            db = costs[1][1] - costs[0][1]
            # clamp at the rep1 measurement: a negative per-layer delta is
            # CPU cost-analysis noise, not negative work
            flops_dev = max(costs[0][0], costs[0][0] + df * (trips - 1))
            bytes_dev = max(costs[0][1], costs[0][1] + db * (trips - 1))
            calibration = {"rep1": costs[0], "rep2": costs[1], "trips": trips}
        # MODEL_FLOPS: 6·N_active·D per step (train ≈ 3 passes -> 6ND; decode 2ND)
        tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                       (shape.seq_len if shape.kind == "prefill" else 1))
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * meta["active_params"] * tokens
        mem_fields = {}
        for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "temp_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                mem_fields[f] = int(v)
        # memory-term floor: every argument is read at least once per step;
        # XLA-CPU cost analysis misses scan-body (per-layer) param reads
        bytes_dev = max(bytes_dev, float(mem_fields.get(
            "argument_size_in_bytes", 0)))
        terms = rl.roofline_terms(flops_dev, bytes_dev, total_coll * chips,
                                  chips)
        record.update({
            "status": "ok",
            "chips": chips,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "meta": meta,
            "cost_analysis": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
            "memory_analysis": mem_fields,
            "collective_bytes_per_device": {k: float(v) for k, v in coll_bytes.items()},
            "collective_counts": coll_counts,
            "calibration": calibration,
            "loop_trips": trips,
            "roofline": terms,
            "model_flops_total": model_flops,
            "model_flops_per_device": model_flops / chips,
            "useful_flops_ratio": (model_flops / chips) / max(1.0, flops_dev),
        })
        if verbose:
            print(f"[dryrun] {cell}: OK lower {t_lower:.0f}s compile "
                  f"{t_compile:.0f}s | flops/dev {flops_dev:.3e} bytes/dev "
                  f"{bytes_dev:.3e} coll/dev {total_coll:.3e} | "
                  f"dominant={terms['dominant']} bound={terms['bound_s']*1e3:.2f}ms")
            print("  memory_analysis:", mem_fields)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        record.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
        if verbose:
            print(f"[dryrun] {cell}: ERROR {type(e).__name__}: {e}")
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=1))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    n_ok = n_err = 0
    for a in archs:
        for s in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                p = out_dir / f"{a}__{s}__{mesh_name}.json"
                if args.skip_existing and p.exists():
                    st = json.loads(p.read_text()).get("status")
                    if st in ("ok", "skip"):
                        continue
                rec = run_cell(a, s, mp, out_dir)
                n_ok += rec["status"] in ("ok", "skip")
                n_err += rec["status"] == "error"
    print(f"[dryrun] done: {n_ok} ok/skip, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
