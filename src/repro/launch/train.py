"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real training loop on the local devices (CPU here; the identical
program runs on a TPU slice — shardings come from the same rules as the
dry-run). Includes checkpoint/restart and the synthetic token pipeline.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax

from repro.configs import ARCHS
from repro.configs import base as cbase
from repro.data.tokens import SyntheticTokens, TokenPipelineConfig
from repro.nn import init as nninit
from repro.train import optimizer as opt_mod
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (full configs need a TPU slice)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args()

    arch = ARCHS[args.arch]
    if arch.kind not in ("lm", "rwkv", "griffin"):
        raise SystemExit(f"{args.arch}: token-LM training only in this driver "
                         "(vlm/encdec need modality batches — see examples/)")
    cfg = arch.make_smoke() if args.smoke else arch.make_full()
    spec = cbase.model_spec(arch, cfg)
    params = nninit.materialize(spec, jax.random.PRNGKey(0))
    n_params = nninit.param_count(spec)
    print(f"[train] arch={args.arch} params={n_params/1e6:.2f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    loader = SyntheticTokens(TokenPipelineConfig(
        vocab_size=cfg.vocab, seq_len=args.seq,
        global_batch=args.batch * args.accum, seed=0))
    trainer = Trainer(
        loss_fn=cbase.loss_fn(arch, cfg), params=params,
        tcfg=TrainerConfig(total_steps=args.steps, ckpt_every=max(10, args.steps // 5),
                           ckpt_dir=args.ckpt_dir, grad_accum=args.accum),
        ocfg=opt_mod.AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                                 total_steps=args.steps,
                                 quantized_state=arch.opt_8bit),
        loader=loader)
    if args.resume and trainer.try_restore():
        print(f"[train] resumed from step {trainer.step}")
    t0 = time.time()
    hist = trainer.run()
    dt = time.time() - t0
    print(f"[train] done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"in {dt:.0f}s ({dt/len(hist):.2f}s/step)")
    if args.metrics_out:
        p = pathlib.Path(args.metrics_out)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(hist, indent=1))
    return hist


if __name__ == "__main__":
    main()
