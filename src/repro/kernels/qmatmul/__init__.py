from repro.kernels.qmatmul import ops, ref

__all__ = ["ops", "ref"]
