"""Pure-jnp oracle for the qmatmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def unpack_int4_ref(w: jax.Array) -> jax.Array:
    low = jax.lax.shift_right_arithmetic(jax.lax.shift_left(w, jnp.int8(4)), jnp.int8(4))
    high = jax.lax.shift_right_arithmetic(w, jnp.int8(4))
    return jnp.stack([low, high], axis=-1).reshape(w.shape[0], w.shape[1] * 2)


def qmatmul_ref(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                w_scale: jax.Array, int4: bool = False,
                out_dtype=jnp.float32) -> jax.Array:
    if int4:
        w_q = unpack_int4_ref(w_q)
    acc = jax.lax.dot_general(
        x_q, w_q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return (acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]).astype(out_dtype)
