"""Public quantized-matmul API: quantize helpers + registry-driven dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.backend import registry
from repro.kernels.qmatmul import kernel, ref


def quantize_rows(x: jax.Array, bits: int = 8):
    """Symmetric per-row quantization. x: (M, K) -> (q int8, scale (M,) f32)."""
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale[:, 0]


def quantize_cols(w: jax.Array, bits: int = 8):
    """Symmetric per-column quantization. w: (K, N) -> (q int8, scale (N,))."""
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0, keepdims=True)
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale[0]


def pack_int4(q: jax.Array) -> jax.Array:
    """(K, N) int8 values in [-8, 7] -> (K, ceil(N/2)) packed (low nibble first)."""
    k, n = q.shape
    if n % 2:
        q = jnp.pad(q, ((0, 0), (0, 1)))
        n += 1
    pairs = q.reshape(k, n // 2, 2)
    low = pairs[..., 0] & 0x0F
    high = jax.lax.shift_left(pairs[..., 1], jnp.int8(4))
    return (low | high).astype(jnp.int8)


def qmatmul(x_q, w_q, x_scale, w_scale, int4: bool = False, out_dtype=jnp.float32,
            use_kernel: bool | None = None, **block_kw):
    """``use_kernel`` forces the path explicitly; None (default) consults
    the active :class:`~repro.backend.registry.LoweringPlan`."""
    plan = registry.get_plan()
    low = plan.select("qmatmul")
    if use_kernel is None:
        use_kernel = not low.is_ref
    if use_kernel:
        return kernel.qmatmul(x_q, w_q, x_scale, w_scale, int4=int4,
                              interpret=plan.run_interpret(low),
                              out_dtype=out_dtype, **block_kw)
    return ref.qmatmul_ref(x_q, w_q, x_scale, w_scale, int4=int4, out_dtype=out_dtype)


def qdense(x: jax.Array, w: jax.Array, bits_x: int = 8, bits_w: int = 8,
           out_dtype=jnp.bfloat16, use_kernel: bool | None = None) -> jax.Array:
    """Quantize-on-the-fly dense layer: x (M, K) f, w (K, N) f -> (M, N)."""
    n = w.shape[1]
    x_q, x_s = quantize_rows(x, bits_x)
    w_q, w_s = quantize_cols(w, bits_w)
    int4 = bits_w == 4
    if int4:
        w_q = pack_int4(w_q)
        if n % 2:
            w_s = jnp.pad(w_s, (0, 1))
    out = qmatmul(x_q, w_q, x_s, w_s, int4=int4, out_dtype=out_dtype,
                  use_kernel=use_kernel)
    return out[:, :n]
