"""Pallas TPU kernel: mixed-precision (int8 / packed-int4) matmul.

TPU adaptation of NSFlow Sec IV-D (adaptive compute for mixed precision):
the MXU natively multiplies int8 at 2× bf16 rate; int4 operands are stored
packed two-per-byte in HBM (halving the memory-bound symbolic stream's
traffic — the same goal as the paper's DSP packing trick [30]) and unpacked
to int8 in VMEM right before the dot.

Layout:  y[m, n] = (Σ_k x_q[m, k] · w_q[k, n]) · x_scale[m] · w_scale[n]

Grid (M/bm, N/bn, K/bk); int32 accumulation in a VMEM scratch tile carried
across the K grid dimension, scales applied on the last K step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def unpack_int4(w: jax.Array) -> jax.Array:
    """(K, N//2) int8, two nibbles per byte -> (K, N) int8 in [-8, 7]."""
    low = jax.lax.shift_right_arithmetic(jax.lax.shift_left(w, jnp.int8(4)), jnp.int8(4))
    high = jax.lax.shift_right_arithmetic(w, jnp.int8(4))
    return jnp.stack([low, high], axis=-1).reshape(w.shape[0], w.shape[1] * 2)


def _qmm_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, n_k: int,
                int4: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...]
    if int4:
        w = unpack_int4(w)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        scale = xs_ref[...][:, None] * ws_ref[...][None, :]
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("int4", "interpret", "bm", "bn", "bk",
                                             "out_dtype"))
def qmatmul(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array, w_scale: jax.Array,
            *, int4: bool = False, interpret: bool = True, bm: int = 128,
            bn: int = 128, bk: int = 128, out_dtype=jnp.float32) -> jax.Array:
    """x_q: (M, K) int8; w_q: (K, N) int8 — or (K, N//2) packed when int4.

    x_scale: (M,) f32 per-row; w_scale: (N,) f32 per-column. -> (M, N).
    """
    m, k = x_q.shape
    n = w_q.shape[1] * (2 if int4 else 1)
    bm, bk = min(bm, m), min(bk, k)
    bn = min(bn, n)
    if int4 and bn % 2:
        bn += 1
    pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-k) % bk
    if pad_m or pad_k:
        x_q = jnp.pad(x_q, ((0, pad_m), (0, pad_k)))
        x_scale = jnp.pad(x_scale, (0, pad_m))
    if pad_k or pad_n:
        w_q = jnp.pad(w_q, ((0, pad_k), (0, pad_n // 2 if int4 else pad_n)))
        w_scale = jnp.pad(w_scale, (0, pad_n))
    mm, nn, kk = m + pad_m, n + pad_n, k + pad_k
    n_k = kk // bk
    wbn = bn // 2 if int4 else bn
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, n_k=n_k, int4=int4),
        name=f"qmm_int{4 if int4 else 8}",
        grid=(mm // bm, nn // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, q: (i, q)),
            pl.BlockSpec((bk, wbn), lambda i, j, q: (q, j)),
            pl.BlockSpec((bm,), lambda i, j, q: (i,)),
            pl.BlockSpec((bn,), lambda i, j, q: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, q: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
    return out[:m, :n]
