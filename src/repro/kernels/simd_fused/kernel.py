"""Pallas TPU kernel: fused VSA similarity chain (the paper's SIMD unit).

NSFlow's custom SIMD unit (Sec IV-E) exists because the symbolic
similarity/reduction chain — blockwise normalize → dot against a dictionary
→ scale → softmax — is memory-bound: run as separate XLA ops it makes one
HBM round-trip per stage. This kernel is the TPU analogue: one VMEM pass
per query tile computing ``match_prob`` end-to-end (paper Listing 1's
``match_prob_multi_batched`` + ``sum``/``clamp`` epilogue).

Grid: (N / tile_n,). The dictionary (M entries) is small in NSAI workloads
(rule/attribute codebooks), so it lives in VMEM for the whole call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _match_prob_kernel(q_ref, d_ref, o_ref, *, temp: float, blocks: int):
    q = q_ref[...].astype(jnp.float32)  # (tn, B, d)
    dic = d_ref[...].astype(jnp.float32)  # (M, B, d)
    # blockwise L2 normalize
    qn = q * jax.lax.rsqrt(jnp.sum(q * q, axis=-1, keepdims=True) + 1e-18)
    dn = dic * jax.lax.rsqrt(jnp.sum(dic * dic, axis=-1, keepdims=True) + 1e-18)
    tn = q.shape[0]
    m = dic.shape[0]
    # mean blockwise cosine == flat dot / blocks
    sims = jax.lax.dot_general(
        qn.reshape(tn, -1), dn.reshape(m, -1),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) / blocks
    z = sims / temp
    z = z - jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("temp", "interpret", "tile_n"))
def fused_match_prob(q: jax.Array, dictionary: jax.Array, temp: float = 1.0,
                     *, interpret: bool = True, tile_n: int = 128) -> jax.Array:
    """q: (N, B, d), dictionary: (M, B, d) -> probs (N, M)."""
    n, b, d = q.shape
    m = dictionary.shape[0]
    tn = min(tile_n, max(8, n))
    pad = (-n) % tn
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_match_prob_kernel, temp=temp, blocks=b),
        name="fused_match_prob",
        grid=((n + pad) // tn,),
        in_specs=[
            pl.BlockSpec((tn, b, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((m, b, d), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tn, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, m), jnp.float32),
        interpret=interpret,
    )(q, dictionary)
    return out[:n]
