"""Pure-jnp oracle for the fused match_prob kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_match_prob_ref(q: jax.Array, dictionary: jax.Array,
                         temp: float = 1.0) -> jax.Array:
    qf = q.astype(jnp.float32)
    df = dictionary.astype(jnp.float32)
    qn = qf / jnp.maximum(jnp.linalg.norm(qf, axis=-1, keepdims=True), 1e-9)
    dn = df / jnp.maximum(jnp.linalg.norm(df, axis=-1, keepdims=True), 1e-9)
    sims = jnp.einsum("nbd,mbd->nm", qn, dn) / q.shape[-2]
    return jax.nn.softmax(sims / temp, axis=-1)
