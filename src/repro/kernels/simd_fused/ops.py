"""Public wrapper for the fused SIMD-unit kernel."""

from __future__ import annotations

import jax

from repro.kernels.simd_fused import kernel, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _fused_kernel(q, dictionary, temp):
    return kernel.fused_match_prob(q, dictionary, temp, interpret=_interpret())


def _fused_fwd(q, dictionary, temp):
    out = kernel.fused_match_prob(q, dictionary, temp, interpret=_interpret())
    return out, (q, dictionary)


def _fused_bwd(temp, res, g):
    # backward through the (cheap) reference chain — forward stays fused
    q, dictionary = res
    _, vjp = jax.vjp(lambda qq, dd: ref.fused_match_prob_ref(qq, dd, temp),
                     q, dictionary)
    return vjp(g)


_fused_kernel.defvjp(_fused_fwd, _fused_bwd)


def fused_match_prob(q: jax.Array, dictionary: jax.Array, temp: float = 1.0,
                     use_kernel: bool = True) -> jax.Array:
    if use_kernel:
        return _fused_kernel(q, dictionary, temp)
    return ref.fused_match_prob_ref(q, dictionary, temp)
