"""Public wrapper for the fused SIMD-unit kernel (registry-driven dispatch)."""

from __future__ import annotations

import functools

import jax

from repro.backend import registry
from repro.kernels.simd_fused import kernel, ref


def _run_kernel(q, dictionary, temp):
    plan = registry.get_plan()
    low = plan.select("simd_fused", size=q.shape[-1])
    if low.is_ref:
        return ref.fused_match_prob_ref(q, dictionary, temp)
    return kernel.fused_match_prob(q, dictionary, temp,
                                   interpret=plan.run_interpret(low))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _fused_kernel(q, dictionary, temp):
    return _run_kernel(q, dictionary, temp)


def _fused_fwd(q, dictionary, temp):
    out = _run_kernel(q, dictionary, temp)
    return out, (q, dictionary)


def _fused_bwd(temp, res, g):
    # backward through the (cheap) reference chain — forward stays fused
    q, dictionary = res
    _, vjp = jax.vjp(lambda qq, dd: ref.fused_match_prob_ref(qq, dd, temp),
                     q, dictionary)
    return vjp(g)


_fused_kernel.defvjp(_fused_fwd, _fused_bwd)


def fused_match_prob(q: jax.Array, dictionary: jax.Array, temp: float = 1.0,
                     use_kernel: bool | None = None) -> jax.Array:
    """``use_kernel`` forces the path explicitly; None (default) consults
    the active :class:`~repro.backend.registry.LoweringPlan`."""
    if use_kernel is None:
        use_kernel = not registry.active("simd_fused",
                                         size=q.shape[-1]).is_ref
    if use_kernel:
        return _fused_kernel(q, dictionary, temp)
    return ref.fused_match_prob_ref(q, dictionary, temp)
