from repro.kernels.simd_fused import ops, ref

__all__ = ["ops", "ref"]
