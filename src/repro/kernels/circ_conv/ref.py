"""Pure-jnp oracle for the circ_conv kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def circ_elem_ref(x: jax.Array, y: jax.Array, mode: str = "conv") -> jax.Array:
    """x, y: (N, B, d) -> (N, B, d). Exact gather formulation."""
    d = x.shape[-1]
    n = jnp.arange(d)[:, None]
    k = jnp.arange(d)[None, :]
    idx = (n - k) % d if mode == "conv" else (n + k) % d
    ymat = y[..., idx]  # (N, B, d, d)
    return jnp.einsum("...k,...nk->...n", x.astype(jnp.float32),
                      ymat.astype(jnp.float32)).astype(x.dtype)


def circ_dict_ref(x: jax.Array, dictionary: jax.Array, mode: str = "conv") -> jax.Array:
    """x: (N, B, d), dictionary: (M, B, d) -> (N, B, M, d)."""
    d = x.shape[-1]
    n = jnp.arange(d)[:, None]
    k = jnp.arange(d)[None, :]
    idx = (n - k) % d if mode == "conv" else (n + k) % d
    dmat = dictionary[..., idx]  # (M, B, d, d)
    return jnp.einsum("xbk,mbnk->xbmn", x.astype(jnp.float32),
                      dmat.astype(jnp.float32)).astype(x.dtype)
