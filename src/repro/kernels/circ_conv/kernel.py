"""Pallas TPU kernel: blockwise circular convolution / correlation.

TPU adaptation of NSFlow's AdArray passing-register streaming (Sec IV-B).
A TPU has no per-PE register muxes, so instead of skew-streaming the second
operand we *materialize its circulant matrix in VMEM* with log2(d)
roll-select steps (each roll is a static concatenate — VPU-friendly), then
feed the MXU:

    conv:  C[n, k] = y[(n-k) mod d]  ->  out = x @ C^T
    corr:  C[n, k] = y[(n+k) mod d]  ->  out = x @ C^T

Two grid layouts:
- ``elem``  — pairwise binding of N (x_i, y_i) pairs: per-row circulants,
  batched mat-vec. Low-reuse, the "symbolic stream" of the paper.
- ``dict``  — N queries against M static dictionary entries: one circulant
  per dictionary entry is reused by a whole (tile_n × d) MXU matmul. This is
  the high-reuse path the TPU rewrite unlocks.

``d`` must be a power of two (NVSA block dims are 256/512); ops.py falls
back to the XLA gather reference otherwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _circulant(base: jax.Array, sign: int) -> jax.Array:
    """base: (R, d) -> (R, d, d) with out[r, n, :] = roll(base[r], sign*n).

    Binary-decomposition build: log2(d) static rolls + masked selects.
    """
    r, d = base.shape
    m = jnp.broadcast_to(base[:, None, :], (r, d, d))
    n_idx = jax.lax.broadcasted_iota(jnp.int32, (1, d, 1), 1)
    shift = 1
    while shift < d:
        rolled = jnp.roll(m, sign * shift, axis=-1)
        take = ((n_idx // shift) % 2) == 1
        m = jnp.where(take, rolled, m)
        shift *= 2
    return m


def _rev_fixed0(y: jax.Array) -> jax.Array:
    """y_rev[k] = y[(-k) mod d]: reverse all but the 0th element."""
    return jnp.concatenate([y[..., :1], jnp.flip(y[..., 1:], axis=-1)], axis=-1)


def _elem_kernel(x_ref, y_ref, o_ref, *, mode: str):
    x = x_ref[:, 0, :].astype(jnp.float32)  # (tn, d)
    y = y_ref[:, 0, :].astype(jnp.float32)
    base = _rev_fixed0(y) if mode == "conv" else y
    c = _circulant(base, 1 if mode == "conv" else -1)  # (tn, d, d)
    # out[r, n] = sum_k x[r, k] * c[r, n, k]  — batched matvec
    out = jax.lax.dot_general(
        c, x,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    o_ref[:, 0, :] = out.astype(o_ref.dtype)


def _dict_kernel(x_ref, y_ref, o_ref, *, mode: str):
    x = x_ref[:, 0, :].astype(jnp.float32)  # (tn, d)
    y = y_ref[0, 0, :].astype(jnp.float32)  # (d,)
    base = _rev_fixed0(y) if mode == "conv" else y
    c = _circulant(base[None], 1 if mode == "conv" else -1)[0]  # (d, d)
    # out[r, n] = sum_k x[r, k] * c[n, k]  — (tn, d) @ (d, d)^T  -> MXU
    out = jax.lax.dot_general(
        x, c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[:, 0, 0, :] = out.astype(o_ref.dtype)


def _elem_tile(d: int, vmem_budget: int = 6 * 1024 * 1024) -> int:
    """Rows per tile such that the f32 circulant fits the VMEM budget."""
    per_row = d * d * 4
    return max(1, min(64, vmem_budget // (2 * per_row)))


@functools.partial(jax.jit, static_argnames=("mode", "interpret", "tile_n"))
def circ_elem(x: jax.Array, y: jax.Array, *, mode: str = "conv",
              interpret: bool = True, tile_n: int | None = None) -> jax.Array:
    """Pairwise binding. x, y: (N, B, d) -> (N, B, d)."""
    n, b, d = x.shape
    tn = tile_n or _elem_tile(d)
    pad = (-n) % tn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
        y = jnp.pad(y, ((0, pad), (0, 0), (0, 0)))
    grid = ((n + pad) // tn, b)
    out = pl.pallas_call(
        functools.partial(_elem_kernel, mode=mode),
        name=f"circ_elem_{mode}",
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, 1, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tn, 1, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((tn, 1, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, b, d), x.dtype),
        interpret=interpret,
    )(x, y)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("mode", "interpret", "tile_n"))
def circ_dict(x: jax.Array, dictionary: jax.Array, *, mode: str = "conv",
              interpret: bool = True, tile_n: int = 128) -> jax.Array:
    """N queries against M dictionary entries.

    x: (N, B, d), dictionary: (M, B, d) -> (N, B, M, d).
    """
    n, b, d = x.shape
    m = dictionary.shape[0]
    tn = min(tile_n, max(8, n))
    pad = (-n) % tn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
    grid = ((n + pad) // tn, b, m)
    out = pl.pallas_call(
        functools.partial(_dict_kernel, mode=mode),
        name=f"circ_dict_{mode}",
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, 1, d), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((1, 1, d), lambda i, j, k: (k, j, 0)),
        ],
        out_specs=pl.BlockSpec((tn, 1, 1, d), lambda i, j, k: (i, j, k, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, b, m, d), x.dtype),
        interpret=interpret,
    )(x, dictionary)
    return out[:n]
