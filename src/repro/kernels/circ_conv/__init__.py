from repro.kernels.circ_conv import ops, ref

__all__ = ["ops", "ref"]
