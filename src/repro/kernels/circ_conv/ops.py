"""Jit'd public wrappers for the circ_conv kernel with shape handling.

Dispatch policy comes from the active :class:`~repro.backend.registry.
LoweringPlan` (``repro.backend.registry``): compiled Pallas on TPU/GPU
(pow2 block dims >= 8 — off-shape call sites degrade past it), interpret
mode on CPU at any shape, and the exact XLA gather reference whenever the
plan forces ``xla``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import registry
from repro.kernels.circ_conv import kernel, ref


def _circ_elem_dispatch(af: jax.Array, bf: jax.Array, mode: str) -> jax.Array:
    plan = registry.get_plan()
    low = plan.select("circ_conv", size=af.shape[-1])
    if low.is_ref:
        return ref.circ_elem_ref(af, bf, mode)
    return kernel.circ_elem(af, bf, mode=mode,
                            interpret=plan.run_interpret(low))


# Custom VJPs so the Pallas kernels are trainable. Circular-conv calculus:
#   z = conv(a, b):  da = corr(b, g),  db = corr(a, g)
#   z = corr(a, b):  da = corr(g, b),  db = conv(g, a)
# — the backward pass reuses the same kernels (stays on the MXU path).


@jax.custom_vjp
def _conv_flat(a: jax.Array, b: jax.Array) -> jax.Array:
    return _circ_elem_dispatch(a, b, "conv")


def _conv_fwd(a, b):
    return _circ_elem_dispatch(a, b, "conv"), (a, b)


def _conv_bwd(res, g):
    a, b = res
    return (_circ_elem_dispatch(b, g, "corr").astype(a.dtype),
            _circ_elem_dispatch(a, g, "corr").astype(b.dtype))


_conv_flat.defvjp(_conv_fwd, _conv_bwd)


@jax.custom_vjp
def _corr_flat(a: jax.Array, b: jax.Array) -> jax.Array:
    return _circ_elem_dispatch(a, b, "corr")


def _corr_fwd(a, b):
    return _circ_elem_dispatch(a, b, "corr"), (a, b)


def _corr_bwd(res, g):
    a, b = res
    return (_circ_elem_dispatch(g, b, "corr").astype(a.dtype),
            _circ_elem_dispatch(g, a, "conv").astype(b.dtype))


_corr_flat.defvjp(_corr_fwd, _corr_bwd)


def circ_bind(a: jax.Array, b: jax.Array, mode: str = "conv") -> jax.Array:
    """Elementwise blockwise circular conv/corr with leading-dim broadcast.

    a, b: (..., blocks, d) -> (..., blocks, d). Differentiable (custom VJP).
    """
    a, b = jnp.broadcast_arrays(a, b)
    lead = a.shape[:-2]
    blocks, d = a.shape[-2:]
    n = int(np.prod(lead)) if lead else 1
    af = a.reshape(n, blocks, d)
    bf = b.reshape(n, blocks, d)
    out = _conv_flat(af, bf) if mode == "conv" else _corr_flat(af, bf)
    return out.reshape(*lead, blocks, d)


def circ_bind_dict(x: jax.Array, dictionary: jax.Array, mode: str = "conv") -> jax.Array:
    """x: (N, blocks, d) vs dictionary: (M, blocks, d) -> (N, M, blocks, d)."""
    plan = registry.get_plan()
    low = plan.select("circ_conv", size=x.shape[-1])
    if low.is_ref:
        out = ref.circ_dict_ref(x, dictionary, mode)
    else:
        out = kernel.circ_dict(x, dictionary, mode=mode,
                               interpret=plan.run_interpret(low))
    return jnp.swapaxes(out, 1, 2)  # (N, B, M, d) -> (N, M, B, d)
