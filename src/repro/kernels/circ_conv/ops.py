"""Jit'd public wrappers for the circ_conv kernel with shape handling.

Dispatch policy: Pallas kernel (interpret-mode on CPU, compiled on TPU) for
power-of-two ``d``; exact XLA gather reference otherwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.circ_conv import kernel, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _is_pow2(d: int) -> bool:
    return (d & (d - 1)) == 0


def _circ_elem_dispatch(af: jax.Array, bf: jax.Array, mode: str) -> jax.Array:
    d = af.shape[-1]
    if _is_pow2(d) and d >= 8:
        return kernel.circ_elem(af, bf, mode=mode, interpret=_interpret())
    return ref.circ_elem_ref(af, bf, mode)


# Custom VJPs so the Pallas kernels are trainable. Circular-conv calculus:
#   z = conv(a, b):  da = corr(b, g),  db = corr(a, g)
#   z = corr(a, b):  da = corr(g, b),  db = conv(g, a)
# — the backward pass reuses the same kernels (stays on the MXU path).


@jax.custom_vjp
def _conv_flat(a: jax.Array, b: jax.Array) -> jax.Array:
    return _circ_elem_dispatch(a, b, "conv")


def _conv_fwd(a, b):
    return _circ_elem_dispatch(a, b, "conv"), (a, b)


def _conv_bwd(res, g):
    a, b = res
    return (_circ_elem_dispatch(b, g, "corr").astype(a.dtype),
            _circ_elem_dispatch(a, g, "corr").astype(b.dtype))


_conv_flat.defvjp(_conv_fwd, _conv_bwd)


@jax.custom_vjp
def _corr_flat(a: jax.Array, b: jax.Array) -> jax.Array:
    return _circ_elem_dispatch(a, b, "corr")


def _corr_fwd(a, b):
    return _circ_elem_dispatch(a, b, "corr"), (a, b)


def _corr_bwd(res, g):
    a, b = res
    return (_circ_elem_dispatch(g, b, "corr").astype(a.dtype),
            _circ_elem_dispatch(g, a, "conv").astype(b.dtype))


_corr_flat.defvjp(_corr_fwd, _corr_bwd)


def circ_bind(a: jax.Array, b: jax.Array, mode: str = "conv") -> jax.Array:
    """Elementwise blockwise circular conv/corr with leading-dim broadcast.

    a, b: (..., blocks, d) -> (..., blocks, d). Differentiable (custom VJP).
    """
    a, b = jnp.broadcast_arrays(a, b)
    lead = a.shape[:-2]
    blocks, d = a.shape[-2:]
    n = int(np.prod(lead)) if lead else 1
    af = a.reshape(n, blocks, d)
    bf = b.reshape(n, blocks, d)
    out = _conv_flat(af, bf) if mode == "conv" else _corr_flat(af, bf)
    return out.reshape(*lead, blocks, d)


def circ_bind_dict(x: jax.Array, dictionary: jax.Array, mode: str = "conv") -> jax.Array:
    """x: (N, blocks, d) vs dictionary: (M, blocks, d) -> (N, M, blocks, d)."""
    if _is_pow2(x.shape[-1]) and x.shape[-1] >= 8:
        out = kernel.circ_dict(x, dictionary, mode=mode, interpret=_interpret())
    else:
        out = ref.circ_dict_ref(x, dictionary, mode)
    return jnp.swapaxes(out, 1, 2)  # (N, B, M, d) -> (N, M, B, d)
