"""Pallas TPU kernel: causal flash attention (forward).

The §Roofline table shows every dense train/prefill cell memory-bound, with
score-tensor materialization a dominant contributor — this kernel is the
designed fix (EXPERIMENTS §Perf "identified movers"): online-softmax tiles
keep the (Sq, Skv) scores in VMEM only, one HBM pass over K/V per Q tile.

Grid (B·H, Sq/bq, Skv/bk); the running (m, l, acc) state lives in VMEM
scratch carried across the Skv grid dimension (same pattern as the qmatmul
accumulator); the output tile normalizes on the last KV step. Causal
blocks entirely above the diagonal are masked (their contribution is exp(-inf)=0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, bq: int, bk: int, scale: float, causal: bool,
                  skv: int):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < skv  # mask KV padding
    if causal:
        valid = valid & (kpos <= qpos)
    s = jnp.where(valid, s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd) -> (BH, Sq, hd)."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    bq, bk = min(bq, sq), min(bk, skv)
    pq, pk = (-sq) % bq, (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    n_q, n_k = (sq + pq) // bq, (skv + pk) // bk
    out = pl.pallas_call(
        functools.partial(_flash_kernel, n_k=n_k, bq=bq, bk=bk, scale=scale,
                          causal=causal, skv=skv),
        name="flash_attention_fwd",
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
