"""Public wrapper: flash attention over (B, S, H, hd) layouts with GQA."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.backend import registry
from repro.kernels.flash_attn import kernel, ref


def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, scale: float,
              causal: bool = True, use_kernel: bool | None = None) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Skv, H, hd) (pre-repeated GQA groups).

    ``use_kernel`` forces the path explicitly; None (default) consults the
    active :class:`~repro.backend.registry.LoweringPlan`.
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    plan = registry.get_plan()
    low = plan.select("flash_attn")
    if use_kernel is None:
        use_kernel = not low.is_ref
    if use_kernel:
        out = kernel.flash_attention(qf, kf, vf, scale=scale, causal=causal,
                                     interpret=plan.run_interpret(low))
    else:
        out = ref.flash_attention_ref(qf, kf, vf, scale=scale, causal=causal)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
