"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: float, causal: bool = True) -> jax.Array:
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        mask = jnp.arange(skv)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
