"""Public wrapper for the fused unbind->classify kernel (registry dispatch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.backend import registry
from repro.kernels.unbind_classify import kernel, ref


def _run_kernel(head, keys, x):
    plan = registry.get_plan()
    low = plan.select("unbind_classify", size=keys.shape[-1])
    if low.is_ref:
        return ref.unbind_classify_ref(head, keys, x)
    k, blocks, d = keys.shape
    c = head["w"].shape[-1]
    w = head["w"].reshape(blocks, d, c)
    bias = head.get("b")
    bias = jnp.zeros((1, c), jnp.float32) if bias is None else \
        jnp.reshape(bias, (1, c)).astype(jnp.float32)
    return kernel.fused_unbind_classify(
        keys, x.reshape(x.shape[0], blocks, d), w, bias,
        interpret=plan.run_interpret(low))


@jax.custom_vjp
def _fused_kernel(head, keys, x):
    return _run_kernel(head, keys, x)


def _fused_fwd(head, keys, x):
    return _run_kernel(head, keys, x), (head, keys, x)


def _fused_bwd(res, g):
    # backward through the (cheap) reference chain — forward stays fused
    head, keys, x = res
    _, vjp = jax.vjp(ref.unbind_classify_ref, head, keys, x)
    return vjp(g)


_fused_kernel.defvjp(_fused_fwd, _fused_bwd)


def unbind_classify(head, keys: jax.Array, x: jax.Array,
                    use_kernel: bool | None = None) -> jax.Array:
    """``use_kernel`` forces the path explicitly; None (default) consults
    the active :class:`~repro.backend.registry.LoweringPlan`."""
    if use_kernel is None:
        use_kernel = not registry.active("unbind_classify",
                                         size=keys.shape[-1]).is_ref
    if use_kernel:
        return _fused_kernel(head, keys, x)
    return ref.unbind_classify_ref(head, keys, x)
