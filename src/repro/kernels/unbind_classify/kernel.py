"""Pallas TPU kernel: fused VSA unbind -> dense classify head.

The symbolic tail of the MIMONet pipeline — per-channel circular
correlation against the binding keys followed by the shared dense head —
is two separate launches in the staged schedule (``unbind`` then
``classify``), each a host-visible dispatch per admission group.  This
kernel runs the whole tail in one ``pallas_call``: each grid step
materializes one key block's correlation circulant in VMEM (the same
log2(d) roll-select builder as the circ_conv kernel), unbinds the query
tile against it on the MXU and immediately multiplies into the classify
head, accumulating logits across blocks without ever writing the unbound
codes back to HBM.

Grid: (N / tile_n, K, B) with the VSA block axis innermost so each output
tile (tn, 1, C) stays resident while its B partial products accumulate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.circ_conv.kernel import _circulant


def _unbind_classify_kernel(x_ref, k_ref, w_ref, b_ref, o_ref):
    x = x_ref[:, 0, :].astype(jnp.float32)        # (tn, d)
    key = k_ref[0, 0, :].astype(jnp.float32)      # (d,)
    # corr(key, x)[n] = Σ_j key[j]·x[(n+j)%d] = Σ_m x[m]·roll(key, n)[m]
    c = _circulant(key[None], 1)[0]               # (d, d): c[n] = roll(key, n)
    unbound = jax.lax.dot_general(
        x, c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (tn, d)
    w = w_ref[0].astype(jnp.float32)              # (d, C)
    part = jax.lax.dot_general(
        unbound, w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (tn, C)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[:, 0, :] = b_ref[0] + part

    @pl.when(pl.program_id(2) > 0)
    def _accumulate():
        o_ref[:, 0, :] += part


@functools.partial(jax.jit, static_argnames=("interpret", "tile_n"))
def fused_unbind_classify(keys: jax.Array, x: jax.Array, w: jax.Array,
                          b: jax.Array, *, interpret: bool = True,
                          tile_n: int = 128) -> jax.Array:
    """keys: (K, B, d), x: (N, B, d), w: (B, d, C), b: (1, C) -> (N, K, C)."""
    n, blocks, d = x.shape
    k = keys.shape[0]
    c_dim = w.shape[-1]
    tn = min(tile_n, max(8, n))
    pad = (-n) % tn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _unbind_classify_kernel,
        name="fused_unbind_classify",
        grid=((n + pad) // tn, k, blocks),
        in_specs=[
            pl.BlockSpec((tn, 1, d), lambda i, kc, blk: (i, blk, 0)),
            pl.BlockSpec((1, 1, d), lambda i, kc, blk: (kc, blk, 0)),
            pl.BlockSpec((1, d, c_dim), lambda i, kc, blk: (blk, 0, 0)),
            pl.BlockSpec((1, c_dim), lambda i, kc, blk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tn, 1, c_dim), lambda i, kc, blk: (i, kc, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, k, c_dim), jnp.float32),
        interpret=interpret,
    )(x, keys, w, b)
    return out[:n]
