"""Pure-jnp oracle for the fused unbind->classify kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import layers
from repro.vsa import ops as vsa


def unbind_classify_ref(head, keys: jax.Array, x: jax.Array) -> jax.Array:
    """keys: (K, B, d), x: (N, B*d), head: dense params (B*d -> C).

    Exactly the staged ops — broadcast circular correlation of each channel
    key against the trunk output, then the dense head — so this reference
    is bit-identical to ``mimonet.classify(params, mimonet.unbind(...))``
    whenever the staged unbind routes to the gather reference too.
    """
    k, b, d = keys.shape
    n = x.shape[0]
    codes = jnp.broadcast_to(x.reshape(n, 1, b, d), (n, k, b, d))
    kb = jnp.broadcast_to(keys[None], (n, k, b, d))
    unbound = vsa.circ_corr_ref(kb, codes).reshape(n, k, b * d)
    return layers.dense(head, unbound, jnp.float32)
