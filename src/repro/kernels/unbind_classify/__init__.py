from repro.kernels.unbind_classify import ops, ref

__all__ = ["ops", "ref"]
