"""Synthetic RAVEN / I-RAVEN / PGM-style progressive-matrix generator.

The original datasets are not redistributable, so the accuracy experiments
(paper Tab. IV) run on a procedurally generated equivalent: 3×3 panels of
rendered geometric objects whose attributes (shape type, size, color) evolve
row-wise under RPM rules {constant, progression ±1, arithmetic ±}. Eight
candidate answers include the target plus attribute-perturbed distractors —
I-RAVEN-style balanced distractors (each differs from the answer in exactly
one attribute) so shortcut solutions do not work.

Everything is numpy (host side) and deterministic in the seed; the loader
yields device-ready jnp batches.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

RULES = ("constant", "prog_plus", "prog_minus", "arith_plus", "arith_minus")
N_RULES = len(RULES)


@dataclasses.dataclass(frozen=True)
class RavenConfig:
    image_size: int = 32
    n_types: int = 5      # shapes: triangle, square, pentagon, hexagon, circle
    n_sizes: int = 6
    n_colors: int = 8
    style: str = "raven"  # raven | iraven | pgm  (distractor / noise policy)
    noise: float = 0.02

    @property
    def attr_sizes(self) -> tuple[int, int, int]:
        return (self.n_types, self.n_sizes, self.n_colors)

    @property
    def n_attrs(self) -> int:
        return 3


def _apply_rule(rule: int, a1: int, a2: int, n: int) -> int:
    """Third value in a row under ``rule`` given the first two. Values that
    leave [0, n) are wrapped — the generator rejects wrap cases for arith."""
    if RULES[rule] == "constant":
        return a2
    if RULES[rule] == "prog_plus":
        return (a2 + 1) % n
    if RULES[rule] == "prog_minus":
        return (a2 - 1) % n
    if RULES[rule] == "arith_plus":
        return (a1 + a2) % n
    return (a1 - a2) % n


def _rule_predicts(rule: int, a1: int, a2: int) -> int:
    """Unwrapped 3rd value a rule abduction engine would predict from the
    first two (no modulo: out-of-range predictions match nothing)."""
    name = RULES[rule]
    if name == "constant":
        return a2
    if name == "prog_plus":
        return a2 + 1
    if name == "prog_minus":
        return a2 - 1
    if name == "arith_plus":
        return a1 + a2
    return a1 - a2


def _grid_ambiguous(rows: np.ndarray, rule: int, n: int) -> bool:
    """True if some other rule also explains both complete rows yet predicts
    a different 9th panel — unanswerable even for a perfect reasoner (e.g.
    (3,0,3),(1,0,1): arith± coincide when a2 == 0 but diverge on row 3).

    Checked under both unwrapped and modulo-wrapped rule semantics, so the
    grid is unambiguous whether the abduction engine treats out-of-range
    predictions as non-matches or wraps them mod n (e.g. prog_plus with
    a2 == n-1 predicting 0 only via wrap-around)."""
    predictors = (_rule_predicts,
                  lambda r, a1, a2: _apply_rule(r, a1, a2, n))
    for r in range(N_RULES):
        if r == rule:
            continue
        for predict in predictors:
            if all(predict(r, rows[i, 0], rows[i, 1]) == rows[i, 2]
                   for i in (0, 1)):
                if predict(r, rows[2, 0], rows[2, 1]) != rows[2, 2]:
                    return True
    return False


def _row_values(rng: np.random.Generator, rule: int, n: int) -> tuple[int, int, int]:
    name = RULES[rule]
    for _ in range(64):
        if name == "constant":
            a1 = int(rng.integers(n))
            row = (a1, a1, a1)
        elif name == "prog_plus":
            a1 = int(rng.integers(0, n - 2))
            row = (a1, a1 + 1, a1 + 2)
        elif name == "prog_minus":
            a1 = int(rng.integers(2, n))
            row = (a1, a1 - 1, a1 - 2)
        elif name == "arith_plus":
            a1 = int(rng.integers(0, n - 1))
            a2 = int(rng.integers(0, n - a1))
            row = (a1, a2, a1 + a2)
        else:  # arith_minus
            a1 = int(rng.integers(0, n))
            a2 = int(rng.integers(0, a1 + 1))
            row = (a1, a2, a1 - a2)
        if all(0 <= v < n for v in row):
            return row
    raise RuntimeError("rule sampling failed")


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------


def _shape_mask(size_px: int, type_idx: int, radius: float) -> np.ndarray:
    """Rasterize shape ``type_idx`` with given radius on a size_px canvas."""
    c = (size_px - 1) / 2.0
    yy, xx = np.mgrid[0:size_px, 0:size_px]
    dy, dx = yy - c, xx - c
    r = np.hypot(dx, dy)
    if type_idx == 4:  # circle
        return r <= radius
    n_vertices = [3, 4, 5, 6][type_idx]
    theta = np.arctan2(dy, dx)
    # regular polygon: boundary radius as a function of angle
    k = np.pi / n_vertices
    offset = np.pi / 2 if n_vertices % 2 else k  # point-up orientation
    bound = radius * np.cos(k) / np.cos(((theta + offset) % (2 * k)) - k)
    return r <= bound


def render_panel(cfg: RavenConfig, attrs: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """attrs: (type, size, color) -> (H, W, 1) float32 in [0, 1]."""
    s = cfg.image_size
    t, sz, col = int(attrs[0]), int(attrs[1]), int(attrs[2])
    radius = (0.18 + 0.62 * (sz + 1) / cfg.n_sizes) * (s / 2 - 1)
    intensity = 0.25 + 0.75 * (col + 1) / cfg.n_colors
    mask = _shape_mask(s, t, radius)
    img = np.zeros((s, s), np.float32)
    img[mask] = intensity
    if cfg.noise > 0:
        img = img + rng.normal(0, cfg.noise, img.shape).astype(np.float32)
    return np.clip(img, 0, 1)[..., None]


# ---------------------------------------------------------------------------
# Problem generation
# ---------------------------------------------------------------------------


def generate_problem(cfg: RavenConfig, seed: int):
    """One RPM problem.

    Returns dict with:
      context_attrs (8, 3) int32, candidate_attrs (8, 3), answer int32,
      rules (3,) int32, context (8, H, W, 1), candidates (8, H, W, 1),
      panel_attrs (9, 3) — full grid incl. the true 9th panel.
    """
    rng = np.random.default_rng(seed)
    sizes = cfg.attr_sizes
    rules = np.array([rng.integers(N_RULES) for _ in range(cfg.n_attrs)], np.int32)
    grid = np.zeros((3, 3, cfg.n_attrs), np.int32)
    for ai in range(cfg.n_attrs):
        for _ in range(64):
            for row in range(3):
                grid[row, :, ai] = _row_values(rng, int(rules[ai]), sizes[ai])
            if not _grid_ambiguous(grid[:, :, ai], int(rules[ai]), sizes[ai]):
                break
    panel_attrs = grid.reshape(9, cfg.n_attrs)
    answer_attrs = panel_attrs[8]

    # I-RAVEN-style distractors: each differs in exactly one attribute
    candidates = [answer_attrs.copy()]
    seen = {tuple(answer_attrs)}
    attempts = 0
    while len(candidates) < 8 and attempts < 256:
        attempts += 1
        c = answer_attrs.copy()
        ai = int(rng.integers(cfg.n_attrs))
        if cfg.style == "pgm":  # pgm-style: perturb 1-2 attributes
            for aj in rng.choice(cfg.n_attrs, size=int(rng.integers(1, 3)),
                                 replace=False):
                c[aj] = int(rng.integers(sizes[aj]))
        else:
            delta = int(rng.integers(1, sizes[ai]))
            c[ai] = (c[ai] + delta) % sizes[ai]
        if tuple(c) not in seen:
            seen.add(tuple(c))
            candidates.append(c)
    while len(candidates) < 8:  # degenerate fallback
        c = np.array([rng.integers(s) for s in sizes], np.int32)
        if tuple(c) not in seen:
            seen.add(tuple(c))
            candidates.append(c)
    candidates = np.stack(candidates)
    perm = rng.permutation(8)
    candidates = candidates[perm]
    answer = int(np.where(perm == 0)[0][0])

    context_imgs = np.stack([render_panel(cfg, a, rng) for a in panel_attrs[:8]])
    cand_imgs = np.stack([render_panel(cfg, a, rng) for a in candidates])
    return {
        "context_attrs": panel_attrs[:8],
        "panel_attrs": panel_attrs,
        "candidate_attrs": candidates,
        "answer": answer,
        "rules": rules,
        "context": context_imgs,
        "candidates": cand_imgs,
    }


def generate_batch(cfg: RavenConfig, seed: int, n: int):
    """Batched problems, stacked along axis 0 (all-numpy, loader-friendly)."""
    probs = [generate_problem(cfg, seed * 100003 + i) for i in range(n)]
    return {k: np.stack([p[k] for p in probs]) for k in probs[0]}


def panel_dataset(cfg: RavenConfig, seed: int, n_problems: int):
    """Flattened (image, attrs) supervision set for the CNN frontend."""
    batch = generate_batch(cfg, seed, n_problems)
    imgs = np.concatenate(
        [batch["context"].reshape(-1, cfg.image_size, cfg.image_size, 1),
         batch["candidates"].reshape(-1, cfg.image_size, cfg.image_size, 1)])
    attrs = np.concatenate(
        [batch["context_attrs"].reshape(-1, cfg.n_attrs),
         batch["candidate_attrs"].reshape(-1, cfg.n_attrs)])
    return imgs.astype(np.float32), attrs.astype(np.int32)
