from repro.data import raven, tokens

__all__ = ["raven", "tokens"]
