"""Deterministic synthetic LM token pipeline.

Produces reproducible (tokens, targets) batches keyed by (seed, step, shard)
so that checkpoint-restart replays the exact stream — the property the fault
tolerance tests assert. The "corpus" is a fixed-vocabulary Markov-ish stream
generated on host with numpy (no tokenizer dependency); entropy is tunable
so small models show a real, declining loss curve.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 2          # markov order of the synthetic stream
    n_modes: int = 64       # latent transition modes (lower = more learnable)


class SyntheticTokens:
    """Stateless loader: ``batch(step, shard, n_shards)`` is pure."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # low-rank transition structure: token -> mode -> next-token peak
        self._mode_of = rng.integers(0, cfg.n_modes, size=v)
        self._peak_of = rng.integers(0, v, size=cfg.n_modes)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1):
        """Returns (tokens, targets): (local_batch, seq_len) int32."""
        cfg = self.cfg
        local = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + shard)
        toks = np.empty((local, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=local)
        noise = rng.random((local, cfg.seq_len))
        rand_tok = rng.integers(0, cfg.vocab_size, size=(local, cfg.seq_len))
        for t in range(cfg.seq_len):
            peak = self._peak_of[self._mode_of[toks[:, t]]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.75,
                                      (peak + (rand_tok[:, t] % 7)) % cfg.vocab_size,
                                      rand_tok[:, t])
        return toks[:, :-1], toks[:, 1:]
