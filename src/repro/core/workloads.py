"""Paper-scale NSAI workload graphs (OpGraph builders).

The paper evaluates NVSA / MIMONet / LVRF at their published scales
(ResNet-18 frontends on RAVEN-size inputs, 4×256-block codes). Tracing our
runnable-on-CPU reduced models would under-size the graphs, so the Tab. III
/ Fig. 5 / Fig. 6 benchmarks build the published-scale graphs directly;
system tests separately validate that ``core.trace`` extracts equivalent
structure from the executable JAX models.
"""

from __future__ import annotations

from repro.core.opgraph import OpGraph, OpNode

DT = 4  # fp32 bytes (device models quantize separately)


def _conv_node(g: OpGraph, name: str, dep: str | None, batch: int, hw: int,
               cin: int, cout: int, k: int, stride: int = 1) -> str:
    out_hw = hw // stride
    m = batch * out_hw * out_hw
    kk = k * k * cin
    node = OpNode(name, "nn", {"m": m, "n": cout, "k": kk,
                               "out_shape": (batch, out_hw, out_hw, cout)},
                  deps=[dep] if dep else [],
                  out_bytes=m * cout * DT, in_bytes=batch * hw * hw * cin * DT,
                  param_bytes=kk * cout * DT, flops=2 * m * cout * kk,
                  label=f"conv{k}x{k}")
    g.add(node)
    return name


def resnet18_graph(g: OpGraph, batch: int = 16, img: int = 160, cin: int = 64,
                   prefix: str = "nn") -> str:
    """ResNet-18 body as in paper Listing 1 ([16, 64, 160, 160] activations)."""
    last = _conv_node(g, f"{prefix}_stem", None, batch, img, 3, cin, 7, 2)
    hw = img // 2
    c = cin
    for stage, (cout, stride) in enumerate([(cin, 1), (cin * 2, 2),
                                            (cin * 4, 2), (cin * 8, 2)]):
        for blk in range(2):
            s = stride if blk == 0 else 1
            a = _conv_node(g, f"{prefix}_s{stage}b{blk}c1", last, batch, hw, c,
                           cout, 3, s)
            hw = hw // s
            c = cout
            last = _conv_node(g, f"{prefix}_s{stage}b{blk}c2", a, batch, hw, c,
                              cout, 3, 1)
    head = OpNode(f"{prefix}_head", "nn",
                  {"m": batch, "n": 512, "k": c, "out_shape": (batch, 512)},
                  deps=[last], out_bytes=batch * 512 * DT,
                  in_bytes=batch * c * DT, param_bytes=c * 512 * DT,
                  flops=2 * batch * 512 * c, label="fc")
    g.add(head)
    return head.name


def _vsa_node(g: OpGraph, name: str, deps: list[str], nvec: int, d: int,
              label: str = "circ_conv") -> str:
    node = OpNode(name, "vsa", {"nvec": nvec, "d": d, "out_shape": (nvec, d)},
                  deps=deps, out_bytes=nvec * d * DT, in_bytes=2 * nvec * d * DT,
                  flops=2 * nvec * d * d, label=label)
    g.add(node)
    return name


def _simd_node(g: OpGraph, name: str, deps: list[str], elems: int,
               label: str = "similarity") -> str:
    node = OpNode(name, "simd", {"elems": elems, "out_shape": (elems,)},
                  deps=deps, out_bytes=elems * DT, in_bytes=2 * elems * DT,
                  flops=elems, label=label)
    g.add(node)
    return name


def nvsa_graph(batch: int = 1, blocks: int = 4, d: int = 256,
               symbolic_scale: int = 48) -> OpGraph:
    """NVSA end-to-end: ResNet-18 perception + VSA abduction/execution.

    One graph = ONE reasoning task (the paper's "single loop"); batching is
    expressed as inter-loop pipelining (Fig. 4 step ③). ``symbolic_scale``
    multiplies the symbolic vector quantity (the Fig. 6 x-axis); the default
    reproduces the paper's Fig. 1 profile of symbolic ≈ 19% of FLOPs
    (NVSA's published codebook/query batches are far larger than one
    row-triple per attribute).
    """
    g = OpGraph()
    feat = resnet18_graph(g, batch=batch)
    # symbolic stage (per batch item: 8 context + 8 candidate panels,
    # 3 attrs × 5 rules × 2 rows abduction + execution + panel composition)
    nv = batch * blocks * symbolic_scale
    last = feat
    for r in range(5):
        last = _vsa_node(g, f"abduct_rule{r}", [last], nv * 6, d)
        _simd_node(g, f"sim_rule{r}", [last], nv * 6 * d // 8)
    ex = _vsa_node(g, "execute_row3", [last], nv * 5, d)
    comp = _vsa_node(g, "compose_panel", [ex], nv * 3, d)
    cand = _vsa_node(g, "compose_cands", [feat], nv * 8 * 3, d)
    _simd_node(g, "match_prob", [comp, cand], batch * 8 * blocks * d,
               label="match_prob")
    return g


def mimonet_graph(batch: int = 4, channels: int = 4, blocks: int = 4,
                  d: int = 512) -> OpGraph:
    g = OpGraph()
    feat = resnet18_graph(g, batch=batch * channels, img=128)
    b = _vsa_node(g, "bind_keys", [feat], batch * channels * blocks * 128, d)
    _simd_node(g, "bundle", [b], batch * blocks * d, label="bundle")
    # trunk on superposed codes
    t1 = OpNode("trunk1", "nn", {"m": batch, "n": 4 * blocks * d,
                                 "k": blocks * d, "out_shape": (batch, 4 * blocks * d)},
                deps=["bundle"], out_bytes=batch * 4 * blocks * d * DT,
                in_bytes=batch * blocks * d * DT,
                param_bytes=4 * (blocks * d) ** 2 * DT,
                flops=2 * batch * 4 * (blocks * d) ** 2, label="trunk_fc")
    g.add(t1)
    t2 = OpNode("trunk2", "nn", {"m": batch, "n": blocks * d,
                                 "k": 4 * blocks * d, "out_shape": (batch, blocks * d)},
                deps=["trunk1"], out_bytes=batch * blocks * d * DT,
                in_bytes=batch * 4 * blocks * d * DT,
                param_bytes=4 * (blocks * d) ** 2 * DT,
                flops=2 * batch * 4 * (blocks * d) ** 2, label="trunk_fc")
    g.add(t2)
    u = _vsa_node(g, "unbind_keys", ["trunk2"], batch * channels * blocks * 128, d,
                  label="circ_corr")
    _simd_node(g, "classify", [u], batch * channels * 64, label="head")
    return g


def lvrf_graph(batch: int = 1, blocks: int = 4, d: int = 256,
               n_rules: int = 8, symbolic_scale: int = 48) -> OpGraph:
    g = OpGraph()
    feat = resnet18_graph(g, batch=batch)
    last = feat
    for r in range(n_rules):
        last = _vsa_node(g, f"rule_vec{r}", [last], batch * blocks * 3 * symbolic_scale, d)
        _simd_node(g, f"posterior{r}", [last], batch * blocks * d // 4)
    ex = _vsa_node(g, "execute", [last], batch * blocks * n_rules * symbolic_scale, d)
    _simd_node(g, "answer", [ex], batch * 8 * blocks * d, label="match_prob")
    return g


WORKLOADS = {
    "nvsa": nvsa_graph,
    "mimonet": mimonet_graph,
    "lvrf": lvrf_graph,
}


def matmul_heavy_graph(n_layers: int = 12, m: int = 64, d: int = 2048,
                       symbolic_scale: int = 256, blocks: int = 4,
                       dv: int = 512) -> OpGraph:
    """MLP-heavy + symbolic workload where Eq. 1 is N_l-sensitive (d2 large,
    m small) — surfaces the Phase II mapping gains (paper Fig. 6's 44%
    claim regime; our conv workloads are stream-bound, see EXPERIMENTS)."""
    g = OpGraph()
    last = None
    for i in range(n_layers):
        node = OpNode(f"fc{i}", "nn", {"m": m, "n": d, "k": d,
                                       "out_shape": (m, d)},
                      deps=[last] if last else [],
                      out_bytes=m * d * DT, in_bytes=m * d * DT,
                      param_bytes=d * d * DT, flops=2 * m * d * d,
                      label="fc")
        g.add(node)
        last = node.name
        if i % 3 == 1:
            last_v = _vsa_node(g, f"vsa{i}", [last],
                               blocks * symbolic_scale * (1 + i % 4), dv)
    return g
