"""Mesh DSE — the TPU retargeting of NSFlow Phase I (beyond-paper).

The paper's Phase I searches (H, W, N) for an FPGA array; the TPU analogue
searches the *mesh factorization* (data × model parallel sizes) and
per-node knobs (remat, microbatch) against the same style of analytical
cost model, now built from the v5e roofline terms:

  compute    = step FLOPs / (chips × peak)
  memory     = (param reads + activation traffic) / (chips × HBM bw)
  collective = TP psums + DP grad reduce (+EP) / (chips × ICI bw)
  (+ a per-device HBM capacity constraint: params + moments + activations)

The predicted-best mesh is validated against dry-run measurements in
EXPERIMENTS.md §Perf — keeping the paper's two-phase structure: a coarse
static split first (this module), per-node refinement second (remat /
precision per layer in the launch configs).
"""

from __future__ import annotations

import dataclasses
import math

from repro.launch.mesh import HW


@dataclasses.dataclass(frozen=True)
class MeshPoint:
    data: int
    model: int
    remat: bool
    accum: int
    compute_s: float
    memory_s: float
    collective_s: float
    hbm_gb: float
    feasible: bool

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def tag(self) -> str:
        """Comma-free provenance tag for BENCH rows / deploy summaries."""
        return (f"mesh={self.data}x{self.model} "
                f"bound={self.bound_s:.2e}s")

    def record(self) -> dict:
        """Plain-dict record (``Deployment.report()`` embeds this)."""
        return {"data": self.data, "model": self.model,
                "bound_s": self.bound_s, "compute_s": self.compute_s,
                "memory_s": self.memory_s,
                "collective_s": self.collective_s,
                "hbm_gb": self.hbm_gb, "feasible": self.feasible}


def _divisors(n: int):
    return [d for d in range(1, n + 1) if n % d == 0]


def search(n_params: float, n_active: float, d_model: int, n_layers: int,
           seq: int, global_batch: int, chips: int = 256,
           bytes_per_param: float = 2.0, moment_bytes: float = 8.0,
           kv_bytes_per_tok: float = 0.0, train: bool = True) -> list[MeshPoint]:
    """Rank mesh factorizations for one (arch × shape).

    Analytic; no compile. Returns points sorted by bound_s (feasible first).
    """
    tokens = global_batch * seq
    passes = 3 if train else 1
    flops = 2 * n_active * tokens * passes
    points = []
    for model in _divisors(chips):
        data = chips // model
        if global_batch % data and global_batch >= data:
            continue
        for remat in ((False, True) if train else (False,)):
          for accum in ((1, 4, 16) if train else (1,)):
            eff_passes = passes + (1 if remat else 0)
            f = 2 * n_active * tokens * eff_passes
            compute = f / (chips * HW["peak_flops_bf16"])
            # memory: weights stream once per pass per chip-shard per
            # microbatch + activations (residual stream, halved by remat)
            w_bytes = n_params * bytes_per_param / model
            act = tokens / data * d_model * 2.0 * n_layers * (2 if not remat else 1)
            memory = (w_bytes * eff_passes * accum + act) / HW["hbm_bw"]
            # collectives: TP psum of activations per layer (2×), DP grad
            # reduce-scatter+all-gather of the model shard
            tp = 0.0 if model == 1 else \
                2 * n_layers * (tokens / data) * d_model * 2.0
            dp = 0.0 if (data == 1 or not train) else \
                2 * n_params * bytes_per_param / model
            collective = (tp + dp) / (HW["ici_bw_per_link"] * HW["ici_links"])
            # live activations: one microbatch's layer boundaries, sharded
            # over the model axis too (sequence-sharded saves)
            act_live = act / (accum * model)
            hbm = (n_params * (bytes_per_param + (moment_bytes if train else 0))
                   / (model * (data if train else 1))  # ZeRO moments over data
                   + act_live * 2 + tokens / data * kv_bytes_per_tok)
            points.append(MeshPoint(data, model, remat, accum, compute, memory,
                                    collective, hbm / 1e9,
                                    hbm < HW["hbm_bytes"]))
    points.sort(key=lambda p: (not p.feasible, p.bound_s))
    return points


def best(n_params, n_active, d_model, n_layers, seq, global_batch,
         chips: int = 256, **kw) -> MeshPoint:
    return search(n_params, n_active, d_model, n_layers, seq, global_batch,
                  chips, **kw)[0]


def serving_search(n_params: float, n_active: float, d_model: int,
                   n_layers: int, seq: int, batch: int, devices: int,
                   kv_bytes_per_tok: float = 0.0,
                   bytes_per_param: float = 4.0,
                   max_model: int | None = None) -> list[MeshPoint]:
    """Mesh DSE in **serving mode**: the factorization deploy() co-searches.

    Serving differs from training everywhere the cost model cares: one
    pass (no backward), no remat/accum sweep, no optimizer moments, no DP
    gradient reduce — and the per-device HBM constraint gains the KV-cache
    term (``kv_bytes_per_tok`` from the arch config).  The ``data`` axis
    of the winner is the *engine replica count* (data parallelism over
    whole engines — :class:`~repro.serve.replica.ReplicaPool`), the
    ``model`` axis the tensor-parallel degree of each replica.

    ``max_model`` caps the model axis: NSAI staged pipelines are served
    data-parallel only (pass 1 — every device hosts a whole pipeline),
    while LM decode may take a real TP axis through
    ``distributed.sharding_rules``.  Points are sorted feasible-first then
    by ``bound_s``; ``serving_best`` returns the winner.
    """
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    pts = search(n_params, n_active, d_model, n_layers, seq,
                 global_batch=batch, chips=devices,
                 bytes_per_param=bytes_per_param, moment_bytes=0.0,
                 kv_bytes_per_tok=kv_bytes_per_tok, train=False)
    if max_model is not None:
        pts = [p for p in pts if p.model <= max_model]
    if not pts:
        raise ValueError(f"no mesh point for devices={devices} "
                         f"max_model={max_model}")
    return pts


def serving_best(n_params, n_active, d_model, n_layers, seq, batch,
                 devices: int, **kw) -> MeshPoint:
    return serving_search(n_params, n_active, d_model, n_layers, seq, batch,
                          devices, **kw)[0]
