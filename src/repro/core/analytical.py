"""Analytical runtime models — paper Sec V-C, Eqs. (1)-(5), verbatim.

Cycle counts for nodes mapped onto the AdArray (H × W sub-arrays, N of
them). ``d1, d2, d3`` are the NN layer's m, n, k; ``nvec, d`` are a VSA
node's vector quantity and dimension. These models are SCALE-Sim-style
(refs [29], [31]) and are what the paper's own evaluation uses.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.opgraph import OpGraph, OpNode


def cdiv(a: float, b: float) -> int:
    return int(math.ceil(a / b))


# --- Eq. (1): NN layer on N_l[i] combined sub-arrays (row-partition scale-out)
def t_layer(H: int, W: int, n_l: int, d1: int, d2: int, d3: int) -> int:
    if n_l <= 0:
        return 1 << 60  # unmapped — infinite
    return (2 * H + W + d1 - 2) * cdiv(cdiv(d2, n_l), H) * cdiv(d3, W)


# --- Eq. (2): total NN runtime over layer set R_l
def t_nn(H: int, W: int, n_ls: Sequence[int], layers: Sequence[OpNode]) -> int:
    return sum(
        t_layer(H, W, n_l, n.dims["m"], n.dims["n"], n.dims["k"])
        * n.dims.get("repeat", 1)
        for n_l, n in zip(n_ls, layers)
    )


# --- Eq. (3)/(4): VSA node under spatial / temporal mapping
def t_vsa_spatial(H: int, W: int, n_v: int, nvec: int, d: int) -> int:
    if n_v <= 0:
        return 1 << 60
    T = 3 * H + d - 1
    return nvec * cdiv(d, W * H * n_v) * T


def t_vsa_temporal(H: int, W: int, n_v: int, nvec: int, d: int) -> int:
    if n_v <= 0:
        return 1 << 60
    T = 3 * H + d - 1
    return cdiv(nvec, W) * cdiv(d, H * n_v) * T


# --- Eq. (5): total VSA runtime (best of the two mappings, per whole set)
def t_vsa(H: int, W: int, n_vs: Sequence[int], vnodes: Sequence[OpNode]) -> int:
    temp = sum(
        t_vsa_temporal(H, W, n_v, n.dims["nvec"], n.dims["d"])
        * n.dims.get("repeat", 1)
        for n_v, n in zip(n_vs, vnodes)
    )
    spat = sum(
        t_vsa_spatial(H, W, n_v, n.dims["nvec"], n.dims["d"])
        * n.dims.get("repeat", 1)
        for n_v, n in zip(n_vs, vnodes)
    )
    return min(temp, spat)


def t_vsa_node(H: int, W: int, n_v: int, node: OpNode) -> int:
    """Best-mapping runtime of a single VSA node."""
    nvec, d = node.dims["nvec"], node.dims["d"]
    r = node.dims.get("repeat", 1)
    return min(t_vsa_spatial(H, W, n_v, nvec, d),
               t_vsa_temporal(H, W, n_v, nvec, d)) * r


def t_simd(lanes: int, simd_nodes: Sequence[OpNode]) -> int:
    """SIMD-unit runtime: one element per lane per cycle."""
    return sum(cdiv(n.dims.get("elems", 1), lanes) * n.dims.get("repeat", 1)
               for n in simd_nodes)


# ---------------------------------------------------------------------------
# Memory sizing (Sec V-C "Memory and SIMD unit", Sec IV-C)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    mem_a1: int   # max NN filter (stationary) bytes
    mem_a2: int   # max VSA node bytes
    mem_b: int    # max NN ifmap bytes
    mem_c: int    # max output bytes
    cache: int    # 2 × (A + B + C)
    simd_lanes: int

    @property
    def mem_a(self) -> int:
        return self.mem_a1 + self.mem_a2

    @property
    def total(self) -> int:
        return self.mem_a + self.mem_b + self.mem_c + self.cache


def memory_plan(graph: OpGraph, t_parallel: int, lane_candidates=(16, 32, 64, 128, 256)) -> MemoryPlan:
    nn = graph.nn_nodes()
    vs = graph.vsa_nodes()
    sd = graph.simd_nodes()
    mem_a1 = max((n.param_bytes for n in nn), default=0)
    mem_a2 = max((n.in_bytes for n in vs), default=0)
    mem_b = max((n.in_bytes - n.param_bytes for n in nn), default=0)
    mem_c = max((n.out_bytes for n in graph if n.kind in ("nn", "vsa", "simd")),
                default=0)
    # smallest SIMD such that elem-wise work hides under the parallel runtime
    lanes = lane_candidates[-1]
    for cand in lane_candidates:
        if t_simd(cand, sd) <= max(1, t_parallel):
            lanes = cand
            break
    cache = 2 * (mem_a1 + mem_a2 + mem_b + mem_c)
    return MemoryPlan(mem_a1, mem_a2, mem_b, mem_c, cache, lanes)
