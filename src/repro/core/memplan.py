"""Memory plan -> Pallas BlockSpec budgets (paper Sec IV-C on TPU).

The FPGA's re-organizable BRAM partition (Mem_A1 weights / Mem_A2 vectors /
Mem_B ifmap / Mem_C outputs) maps onto the per-core VMEM budget: the DAG's
memory plan decides how much VMEM each kernel operand class may claim, and
this module converts those budgets into concrete tile shapes for the
repo's kernels. "Merging A1/A2" (paper ①) happens automatically when a
kernel runs without a concurrent sibling stream — it receives the combined
budget.
"""

from __future__ import annotations

import dataclasses

from repro.core.analytical import MemoryPlan
from repro.launch.mesh import HW


@dataclasses.dataclass(frozen=True)
class KernelTiles:
    circ_elem_tile_n: int      # rows per circulant tile (Mem_A2 budget)
    circ_dict_tile_n: int      # query rows per dict tile
    qmm_bm: int
    qmm_bn: int
    qmm_bk: int
    vmem_budget: int


def plan_tiles(mem: MemoryPlan, d: int = 256, vmem: int | None = None,
               concurrent: bool = True) -> KernelTiles:
    """Derive kernel tiles from a workload memory plan.

    ``concurrent=True`` = folded execution: the VSA kernels get the Mem_A2
    share of VMEM and the NN kernels Mem_A1+Mem_B; otherwise each kernel
    class may claim the merged budget (paper's runtime re-partition).
    """
    vmem = vmem or int(HW["vmem_bytes"])
    total_plan = max(1, mem.mem_a + mem.mem_b)
    if concurrent:
        vsa_budget = max(vmem // 8, int(vmem * mem.mem_a2 / total_plan))
        nn_budget = max(vmem // 8, vmem - vsa_budget)
    else:
        vsa_budget = nn_budget = vmem
    # circ_elem: per-row f32 circulant d*d*4 (double-buffered)
    per_row = d * d * 4 * 2
    tile_n = max(1, min(64, vsa_budget // per_row))
    # circ_dict: one circulant + query tile
    dict_tile = max(8, min(512, (vsa_budget - d * d * 4) // (d * 4 * 2)))
    # qmatmul: bm*bk + bk*bn int8 + bm*bn int32 acc within nn budget,
    # MXU-aligned (multiples of 128)
    b = 128
    while (b * b * 2 + b * b * 4) * 2 < nn_budget and b < 1024:
        b *= 2
    b = max(128, b // 2)
    return KernelTiles(tile_n, dict_tile, b, b, b, vmem)
