"""Two-phase design-space exploration — paper Sec V-C, Algorithm 1, Tab. II.

Phase I  : grid over (H, W) with the paper's aspect-ratio pruning
           (1/4 ≤ H/W ≤ 16), N = ⌊M / (H·W)⌋ sub-arrays, and a *static*
           partition N̄_l : N̄_v swept over [1, N). Also evaluates the
           sequential (unfolded) mode and returns it when it wins (Alg. 1
           line 14).
Phase II : per-node refinement around (N̄_l, N̄_v): for each layer node i the
           concurrent VSA window [j', j''] is located via the dataflow
           graph, and ±1 sub-array moves are applied in the direction that
           reduces t_para = max(t_nn, t_vsa), up to Iter_max sweeps.
           (The printed pseudocode's move condition is degenerate —
           ``t_seq < t_para`` does not depend on i — so we implement the
           evident intent: shift capacity toward the slower stream, greedy
           with revert. Recorded in DESIGN.md §7.)

Search-space accounting reproduces Tab. II's reduction claim.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import analytical as ana
from repro.core.dataflow import DataflowGraph


@dataclasses.dataclass
class DesignConfig:
    H: int
    W: int
    N: int
    mode: str                 # parallel | sequential
    n_l: list[int]            # per NN node sub-array assignment
    n_v: list[int]            # per VSA node sub-array assignment
    nl_bar: int
    nv_bar: int
    t_para: int
    t_seq: int
    t_phase1: int
    mem: ana.MemoryPlan | None = None
    searched_points: int = 0

    @property
    def t_best(self) -> int:
        return min(self.t_para, self.t_seq) if self.mode == "parallel" else self.t_seq

    def tag(self) -> str:
        """Compact comma-free provenance tag (``HxWxN/nl:nv/mode``) —
        recorded in BENCH_*.json rows and deployment reports so every
        measurement says which DSE point served it."""
        return (f"{self.H}x{self.W}x{self.N}"
                f"/{self.nl_bar}:{self.nv_bar}/{self.mode}")

    def summary(self) -> dict:
        return {
            "AdArray (H, W, N)": (self.H, self.W, self.N),
            "partition": f"{self.nl_bar}:{self.nv_bar}",
            "mode": self.mode,
            "t_para_cycles": self.t_para,
            "t_seq_cycles": self.t_seq,
            "SIMD": self.mem.simd_lanes if self.mem else None,
            "MemA1": self.mem.mem_a1 if self.mem else None,
            "MemA2": self.mem.mem_a2 if self.mem else None,
            "MemB": self.mem.mem_b if self.mem else None,
            "MemC": self.mem.mem_c if self.mem else None,
            "cache": self.mem.cache if self.mem else None,
        }


#: FPGA-placeable sub-array bounds. The paper's deployed configs (Tab. III)
#: top out at 32×32 — a monolithic wide array does not route/time on an
#: FPGA fabric, which is exactly why AdArray scales out via N sub-arrays.
RANGE_H = (4, 32)
RANGE_W = (4, 32)


def _hw_candidates(max_pes: int, range_h=RANGE_H, range_w=RANGE_W):
    """(H, W) grid with the paper's pruning: 1/4 <= H/W <= 16."""
    out = []
    h = range_h[0]
    while h <= range_h[1]:
        w = range_w[0]
        while w <= range_w[1]:
            if h * w <= max_pes and 0.25 <= h / w <= 16.0:
                out.append((h, w))
            w *= 2
        h *= 2
    return out


def phase1(df: DataflowGraph, max_pes: int) -> DesignConfig:
    layers = df.nn_nodes
    vnodes = df.vsa_nodes
    L, V = len(layers), len(vnodes)
    best_para = None  # (t, H, W, N, nl_bar)
    best_seq = None   # (t, H, W, N)
    searched = 0
    for H, W in _hw_candidates(max_pes):
        N = max_pes // (H * W)
        if N < 1:
            continue
        # parallel candidates: static split
        if N >= 2 and L and V:
            for nl_bar in range(1, N):
                searched += 1
                tp = max(ana.t_nn(H, W, [nl_bar] * L, layers),
                         ana.t_vsa(H, W, [N - nl_bar] * V, vnodes))
                if best_para is None or tp < best_para[0]:
                    best_para = (tp, H, W, N, nl_bar)
        # sequential: every node gets the whole array (Alg. 1 line 12)
        searched += 1
        ts = (ana.t_nn(H, W, [N] * L, layers) if L else 0) + \
             (ana.t_vsa(H, W, [N] * V, vnodes) if V else 0)
        if best_seq is None or ts < best_seq[0]:
            best_seq = (ts, H, W, N)

    if best_para is None or (best_seq is not None and best_seq[0] < best_para[0]):
        t, H, W, N = best_seq
        return DesignConfig(H, W, N, "sequential", [N] * L, [N] * V, N, N,
                            t, t, t, searched_points=searched)
    t, H, W, N, nl_bar = best_para
    ts = (ana.t_nn(H, W, [N] * L, layers) if L else 0) + \
         (ana.t_vsa(H, W, [N] * V, vnodes) if V else 0)
    return DesignConfig(H, W, N, "parallel", [nl_bar] * L,
                        [N - nl_bar] * V, nl_bar, N - nl_bar, t, ts, t,
                        searched_points=searched)


def _vsa_window(i: int, L: int, V: int) -> tuple[int, int]:
    """VSA node index range concurrent with layer i (span-proportional)."""
    j0 = (i * V) // max(1, L)
    j1 = ((i + 1) * V) // max(1, L)
    return j0, max(j0 + 1, j1)


def phase2(df: DataflowGraph, cfg: DesignConfig, iter_max: int = 8) -> DesignConfig:
    if cfg.mode == "sequential":
        return cfg
    layers, vnodes = df.nn_nodes, df.vsa_nodes
    L, V = len(layers), len(vnodes)
    H, W, N = cfg.H, cfg.W, cfg.N
    n_l, n_v = list(cfg.n_l), list(cfg.n_v)
    best = max(ana.t_nn(H, W, n_l, layers), ana.t_vsa(H, W, n_v, vnodes))
    searched = cfg.searched_points
    for _ in range(iter_max):
        improved = False
        for i in range(L):
            j0, j1 = _vsa_window(i, L, V)
            t_layer_i = ana.t_layer(H, W, n_l[i], layers[i].dims["m"],
                                    layers[i].dims["n"], layers[i].dims["k"])
            t_vsa_win = max(ana.t_vsa_node(H, W, n_v[j], vnodes[j])
                            for j in range(j0, min(j1, V)))
            # shift sub-arrays toward the slower stream; Eq. 1's ceilings
            # plateau at large N, so sweep move sizes (paper uses ±1 at
            # N=16; at N=64 single steps sit inside a ceil() plateau)
            direction = 1 if t_layer_i >= t_vsa_win else -1
            steps = sorted({max(1, N // 8), max(1, N // 16), 8, 4, 2, 1},
                           reverse=True)
            for step in steps:
                trial_l = n_l[i] + direction * step
                if not (1 <= trial_l <= N - 1):
                    continue
                trial_nv = list(n_v)
                ok = True
                for j in range(j0, min(j1, V)):
                    trial_nv[j] -= direction * step
                    if not (1 <= trial_nv[j] <= N - 1):
                        ok = False
                if not ok:
                    continue
                trial_nl = list(n_l)
                trial_nl[i] = trial_l
                searched += 1
                t = max(ana.t_nn(H, W, trial_nl, layers),
                        ana.t_vsa(H, W, trial_nv, vnodes))
                if t < best:
                    best = t
                    n_l, n_v = trial_nl, trial_nv
                    improved = True
                    break
        if not improved:
            break
    out = dataclasses.replace(cfg, n_l=n_l, n_v=n_v, t_para=best,
                              searched_points=searched)
    return out


def explore(df: DataflowGraph, max_pes: int = 16384, iter_max: int = 8,
            simd_lanes=(16, 32, 64, 128, 256)) -> DesignConfig:
    """Full Algorithm 1 + memory/SIMD sizing."""
    cfg = phase1(df, max_pes)
    cfg = phase2(df, cfg, iter_max)
    mem = ana.memory_plan(df.graph, cfg.t_best, simd_lanes)
    return dataclasses.replace(cfg, mem=mem)


# ---------------------------------------------------------------------------
# Generator -> serving architecture (the deploy() loop)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """Serving-runtime knobs derived from one explored :class:`DesignConfig`.

    This is the software half of the paper's generator->architecture loop:
    ``repro.serve.deploy`` traces a workload's dataflow graph, runs
    :func:`explore` over it, and configures the serving runtime from the
    winning design point instead of hand-set config fields.
    """

    batch_size: int               # admission-group ceiling
    buckets: tuple[int, ...]      # compiled batch-size buckets, ascending
    max_inflight: int             # depth of the pipelined in-flight window
    schedule: str                 # overlap | sequential (ReasonConfig knob)
    design: DesignConfig          # the DSE point the knobs derive from


def serving_plan(design: DesignConfig, max_batch: int = 8,
                 inflight_cap: int = 4, min_bucket: int = 2) -> ServingPlan:
    """Map an explored design point onto the serving runtime's knobs.

    - **schedule**: Algorithm 1's mode decision carries over directly —
      a ``parallel`` design (concurrent nn/vsa streams win analytically)
      serves with the ``overlap`` pipelined schedule; a ``sequential``
      design (unfolded array wins) serves with the synchronous schedule.
    - **batch buckets**: the admission width maps requests across the
      ``N`` sub-arrays, so the group ceiling is the largest power of two
      <= N (clamped to [min_bucket, max_batch]); the covering-bucket
      ladder below it comes from ``serve.frontdoor.pow2_buckets`` (whose
      ``min_bucket=2`` default carries the XLA batch-1 bit-equality
      caveat — documented there, not re-derived here).
    - **max_inflight**: the in-flight window depth is the analytical
      folded-vs-unfolded gain ``t_seq / t_para`` rounded (clamped to
      [1, inflight_cap]) — the deeper the array's concurrency win, the
      more groups the host keeps resident; a sequential design pipelines
      nothing (depth 1).
    """
    # lazy import: serve.frontdoor is jax-free and does not import core,
    # so borrowing its bucket ladder keeps one source of bucket policy
    from repro.serve.frontdoor import pow2_buckets

    if max_batch < 1 or min_bucket < 1:
        raise ValueError("max_batch and min_bucket must be >= 1")
    min_bucket = min(min_bucket, max_batch)
    schedule = "overlap" if design.mode == "parallel" else "sequential"
    batch = 1
    while batch * 2 <= max(1, design.N):
        batch *= 2
    batch = max(min_bucket, min(max_batch, batch))
    buckets = pow2_buckets(batch, min_bucket=min_bucket)
    if schedule == "sequential":
        depth = 1
    else:
        depth = max(1, min(inflight_cap,
                           round(design.t_seq / max(1, design.t_para))))
    return ServingPlan(batch_size=batch, buckets=buckets, max_inflight=depth,
                       schedule=schedule, design=design)


# ---------------------------------------------------------------------------
# Search-space accounting (Tab. II)
# ---------------------------------------------------------------------------


def search_space(m: int, n_nodes: int, iter_max: int = 8, n_layers: int = 0) -> dict:
    """Tab. II: original vs two-phase search-space sizes, #PEs = 2^m.

    Original: every (H, W) with H·W ≤ 2^m (m(m+1)/2 power-of-two configs),
    times (N-1)^k per-node mapping choices. DAG: Phase I is the pruned
    (H, W) grid × (N-1) static splits; Phase II is Iter × #layers moves.
    """
    hw_orig = m * (m + 1) // 2
    log10_orig = 0.0
    for i in range(1, m + 1):
        for j in range(1, m - i + 1 + 1):
            n = 2 ** m // (2 ** i * 2 ** j)
            if n >= 2:
                log10_orig += 0  # accumulate in log-space below
    # total = sum over configs of (N-1)^k  — dominated by the largest N
    best_log = 0.0
    for i in range(1, m + 1):
        for j in range(1, m + 1):
            if i + j > m:
                continue
            n = 2 ** (m - i - j)
            if n >= 2:
                best_log = max(best_log, n_nodes * math.log10(n - 1 if n > 2 else 2))
    pruned = [(h, w) for h, w in _hw_candidates(2 ** m)]
    phase1_points = sum(max(1, (2 ** m) // (h * w) - 1) for h, w in pruned)
    phase2_points = iter_max * (n_layers or n_nodes)
    return {
        "original_hw_configs": hw_orig,
        "original_log10_total": best_log + math.log10(max(1, hw_orig)),
        "dag_phase1_points": phase1_points,
        "dag_phase2_points": phase2_points,
        "dag_total_points": phase1_points + phase2_points,
        "reduction_log10": best_log + math.log10(max(1, hw_orig))
                           - math.log10(max(1, phase1_points + phase2_points)),
    }
