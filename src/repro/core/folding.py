"""Mesh folding — the TPU analogue of AdArray sub-array folding (Sec IV-B).

NSFlow splits its systolic array into sub-arrays so NN and vector-symbolic
streams run *concurrently*. On a TPU mesh the same move is a spatial device
split: inside one SPMD program, devices with ``axis_index < n_l`` execute
the NN stream on their slice of the NN batch while the remaining ``n_v``
devices execute the VSA stream — one ``lax.cond`` on the axis index, one
psum to reassemble each stream's output. The DSE's (N_l : N_v) partition
(Algorithm 1) chooses the split.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as PS

from repro.common.util import shard_map_unreplicated as shard_map


def make_folded_fn(mesh, axis: str, n_l: int, nn_fn: Callable,
                   vsa_fn: Callable, nn_out_shape, vsa_out_shape):
    """Build f(nn_x, vsa_x) -> (nn_out, vsa_out) where the two streams run
    concurrently on disjoint device groups of the ``axis`` (sizes n_l : n_v).

    nn_x: (B_nn, ...) — row-sharded across the first n_l devices;
    vsa_x: (B_vsa, ...) — row-sharded across the remaining devices.
    Shapes must divide by their group size.
    """
    n_total = mesh.shape[axis]
    n_v = n_total - n_l

    def inner(nn_x, vsa_x):
        idx = jax.lax.axis_index(axis)
        nn_shard = nn_x.shape[0] // n_l
        vsa_shard = vsa_x.shape[0] // n_v

        def nn_branch(_):
            i = jnp.clip(idx, 0, n_l - 1)
            xs = jax.lax.dynamic_slice_in_dim(nn_x, i * nn_shard, nn_shard)
            out = jnp.zeros(nn_out_shape, jnp.float32)
            out = jax.lax.dynamic_update_slice_in_dim(
                out, nn_fn(xs).astype(jnp.float32), i * nn_shard, 0)
            return out, jnp.zeros(vsa_out_shape, jnp.float32)

        def vsa_branch(_):
            j = jnp.clip(idx - n_l, 0, n_v - 1)
            xs = jax.lax.dynamic_slice_in_dim(vsa_x, j * vsa_shard, vsa_shard)
            out = jnp.zeros(vsa_out_shape, jnp.float32)
            out = jax.lax.dynamic_update_slice_in_dim(
                out, vsa_fn(xs).astype(jnp.float32), j * vsa_shard, 0)
            return jnp.zeros(nn_out_shape, jnp.float32), out

        nn_out, vsa_out = jax.lax.cond(idx < n_l, nn_branch, vsa_branch, None)
        return jax.lax.psum(nn_out, axis), jax.lax.psum(vsa_out, axis)

    def wrapped(nn_x, vsa_x):
        return shard_map(inner, mesh=mesh, in_specs=(PS(), PS()),
                         out_specs=(PS(), PS()))(nn_x, vsa_x)

    return wrapped
