"""Operation-graph IR — the unit the NSFlow frontend operates on.

Node kinds mirror the paper's workload taxonomy (Sec II):
  nn    — matmul / convolution (MXU / combined sub-array work)
  vsa   — blockwise circular convolution / correlation (symbolic binding)
  simd  — element-wise, reductions, softmax, similarity chains (SIMD unit)
  mem   — data movement only (reshape/transpose/gather)

Dims convention:
  nn   : m, n, k        (output m×n, contraction k) — paper's d1, d2, d3
  vsa  : nvec, d        (vector quantity n_j and dimension d_j)
  simd : elems
"""

from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass
class OpNode:
    name: str
    kind: str                      # nn | vsa | simd | mem
    dims: dict
    deps: list[str] = dataclasses.field(default_factory=list)
    out_bytes: int = 0
    in_bytes: int = 0
    param_bytes: int = 0           # stationary operand (weights / codebook)
    flops: int = 0
    label: str = ""                # human-readable (primitive name)

    # dataflow-graph annotations (filled by repro.core.dataflow)
    depth: int = -1
    on_critical_path: bool = False
    attached_to: str | None = None  # critical-path node this runs parallel to


@dataclasses.dataclass
class OpGraph:
    nodes: dict[str, OpNode] = dataclasses.field(default_factory=dict)
    order: list[str] = dataclasses.field(default_factory=list)  # topo order

    def add(self, node: OpNode) -> OpNode:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name}")
        self.nodes[node.name] = node
        self.order.append(node.name)
        return node

    def __iter__(self) -> Iterable[OpNode]:
        return (self.nodes[n] for n in self.order)

    def __len__(self) -> int:
        return len(self.order)

    def nn_nodes(self) -> list[OpNode]:
        return [n for n in self if n.kind == "nn"]

    def vsa_nodes(self) -> list[OpNode]:
        return [n for n in self if n.kind == "vsa"]

    def simd_nodes(self) -> list[OpNode]:
        return [n for n in self if n.kind == "simd"]

    def consumers(self) -> dict[str, list[str]]:
        out: dict[str, list[str]] = {k: [] for k in self.nodes}
        for n in self:
            for d in n.deps:
                if d in out:
                    out[d].append(n.name)
        return out

    def total_bytes(self, kind: str | None = None) -> int:
        return sum(n.out_bytes + n.param_bytes for n in self
                   if kind is None or n.kind == kind)

    def total_flops(self, kind: str | None = None) -> int:
        return sum(n.flops for n in self if kind is None or n.kind == kind)


def format_trace(graph: OpGraph, max_nodes: int = 0) -> str:
    """Listing-1-style program trace rendering."""
    lines = []
    names = graph.order[:max_nodes] if max_nodes else graph.order
    for name in names:
        n = graph.nodes[name]
        shape = n.dims.get("out_shape", "")
        args = ", ".join(f"%{d}" for d in n.deps) or "-"
        lines.append(f"%{n.name}{list(shape) if shape != '' else ''} : "
                     f"{n.kind}[{n.label}](args = ({args}))")
    return "\n".join(lines)
