"""Dataflow-graph generation — paper Sec V-B, Fig. 4.

Steps (paper numbering):
  ① critical-path identification  — longest path through the op graph (DFS)
  ② inner-loop parallelism        — BFS depth assignment; off-path nodes
                                    attach to the critical-path node at the
                                    same depth (earliest legal start)
  ③ inter-loop parallelism        — steady-state overlap: the next loop's
                                    first NN layer starts when the NN stream
                                    frees, running alongside this loop's
                                    symbolic tail
  ④ runtime functions             — attached per node via analytical.py
  ⑤ memory cost                   — per-node bytes for the memory planner

Graph -> schedule correspondence: the same DataflowGraph that drives the
DSE also drives *execution*.  ``serve.schedule.compile_schedule`` lowers a
workload's stage list into an executable ``StagedSchedule`` and traces the
composed pipeline back into this IR (``core.trace`` on the jaxpr): stage
boundaries land on the nn/vsa/simd stream transitions modeled here, the
per-stage buffer specs realize step ⑤, and the serving engine's
double-buffered overlap of consecutive admission batches is the host/device
realization of step ③ — ``interloop_overlap`` predicts the speedup that
``benchmarks/bench_nsai.py`` measures on real traffic.
"""

from __future__ import annotations

import dataclasses

from repro.core import analytical
from repro.core.opgraph import OpGraph, OpNode


@dataclasses.dataclass
class DataflowGraph:
    graph: OpGraph
    critical_path: list[str]
    depth: dict[str, int]
    parallel_groups: dict[str, list[str]]  # critical node -> attached nodes
    nn_span: tuple[int, int]               # depth range of the NN stream
    vsa_span: tuple[int, int]

    @property
    def nn_nodes(self) -> list[OpNode]:
        return self.graph.nn_nodes()

    @property
    def vsa_nodes(self) -> list[OpNode]:
        return self.graph.vsa_nodes()


def _node_weight(n: OpNode) -> int:
    """Unit-array runtime estimate used only to pick the critical path."""
    if n.kind == "nn":
        if all(k in n.dims for k in ("m", "n", "k")):
            return analytical.t_layer(32, 32, 1, n.dims["m"], n.dims["n"],
                                      n.dims["k"])
        # matmul-class kernel node (e.g. traced Pallas qmatmul) without
        # factored dims: fall back to MACs over a 32x32 array
        return analytical.cdiv(n.flops, 2 * 32 * 32) or 1
    if n.kind == "vsa":
        return analytical.t_vsa_node(32, 32, 1, n)
    if n.kind == "simd":
        return analytical.cdiv(n.dims.get("elems", 1), 64)
    return 0


def build(graph: OpGraph) -> DataflowGraph:
    # ① longest (weighted) path via DP over the topological order
    dist: dict[str, int] = {}
    pred: dict[str, str | None] = {}
    for name in graph.order:
        n = graph.nodes[name]
        best, bp = 0, None
        for d in n.deps:
            if d in dist and dist[d] > best:
                best, bp = dist[d], d
        dist[name] = best + _node_weight(n)
        pred[name] = bp
    end = max(dist, key=dist.get)
    path = []
    cur: str | None = end
    while cur is not None:
        path.append(cur)
        cur = pred[cur]
    path.reverse()
    on_path = set(path)

    # ② BFS depth assignment + attachment of same-depth off-path nodes
    depth: dict[str, int] = {}
    for name in graph.order:
        n = graph.nodes[name]
        depth[name] = 1 + max((depth[d] for d in n.deps if d in depth), default=-1)
    path_at_depth = {depth[p]: p for p in path}
    groups: dict[str, list[str]] = {p: [] for p in path}
    for name in graph.order:
        n = graph.nodes[name]
        n.depth = depth[name]
        n.on_critical_path = name in on_path
        if name not in on_path:
            # attach to the critical-path node at the same (or nearest lower)
            # depth — its earliest legal concurrent slot
            d = depth[name]
            while d >= 0 and d not in path_at_depth:
                d -= 1
            anchor = path_at_depth.get(max(d, 0), path[0])
            n.attached_to = anchor
            groups[anchor].append(name)

    nn_d = [depth[n.name] for n in graph.nn_nodes()] or [0]
    vsa_d = [depth[n.name] for n in graph.vsa_nodes()] or [0]
    return DataflowGraph(graph, path, depth, groups,
                         (min(nn_d), max(nn_d)), (min(vsa_d), max(vsa_d)))


def interloop_overlap(df: DataflowGraph, t_nn_stream: int, t_vsa_stream: int,
                      n_loops: int = 2) -> dict:
    """③ steady-state pipelined runtime for ``n_loops`` iterations.

    With folding, loop i+1's NN stream starts as soon as the NN resource
    frees (after this loop's NN stream), overlapping loop i's symbolic tail:
        t_total = t_nn + (n-1)·max(t_nn, t_vsa) + t_vsa  [pipeline formula]
    Without folding (sequential array): t_total = n·(t_nn + t_vsa).

    ``bubble`` is the idle fraction of the two streams over the (n-1)
    steady-state slots — pipelined vs the ideal where each slot carries one
    NN and one symbolic stream with no slack: a slot lasts max(t_nn, t_vsa)
    of the 2·max capacity, of which t_nn + t_vsa is busy.  A single loop
    (n_loops=1) has no pipeline slots and hence no bubble by definition,
    and balanced streams (t_nn == t_vsa) pipeline bubble-free.
    """
    stage = max(t_nn_stream, t_vsa_stream)
    pipelined = t_nn_stream + (n_loops - 1) * stage + t_vsa_stream
    sequential = n_loops * (t_nn_stream + t_vsa_stream)
    if n_loops <= 1 or stage <= 0:
        bubble = 0.0
    else:
        bubble = min(1.0, max(
            0.0, 1.0 - (t_nn_stream + t_vsa_stream) / (2 * stage)))
    return {
        "pipelined": pipelined,
        "sequential": sequential,
        "speedup": sequential / max(1, pipelined),
        "bubble": bubble,
    }
