"""Execution-trace extraction: jaxpr -> OpGraph (paper Sec V-B, Listing 1).

The paper extracts a compiled program trace from the PyTorch workload; the
JAX-native equivalent is a jaxpr walk. We recurse through pjit / custom-vjp /
scan / remat wrappers, classify every primitive into the paper's kernel
taxonomy (nn / vsa / simd / mem), and record dims, bytes and FLOPs so the
analytical models (Sec V-C) can attach runtime functions to each node.
"""

from __future__ import annotations

import collections
from typing import Any

import jax
import numpy as np

from repro.core.opgraph import OpGraph, OpNode

_ELEMWISE = {
    "add", "sub", "mul", "div", "max", "min", "pow", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "sin", "cos", "sign", "abs", "neg", "floor",
    "ceil", "round", "erf", "integer_pow", "and", "or", "not", "xor", "select_n",
    "clamp", "nextafter", "is_finite", "square", "cumsum", "cumprod", "cumlogsumexp",
}
_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
           "reduce_or", "argmax", "argmin", "reduce_precision"}
_MEM = {
    "reshape", "transpose", "broadcast_in_dim", "convert_element_type", "slice",
    "dynamic_slice", "dynamic_update_slice", "concatenate", "pad", "squeeze",
    "gather", "scatter", "scatter-add", "scatter_add", "rev", "iota", "copy",
    "split", "expand_dims", "bitcast_convert_type",
}
_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr", "body_jaxpr")


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _classify_pallas(name: str) -> tuple[str, str]:
    if "circ" in name or "elem_kernel" in name or "dict_kernel" in name:
        return "vsa", "circ_conv_kernel"
    if "match_prob" in name:
        return "simd", "fused_match_prob_kernel"
    if "qmm" in name:
        return "nn", "qmatmul_kernel"
    return "simd", f"pallas:{name}"


class _Tracer:
    def __init__(self):
        self.graph = OpGraph()
        self.counts: dict[str, int] = collections.defaultdict(int)
        self.env: dict[Any, str] = {}  # jaxpr Var -> producing node name

    def _fresh(self, stem: str) -> str:
        self.counts[stem] += 1
        return f"{stem}_{self.counts[stem]}"

    def _deps(self, invars) -> list[str]:
        out = []
        for v in invars:
            key = id(v)
            if key in self.env and self.env[key] not in out:
                out.append(self.env[key])
        return out

    def _bind_outs(self, outvars, name: str):
        for v in outvars:
            self.env[id(v)] = name

    def _sub(self, params: dict):
        for key in _SUBJAXPR_PARAMS:
            if key in params:
                j = params[key]
                return j.jaxpr if hasattr(j, "jaxpr") else j
        return None

    def walk(self, jaxpr, invar_sources: dict | None = None, scale: int = 1):
        if invar_sources:
            self.env.update(invar_sources)
        for eqn in jaxpr.eqns:
            self._eqn(eqn, scale)

    def _eqn(self, eqn, scale: int):
        prim = eqn.primitive.name
        params = eqn.params
        deps = self._deps(eqn.invars)
        out_aval = eqn.outvars[0].aval if eqn.outvars else None
        out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(_aval_bytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
        out_shape = tuple(getattr(out_aval, "shape", ()) or ())

        # --- structural primitives: recurse ---
        if prim in ("jit", "pjit", "closed_call", "core_call", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr", "remat", "remat2",
                    "checkpoint", "custom_lin"):
            sub = self._sub(params)
            if sub is not None:
                mapping = {id(iv): self.env[id(ov)]
                           for iv, ov in zip(sub.invars, eqn.invars)
                           if id(ov) in self.env}
                self.walk(sub, mapping, scale)
                for sv, ov in zip(sub.outvars, eqn.outvars):
                    if id(sv) in self.env:
                        self.env[id(ov)] = self.env[id(sv)]
                return
        if prim == "scan":
            sub = self._sub(params)
            length = int(params.get("length", 1))
            if sub is not None:
                mapping = {id(iv): self.env[id(ov)]
                           for iv, ov in zip(sub.invars, eqn.invars)
                           if id(ov) in self.env}
                self.walk(sub, mapping, scale * length)
                name = self._fresh("scan_out")
                node = OpNode(name, "mem", {"out_shape": out_shape,
                                            "repeat": length},
                              deps=self._deps(eqn.invars), out_bytes=out_bytes,
                              label=f"scan[{length}]")
                self.graph.add(node)
                self._bind_outs(eqn.outvars, name)
                return
        if prim in ("while", "cond"):
            for key in ("body_jaxpr", "cond_jaxpr"):
                if key in params:
                    j = params[key]
                    self.walk(j.jaxpr if hasattr(j, "jaxpr") else j, None, scale)
            if "branches" in params:
                for br in params["branches"]:
                    self.walk(br.jaxpr if hasattr(br, "jaxpr") else br, None, scale)
            name = self._fresh(prim)
            self.graph.add(OpNode(name, "mem", {"out_shape": out_shape}, deps,
                                  out_bytes=out_bytes, label=prim))
            self._bind_outs(eqn.outvars, name)
            return

        # --- compute primitives ---
        if prim == "dot_general":
            dn = params["dimension_numbers"]
            (lc, rc), (lb, rb) = dn
            lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
            k = int(np.prod([lhs.shape[i] for i in lc])) or 1
            b = int(np.prod([lhs.shape[i] for i in lb])) or 1
            m = int(np.prod([s for i, s in enumerate(lhs.shape)
                             if i not in lc and i not in lb])) or 1
            n = int(np.prod([s for i, s in enumerate(rhs.shape)
                             if i not in rc and i not in rb])) or 1
            node = OpNode(self._fresh("dot_general"), "nn",
                          {"m": m * b, "n": n, "k": k, "out_shape": out_shape,
                           "repeat": scale},
                          deps, out_bytes=out_bytes, in_bytes=in_bytes,
                          param_bytes=_aval_bytes(rhs),
                          flops=2 * b * m * n * k * scale, label="matmul")
        elif prim == "conv_general_dilated":
            lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
            out_elems = int(np.prod(out_shape))
            k = int(np.prod(rhs.shape[:-1]))  # HWIO: kh*kw*cin
            node = OpNode(self._fresh("conv"), "nn",
                          {"m": out_elems // max(1, out_shape[-1]),
                           "n": out_shape[-1] if out_shape else 1, "k": k,
                           "out_shape": out_shape, "repeat": scale},
                          deps, out_bytes=out_bytes, in_bytes=in_bytes,
                          param_bytes=_aval_bytes(rhs),
                          flops=2 * out_elems * k * scale, label="conv2d")
        elif prim == "pallas_call":
            kname = str(params.get("name", "") or
                        getattr(params.get("name_and_src_info", ""), "name", ""))
            kind, label = _classify_pallas(kname)
            dims = {"out_shape": out_shape, "repeat": scale}
            if kind == "vsa" and len(out_shape) >= 2:
                dims["nvec"] = int(np.prod(out_shape[:-1]))
                dims["d"] = int(out_shape[-1])
                flops = 2 * dims["nvec"] * dims["d"] ** 2 * scale
            else:
                flops = 2 * int(np.prod(out_shape)) * scale
            node = OpNode(self._fresh(label), kind, dims, deps,
                          out_bytes=out_bytes, in_bytes=in_bytes,
                          flops=flops, label=label)
        elif prim in ("fft",):
            n_el = int(np.prod(out_shape))
            d = out_shape[-1] if out_shape else 1
            node = OpNode(self._fresh("fft"), "vsa",
                          {"nvec": n_el // max(1, d), "d": int(d),
                           "out_shape": out_shape, "repeat": scale},
                          deps, out_bytes=out_bytes, in_bytes=in_bytes,
                          flops=int(5 * n_el * max(1, np.log2(max(2, d)))) * scale,
                          label="fft")
        elif prim in _REDUCE or prim in _ELEMWISE or prim.startswith("reduce_"):
            elems = int(np.prod(out_shape)) if out_shape else 1
            node = OpNode(self._fresh(prim), "simd",
                          {"elems": elems, "out_shape": out_shape, "repeat": scale},
                          deps, out_bytes=out_bytes, in_bytes=in_bytes,
                          flops=elems * scale, label=prim)
        elif prim in _MEM:
            node = OpNode(self._fresh(prim), "mem",
                          {"out_shape": out_shape, "repeat": scale}, deps,
                          out_bytes=out_bytes, in_bytes=in_bytes, label=prim)
        else:
            elems = int(np.prod(out_shape)) if out_shape else 1
            node = OpNode(self._fresh(prim), "simd",
                          {"elems": elems, "out_shape": out_shape, "repeat": scale},
                          deps, out_bytes=out_bytes, in_bytes=in_bytes,
                          flops=elems * scale, label=prim)
        self.graph.add(node)
        self._bind_outs(eqn.outvars, node.name)


def extract(fn, *example_args, **example_kwargs) -> OpGraph:
    """Trace ``fn`` on example args (arrays or ShapeDtypeStructs) -> OpGraph."""
    closed = jax.make_jaxpr(fn)(*example_args, **example_kwargs)
    tracer = _Tracer()
    tracer.walk(closed.jaxpr)
    return tracer.graph
