"""Device-level simulator for the paper's evaluation (Fig. 5 / Fig. 6).

The paper's own numbers come from SCALE-Sim-style analytical models (refs
[29], [31]) plus RTL synthesis — not silicon measurements of NSFlow — so the
honest reproduction is the same methodology:

- **NSFlow (AdArray)**: DSE-chosen (H, W, N) + folding; NN/VSA streams
  overlap (dataflow pipelining); cycles from Eqs. (1)-(5) at 272 MHz.
- **TPU-like 128×128 systolic array**: NN via Eq. (1) with H=W=128, N=1;
  circular convolution has no streaming path on a weight-stationary matmul
  array, so it must materialize the circulant matrix (d× traffic
  amplification) and run memory-bound; strictly sequential NN→VSA.
- **GPU / CPU / edge SoCs / DPU**: per-node roofline max(flops/peak,
  bytes/bw) + per-kernel launch overhead; symbolic nodes are memory-bound
  exactly as the paper's Fig. 1c roofline shows.

Device constants are public datasheet numbers (annotated); ratios — not the
absolute seconds — are the reproduced claim.
"""

from __future__ import annotations

import dataclasses

from repro.core import analytical as ana
from repro.core import dataflow as dfl
from repro.core import dse as dse_mod
from repro.core.opgraph import OpGraph


@dataclasses.dataclass(frozen=True)
class Device:
    name: str
    peak_flops: float          # effective FLOP/s for NN kernels
    dram_bw: float             # bytes/s
    launch_overhead: float     # s per op node (kernel launch / dispatch)
    symbolic_native: bool      # has a circular-conv streaming path
    freq: float = 272e6        # array clock (systolic models)

    def nn_time(self, flops: int, bytes_: int) -> float:
        return max(flops / self.peak_flops, bytes_ / self.dram_bw) + self.launch_overhead

    def vsa_time(self, nvec: int, d: int, dtype_bytes: int = 4) -> float:
        if self.symbolic_native:
            raise RuntimeError("use array model for native devices")
        # circulant materialization: d× traffic amplification, memory bound
        traffic = nvec * d * d * dtype_bytes + nvec * 2 * d * dtype_bytes
        flops = 2 * nvec * d * d
        return max(flops / self.peak_flops, traffic / self.dram_bw) + self.launch_overhead

    def simd_time(self, elems: int, bytes_: int) -> float:
        return max(elems / (self.peak_flops / 16), bytes_ / self.dram_bw) \
            + self.launch_overhead


# Datasheet-derived constants (see benchmarks/bench_runtime_fig5.py table).
DEVICES = {
    "tx2": Device("Jetson TX2", 1.33e12, 59.7e9, 12e-6, False),
    "nx": Device("Xavier NX", 6.0e12, 51.2e9, 10e-6, False),
    "xeon": Device("Xeon CPU", 1.0e12, 94e9, 2e-6, False),
    "rtx2080": Device("RTX 2080 Ti", 13.4e12, 616e9, 5e-6, False),
    "coral": Device("Coral edge TPU", 4.0e12, 25.6e9, 30e-6, False),
    "dpu": Device("Xilinx DPU (U250)", 4.0e12, 77e9, 8e-6, False),
}

NSFLOW_FREQ = 272e6   # paper Tab. III
NSFLOW_DRAM_BW = 77e9  # U250 DDR4 (4 channels)
TPU_LIKE_FREQ = 272e6  # same fabric as NSFlow for apples-to-apples (Fig. 5)


@dataclasses.dataclass
class SimResult:
    device: str
    total: float
    nn: float
    vsa: float
    simd: float
    detail: dict = dataclasses.field(default_factory=dict)


def simulate_generic(graph: OpGraph, device: Device) -> SimResult:
    """Sequential per-node roofline execution (GPU/CPU/SoC/DPU model)."""
    t_nn = t_vsa = t_simd = 0.0
    for n in graph:
        r = n.dims.get("repeat", 1)
        if n.kind == "nn":
            t_nn += device.nn_time(n.flops, (n.in_bytes + n.out_bytes) * r)
        elif n.kind == "vsa":
            t_vsa += device.vsa_time(n.dims["nvec"] * r, n.dims["d"])
        elif n.kind == "simd":
            t_simd += device.simd_time(n.dims.get("elems", 1) * r,
                                       (n.in_bytes + n.out_bytes) * r)
    return SimResult(device.name, t_nn + t_vsa + t_simd, t_nn, t_vsa, t_simd)


def simulate_tpu_like(graph: OpGraph, array: int = 128,
                      freq: float = TPU_LIKE_FREQ,
                      dram_bw: float = 600e9,
                      staging_factor: float = 1.0) -> SimResult:
    """Weight-stationary 128×128 systolic array, sequential NN→VSA.

    Circular convolution has no native mapping on a weight-stationary
    matmul array: the standard lowering (what XLA emits today) gathers the
    circulant matrix per binding pair — d× DRAM traffic amplification —
    then runs batched mat-vecs at poor MXU occupancy (~1/8). This DRAM-
    materialization model reproduces the paper's own Fig. 1b measurement
    that symbolic ops take ~90% of runtime on real accelerators.
    ``staging_factor`` > 1 would model on-chip circulant staging (not
    available in stock lowerings; kept as a sensitivity knob).
    """
    t_nn_cyc = ana.t_nn(array, array, [1] * len(graph.nn_nodes()),
                        graph.nn_nodes())
    t_nn = t_nn_cyc / freq
    peak = 2 * array * array * freq  # MAC/s of the array
    bmm_util = 1.0 / 8.0  # batched per-pair mat-vecs: poor MXU occupancy
    t_vsa = 0.0
    for n in graph.vsa_nodes():
        r = n.dims.get("repeat", 1)
        nvec, d = n.dims["nvec"] * r, n.dims["d"]
        # best TPU mapping = batched (d,d)@(d,) circulant mat-vecs:
        # compute at ~1/8 occupancy, circulants staged via on-chip SRAM
        traffic = nvec * d * d * 4
        io = nvec * 2 * d * 4
        flops = 2 * nvec * d * d
        t_vsa += max(flops / (peak * bmm_util),
                     traffic / (staging_factor * dram_bw) + io / dram_bw)
    t_simd = sum(ana.cdiv(n.dims.get("elems", 1), 128) * n.dims.get("repeat", 1)
                 for n in graph.simd_nodes()) / freq
    return SimResult(f"TPU-like SA {array}x{array}", t_nn + t_vsa + t_simd,
                     t_nn, t_vsa, t_simd)


def simulate_nsflow(graph: OpGraph, max_pes: int = 16384, iter_max: int = 8,
                    freq: float = NSFLOW_FREQ, dram_bw: float = NSFLOW_DRAM_BW,
                    n_loops: int = 4, force_mode: str | None = None,
                    phase2_enabled: bool = True) -> SimResult:
    """NSFlow AdArray: DSE config + folding overlap + SIMD hiding."""
    df = dfl.build(graph)
    cfg = dse_mod.phase1(df, max_pes)
    if force_mode == "sequential":
        cfg = dataclasses.replace(cfg, mode="sequential",
                                  t_para=cfg.t_seq)
    elif phase2_enabled:
        cfg = dse_mod.phase2(df, cfg, iter_max)
    mem = ana.memory_plan(graph, cfg.t_best)
    layers, vnodes = df.nn_nodes, df.vsa_nodes
    if cfg.mode == "parallel":
        t_nn_cyc = ana.t_nn(cfg.H, cfg.W, cfg.n_l, layers)
        t_vsa_cyc = ana.t_vsa(cfg.H, cfg.W, cfg.n_v, vnodes)
        overlap = dfl.interloop_overlap(df, t_nn_cyc, t_vsa_cyc, n_loops)
        cycles = overlap["pipelined"] / n_loops
    else:
        t_nn_cyc = ana.t_nn(cfg.H, cfg.W, [cfg.N] * len(layers), layers) if layers else 0
        t_vsa_cyc = ana.t_vsa(cfg.H, cfg.W, [cfg.N] * len(vnodes), vnodes) if vnodes else 0
        cycles = t_nn_cyc + t_vsa_cyc
    # SIMD stream is sized to hide under the array runtime (Sec V-C)
    t_simd_cyc = ana.t_simd(mem.simd_lanes, graph.simd_nodes())
    hidden = min(t_simd_cyc, cycles)
    total_cycles = cycles + (t_simd_cyc - hidden)
    # off-chip transfer overlapped with compute via double buffering; only
    # the non-overlappable excess stalls
    bytes_total = graph.total_bytes()
    t_mem = bytes_total / dram_bw
    t_compute = total_cycles / freq
    total = max(t_compute, t_mem)
    return SimResult("NSFlow", total, t_nn_cyc / freq, t_vsa_cyc / freq,
                     t_simd_cyc / freq,
                     detail={"config": cfg.summary(), "mem_stall_bound": t_mem,
                             "cycles_per_loop": cycles})
