"""GPipe pipeline parallelism over the ``pod`` mesh axis.

The multi-pod mesh maps pods to pipeline stages: stage s holds a contiguous
layer slice (params stacked with a leading stage dim, sharded over
``pod``), microbatches flow stage-to-stage via ``ppermute``, and the
schedule runs n_micro + n_stages - 1 ticks (bubble fraction
(S-1)/(M+S-1)). Backward differentiates straight through the schedule
(ppermute transposes to the reverse permute), so one ``jax.grad`` trains
the pipelined model.

This is the TPU analogue of NSFlow's inter-loop overlap (Fig. 4 ③): loop
i+1 enters stage 0 while loop i occupies later stages.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.common.util import shard_map_unreplicated as shard_map


def pipeline_fwd(stage_fn: Callable, n_stages: int, axis: str,
                 params_stage, x_micro: jax.Array) -> jax.Array:
    """GPipe schedule, called inside shard_map.

    params_stage: this stage's layer params (leading stage dim removed);
    x_micro: (n_micro, mb, ...) microbatches (replicated; stage 0 consumes).
    Returns (n_micro, mb, ...) — real values on the LAST stage, zeros
    elsewhere (caller psums over ``axis`` to broadcast).
    """
    stage = jax.lax.axis_index(axis)
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def tick(carry, t):
        held, outs = carry  # held: (mb, ...) this stage's last output
        incoming = jax.lax.ppermute(held, axis, fwd_perm)
        inject = jnp.clip(t, 0, n_micro - 1)
        my_in = jnp.where(stage == 0, x_micro[inject], incoming)
        active = (t >= stage) & (t - stage < n_micro)
        out = stage_fn(params_stage, my_in)
        out = jnp.where(active, out, jnp.zeros_like(out))
        mb = jnp.clip(t - stage, 0, n_micro - 1)
        record = active & (stage == n_stages - 1)
        outs = outs.at[mb].set(jnp.where(record, out, outs[mb]))
        return (out, outs), None

    held0 = jnp.zeros(x_micro.shape[1:], x_micro.dtype)
    outs0 = jnp.zeros((n_micro,) + x_micro.shape[1:], x_micro.dtype)
    (_, outs), _ = jax.lax.scan(tick, (held0, outs0), jnp.arange(ticks))
    return outs


def make_pipelined_fn(stage_fn: Callable, n_stages: int, mesh,
                      axis: str = "pod"):
    """Build f(params_stacked, x_micro) -> (n_micro, mb, ...) outputs.

    ``params_stacked``: pytree whose leaves have a leading (n_stages,) dim
    (sharded over ``axis``); ``x_micro``: (n_micro, mb, ...) replicated.
    """

    def inner(params_stacked, x_micro):
        params_stage = jax.tree.map(lambda p: jnp.squeeze(p, 0), params_stacked)
        outs = pipeline_fwd(stage_fn, n_stages, axis, params_stage, x_micro)
        return jax.lax.psum(outs, axis)  # non-last stages contribute zeros

    def wrapped(params_stacked, x_micro):
        in_specs = (jax.tree.map(lambda _: PS(axis), params_stacked), PS())
        return shard_map(inner, mesh=mesh, in_specs=in_specs,
                         out_specs=PS())(params_stacked, x_micro)

    return wrapped


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
