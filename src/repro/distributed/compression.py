"""Error-feedback int8 gradient compression for cross-pod reduction.

At 1000+ nodes the pod-to-pod (DCN/ICI-bridge) axis is the scarce
bandwidth; compressing the gradient all-reduce over that axis 4× (f32 ->
int8 + per-tensor scale) with error feedback keeps convergence unchanged
(the EF residual re-injects quantization error next step).

``compressed_psum(g, axis)`` runs inside shard_map: all_gather of int8
shards + local dequant-sum — 4× less data over ``axis`` than an f32 psum.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jax.Array):
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, axis: str):
    """int8 all-gather + local sum == psum at 1/4 the wire bytes.

    Must be called inside shard_map with ``axis`` unmapped on g.
    """
    q, scale = quantize(g)
    qs = jax.lax.all_gather(q, axis)          # (n, ...)  int8 on the wire
    ss = jax.lax.all_gather(scale, axis)      # (n,)      f32 (negligible)
    return jnp.tensordot(ss, qs.astype(jnp.float32), axes=1)


def ef_compress_tree(grads, residuals):
    """Error-feedback step: quantize (g + residual), return (quantized
    payload tree, new residuals). Residuals live in f32 and are sharded
    like the gradients."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize(x)
        new_r = x - dequantize(q, s)
        return (q, s), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    payload = jax.tree.unflatten(tdef, [p[0] for p in pairs])
    new_res = jax.tree.unflatten(tdef, [p[1] for p in pairs])
    return payload, new_res


def ef_decompress_tree(payload):
    return jax.tree.map(lambda qs: dequantize(*qs), payload,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
