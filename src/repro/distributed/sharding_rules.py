"""Logical-axis -> mesh-axis sharding rules (TP / FSDP / EP / SP).

Every parameter spec carries logical axis names (repro.nn.init.P); these
rules map them onto the production mesh. Defaults are megatron-style TP
over ``model`` with optional FSDP of the remaining dim over ``data``
(needed by deepseek-v3-scale cells), experts EP-sharded over ``model``.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.nn import init as nninit

# logical axis -> mesh axis (None = replicate)
TP_RULES = {
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "experts": "model",
    "heads_flat": "model",
    "conv_out": None,
    "conv_in": None,
    "embed": None,
    "embed2": None,
    "qlora": None,
    "kvlora": None,
    "hd": None,
    "layers": None,
}

FSDP_RULES = dict(TP_RULES, embed="data")


def _divisible(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    size = mesh.shape[axis] if not isinstance(axis, tuple) else \
        int(np.prod([mesh.shape[a] for a in axis]))
    return dim % size == 0 and dim >= size


#: logical axes eligible as a TP fallback when the preferred axis does not
#: divide the mesh (e.g. llama's 24 heads on a 16-way model axis -> shard
#: the embed dim instead: row-parallel with a psum the block already pays).
FALLBACK_TP_AXES = ("embed", "mlp", "heads_flat", "embed2", "qlora", "kvlora",
                    "hd", "vocab")

_MIN_SHARD_ELEMS = 1 << 20  # don't bother re-sharding small tensors


def spec_to_pspec(axes: tuple, shape: tuple, mesh: Mesh, rules: dict,
                  min_shard_elems: int | None = None) -> PS:
    """Build a PartitionSpec, dropping assignments that do not divide; if the
    preferred TP axis does not divide, fall back to another large dim.

    ``min_shard_elems`` gates only the *fallback* (preferred-axis sharding
    has no size floor): tensors smaller than it stay replicated rather
    than re-sharded over a non-preferred axis.  None = the production
    default; serving-path callers pass 0 so smoke-scale params still
    exercise the FALLBACK_TP_AXES path.
    """
    if min_shard_elems is None:
        min_shard_elems = _MIN_SHARD_ELEMS
    assigned = []
    used = set()
    for ax_name, dim in zip(axes, shape):
        mesh_axis = rules.get(ax_name)
        if mesh_axis is not None and mesh_axis not in used and \
                _divisible(dim, mesh, mesh_axis):
            assigned.append(mesh_axis)
            used.add(mesh_axis)
        else:
            assigned.append(None)
    if "model" not in used and int(np.prod(shape)) >= min_shard_elems:
        for i, (ax_name, dim) in enumerate(zip(axes, shape)):
            if assigned[i] is None and ax_name in FALLBACK_TP_AXES and \
                    _divisible(dim, mesh, "model"):
                assigned[i] = "model"
                break
    while assigned and assigned[-1] is None:
        assigned.pop()
    return PS(*assigned)


def param_shardings(spec_tree, mesh: Mesh, fsdp: bool = False,
                    min_shard_elems: int | None = None):
    """Spec tree -> NamedSharding tree (same structure).

    ``min_shard_elems`` forwards to :func:`spec_to_pspec` (the fallback
    re-shard size floor; None = production default)."""
    rules = FSDP_RULES if fsdp else TP_RULES
    axes_tree = nninit.axes(spec_tree)
    shapes_tree = nninit.shapes(spec_tree)

    def one(axes, shp):
        return NamedSharding(mesh, spec_to_pspec(axes, shp.shape, mesh, rules,
                                                 min_shard_elems))

    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(a, (str, type(None))) for a in x))


def data_axes(mesh: Mesh) -> tuple:
    """Mesh axes that carry the batch dimension (pod folds into data)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_sharding(mesh: Mesh, ndim: int = 2) -> NamedSharding:
    return NamedSharding(mesh, PS(data_axes(mesh), *([None] * (ndim - 1))))


def cache_pspec(shape: tuple, mesh: Mesh, kv_axis: int | None = None,
                seq_axis: int | None = None, batch_axis: int = 0) -> PS:
    """KV-cache sharding policy (SP):

    - batch over the data axes when divisible,
    - kv-heads over ``model`` when divisible, else the *sequence* dim over
      ``model`` (sequence parallelism — the long_500k/batch-1 case),
    - otherwise replicate.
    """
    spec: list = [None] * len(shape)
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    if shape[batch_axis] % dsize == 0 and shape[batch_axis] >= dsize:
        spec[batch_axis] = daxes
    msize = mesh.shape["model"]
    if kv_axis is not None and shape[kv_axis] % msize == 0 and shape[kv_axis] >= msize:
        spec[kv_axis] = "model"
    elif seq_axis is not None and shape[seq_axis] % msize == 0:
        spec[seq_axis] = "model"
    while spec and spec[-1] is None:
        spec.pop()
    return PS(*spec)


def tree_cache_shardings(shapes_tree, mesh: Mesh):
    """Heuristic cache sharding: identify (B, S, KV, hd) / (B, S, r) /
    (B, H, hd, hd) / stacked (L, ...) variants by rank and shard per policy."""

    def one(s):
        shape = s.shape
        off = 0
        # stacked layer dim heuristic: leading dim small & others large
        if len(shape) >= 4 and shape[0] <= 128 and shape[1] <= 4096:
            off = 1
        rank = len(shape) - off
        if rank == 4:   # (B, S, KV, hd)
            return NamedSharding(mesh, cache_pspec(
                shape, mesh, kv_axis=off + 2, seq_axis=off + 1, batch_axis=off))
        if rank == 3:   # (B, S, r) MLA or (B, H, hd*) partial
            return NamedSharding(mesh, cache_pspec(
                shape, mesh, kv_axis=None, seq_axis=off + 1, batch_axis=off))
        if rank == 2:   # (B, D) recurrent carries
            return NamedSharding(mesh, cache_pspec(shape, mesh, batch_axis=off))
        return NamedSharding(mesh, cache_pspec(shape, mesh, batch_axis=off))

    return jax.tree.map(one, shapes_tree)
