"""Ambient-mesh-aware sharding constraints.

``maybe_constrain(x, axes)`` applies ``with_sharding_constraint`` when the
named mesh axes exist in the ambient (jit-context) mesh, and is a no-op on
host-only runs — so model code can carry distribution hints without
depending on a mesh being present (smoke tests, examples).
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as PS


def _ambient_axes() -> tuple:
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return ()
        return tuple(mesh.axis_names)
    except Exception:
        return ()


def maybe_constrain(x: jax.Array, axes: tuple):
    """axes: per-dim mesh axis name (or tuple of names, or None).

    Dims whose axis is absent from the ambient mesh fall back to None.
    """
    names = _ambient_axes()
    if not names:
        return x
    spec = []
    for a in axes:
        if a is None:
            spec.append(None)
        elif isinstance(a, tuple):
            present = tuple(ax for ax in a if ax in names)
            spec.append(present if present else None)
        else:
            spec.append(a if a in names else None)
    while spec and spec[-1] is None:
        spec.pop()
    try:
        return jax.lax.with_sharding_constraint(x, PS(*spec))
    except Exception:
        return x


def batch_seq_heads(x: jax.Array):
    """(B, S, H, hd) activation: batch over data axes, heads over model."""
    return maybe_constrain(x, (("pod", "data"), None, "model", None))


def batch_only(x: jax.Array):
    return maybe_constrain(x, (("pod", "data"),) + (None,) * (x.ndim - 1))
