"""Small shared utilities used across the framework."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def cdiv(a: int, b: int) -> int:
    """Ceiling division."""
    return -(-a // b)


def pad_to_multiple(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    """Zero-pad ``x`` along ``axis`` up to the next multiple of ``multiple``."""
    size = x.shape[axis]
    target = cdiv(size, multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


def tree_count(tree: PyTree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def tree_bytes(tree: PyTree) -> int:
    """Total byte size of a pytree of arrays / ShapeDtypeStructs."""
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def split_key(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


def human_bytes(n: float) -> str:
    for unit in ["B", "KiB", "MiB", "GiB", "TiB"]:
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"


def human_flops(n: float) -> str:
    for unit in ["FLOP", "KFLOP", "MFLOP", "GFLOP", "TFLOP", "PFLOP"]:
        if abs(n) < 1000.0:
            return f"{n:.2f} {unit}"
        n /= 1000.0
    return f"{n:.2f} EFLOP"


def round_up_pow2(n: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(1, n))))


def mesh_context(mesh):
    """Ambient-mesh context manager across jax versions.

    ``jax.sharding.set_mesh`` exists on newer jax; older releases use the
    ``Mesh`` object itself as the context manager.
    """
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def shard_map_unreplicated(fn, *, mesh, in_specs, out_specs):
    """shard_map with replication checking off, across jax versions.

    The flag is ``check_vma`` on newer jax, ``check_rep`` before that; the
    entry point moved from ``jax.experimental.shard_map`` to ``jax.shard_map``.
    """
    import inspect

    try:
        smap = jax.shard_map
    except AttributeError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map as smap  # type: ignore

    flag = ("check_vma" if "check_vma" in inspect.signature(smap).parameters
            else "check_rep")
    return smap(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                **{flag: False})
