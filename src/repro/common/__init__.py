from repro.common.util import (
    PyTree,
    tree_bytes,
    tree_count,
    split_key,
    pad_to_multiple,
    cdiv,
)

__all__ = [
    "PyTree",
    "tree_bytes",
    "tree_count",
    "split_key",
    "pad_to_multiple",
    "cdiv",
]
