"""Fractional Power Encoding (FPE) over unitary block codes.

NVSA-style attribute encoding: a base unitary vector ``u`` (per attribute)
encodes value ``v`` as the v-th circular-convolution power ``u^v`` — computed
in the spectral domain as phase scaling. Binding then *is* attribute
arithmetic:

    bind(u^a, u^b)   = u^(a+b)      (circular convolution adds phases)
    unbind(u^a, u^b) = u^(b-a)      (correlation subtracts phases)

which makes RAVEN rule execution (progression / arithmetic) a chain of the
paper's circular-convolution kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fpe_base_phase(key: jax.Array, blocks: int, d: int) -> jax.Array:
    """Random base phase φ: codes are irfft(exp(i·v·φ))."""
    phase = jax.random.uniform(key, (blocks, d // 2 + 1), jnp.float32,
                               -np.pi, np.pi)
    phase = phase.at[..., 0].set(0.0)
    if d % 2 == 0:
        phase = phase.at[..., -1].set(0.0)
    return phase


def fpe_encode(phase: jax.Array, v, d: int) -> jax.Array:
    """Encode value(s) ``v`` (scalar or (n,) array) -> (n, blocks, d)."""
    v = jnp.atleast_1d(jnp.asarray(v, jnp.float32))
    spec = jnp.exp(1j * v[:, None, None] * phase[None])
    return jnp.fft.irfft(spec, n=d, axis=-1)


def fpe_codebook(phase: jax.Array, n_values: int, d: int) -> jax.Array:
    """Integer codebook for values 0..n_values-1 -> (n_values, blocks, d)."""
    return fpe_encode(phase, jnp.arange(n_values), d)
