"""Vector-Symbolic Architecture algebra on block codes.

Vectors are *block codes*: shape ``(..., blocks, d)`` — NVSA-style VSAs use
B blocks of dimension d (e.g. 4 × 256). The key kernel the paper accelerates
(Sec II-A) is the **blockwise circular convolution**

    C[n] = Σ_k A[k] · B[(n−k) mod d]            (binding)

and its inverse, circular correlation (unbinding). Bundling is normalized
superposition; similarity is the blockwise mean of dot products.

Compute paths:
- ``bind``/``unbind``/``match_prob`` dispatch through the backend lowering
  registry (``repro.backend.registry``): the active
  :class:`~repro.backend.registry.LoweringPlan` picks compiled Pallas
  (TPU/GPU), Pallas interpret mode (CPU), or the exact gather/XLA
  reference per kernel — registered there with its capability predicates
  (power-of-two ``d``, the ``dispatch_min_size`` perf threshold below
  which XLA wins anyway) and overridable via ``REPRO_BACKEND``.
  ``dispatch_path`` reports the resolved route for a given ``d``.
- ``*_ref`` functions here are the pure-jnp oracles used by kernel tests
  (and double as the registry's ``xla`` lowering of ``circ_conv``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import registry


# ---------------------------------------------------------------------------
# Reference (oracle) implementations — exact gather formulation
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=())
def circ_conv_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Blockwise circular convolution. a, b: (..., blocks, d)."""
    d = a.shape[-1]
    n = jnp.arange(d)[:, None]
    k = jnp.arange(d)[None, :]
    idx = (n - k) % d  # (d, d): row n gathers b[(n-k) % d]
    bmat = b[..., idx]  # (..., blocks, d, d)
    return jnp.einsum("...k,...nk->...n", a, bmat)


@functools.partial(jax.jit, static_argnames=())
def circ_corr_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Blockwise circular correlation (inverse binding): Σ_k a[k]·b[(n+k)%d]."""
    d = a.shape[-1]
    n = jnp.arange(d)[:, None]
    k = jnp.arange(d)[None, :]
    idx = (n + k) % d
    bmat = b[..., idx]
    return jnp.einsum("...k,...nk->...n", a, bmat)


def circ_conv_fft(a: jax.Array, b: jax.Array) -> jax.Array:
    """FFT oracle (float path — used for cross-validation in tests)."""
    fa = jnp.fft.rfft(a.astype(jnp.float32), axis=-1)
    fb = jnp.fft.rfft(b.astype(jnp.float32), axis=-1)
    return jnp.fft.irfft(fa * fb, n=a.shape[-1], axis=-1).astype(a.dtype)


def circ_corr_fft(a: jax.Array, b: jax.Array) -> jax.Array:
    fa = jnp.fft.rfft(a.astype(jnp.float32), axis=-1)
    fb = jnp.fft.rfft(b.astype(jnp.float32), axis=-1)
    return jnp.fft.irfft(jnp.conj(fa) * fb, n=a.shape[-1], axis=-1).astype(a.dtype)


# ---------------------------------------------------------------------------
# Public API (kernel-dispatching)
# ---------------------------------------------------------------------------

def dispatch_path(d: int) -> str:
    """Which implementation ``bind``/``unbind`` route to for block dim ``d``
    under the active :class:`~repro.backend.registry.LoweringPlan`.

    "kernel" = a Pallas lowering of ``circ_conv`` (feasible at the
    call-site shape — the compiled lowering wants pow2 d, the interpreter
    takes any — and at or above the registry's ``dispatch_min_size``);
    "gather" = the exact XLA gather reference. Exposed so the
    kernel-conformance tests can assert the routing, not just the numerics.
    """
    low = registry.active("circ_conv", size=d, dispatch=True)
    return "gather" if low.is_ref else "kernel"


def _use_kernel(a: jax.Array, use_kernel: bool | None) -> bool:
    if use_kernel is None:
        return dispatch_path(a.shape[-1]) == "kernel"
    return use_kernel


def bind(a: jax.Array, b: jax.Array, use_kernel: bool | None = None) -> jax.Array:
    """Binding = blockwise circular convolution. Shapes broadcast on lead dims."""
    if _use_kernel(a, use_kernel):
        from repro.kernels.circ_conv import ops as k_ops

        return k_ops.circ_bind(a, b, mode="conv")
    return circ_conv_ref(a, b)


def unbind(a: jax.Array, b: jax.Array, use_kernel: bool | None = None) -> jax.Array:
    """Inverse binding = blockwise circular correlation of ``a`` against ``b``."""
    if _use_kernel(a, use_kernel):
        from repro.kernels.circ_conv import ops as k_ops

        return k_ops.circ_bind(a, b, mode="corr")
    return circ_corr_ref(a, b)


def bundle(*vs: jax.Array, normalize: bool = True) -> jax.Array:
    """Superposition of block codes."""
    s = sum(vs[1:], start=vs[0])
    if normalize:
        s = s / jnp.maximum(jnp.linalg.norm(s, axis=-1, keepdims=True), 1e-9)
    return s


def normalize(v: jax.Array) -> jax.Array:
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-9)


def similarity(a: jax.Array, b: jax.Array) -> jax.Array:
    """Blockwise cosine similarity, averaged over blocks.

    a: (..., blocks, d), b: (..., blocks, d) -> (...)
    """
    an = normalize(a.astype(jnp.float32))
    bn = normalize(b.astype(jnp.float32))
    return jnp.mean(jnp.sum(an * bn, axis=-1), axis=-1)


def similarity_matrix(q: jax.Array, dictionary: jax.Array) -> jax.Array:
    """q: (n, blocks, d) vs dictionary: (m, blocks, d) -> (n, m)."""
    qn = normalize(q.astype(jnp.float32))
    dn = normalize(dictionary.astype(jnp.float32))
    return jnp.einsum("nbd,mbd->nm", qn, dn) / q.shape[-2]


def match_prob(q: jax.Array, dictionary: jax.Array, temp: float = 1.0,
               use_kernel: bool | None = None) -> jax.Array:
    """Paper Listing 1 ``match_prob_multi_batched``: probability that each
    query matches each dictionary entry — softmax over scaled similarities.

    q: (n, blocks, d), dictionary: (m, blocks, d) -> (n, m).
    Routes through the fused SIMD-unit kernel when enabled.
    """
    d = q.shape[-1]
    if use_kernel is None:
        use_kernel = not registry.active("simd_fused", size=d,
                                         dispatch=True).is_ref
    if use_kernel:
        from repro.kernels.simd_fused import ops as k_ops

        return k_ops.fused_match_prob(q, dictionary, temp)
    sims = similarity_matrix(q, dictionary)
    return jax.nn.softmax(sims / temp, axis=-1)


def random_codebook(key: jax.Array, n: int, blocks: int, d: int,
                    dtype=jnp.float32) -> jax.Array:
    """Random unit-norm block codes. Unbinding a binding with a random code
    recovers the other factor in expectation (quasi-orthogonality)."""
    v = jax.random.normal(key, (n, blocks, d), jnp.float32)
    return normalize(v).astype(dtype)


def unitary_codebook(key: jax.Array, n: int, blocks: int, d: int,
                     dtype=jnp.float32) -> jax.Array:
    """Unitary block codes (|FFT| = 1): binding is exactly invertible —
    unbind(bind(a, u), u) == a. Used by NVSA-style reasoning."""
    phase = jax.random.uniform(key, (n, blocks, d // 2 + 1), jnp.float32,
                               -np.pi, np.pi)
    # enforce real signal constraints: DC and Nyquist bins real (phase 0/π)
    phase = phase.at[..., 0].set(0.0)
    if d % 2 == 0:
        phase = phase.at[..., -1].set(0.0)
    spec = jnp.exp(1j * phase)
    v = jnp.fft.irfft(spec, n=d, axis=-1)  # rfft(v) == spec, |spec| == 1
    return v.astype(dtype)


def codebook_circulant(dictionary: jax.Array, mode: str = "conv") -> jax.Array:
    """Precompute the circulant expansion of a (static) codebook.

    dictionary: (m, blocks, d) -> (m, blocks, d, d) such that
    ``bind(x, dict_i) == einsum('bk,bnk->bn', x, out_i)``.

    This is the TPU adaptation of the paper's passing-register streaming: a
    one-time d× memory expansion of a *small static* codebook turns every
    subsequent binding into an MXU matmul (see DESIGN.md §2).
    """
    d = dictionary.shape[-1]
    n = jnp.arange(d)[:, None]
    k = jnp.arange(d)[None, :]
    idx = (n - k) % d if mode == "conv" else (n + k) % d
    return dictionary[..., idx]
