from repro.vsa.ops import (
    bind,
    unbind,
    bundle,
    similarity,
    match_prob,
    random_codebook,
    circ_conv_ref,
    circ_corr_ref,
)

__all__ = [
    "bind",
    "unbind",
    "bundle",
    "similarity",
    "match_prob",
    "random_codebook",
    "circ_conv_ref",
    "circ_corr_ref",
]
