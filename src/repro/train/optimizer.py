"""Optimizers (pure JAX): AdamW with optional 8-bit quantized moments.

The 8-bit path (blockwise-scaled int8 m/v, error kept implicitly by
re-quantization — bitsandbytes-style) is what lets the deepseek-v3 cell fit
a 16 GB/chip budget: moment memory drops 4× vs fp32. States inherit the
parameter sharding, i.e. ZeRO-style: with params sharded over
(model × data[FSDP]), so are the moments.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    quantized_state: bool = False  # 8-bit moments
    qblock: int = 256


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


# --- blockwise int8 moment quantization -----------------------------------


def _q8(x: jax.Array, block: int):
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -128, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array, shape, size: int):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def init_state(params, cfg: AdamWConfig):
    def zero_like(p):
        if cfg.quantized_state:
            n_blocks = -(-p.size // cfg.qblock)
            return {
                "m_q": jnp.zeros((n_blocks, cfg.qblock), jnp.int8),
                "m_s": jnp.zeros((n_blocks, 1), jnp.float32),
                "v_q": jnp.zeros((n_blocks, cfg.qblock), jnp.int8),
                "v_s": jnp.zeros((n_blocks, 1), jnp.float32),
            }
        return {"m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32)}
    return {"mu": jax.tree.map(zero_like, params,
                               is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def state_shapes(param_shapes, cfg: AdamWConfig):
    def shape_like(p):
        if cfg.quantized_state:
            size = 1
            for s in p.shape:
                size *= s
            n_blocks = -(-size // cfg.qblock)
            return {
                "m_q": jax.ShapeDtypeStruct((n_blocks, cfg.qblock), jnp.int8),
                "m_s": jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
                "v_q": jax.ShapeDtypeStruct((n_blocks, cfg.qblock), jnp.int8),
                "v_s": jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
            }
        return {"m": jax.ShapeDtypeStruct(p.shape, jnp.float32),
                "v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}
    return {"mu": jax.tree.map(shape_like, param_shapes,
                               is_leaf=lambda x: hasattr(x, "shape")),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, state["step"])
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu):
        g = g.astype(jnp.float32) * clip
        if cfg.quantized_state:
            m = _dq8(mu["m_q"], mu["m_s"], g.shape, g.size)
            # v is stored as quantized sqrt(v): the second moment spans many
            # orders of magnitude and tiny entries must not round to zero
            # (rsqrt blowup) — sqrt halves the dynamic range (8-bit-Adam).
            v = jnp.square(_dq8(mu["v_q"], mu["v_s"], g.shape, g.size))
        else:
            m, v = mu["m"], mu["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (update + cfg.weight_decay * p.astype(jnp.float32))
        if cfg.quantized_state:
            mq, ms = _q8(m, cfg.qblock)
            vq, vs = _q8(jnp.sqrt(v), cfg.qblock)
            return new_p.astype(p.dtype), {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
        return new_p.astype(p.dtype), {"m": m, "v": v}

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    out = [upd(p, g, mu) for p, g, mu in zip(flat_p, flat_g, flat_mu)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_params, {"mu": new_mu, "step": step}, {"grad_norm": gnorm, "lr": lr}
