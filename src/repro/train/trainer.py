"""Training loop: grad accumulation, checkpoint/restart, failure injection,
elastic remesh, straggler mitigation hooks.

Fault model (what the tests exercise on CPU; the design scales to real
clusters):
- **checkpoint/restart**: atomic step-tagged saves (train.checkpoint);
  ``run()`` restores from LATEST, and the data pipeline is keyed by
  (seed, step, shard) so the token stream replays identically.
- **failure injection**: ``FailureInjector`` raises at a configured step /
  mid-checkpoint; the restart test verifies bit-exact continuation.
- **elastic remesh**: restore accepts new shardings/mesh (checkpoint leaves
  are stored gathered), so a job can restart on a different device count.
- **straggler mitigation**: per-step deadline hook — on a real cluster the
  runner re-schedules the step on a spare slice; here the hook records and
  skips (documented, tested via the hook firing).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokens import SyntheticTokens, TokenPipelineConfig
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    grad_accum: int = 1
    log_every: int = 10
    step_deadline_s: float | None = None  # straggler threshold
    async_checkpoint: bool = False


class FailureInjector:
    """Deterministic failure injection for fault-tolerance tests."""

    def __init__(self, fail_at_step: int | None = None,
                 fail_in_checkpoint: bool = False):
        self.fail_at_step = fail_at_step
        self.fail_in_checkpoint = fail_in_checkpoint
        self.fired = False

    def maybe_fail(self, step: int):
        if not self.fired and self.fail_at_step is not None and \
                step == self.fail_at_step:
            self.fired = True
            raise RuntimeError(f"injected node failure at step {step}")


class Trainer:
    def __init__(self, loss_fn: Callable, params: Any, tcfg: TrainerConfig,
                 ocfg: opt_mod.AdamWConfig, loader: SyntheticTokens,
                 injector: FailureInjector | None = None,
                 straggler_log: list | None = None):
        self.loss_fn = loss_fn
        self.tcfg = tcfg
        self.ocfg = ocfg
        self.loader = loader
        self.injector = injector
        self.straggler_log = straggler_log if straggler_log is not None else []
        self.params = params
        self.opt_state = opt_mod.init_state(params, ocfg)
        self.step = 0
        self.metrics_history: list[dict] = []
        self._ckpt = ckpt.AsyncCheckpointer(tcfg.ckpt_dir) \
            if tcfg.async_checkpoint else None

        accum = tcfg.grad_accum

        def train_step(params, opt_state, batches):
            """batches: pytree with leading (accum, ...) microbatch dim."""
            def micro(i, acc):
                mb = jax.tree.map(lambda x: x[i], batches)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g))

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            loss, grads = jax.lax.fori_loop(
                0, accum, micro, (jnp.zeros(()), zeros))
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
            params, opt_state, metrics = opt_mod.apply_updates(
                params, grads, opt_state, self.ocfg)
            return params, opt_state, {"loss": loss, **metrics}

        self._train_step = jax.jit(train_step, donate_argnums=(0, 1))

    # -- checkpoint/restart ------------------------------------------------

    def state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self):
        if self._ckpt is not None:
            self._ckpt.save(self.step, self.state_tree())
        else:
            ckpt.save(self.tcfg.ckpt_dir, self.step, self.state_tree())

    def try_restore(self, shardings=None) -> bool:
        step = ckpt.latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return False
        tree, step = ckpt.restore(self.tcfg.ckpt_dir, self.state_tree(),
                                  shardings=shardings)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = step
        return True

    # -- loop ----------------------------------------------------------------

    def _batch(self, step: int):
        toks, tgts = self.loader.batch(step)
        a = self.tcfg.grad_accum
        b = toks.shape[0] // a
        return {
            "tokens": jnp.asarray(toks.reshape(a, b, -1)),
            "targets": jnp.asarray(tgts.reshape(a, b, -1)),
        }

    def run(self, steps: int | None = None) -> list[dict]:
        end = self.step + steps if steps is not None else self.tcfg.total_steps
        while self.step < end:
            if self.injector is not None:
                self.injector.maybe_fail(self.step)
            t0 = time.time()
            batch = self._batch(self.step)
            self.params, self.opt_state, metrics = self._train_step(
                self.params, self.opt_state, batch)
            dt = time.time() - t0
            if self.tcfg.step_deadline_s is not None and \
                    dt > self.tcfg.step_deadline_s:
                self.straggler_log.append({"step": self.step, "latency_s": dt})
            self.step += 1
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = self.step
            m["step_time_s"] = dt
            self.metrics_history.append(m)
            if self.step % self.tcfg.ckpt_every == 0 or self.step == end:
                self.save()
        if self._ckpt is not None:
            self._ckpt.wait()
        return self.metrics_history


def run_with_restarts(make_trainer: Callable[[], Trainer], total_steps: int,
                      max_restarts: int = 5) -> Trainer:
    """Restart-from-latest supervision loop (the cluster runner analogue)."""
    restarts = 0
    while True:
        trainer = make_trainer()
        trainer.try_restore()
        try:
            remaining = total_steps - trainer.step
            if remaining <= 0:
                return trainer
            trainer.run(remaining)
            return trainer
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
