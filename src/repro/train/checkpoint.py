"""Sharded, atomic, reshardable checkpointing (no orbax dependency).

Layout:  <dir>/step_<n>/
             index.json           tree structure, shapes, dtypes, step
             a_<i>.npy            one file per leaf (gathered)
         <dir>/LATEST             text file naming the newest complete step

Properties the fault-tolerance tests assert:
- **atomic**: written to ``step_<n>.tmp`` then renamed; LATEST updated last,
  so a crash mid-save never corrupts the restore point.
- **reshardable (elastic)**: restore takes target shardings — a checkpoint
  written on one mesh restores onto any other mesh/device count (leaves are
  stored gathered; device_put re-shards).
- **self-describing**: index carries the pytree def, so restore needs no
  template when structures match.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in flat]


def save(ckpt_dir: str | os.PathLike, step: int, tree: Any,
         *, _fail_after_files: int | None = None) -> pathlib.Path:
    """Write one checkpoint. ``_fail_after_files`` injects a mid-write crash
    (fault-tolerance tests only)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    index = {
        "step": step,
        "paths": _leaf_paths(tree),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(jax.device_get(x)).dtype) if not hasattr(x, "dtype")
                   else str(x.dtype) for x in leaves],
        "n_leaves": len(leaves),
    }
    for i, leaf in enumerate(leaves):
        if _fail_after_files is not None and i >= _fail_after_files:
            raise RuntimeError("injected checkpoint failure")
        np.save(tmp / f"a_{i}.npy", np.asarray(jax.device_get(leaf)))
    (tmp / "index.json").write_text(json.dumps(index))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic on POSIX
    (ckpt_dir / "LATEST.tmp").write_text(str(step))
    (ckpt_dir / "LATEST.tmp").rename(ckpt_dir / "LATEST")
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    p = pathlib.Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    step = int(p.read_text().strip())
    if not (pathlib.Path(ckpt_dir) / f"step_{step:08d}" / "index.json").exists():
        # LATEST pointed at an incomplete save; fall back to newest complete
        steps = sorted(int(d.name.split("_")[1])
                       for d in pathlib.Path(ckpt_dir).glob("step_*")
                       if (d / "index.json").exists())
        return steps[-1] if steps else None
    return step


def restore(ckpt_dir: str | os.PathLike, template: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``template``; optionally re-shard.

    ``shardings``: pytree of jax.sharding.Sharding matching template (or
    None for host arrays) — this is the elastic-remesh path.
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    index = json.loads((d / "index.json").read_text())
    leaves, treedef = jax.tree.flatten(template)
    if len(leaves) != index["n_leaves"]:
        raise ValueError(f"leaf count mismatch: template {len(leaves)} vs "
                         f"checkpoint {index['n_leaves']}")
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (tmpl, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(d / f"a_{i}.npy")
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(f"shape mismatch at leaf {i}: {arr.shape} vs "
                             f"{np.shape(tmpl)}")
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree.unflatten(treedef, out), step


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in flight)."""

    def __init__(self, ckpt_dir):
        self.ckpt_dir = ckpt_dir
        self._thread: threading.Thread | None = None
        self.error: BaseException | None = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _run():
            try:
                save(self.ckpt_dir, step, host_tree)
            except BaseException as e:  # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err
