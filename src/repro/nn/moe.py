"""Mixture-of-Experts with expert parallelism.

Two execution paths share one parameter layout (E stacked experts, sharded
over the ``model`` mesh axis = expert parallelism):

- ``gather``  (default): tokens are TP-replicated across the model axis, so
  each EP shard locally gathers the tokens routed to *its* experts
  (capacity-bounded), computes them, scatter-adds into the output, and the
  per-shard partial outputs merge in the block's existing TP all-reduce.
  No all-to-all is needed — dispatch communication is zero by construction.
  This is the TPU adaptation of NSFlow's "array folding": the heterogeneous
  (router vs expert-matmul) kernels are spatially partitioned over the array.

- ``dense``: one-hot einsum dispatch (Shazeer-style). O(T·E·C) memory — used
  only by small smoke/equivalence tests, and as the oracle for the EP path.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.nn.init import P
from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int  # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0  # always-on shared experts (DeepSeek)
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    impl: str = "gather"  # gather | dense
    router_norm_topk: bool = True  # renormalize top-k probs
    ep_constraint: bool = False  # REFUTED for scatter-built buffers (see §Perf)


def moe_spec(cfg: MoEConfig, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s = lambda fan: 1.0 / math.sqrt(fan)
    spec = {
        "router": P((d, e), ("embed", "experts"), dtype=jnp.float32, scale=s(d)),
        "gate": P((e, d, f), ("experts", "embed", "mlp"), dtype=dtype, scale=s(d)),
        "up": P((e, d, f), ("experts", "embed", "mlp"), dtype=dtype, scale=s(d)),
        "down": P((e, f, d), ("experts", "mlp", "embed"), dtype=dtype, scale=s(f)),
    }
    if cfg.n_shared:
        sf = cfg.shared_d_ff or cfg.d_ff * cfg.n_shared
        spec["shared"] = layers.glu_mlp_spec(d, sf, dtype=dtype)
    return spec


def route(params, cfg: MoEConfig, x: jax.Array):
    """x: (T, D) -> (weights (T, k), idx (T, k), probs (T, E) fp32)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.router_norm_topk:
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx, probs


def aux_load_balance_loss(probs: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balance loss (mean prob × mean assignment fraction)."""
    me = jnp.mean(probs, axis=0)
    assign = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32).sum(axis=1)  # (T, E)
    ce = jnp.mean(assign, axis=0)
    return n_experts * jnp.sum(me * ce)


def _capacity(cfg: MoEConfig, n_tokens: int) -> int:
    c = int(math.ceil(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts))
    return max(4, -(-c // 4) * 4)


def _expert_ffn(gate_w, up_w, down_w, xe: jax.Array, compute_dtype) -> jax.Array:
    """xe: (E, C, D) -> (E, C, D), batched over experts (einsum -> MXU)."""
    g = jnp.einsum("ecd,edf->ecf", xe, gate_w.astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, up_w.astype(compute_dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, down_w.astype(compute_dtype))


def moe_dense(params, cfg: MoEConfig, x: jax.Array, compute_dtype=jnp.bfloat16):
    """One-hot dispatch oracle. x: (T, D)."""
    t, d = x.shape
    w, idx, probs = route(params, cfg, x)
    cap = _capacity(cfg, t)
    # position of each (token, slot) within its expert queue (sort-based —
    # see moe_gather for why not a big cumsum)
    flat_e = idx.reshape(-1)
    counts = jnp.sum(jax.nn.one_hot(flat_e, cfg.n_experts, dtype=jnp.int32),
                     axis=0)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    order = jnp.argsort(flat_e, stable=True)
    ranks = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - offsets[flat_e[order]]
    pos_flat = jnp.zeros_like(flat_e).at[order].set(ranks)
    pos = pos_flat.reshape(t, cfg.top_k)
    keep = pos < cap
    onehot_e = jax.nn.one_hot(idx, cfg.n_experts, dtype=compute_dtype)
    onehot_c = jax.nn.one_hot(pos, cap, dtype=compute_dtype)
    disp = (onehot_e[..., :, None] * onehot_c[..., None, :]
            * keep[..., None, None].astype(compute_dtype))  # (T,k,E,C)
    comb = disp * w[..., None, None].astype(compute_dtype)
    xe = jnp.einsum("td,tkec->ecd", x.astype(compute_dtype), disp)
    ye = _expert_ffn(params["gate"], params["up"], params["down"], xe, compute_dtype)
    y = jnp.einsum("ecd,tkec->td", ye, comb)
    if cfg.n_shared:
        y = y + layers.glu_mlp(params["shared"], x, compute_dtype=compute_dtype)
    return y, aux_load_balance_loss(probs, idx, cfg.n_experts)


def moe_gather(params, cfg: MoEConfig, x: jax.Array, compute_dtype=jnp.bfloat16,
               expert_shard: tuple[int, int] | None = None):
    """Gather/scatter EP path. x: (T, D) local tokens (replicated over the
    model axis under TP). ``expert_shard=(lo, n)`` restricts this device to
    experts [lo, lo+n) — outputs are PARTIAL and must be psum'd over the
    model axis by the caller (merged with the block's TP reduce).
    """
    t, d = x.shape
    w, idx, probs = route(params, cfg, x)
    lo, n_local = expert_shard if expert_shard is not None else (0, cfg.n_experts)
    cap = _capacity(cfg, t)

    # flatten (token, slot) pairs, keep those routed to local experts
    flat_idx = idx.reshape(-1)  # (T*k,)
    flat_w = w.reshape(-1)
    local = (flat_idx >= lo) & (flat_idx < lo + n_local)
    local_e = jnp.where(local, flat_idx - lo, n_local)  # n_local = overflow bin
    # queue position within each local expert — sort-based. (A cumsum over
    # the (T·k, E) one-hot is O(T²·E) under XLA's reduce-window costing and
    # was the dominant "compute" of MoE cells; sort is O(T log T).)
    n_pairs = flat_idx.shape[0]
    counts = jnp.sum(jax.nn.one_hot(local_e, n_local + 1, dtype=jnp.int32),
                     axis=0)  # (E_local+1,)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])  # tiny cumsum
    order = jnp.argsort(local_e, stable=True)
    ranks_sorted = jnp.arange(n_pairs, dtype=jnp.int32) - offsets[local_e[order]]
    pos = jnp.zeros((n_pairs,), jnp.int32).at[order].set(ranks_sorted)
    keep = local & (pos >= 0) & (pos < cap)
    slot = jnp.where(keep, local_e * cap + pos, n_local * cap)  # overflow slot

    token_of = jnp.arange(t * cfg.top_k) // cfg.top_k
    # gather tokens into (n_local*cap + 1, D) slots
    xe = jnp.zeros((n_local * cap + 1, d), compute_dtype)
    xe = xe.at[slot].set(x.astype(compute_dtype)[token_of])
    xe = xe[:-1].reshape(n_local, cap, d)

    gate_w = jax.lax.dynamic_slice_in_dim(params["gate"], lo, n_local, 0)
    up_w = jax.lax.dynamic_slice_in_dim(params["up"], lo, n_local, 0)
    down_w = jax.lax.dynamic_slice_in_dim(params["down"], lo, n_local, 0)
    if cfg.ep_constraint and expert_shard is None:
        # EP: keep the per-expert token buffers sharded over the model axis
        # like the expert weights — without this GSPMD replicates the
        # (E, cap, D) buffers on every model shard (§Perf deepseek iter 1)
        from repro.distributed import constraints as C

        xe = C.maybe_constrain(xe, ("model", None, None))
    ye = _expert_ffn(gate_w, up_w, down_w, xe, compute_dtype)  # (n_local, C, D)
    if cfg.ep_constraint and expert_shard is None:
        from repro.distributed import constraints as C

        ye = C.maybe_constrain(ye, ("model", None, None))

    # scatter-add back with combine weights
    ye_flat = jnp.concatenate([ye.reshape(n_local * cap, d),
                               jnp.zeros((1, d), compute_dtype)], axis=0)
    contrib = ye_flat[slot] * (flat_w[:, None] * keep[:, None]).astype(compute_dtype)
    y = jnp.zeros((t, d), compute_dtype).at[token_of].add(contrib)
    if cfg.n_shared and (expert_shard is None or lo == 0):
        # shared expert computed once (on shard 0 when partial; caller psums)
        y = y + layers.glu_mlp(params["shared"], x, compute_dtype=compute_dtype)
    return y, aux_load_balance_loss(probs, idx, cfg.n_experts)


def moe_block(params, cfg: MoEConfig, x: jax.Array, compute_dtype=jnp.bfloat16):
    """x: (B, S, D) -> (y, aux_loss). Under pjit the expert axis sharding of
    the stacked weights drives XLA SPMD to partition the expert loop."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    if cfg.impl == "dense":
        y, aux = moe_dense(params, cfg, xf, compute_dtype)
    else:
        y, aux = moe_gather(params, cfg, xf, compute_dtype)
    return y.reshape(b, s, d), aux
