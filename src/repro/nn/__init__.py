from repro.nn import attention, layers, moe, resnet, ssm
from repro.nn.init import P, materialize, shapes, axes

__all__ = ["P", "materialize", "shapes", "axes", "layers", "attention", "moe", "ssm", "resnet"]
