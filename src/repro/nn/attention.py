"""Attention: GQA / MQA / MLA, full + sliding-window, train/prefill/decode.

Memory-bounded prefill: long sequences use a chunked online-softmax
(flash-attention-style) computed with ``lax.scan`` over KV blocks, so the
32k-prefill dry-run cells never materialize an (S, S) score tensor.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn.init import P
from repro.nn import layers

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_base: float = 10000.0
    rotary_dim: int | None = None  # partial rotary if < head_dim
    window: int | None = None  # sliding-window size (None = full)
    qkv_bias: bool = False
    softmax_scale: float | None = None
    qk_norm: bool = False  # gemma3-style per-head RMS norm of q/k
    shard_heads: bool = True  # constrain q/k/v head axis onto the model axis

    @property
    def scale(self) -> float:
        return self.softmax_scale or 1.0 / math.sqrt(self.head_dim)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def gqa_spec(cfg: AttnConfig, dtype=jnp.float32):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    spec = {
        "wq": P((d, h, hd), ("embed", "heads", "hd"), dtype=dtype,
                scale=1.0 / math.sqrt(d)),
        "wk": P((d, kv, hd), ("embed", "kv", "hd"), dtype=dtype,
                scale=1.0 / math.sqrt(d)),
        "wv": P((d, kv, hd), ("embed", "kv", "hd"), dtype=dtype,
                scale=1.0 / math.sqrt(d)),
        "wo": P((h, hd, d), ("heads", "hd", "embed"), dtype=dtype,
                scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        spec["bq"] = P((h, hd), ("heads", "hd"), init="zeros", dtype=dtype)
        spec["bk"] = P((kv, hd), ("kv", "hd"), init="zeros", dtype=dtype)
        spec["bv"] = P((kv, hd), ("kv", "hd"), init="zeros", dtype=dtype)
    if cfg.qk_norm:
        spec["qnorm"] = P((hd,), ("hd",), init="ones", dtype=dtype)
        spec["knorm"] = P((hd,), ("hd",), init="ones", dtype=dtype)
    return spec


def _headwise_rms(x, scale, eps=1e-6):
    v = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(v + eps) * scale).astype(x.dtype)


def gqa_project(params, cfg: AttnConfig, x: jax.Array, positions: jax.Array,
                compute_dtype=jnp.bfloat16):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd), RoPE applied."""
    x = x.astype(compute_dtype)
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(compute_dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"].astype(compute_dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"].astype(compute_dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(compute_dtype)
        k = k + params["bk"].astype(compute_dtype)
        v = v + params["bv"].astype(compute_dtype)
    if cfg.qk_norm:
        q = _headwise_rms(q, params["qnorm"].astype(jnp.float32))
        k = _headwise_rms(k, params["knorm"].astype(jnp.float32))
    q = layers.apply_rope(q, positions, cfg.rope_base, cfg.rotary_dim)
    k = layers.apply_rope(k, positions, cfg.rope_base, cfg.rotary_dim)
    if cfg.shard_heads:
        # keep the (quadratic) attention math head-sharded over the model
        # axis even when the weights fell back to row-parallel sharding —
        # GSPMD pads uneven head counts. Without this the scores/AV einsums
        # replicate across the whole model axis (16× waste at TP=16).
        from repro.distributed import constraints as C

        q, k, v = C.batch_seq_heads(q), C.batch_seq_heads(k), C.batch_seq_heads(v)
    return q, k, v


# ---------------------------------------------------------------------------
# Core attention computations
# ---------------------------------------------------------------------------


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd)).reshape(
        b, s, kv * groups, hd
    )


def causal_mask(sq: int, skv: int, q_offset: int = 0, window: int | None = None):
    """(sq, skv) boolean mask — True = attendable."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


def attend_full(q, k, v, mask, scale: float) -> jax.Array:
    """Direct attention. q: (B,Sq,H,hd), k/v: (B,Skv,H,hd), mask: (Sq,Skv)."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attend_chunked(q, k, v, scale: float, q_offset: int = 0,
                   window: int | None = None, kv_chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, scanning over KV chunks (flash-style).

    Never materializes more than (B, H, Sq, kv_chunk) scores. Causal.
    """
    b, sq, h, hd = q.shape
    vd = v.shape[-1]  # may differ from hd (MLA: qk 192 vs v 128)
    skv = k.shape[1]
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, h, vd).transpose(1, 0, 2, 3, 4)

    qpos = jnp.arange(sq) + q_offset  # absolute query positions

    def step(carry, inp):
        m, l, acc = carry  # (B,H,Sq), (B,H,Sq), (B,H,Sq,hd) fp32
        ci, (kb, vb) = inp
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        valid = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < skv)
        if window is not None:
            valid = valid & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(valid[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, h, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
        jnp.zeros((b, h, sq, vd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (jnp.arange(n_chunks), (kc, vc)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,hd)


CHUNKED_THRESHOLD = 4096


def attention(params, cfg: AttnConfig, x: jax.Array, positions: jax.Array,
              compute_dtype=jnp.bfloat16, kv_chunk: int = 1024) -> jax.Array:
    """Self-attention over a full sequence (train / prefill)."""
    q, k, v = gqa_project(params, cfg, x, positions, compute_dtype)
    groups = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, groups), _repeat_kv(v, groups)
    s = x.shape[1]
    if s > CHUNKED_THRESHOLD:
        out = attend_chunked(q, k, v, cfg.scale, window=cfg.window, kv_chunk=kv_chunk)
    else:
        mask = causal_mask(s, s, window=cfg.window)
        out = attend_full(q, k, v, mask, cfg.scale)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(compute_dtype))


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


def kv_cache_shape(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Returns ShapeDtypeStructs {k, v}. Sliding-window layers allocate only
    the window (ring buffer) — this is the gemma3 long_500k memory saver."""
    length = min(max_len, cfg.window) if cfg.window else max_len
    shp = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shp, dtype),
        "v": jax.ShapeDtypeStruct(shp, dtype),
    }


def init_kv_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        kv_cache_shape(cfg, batch, max_len, dtype))


def decode_step(params, cfg: AttnConfig, cache, x_t: jax.Array, pos: jax.Array,
                compute_dtype=jnp.bfloat16):
    """One-token decode. x_t: (B, D); pos: scalar int32 or (B,) int32
    per-slot positions (continuous batching: each slot may be at a
    different depth).

    Returns (new_cache, out (B, D)). Ring-buffer update for windowed layers.
    """
    b, d = x_t.shape
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q, k_t, v_t = gqa_project(params, cfg, x_t[:, None, :], pos_b[:, None],
                              compute_dtype)
    cache_len = cache["k"].shape[1]
    slot = pos_b % cache_len if cfg.window else pos_b  # (B,)
    rows = jnp.arange(b)
    k_cache = cache["k"].at[rows, slot].set(k_t[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[rows, slot].set(v_t[:, 0].astype(cache["v"].dtype))

    groups = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(k_cache.astype(compute_dtype), groups)
    v = _repeat_kv(v_cache.astype(compute_dtype), groups)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * cfg.scale
    kpos = jnp.arange(cache_len)
    if cfg.window:
        # ring buffer: entry i holds absolute position p with p % L == i, the
        # latest such p <= pos. valid if within window.
        age = (slot[:, None] - kpos[None, :]) % cache_len
        valid = age < jnp.minimum(pos_b + 1, cache_len)[:, None]
    else:
        valid = kpos[None, :] <= pos_b[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)[:, 0]
    y = jnp.einsum("bhe,hed->bd", out, params["wo"].astype(compute_dtype))
    return {"k": k_cache, "v": v_cache}, y


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V3), with absorbed decode path
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_base: float = 10000.0

    @property
    def scale(self) -> float:
        return 1.0 / math.sqrt(self.qk_nope_dim + self.qk_rope_dim)


def mla_spec(cfg: MLAConfig, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    s = lambda fan: 1.0 / math.sqrt(fan)
    return {
        "wq_a": P((d, r_q), ("embed", "qlora"), dtype=dtype, scale=s(d)),
        "q_a_norm": P((r_q,), ("qlora",), init="ones", dtype=dtype),
        "wq_b": P((r_q, h, dn + dr), ("qlora", "heads", "hd"), dtype=dtype, scale=s(r_q)),
        "wkv_a": P((d, r_kv + dr), ("embed", "kvlora"), dtype=dtype, scale=s(d)),
        "kv_a_norm": P((r_kv,), ("kvlora",), init="ones", dtype=dtype),
        "wk_b": P((r_kv, h, dn), ("kvlora", "heads", "hd"), dtype=dtype, scale=s(r_kv)),
        "wv_b": P((r_kv, h, dv), ("kvlora", "heads", "hd"), dtype=dtype, scale=s(r_kv)),
        "wo": P((h, dv, d), ("heads", "hd", "embed"), dtype=dtype, scale=s(h * dv)),
    }


def _rms(x, scale, eps=1e-6):
    v = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(v + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def mla_attention(params, cfg: MLAConfig, x: jax.Array, positions: jax.Array,
                  compute_dtype=jnp.bfloat16, kv_chunk: int = 1024) -> jax.Array:
    """Train/prefill MLA: decompress K/V per head, chunked causal attention."""
    x = x.astype(compute_dtype)
    b, s, _ = x.shape
    cq = _rms(jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(compute_dtype)),
              params["q_a_norm"])
    q = jnp.einsum("bsr,rhe->bshe", cq, params["wq_b"].astype(compute_dtype))
    q_nope, q_pe = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(compute_dtype))
    c_kv = _rms(kv_a[..., : cfg.kv_lora_rank], params["kv_a_norm"])
    k_pe = kv_a[..., cfg.kv_lora_rank:][:, :, None, :]  # (B,S,1,dr) shared head
    q_pe = layers.apply_rope(q_pe, positions, cfg.rope_base)
    k_pe = layers.apply_rope(k_pe, positions, cfg.rope_base)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, params["wk_b"].astype(compute_dtype))
    v = jnp.einsum("bsr,rhe->bshe", c_kv, params["wv_b"].astype(compute_dtype))
    k_pe_b = jnp.broadcast_to(k_pe, (b, s, cfg.n_heads, cfg.qk_rope_dim))
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_full = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    if s > CHUNKED_THRESHOLD:
        out = attend_chunked(q_full, k_full, v, cfg.scale, kv_chunk=kv_chunk)
    else:
        out = attend_full(q_full, k_full, v, causal_mask(s, s), cfg.scale)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(compute_dtype))


def mla_cache_shape(cfg: MLAConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Compressed cache: (c_kv ‖ k_pe) per token — the MLA memory win."""
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dtype),
        "kpe": jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_init_cache(cfg: MLAConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        mla_cache_shape(cfg, batch, max_len, dtype))


def mla_decode_step(params, cfg: MLAConfig, cache, x_t: jax.Array, pos: jax.Array,
                    compute_dtype=jnp.bfloat16):
    """Absorbed decode: attention runs in the compressed (rank-512) space.

    score = (q_nope @ W_kb)ᵀ c + q_peᵀ k_pe ; out = (attn @ c) @ W_vb.

    ``pos`` may be a scalar or a (B,) vector of per-slot positions.
    """
    x_t = x_t.astype(compute_dtype)
    b, _ = x_t.shape
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    cq = _rms(jnp.einsum("bd,dr->br", x_t, params["wq_a"].astype(compute_dtype)),
              params["q_a_norm"])
    q = jnp.einsum("br,rhe->bhe", cq, params["wq_b"].astype(compute_dtype))
    q_nope, q_pe = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_pe = layers.apply_rope(q_pe[:, None], pos_b[:, None], cfg.rope_base)[:, 0]

    kv_a = jnp.einsum("bd,dr->br", x_t, params["wkv_a"].astype(compute_dtype))
    c_t = _rms(kv_a[..., : cfg.kv_lora_rank], params["kv_a_norm"])
    kpe_t = layers.apply_rope(kv_a[:, None, None, cfg.kv_lora_rank:],
                              pos_b[:, None], cfg.rope_base)[:, 0, 0]

    rows = jnp.arange(b)
    ckv = cache["ckv"].at[rows, pos_b].set(c_t.astype(cache["ckv"].dtype))
    kpe = cache["kpe"].at[rows, pos_b].set(kpe_t.astype(cache["kpe"].dtype))

    # absorb W_kb into the query: q_eff (B, H, r_kv)
    q_eff = jnp.einsum("bhe,rhe->bhr", q_nope, params["wk_b"].astype(compute_dtype))
    s_c = jnp.einsum("bhr,bsr->bhs", q_eff, ckv.astype(compute_dtype))
    s_pe = jnp.einsum("bhe,bse->bhs", q_pe, kpe.astype(compute_dtype))
    scores = (s_c + s_pe).astype(jnp.float32) * cfg.scale
    valid = jnp.arange(ckv.shape[1])[None, :] <= pos_b[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    out_c = jnp.einsum("bhs,bsr->bhr", probs, ckv.astype(compute_dtype))
    out = jnp.einsum("bhr,rhe->bhe", out_c, params["wv_b"].astype(compute_dtype))
    y = jnp.einsum("bhe,hed->bd", out, params["wo"].astype(compute_dtype))
    return {"ckv": ckv, "kpe": kpe}, y


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec, seamless-m4t)
# ---------------------------------------------------------------------------


def cross_attention(params, cfg: AttnConfig, x: jax.Array, enc_kv, compute_dtype=jnp.bfloat16):
    """x: (B, Sq, D); enc_kv: precomputed {k, v}: (B, Skv, KV, hd)."""
    x = x.astype(compute_dtype)
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(compute_dtype))
    groups = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(enc_kv["k"].astype(compute_dtype), groups)
    v = _repeat_kv(enc_kv["v"].astype(compute_dtype), groups)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * cfg.scale
    probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(compute_dtype))


def encode_kv(params, cfg: AttnConfig, enc_out: jax.Array, compute_dtype=jnp.bfloat16):
    enc_out = enc_out.astype(compute_dtype)
    k = jnp.einsum("bsd,dhe->bshe", enc_out, params["wk"].astype(compute_dtype))
    v = jnp.einsum("bsd,dhe->bshe", enc_out, params["wv"].astype(compute_dtype))
    return {"k": k, "v": v}
