"""Parameter spec system.

Models declare their parameters ONCE as a pytree of :class:`P` specs
(shape + logical axes + initializer).  From that single declaration we derive:

- ``materialize(spec, key)``  -> actual parameter pytree (jnp arrays)
- ``shapes(spec)``            -> ShapeDtypeStruct pytree (dry-run, no allocation)
- ``axes(spec)``              -> logical-axis pytree (consumed by sharding rules)

This is the substrate equivalent of flax's ``param`` + ``nn.logical_axes`` in
~100 lines, with no tracing involved, so it is safe to call under
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` without allocating.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """A single parameter spec.

    ``axes`` holds one *logical* axis name (or None) per shape dim, e.g.
    ``("embed", "heads", "hd")``.  Sharding rules later map logical names to
    mesh axes.
    """

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | uniform | constant
    scale: float | None = None  # stddev override for normal init
    dtype: Any = jnp.float32
    constant: float = 0.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} rank mismatch")


def _fan_in(shape: Sequence[int]) -> int:
    # last axis is the output axis by convention (x @ W)
    if len(shape) == 1:
        return shape[0]
    return int(np.prod(shape[:-1]))


def _materialize_one(spec: P, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "constant":
        return jnp.full(spec.shape, spec.constant, spec.dtype)
    if spec.init == "uniform":
        lim = spec.scale if spec.scale is not None else 1.0 / math.sqrt(_fan_in(spec.shape))
        return jax.random.uniform(key, spec.shape, spec.dtype, -lim, lim)
    if spec.init == "normal":
        std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(1, _fan_in(spec.shape)))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init}")


def _is_spec(x) -> bool:
    return isinstance(x, P)


def materialize(spec_tree, key: jax.Array):
    """Build real parameters from a spec tree with per-leaf folded keys."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    out = []
    for i, leaf in enumerate(leaves):
        out.append(_materialize_one(leaf, jax.random.fold_in(key, i)))
    return jax.tree.unflatten(treedef, out)


def shapes(spec_tree):
    """ShapeDtypeStruct tree — safe for .lower() without any allocation."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), spec_tree, is_leaf=_is_spec
    )


def axes(spec_tree):
    """Logical-axis tree matching the spec tree structure."""
    return jax.tree.map(lambda p: tuple(p.axes), spec_tree, is_leaf=_is_spec)


def param_count(spec_tree) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(spec_tree, is_leaf=_is_spec))


def param_bytes(spec_tree) -> int:
    return sum(
        int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize
        for p in jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    )


def map_with_path(fn: Callable, spec_tree):
    return jax.tree_util.tree_map_with_path(fn, spec_tree, is_leaf=_is_spec)
