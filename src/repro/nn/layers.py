"""Core pure-JAX layers: dense, embedding, norms, RoPE, conv, pooling.

Every layer is a pair (``<name>_spec`` -> P tree, ``<name>`` apply fn). Specs
carry logical axis names consumed by ``repro.distributed.sharding_rules``.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn.init import P

# ---------------------------------------------------------------------------
# Dense / embedding
# ---------------------------------------------------------------------------


def dense_spec(d_in: int, d_out: int, axes=("embed", "mlp"), bias: bool = False,
               dtype=jnp.float32, scale: float | None = None):
    spec = {"w": P((d_in, d_out), axes, init="normal", scale=scale, dtype=dtype)}
    if bias:
        spec["b"] = P((d_out,), (axes[1],), init="zeros", dtype=dtype)
    return spec


def dense(params, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    w = params["w"].astype(compute_dtype)
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype), w)
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y


def embedding_spec(vocab: int, d: int, dtype=jnp.float32):
    return {"table": P((vocab, d), ("vocab", "embed"), init="normal", scale=0.02, dtype=dtype)}


def embedding(params, ids: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    return params["table"].astype(compute_dtype)[ids]


def logits(params, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    """Tied-embedding readout: x @ table.T"""
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype),
                      params["table"].astype(compute_dtype))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int, dtype=jnp.float32):
    return {"scale": P((d,), ("embed",), init="ones", dtype=dtype)}


def rmsnorm(params, x: jax.Array, eps: float = 1e-6, offset: float = 0.0) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (offset + params["scale"].astype(jnp.float32))
    return y.astype(dtype)


def layernorm_spec(d: int, dtype=jnp.float32):
    return {
        "scale": P((d,), ("embed",), init="ones", dtype=dtype),
        "bias": P((d,), ("embed",), init="zeros", dtype=dtype),
    }


def layernorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def groupnorm(x: jax.Array, num_groups: int, scale: jax.Array, bias: jax.Array,
              eps: float = 64e-5) -> jax.Array:
    """GroupNorm over the last axis (used by RWKV time-mix output)."""
    dtype = x.dtype
    *lead, d = x.shape
    x = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(*lead, d) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, base: float = 10000.0) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (base ** exponent)  # (head_dim//2,)


def apply_rope(x: jax.Array, positions: jax.Array, base: float = 10000.0,
               rotary_dim: int | None = None) -> jax.Array:
    """Apply rotary embedding.

    x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).
    ``rotary_dim`` < head_dim applies partial rotary (StableLM-style).
    """
    head_dim = x.shape[-1]
    rd = rotary_dim if rotary_dim is not None else head_dim
    xr, xp = x[..., :rd], x[..., rd:]
    freqs = rope_freqs(rd, base)  # (rd//2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, rd//2)
    angles = angles[..., None, :]  # (..., seq, 1, rd//2) broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    rotated = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return jnp.concatenate([rotated, xp], axis=-1) if rd < head_dim else rotated


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def geglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.gelu(gate, approximate=True) * up


def relu_sq(x: jax.Array) -> jax.Array:
    return jnp.square(jax.nn.relu(x))


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------


def glu_mlp_spec(d_model: int, d_ff: int, dtype=jnp.float32):
    return {
        "gate": dense_spec(d_model, d_ff, ("embed", "mlp"), dtype=dtype),
        "up": dense_spec(d_model, d_ff, ("embed", "mlp"), dtype=dtype),
        "down": dense_spec(d_ff, d_model, ("mlp", "embed"), dtype=dtype),
    }


def glu_mlp(params, x: jax.Array, act=swiglu, compute_dtype=jnp.bfloat16) -> jax.Array:
    g = dense(params["gate"], x, compute_dtype)
    u = dense(params["up"], x, compute_dtype)
    return dense(params["down"], act(g, u), compute_dtype)


def mlp_spec(d_model: int, d_ff: int, dtype=jnp.float32, bias: bool = False):
    return {
        "up": dense_spec(d_model, d_ff, ("embed", "mlp"), bias=bias, dtype=dtype),
        "down": dense_spec(d_ff, d_model, ("mlp", "embed"), bias=bias, dtype=dtype),
    }


def mlp(params, x: jax.Array, act=jax.nn.gelu, compute_dtype=jnp.bfloat16) -> jax.Array:
    return dense(params["down"], act(dense(params["up"], x, compute_dtype)), compute_dtype)


# ---------------------------------------------------------------------------
# Conv / pooling (NSAI CNN frontends)
# ---------------------------------------------------------------------------


def conv2d_spec(c_in: int, c_out: int, k: int, dtype=jnp.float32, bias: bool = False):
    fan_in = c_in * k * k
    spec = {
        "w": P((k, k, c_in, c_out), (None, None, "conv_in", "conv_out"),
               init="normal", scale=math.sqrt(2.0 / fan_in), dtype=dtype)
    }
    if bias:
        spec["b"] = P((c_out,), ("conv_out",), init="zeros", dtype=dtype)
    return spec


def conv2d(params, x: jax.Array, stride: int = 1, padding: str = "SAME",
           compute_dtype=jnp.bfloat16) -> jax.Array:
    """x: (B, H, W, C)."""
    y = jax.lax.conv_general_dilated(
        x.astype(compute_dtype),
        params["w"].astype(compute_dtype),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y


def batchnorm_spec(c: int, dtype=jnp.float32):
    return {
        "scale": P((c,), ("conv_out",), init="ones", dtype=dtype),
        "bias": P((c,), ("conv_out",), init="zeros", dtype=dtype),
        "mean": P((c,), ("conv_out",), init="zeros", dtype=dtype),
        "var": P((c,), ("conv_out",), init="ones", dtype=dtype),
    }


def batchnorm(params, x: jax.Array, train: bool = False, eps: float = 1e-5,
              stats_sink: dict | None = None, stats_key=None):
    """Functional BN. ``train=True`` normalizes with batch statistics and —
    when the caller passes a ``stats_sink`` dict — records them under
    ``stats_key`` so the trainer can fold them into the params' running
    ``mean``/``var`` with :func:`bn_apply_stats` (functional EMA, no state).
    ``train=False`` uses the running stats: per-example independent."""
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x.astype(jnp.float32), axis=axes)
        var = jnp.var(x.astype(jnp.float32), axis=axes)
        if stats_sink is not None:
            stats_sink[stats_key] = (mean, var)
    else:
        mean, var = params["mean"], params["var"]
    inv = jax.lax.rsqrt(var.astype(jnp.float32) + eps) * params["scale"].astype(jnp.float32)
    y = (x.astype(jnp.float32) - mean) * inv + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def bn_apply_stats(params, stats: dict, momentum: float = 0.9):
    """Fold collected BN batch statistics into running stats (pure EMA).

    ``stats`` maps a path tuple into ``params`` (as produced by the
    ``stats_sink``/``stats_key`` plumbing, e.g. ``("stages", 0, 1, "bn1")``)
    to ``(batch_mean, batch_var)``.  Returns a new params tree with
    ``mean``/``var`` EMA-updated; everything else is shared, and the dict
    structure is static, so this jits inside a train step.
    """
    def update(tree, path):
        if not path:
            return {**tree, "mean": momentum * tree["mean"]
                    + (1 - momentum) * mean,
                    "var": momentum * tree["var"] + (1 - momentum) * var}
        head, rest = path[0], path[1:]
        if isinstance(tree, dict):
            return {k: (update(v, rest) if k == head else v)
                    for k, v in tree.items()}
        return [update(v, rest) if i == head else v
                for i, v in enumerate(tree)]

    for path, (mean, var) in stats.items():
        params = update(params, tuple(path))
    return params


def maxpool2d(x: jax.Array, k: int = 2, stride: int | None = None) -> jax.Array:
    stride = stride or k
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), "SAME"
    )


def avgpool_global(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------------------
# Temporal conv (RG-LRU block)
# ---------------------------------------------------------------------------


def conv1d_spec(d: int, width: int = 4, dtype=jnp.float32):
    return {
        "w": P((width, d), (None, "embed"), init="normal",
               scale=1.0 / math.sqrt(width), dtype=dtype),
        "b": P((d,), ("embed",), init="zeros", dtype=dtype),
    }


def causal_conv1d(params, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    """Depthwise causal temporal conv. x: (B, S, D)."""
    w = params["w"].astype(compute_dtype)  # (K, D)
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i: i + x.shape[1], :] * w[i] for i in range(k))
    return y + params["b"].astype(compute_dtype)


def causal_conv1d_step(params, state: jax.Array, x_t: jax.Array):
    """Single decode step. state: (B, K-1, D) trailing inputs; x_t: (B, D)."""
    w = params["w"].astype(x_t.dtype)
    k = w.shape[0]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, K, D)
    y = jnp.einsum("bkd,kd->bd", window, w) + params["b"].astype(x_t.dtype)
    return window[:, 1:, :], y
