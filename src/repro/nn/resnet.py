"""ResNet-18 (NHWC, pure JAX) — the neural frontend of the NSAI workloads.

The paper's NVSA/PrAE pipelines use a ResNet-18-class CNN for perception
(paper Listing 1 shows the resnet18 trace). Width/depth are configurable so
the NSAI smoke tests can run reduced variants on CPU.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    in_channels: int = 1
    width: int = 64  # stem width; stages are (w, 2w, 4w, 8w)
    blocks_per_stage: tuple[int, ...] = (2, 2, 2, 2)  # resnet18
    out_dim: int = 512
    dtype: object = jnp.float32


def _block_spec(c_in: int, c_out: int, stride: int, dtype):
    spec = {
        "conv1": layers.conv2d_spec(c_in, c_out, 3, dtype=dtype),
        "bn1": layers.batchnorm_spec(c_out, dtype=dtype),
        "conv2": layers.conv2d_spec(c_out, c_out, 3, dtype=dtype),
        "bn2": layers.batchnorm_spec(c_out, dtype=dtype),
    }
    if stride != 1 or c_in != c_out:
        spec["proj"] = layers.conv2d_spec(c_in, c_out, 1, dtype=dtype)
        spec["proj_bn"] = layers.batchnorm_spec(c_out, dtype=dtype)
    return spec


def resnet_spec(cfg: ResNetConfig):
    w, dtype = cfg.width, cfg.dtype
    spec = {
        "stem": layers.conv2d_spec(cfg.in_channels, w, 7, dtype=dtype),
        "stem_bn": layers.batchnorm_spec(w, dtype=dtype),
        "stages": [],
        "head": layers.dense_spec(w * 8, cfg.out_dim, ("embed", "mlp"), bias=True,
                                  dtype=dtype),
    }
    c_in = w
    for si, n_blocks in enumerate(cfg.blocks_per_stage):
        c_out = w * (2 ** si)
        stage = []
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            stage.append(_block_spec(c_in, c_out, stride, dtype))
            c_in = c_out
        spec["stages"].append(stage)
    return spec


def _block(params, x, stride: int, train: bool, compute_dtype,
           bn_stats, path):
    def bn(name, y):
        return layers.batchnorm(params[name], y, train, stats_sink=bn_stats,
                                stats_key=path + (name,))

    y = layers.conv2d(params["conv1"], x, stride=stride, compute_dtype=compute_dtype)
    y = jax.nn.relu(bn("bn1", y))
    y = layers.conv2d(params["conv2"], y, compute_dtype=compute_dtype)
    y = bn("bn2", y)
    if "proj" in params:
        x = bn("proj_bn", layers.conv2d(params["proj"], x, stride=stride,
                                        compute_dtype=compute_dtype))
    return jax.nn.relu(x + y)


def resnet(params, cfg: ResNetConfig, images: jax.Array, train: bool = False,
           compute_dtype=jnp.bfloat16, bn_stats: dict | None = None) -> jax.Array:
    """images: (B, H, W, C) -> (B, out_dim).

    ``train=True`` uses batch-statistics BN; pass a ``bn_stats`` dict to
    collect each BN layer's batch mean/var keyed by its path into
    ``params`` — the trainer folds them into the running stats with
    ``layers.bn_apply_stats`` (functional EMA).  ``train=False`` evaluates
    with the running stats, making the output of each example independent
    of the rest of its batch (per-request independence when serving).
    """
    x = layers.conv2d(params["stem"], images.astype(compute_dtype), stride=2,
                      compute_dtype=compute_dtype)
    x = jax.nn.relu(layers.batchnorm(params["stem_bn"], x, train,
                                     stats_sink=bn_stats,
                                     stats_key=("stem_bn",)))
    x = layers.maxpool2d(x, 3, 2)
    for si, stage in enumerate(params["stages"]):
        for bi, block in enumerate(stage):
            stride = 2 if (bi == 0 and si > 0) else 1
            x = _block(block, x, stride, train, compute_dtype, bn_stats,
                       ("stages", si, bi))
    x = layers.avgpool_global(x)
    return layers.dense(params["head"], x, compute_dtype)
