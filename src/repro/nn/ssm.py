"""Attention-free sequence mixers: RWKV-6 (Finch) time-mix and RG-LRU (Griffin).

RWKV-6 ships two functionally-equivalent forward paths:

- ``wkv6_scan``     token-level ``lax.scan`` — numerically exact; the oracle,
                    and the per-token decode step.
- ``wkv6_chunked``  chunk-parallel matmul form (flash-linear-attention style):
                    O(S/C) sequential steps of MXU-shaped work instead of O(S).
                    Intra-chunk decay products are computed in log-space fp32
                    with a clamp at ``LOG_CLAMP`` — exact for realistic decay
                    magnitudes (|log w| ≲ 60/chunk), the regime trained RWKV
                    occupies.

This heterogeneous (recurrence = memory-bound stream, channel-mix = MXU
stream) structure is what makes RWKV the strongest LM-side analogue of the
paper's neuro/symbolic kernel mix — see DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.nn.init import P
from repro.nn import layers

LOG_CLAMP = 60.0


# ---------------------------------------------------------------------------
# RWKV-6 time mix
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    head_dim: int = 64
    shift_lora: int = 32
    decay_lora: int = 64
    chunk: int = 16
    impl: str = "chunked"  # chunked | scan

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def timemix_spec(cfg: RWKV6Config, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.head_dim
    h = cfg.n_heads
    s = lambda fan: 1.0 / math.sqrt(fan)
    names = ["r", "k", "v", "w", "g"]
    spec = {
        # data-dependent token-shift: shared LoRA-A, per-stream B + static mu
        "mu_x": P((d,), ("embed",), init="uniform", scale=0.5, dtype=dtype),
        "shift_a": P((d, cfg.shift_lora), ("embed", None), dtype=dtype, scale=s(d)),
        "shift_b": P((5, cfg.shift_lora, d), (None, None, "embed"), init="zeros", dtype=dtype),
        "mu": P((5, d), (None, "embed"), init="uniform", scale=0.5, dtype=dtype),
        # projections
        "wr": P((d, d), ("embed", "heads_flat"), dtype=dtype, scale=s(d)),
        "wk": P((d, d), ("embed", "heads_flat"), dtype=dtype, scale=s(d)),
        "wv": P((d, d), ("embed", "heads_flat"), dtype=dtype, scale=s(d)),
        "wg": P((d, d), ("embed", "heads_flat"), dtype=dtype, scale=s(d)),
        "wo": P((d, d), ("heads_flat", "embed"), dtype=dtype, scale=s(d)),
        # data-dependent decay
        "w0": P((d,), ("embed",), init="constant", constant=-4.0, dtype=dtype),
        "decay_a": P((d, cfg.decay_lora), ("embed", None), dtype=dtype, scale=s(d)),
        "decay_b": P((cfg.decay_lora, d), (None, "embed"), init="zeros", dtype=dtype),
        # per-(head, channel) bonus
        "u": P((h, hd), ("heads", "hd"), init="uniform", scale=0.5, dtype=dtype),
        # output groupnorm
        "ln_scale": P((d,), ("embed",), init="ones", dtype=dtype),
        "ln_bias": P((d,), ("embed",), init="zeros", dtype=dtype),
    }
    return spec


def _shift(x: jax.Array) -> jax.Array:
    """Previous-token shift along seq. x: (B, S, D)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def timemix_project(params, cfg: RWKV6Config, x: jax.Array, x_prev: jax.Array | None,
                    compute_dtype=jnp.bfloat16):
    """Compute r,k,v,g,logw from (B,S,D) input. ``x_prev``: (B,D) carry for
    decode (last token of previous step), else None for full-sequence."""
    x = x.astype(compute_dtype)
    if x_prev is None:
        sx = _shift(x) - x
    else:
        prev = jnp.concatenate([x_prev[:, None].astype(compute_dtype), x[:, :-1]], axis=1)
        sx = prev - x
    xr_base = x + sx * params["mu_x"].astype(compute_dtype)
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", xr_base, params["shift_a"].astype(compute_dtype)))
    deltas = jnp.einsum("bsr,nrd->nbsd", lora, params["shift_b"].astype(compute_dtype))
    mixed = [x + sx * (params["mu"][i].astype(compute_dtype) + deltas[i]) for i in range(5)]
    xr, xk, xv, xw, xg = mixed
    r = jnp.einsum("bsd,de->bse", xr, params["wr"].astype(compute_dtype))
    k = jnp.einsum("bsd,de->bse", xk, params["wk"].astype(compute_dtype))
    v = jnp.einsum("bsd,de->bse", xv, params["wv"].astype(compute_dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["wg"].astype(compute_dtype)))
    dlora = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, params["decay_a"].astype(compute_dtype)))
    logw = -jnp.exp(
        params["w0"].astype(jnp.float32)
        + jnp.einsum("bsr,rd->bsd", dlora.astype(jnp.float32),
                     params["decay_b"].astype(jnp.float32))
    )  # (B, S, D) strictly negative
    return r, k, v, g, logw


def _to_heads(x: jax.Array, h: int, hd: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, h, hd)


def wkv6_scan(r, k, v, logw, u, state=None):
    """Exact recurrence. r,k,v: (B,S,H,hd) f32; logw: (B,S,H,hd); u: (H,hd).

    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ ;  out_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)
    Returns (out (B,S,H,hd), final state (B,H,hd,hd)).
    """
    b, s, h, hd = r.shape
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(S, inp):
        rt, kt, vt, lwt = inp  # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S_new = jnp.exp(lwt)[..., :, None] * S + kv
        return S_new, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, logw))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state


def wkv6_chunked(r, k, v, logw, u, state=None, chunk: int = 16):
    """Chunk-parallel WKV. Same signature/result as ``wkv6_scan``."""
    b, s, h, hd = r.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    f32 = jnp.float32
    rc = r.astype(f32).reshape(b, nc, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    kc = k.astype(f32).reshape(b, nc, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vc = v.astype(f32).reshape(b, nc, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    lw = logw.astype(f32).reshape(b, nc, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    # (nc, B, H, C, hd)

    if state is None:
        state = jnp.zeros((b, h, hd, hd), f32)

    def chunk_step(S, inp):
        rb, kb, vb, lwb = inp  # (B,H,C,hd)
        L = jnp.cumsum(lwb, axis=2) - lwb  # exclusive cumsum: L_t = sum_{s<t}
        Ltot = L[:, :, -1:, :] + lwb[:, :, -1:, :]  # (B,H,1,hd)
        cl = lambda z: jnp.clip(z, -LOG_CLAMP, LOG_CLAMP)
        r_dec = rb * jnp.exp(cl(L))                      # r̃_t
        k_inc = kb * jnp.exp(cl(-(L + lwb)))             # k̃_s = k ⊘ P_{s+1}
        k_out = kb * jnp.exp(cl(Ltot - L - lwb))         # k̂_s for state update
        A = jnp.einsum("bhtd,bhsd->bhts", r_dec, k_inc)  # (B,H,C,C)
        tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)
        diag = jnp.einsum("bhtd,bhtd->bht", rb, u[None, :, None, :] * kb)
        A = A * tri + jnp.eye(chunk, dtype=f32)[None, None] * diag[..., None]
        out = jnp.einsum("bhts,bhsd->bhtd", A, vb)
        out = out + jnp.einsum("bhtd,bhdv->bhtv", r_dec, S)
        S_new = jnp.exp(cl(Ltot))[..., 0, :, None] * S + jnp.einsum(
            "bhsd,bhsv->bhdv", k_out, vb)
        return S_new, out

    state, out = jax.lax.scan(chunk_step, state, (rc, kc, vc, lw))
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, nc * chunk, h, hd)
    return out[:, :s], state


def timemix(params, cfg: RWKV6Config, x: jax.Array, compute_dtype=jnp.bfloat16):
    """Full-sequence RWKV6 time mix. x: (B,S,D) -> (B,S,D)."""
    h, hd = cfg.n_heads, cfg.head_dim
    r, k, v, g, logw = timemix_project(params, cfg, x, None, compute_dtype)
    rh, kh, vh = (_to_heads(a, h, hd) for a in (r, k, v))
    lwh = _to_heads(logw, h, hd)
    u = params["u"].astype(jnp.float32)
    if cfg.impl == "scan":
        out, _ = wkv6_scan(rh, kh, vh, lwh, u)
    else:
        out, _ = wkv6_chunked(rh, kh, vh, lwh, u, chunk=cfg.chunk)
    b, s, _, _ = out.shape
    out = layers.groupnorm(out.reshape(b, s, h * hd).astype(compute_dtype), h,
                           params["ln_scale"], params["ln_bias"])
    out = out * g
    return jnp.einsum("bsd,de->bse", out, params["wo"].astype(compute_dtype))


def timemix_state_shape(cfg: RWKV6Config, batch: int):
    h, hd = cfg.n_heads, cfg.head_dim
    return {
        "wkv": jax.ShapeDtypeStruct((batch, h, hd, hd), jnp.float32),
        "x_prev": jax.ShapeDtypeStruct((batch, cfg.d_model), jnp.bfloat16),
    }


def timemix_step(params, cfg: RWKV6Config, state, x_t: jax.Array,
                 compute_dtype=jnp.bfloat16):
    """One-token decode: O(1) state. x_t: (B,D)."""
    h, hd = cfg.n_heads, cfg.head_dim
    r, k, v, g, logw = timemix_project(
        params, cfg, x_t[:, None], state["x_prev"], compute_dtype)
    rh, kh, vh = (_to_heads(a, h, hd) for a in (r, k, v))
    lwh = _to_heads(logw, h, hd)
    out, wkv = wkv6_scan(rh, kh, vh, lwh, params["u"].astype(jnp.float32),
                         state["wkv"])
    b = x_t.shape[0]
    y = layers.groupnorm(out.reshape(b, 1, h * hd).astype(compute_dtype), h,
                         params["ln_scale"], params["ln_bias"])
    y = (y * g)[:, 0]
    y = jnp.einsum("bd,de->be", y, params["wo"].astype(compute_dtype))
    return {"wkv": wkv, "x_prev": x_t.astype(jnp.bfloat16)}, y


# ---------------------------------------------------------------------------
# RWKV channel mix
# ---------------------------------------------------------------------------


def channelmix_spec(d: int, d_ff: int, dtype=jnp.float32):
    s = lambda fan: 1.0 / math.sqrt(fan)
    return {
        "mu_k": P((d,), ("embed",), init="uniform", scale=0.5, dtype=dtype),
        "wk": P((d, d_ff), ("embed", "mlp"), dtype=dtype, scale=s(d)),
        "wv": P((d_ff, d), ("mlp", "embed"), dtype=dtype, scale=s(d_ff)),
    }


def channelmix(params, x: jax.Array, x_prev: jax.Array | None = None,
               compute_dtype=jnp.bfloat16):
    x = x.astype(compute_dtype)
    if x_prev is None:
        sx = _shift(x) - x
    else:
        prev = jnp.concatenate([x_prev[:, None].astype(compute_dtype), x[:, :-1]], axis=1)
        sx = prev - x
    xk = x + sx * params["mu_k"].astype(compute_dtype)
    h = layers.relu_sq(jnp.einsum("bsd,df->bsf", xk, params["wk"].astype(compute_dtype)))
    return jnp.einsum("bsf,fd->bsd", h, params["wv"].astype(compute_dtype))


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    width: int
    c: float = 8.0


def rglru_spec(cfg: RGLRUConfig, dtype=jnp.float32):
    d = cfg.width
    s = 1.0 / math.sqrt(d)
    return {
        # Λ init so that a = exp(-c·softplus(Λ)) lands in [0.9, 0.999]
        "lam": P((d,), ("embed",), init="uniform", scale=0.5, dtype=dtype),
        "wa": P((d, d), ("embed", "embed2"), dtype=dtype, scale=s),
        "ba": P((d,), ("embed",), init="zeros", dtype=dtype),
        "wx": P((d, d), ("embed", "embed2"), dtype=dtype, scale=s),
        "bx": P((d,), ("embed",), init="zeros", dtype=dtype),
    }


def _rglru_gates(params, cfg: RGLRUConfig, x: jax.Array):
    f32 = jnp.float32
    ra = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x.astype(f32),
                                   params["wa"].astype(f32)) + params["ba"].astype(f32))
    rx = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x.astype(f32),
                                   params["wx"].astype(f32)) + params["bx"].astype(f32))
    log_a = -cfg.c * jax.nn.softplus(params["lam"].astype(f32)) * ra
    a = jnp.exp(log_a)
    gated_x = rx * x.astype(f32)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * gated_x


def rglru(params, cfg: RGLRUConfig, x: jax.Array, h0: jax.Array | None = None):
    """x: (B,S,D). First-order diagonal linear recurrence via associative scan.
    h_t = a_t ⊙ h_{t-1} + sqrt(1-a_t²) ⊙ (σ(gate_x)·x_t)."""
    a, b = _rglru_gates(params, cfg, x)  # (B,S,D) f32 each
    if h0 is not None:
        # fold carry into the first element: b_0 += a_0 * h0
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(params, cfg: RGLRUConfig, h: jax.Array, x_t: jax.Array):
    """One decode step. h: (B,D) f32; x_t: (B,D)."""
    a, b = _rglru_gates(params, cfg, x_t)
    h_new = a * h.astype(jnp.float32) + b
    return h_new, h_new.astype(x_t.dtype)
