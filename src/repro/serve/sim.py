"""Deterministic simulated engine + bursty traces for control-plane soak.

Soaking the overload control plane needs *hours* of bursty traffic and
100k+ requests — far beyond what the real jitted engines can serve in a
CI budget, and irrelevant to what's under test (the admission policy,
the feedback controller, the shedding accounting).  :class:`SimEngine`
is an :class:`~repro.serve.runtime.EngineProtocol` implementation whose
service is a closed-form queueing model on the *injected virtual
clock*: one serial server, per-group service time ``base_s +
per_item_s * bucket``.  Because it never reads real time (no ``time``
import — analyzer rule NSF105 enforces this for control-plane files),
an entire multi-hour soak runs in seconds of host time and two runs of
the same trace produce bit-identical reports.

:func:`bursty_times` generates the production-shaped load: a diurnal
sinusoid over a base Poisson rate with superimposed burst windows —
the traffic NSFlow-style real-time serving has to survive.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.serve import runtime as rt
from repro.serve.runtime import GroupRecord


@dataclasses.dataclass(frozen=True)
class SimRequest:
    """Minimal protocol request envelope for the simulated engine."""

    uid: int
    priority: str = "standard"
    work: int = 1


@dataclasses.dataclass(frozen=True)
class SimResult:
    uid: int


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Closed-form per-group service time: ``base_s`` dispatch overhead
    plus ``per_item_s`` per padded row of the compiled bucket."""

    base_s: float = 0.004
    per_item_s: float = 0.001

    def group_s(self, bucket: int) -> float:
        return self.base_s + self.per_item_s * bucket

    def capacity_rps(self, bucket: int) -> float:
        """Advertised steady-state capacity serving full groups at
        ``bucket``: requests per second the serial server sustains."""
        return bucket / self.group_s(bucket)


class SimEngine:
    """Protocol engine with deterministic virtual-time service.

    ``clock``/``sleep`` are *required*: a simulated engine on the host
    clock is meaningless, and the front-door drives both (it points
    ``eng.clock`` at its own clock for the serve and its sleeps advance
    the shared virtual time).  Completion is single-server FIFO: a
    group dispatched at ``t`` finishes at ``max(t, server_free) +
    group_s(bucket)``.
    """

    def __init__(self, clock: Callable[[], float],
                 sleep: Callable[[float], None],
                 cap: int = 8, buckets: Sequence[int] | None = None,
                 service: ServiceModel | None = None,
                 max_inflight: int = 4, variant: str = "sim"):
        if cap < 1:
            raise ValueError(f"cap must be >= 1, got {cap}")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, "
                             f"got {max_inflight}")
        self.clock = clock
        self._sleep = sleep
        self.cap = cap
        self.buckets = tuple(sorted(buckets)) if buckets else \
            _pow2_chain(cap)
        if self.buckets[-1] != cap:
            raise ValueError(f"largest bucket {self.buckets[-1]} must "
                             f"equal cap {cap}")
        self.service = service or ServiceModel()
        self.max_inflight = max_inflight
        self.variant = variant
        self.stats = rt.fresh_split_stats()
        self.runs: list[dict] = []
        self._inflight: list[tuple[GroupRecord, list[SimRequest], float]] \
            = []
        # results collected by the window trim inside submit, buffered
        # until the next drain call (mirrors ReasonEngine's ready buffer)
        self._done: dict[int, SimResult] = {}
        self._free_t: float | None = None
        self._index = 0
        self._warm: set[int] = set()

    @property
    def admission_cap(self) -> int:
        return self.cap

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    @property
    def accepting(self) -> bool:
        """True while ``submit`` would dispatch without blocking on the
        in-flight window — the backpressure signal the front-door's
        overload path reads (see ``FrontDoor._accepting``)."""
        return len(self._inflight) < self.max_inflight

    def _bucket_for(self, size: int) -> int:
        for b in self.buckets:
            if b >= size:
                return b
        return self.buckets[-1]

    def submit(self, group: Sequence[SimRequest]) -> GroupRecord:
        if not group:
            raise ValueError("empty admission group")
        if len(group) > self.cap:
            raise ValueError(f"group of {len(group)} exceeds "
                             f"admission cap {self.cap}")
        bucket = self._bucket_for(len(group))
        rec = GroupRecord(uids=tuple(r.uid for r in group),
                          index=self._index, variant=self.variant,
                          bucket=bucket, size=len(group))
        self._index += 1
        rec.dispatch_t = self.clock()
        # bounded in-flight window: block (advancing virtual time) until
        # there is room — mirrors the staged pipeline's depth-k window
        while len(self._inflight) >= self.max_inflight:
            self._drain_one()
        start = rec.dispatch_t if self._free_t is None else \
            max(rec.dispatch_t, self._free_t)
        done_at = start + self.service.group_s(bucket)
        self._free_t = done_at
        self._inflight.append((rec, list(group), done_at))
        return rec

    def _drain_one(self) -> None:
        rec, group, done_at = self._inflight.pop(0)
        dt = done_at - self.clock()
        if dt > 0:
            self._sleep(dt)
        self._collect(rec, group, done_at)

    def _collect(self, rec: GroupRecord, group: list[SimRequest],
                 done_at: float) -> None:
        rec.done_t = max(done_at, self.clock())
        warm = rec.bucket in self._warm
        self._warm.add(rec.bucket)
        split = self.stats["measured" if warm else "warmup"]
        split["requests"] += rec.size
        split["work"] += sum(r.work for r in group)
        split["wall_time_s"] += rec.done_t - rec.dispatch_t
        self.runs.append({"index": rec.index, "bucket": rec.bucket,
                          "size": rec.size, "warmup": not warm})
        self._done.update((r.uid, SimResult(uid=r.uid)) for r in group)

    def drain_ready(self) -> dict[int, SimResult]:
        """Collect every in-flight group whose completion time has
        passed on the (possibly virtual) clock.  Non-blocking."""
        now = self.clock()
        while self._inflight and self._inflight[0][2] <= now:
            self._collect(*self._inflight.pop(0))
        out, self._done = self._done, {}
        return out

    def drain_all(self) -> dict[int, SimResult]:
        while self._inflight:
            self._drain_one()
        out, self._done = self._done, {}
        return out


def _pow2_chain(cap: int) -> tuple[int, ...]:
    out, b = [], 1
    while b < cap:
        out.append(b)
        b *= 2
    return tuple(out) + (cap,)


# ---------------------------------------------------------------------------
# bursty traffic
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Burst:
    """One overload window: offered rate is multiplied by ``mult`` for
    ``dur_s`` seconds starting at ``t0_s``."""

    t0_s: float
    dur_s: float
    mult: float


def diurnal_rate(t: float, base_rps: float, amp: float = 0.4,
                 period_s: float = 3600.0,
                 bursts: Sequence[Burst] = ()) -> float:
    """Offered rate at time ``t``: diurnal sinusoid over ``base_rps``
    with burst windows multiplied on top."""
    r = base_rps * (1.0 + amp * np.sin(2.0 * np.pi * t / period_s))
    for b in bursts:
        if b.t0_s <= t < b.t0_s + b.dur_s:
            r *= b.mult
    return float(max(r, 1e-9))


def bursty_times(n: int, base_rps: float, *, amp: float = 0.4,
                 period_s: float = 3600.0, bursts: Sequence[Burst] = (),
                 seed: int = 0, start_s: float = 0.0) -> list[float]:
    """``n`` arrival times from an inhomogeneous Poisson process whose
    rate follows :func:`diurnal_rate`.  Deterministic in ``seed``."""
    if base_rps <= 0:
        raise ValueError(f"base_rps must be > 0, got {base_rps}")
    rng = np.random.default_rng(seed)
    t = start_s
    out = []
    for _ in range(n):
        t += float(rng.exponential(
            1.0 / diurnal_rate(t, base_rps, amp, period_s, bursts)))
        out.append(t)
    return out


def sim_requests(n: int, mix: dict[str, float] | None = None,
                 seed: int = 0, uid0: int = 0) -> list[SimRequest]:
    """``n`` :class:`SimRequest` envelopes with priorities drawn from
    ``mix`` (class -> weight; default all ``standard``).  Deterministic
    in ``seed``."""
    if not mix:
        return [SimRequest(uid=uid0 + i) for i in range(n)]
    from repro.serve.slo import validate_priority

    classes = [validate_priority(c) for c in mix]
    w = np.asarray([float(mix[c]) for c in classes], dtype=float)
    if (w < 0).any() or not w.sum():
        raise ValueError(f"priority mix weights must be >= 0 and sum > 0: "
                         f"{mix}")
    rng = np.random.default_rng(seed)
    picks = rng.choice(len(classes), size=n, p=w / w.sum())
    return [SimRequest(uid=uid0 + i, priority=classes[int(k)])
            for i, k in enumerate(picks)]
