"""``deploy()``: the paper's generator -> serving-architecture loop.

NSFlow's headline claim (paper Sec III, V) is *end-to-end*: a design
architecture generator reads the workload's dataflow dependencies and
emits the serving architecture.  This module closes that loop in the
actual serving path:

1. **trace** — each NSAI workload's staged pipeline is compiled and its
   :class:`~repro.core.dataflow.DataflowGraph` traced from the composed
   stages (``serve.schedule.ensure_graph`` — the same jaxpr-derived graph
   the analytic side consumes).
2. **explore** — ``core.dse.explore`` runs Algorithm 1 over the graph
   under the deployment :class:`Budget` (PE count), picking the AdArray
   shape, mode, and static nn/vsa partition.
3. **derive** — ``core.dse.serving_plan`` maps the winning design point
   onto the serving runtime's knobs (batch buckets, ``max_inflight``,
   overlap-vs-sequential schedule), and the engines are compiled from the
   *plan* instead of hand-set ``ReasonConfig`` fields.

LM workloads (token-in/token-out archs) have a single homogeneous nn
stream — the dual-stream AdArray DSE has nothing to partition — so their
slot-pool engines are sized from the :class:`Budget` directly (``designs``
records ``None`` for them).

The result is a :class:`Deployment`: one :class:`~repro.serve.frontdoor.
FrontDoor` over every engine, so mixed LM + NSAI arrival streams serve
through a single admission layer.  ``Deployment.report()`` surfaces the
chosen ``DesignConfig.summary()`` per workload, so benchmark records can
say which DSE point served each measurement.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Mapping

from repro.backend import registry as backend_registry
from repro.serve.control import (ControlConfig, OverloadController,
                                 validate_shed_policy)
from repro.serve.frontdoor import (ArrivalRequest, FrontDoor,
                                   FrontDoorConfig, FrontDoorReport,
                                   merge_arrivals, poisson_arrivals,
                                   with_priorities)
from repro.serve.slo import slo_targets


@dataclasses.dataclass(frozen=True)
class Traffic:
    """What the deployment is sized to serve (the ``traffic`` argument)."""

    rate_rps: float = 20.0        # per-model Poisson offered load
    deadline_s: float = 0.02      # admission-group deadline
    poll_s: float = 0.002         # front-door drain poll while in flight


@dataclasses.dataclass(frozen=True)
class Budget:
    """Resource envelope the generator explores under.

    ``devices`` / ``replicas`` / ``tp`` size the *mesh* side of the
    search: ``devices`` is the device pool (None = ``jax.device_count()``
    — fake host devices via ``XLA_FLAGS=--xla_force_host_platform_
    device_count=N``), ``replicas`` the data-parallel engine replica
    count per model (None = 1, ``"auto"`` = the data axis of the
    mesh-DSE winner under ``devices``), ``tp`` the tensor-parallel degree
    of each LM replica (NSAI pipelines serve whole-pipeline-per-device,
    so ``tp`` does not apply to them).  ``replicas`` may exceed
    ``devices`` (placement wraps round-robin) — useful on 1-device hosts
    where N replicas still shard load across N in-flight windows.

    ``slo_ms`` / ``queue_depth`` / ``shed_policy`` size the *overload
    control plane*: setting either of the first two attaches an
    :class:`~repro.serve.control.OverloadController` to the front-door,
    with the DSE-derived serving plan as its initial operating point.
    ``slo_ms`` is a scalar (interactive p99 target; see
    :func:`~repro.serve.slo.slo_targets`) or a per-class mapping;
    ``queue_depth`` bounds each model's pending queue (arrivals beyond
    it shed per ``shed_policy`` instead of growing the queue without
    bound).
    """

    max_pes: int = 4096           # AdArray PE budget handed to the DSE
    max_batch: int = 8            # admission-group ceiling (NSAI buckets)
    inflight_cap: int = 4         # ceiling on the DSE-derived window depth
    max_slots: int = 4            # LM slot-pool size
    max_len: int = 128            # LM per-slot KV capacity
    decode_block: int = 8         # LM tokens per fused decode dispatch
    max_new_tokens: int = 24      # LM default generation budget
    devices: int | None = None    # device pool (None = jax.device_count())
    replicas: int | str | None = None  # DP engine replicas (None=1, "auto")
    tp: int | None = None         # LM tensor-parallel degree (None = 1)
    # overload control plane (both None = legacy static front-door)
    slo_ms: float | Mapping[str, float] | None = None
    queue_depth: int | None = None
    shed_policy: str = "lowest-priority"


@dataclasses.dataclass
class Deployment:
    """One deployed serving runtime: protocol engines + one front-door.

    ``classes[model]`` is the runtime traffic class ("reason" | "lm");
    ``designs`` / ``plans`` carry the DSE point and derived serving plan
    for NSAI models (None for LM models); ``configs`` the per-model model
    config (an ``NVSAConfig``-style workload config or an arch smoke
    config — whatever the model class builds traffic from).
    """

    engines: dict[str, Any]
    door: FrontDoor
    classes: dict[str, str]
    designs: dict[str, Any]
    plans: dict[str, Any]
    configs: dict[str, Any]
    variants: dict[str, str | None]
    traffic: Traffic
    budget: Budget
    seed: int = 0
    # the LoweringPlan negotiated once at deploy() time; every NSAI
    # schedule compiled under it (None only for hand-built Deployments)
    backend: backend_registry.LoweringPlan | None = None
    # the per-model option kwargs deploy() was called with — kept so a
    # recorded golden trace can re-deploy the same models for replay
    options: dict = dataclasses.field(default_factory=dict)
    # mesh-DSE outcome per model: the deployed MeshPoint (data = replica
    # count, model = TP degree; empty for hand-built Deployments) and the
    # resolved replica count (defaults to 1 when absent)
    mesh: dict = dataclasses.field(default_factory=dict)
    replicas: dict = dataclasses.field(default_factory=dict)
    # the preflight AnalysisReport deploy() ran over the compiled
    # schedules + serving sources (None when preflight="off" or for
    # hand-built Deployments)
    analysis: Any = None
    # the overload controller attached to the front-door (None when the
    # Budget requested no SLO targets and no queue bound)
    controller: OverloadController | None = None

    def _pool(self, m: str):
        """The model's ReplicaPool, or None when served by a bare engine."""
        from repro.serve.replica import ReplicaPool

        eng = self.engines[m]
        return eng if isinstance(eng, ReplicaPool) else None

    def _base(self, m: str):
        """The model's representative engine (replica 0 of a pool) — the
        one to read compile-time structure (cfg / schedules) from; stats
        should come from the pool (merged) instead."""
        pool = self._pool(m)
        return pool.replicas[0] if pool is not None else self.engines[m]

    def serve(self, arrivals: Iterable[ArrivalRequest]) -> FrontDoorReport:
        """Serve one merged arrival stream through the front-door."""
        return self.door.serve(arrivals)

    def backend_record(self) -> dict | None:
        """The negotiated LoweringPlan as a plain record: platform, how it
        was chosen (negotiated vs env/explicit override), and the headline
        lowering per registered kernel."""
        if self.backend is None:
            return None
        return {
            "platform": self.backend.platform,
            "source": self.backend.source,
            "lowerings": self.backend.tags(),
        }

    def report(self) -> dict:
        """Per-model deployment record, incl. the chosen DSE point and the
        negotiated per-kernel backend lowerings."""
        out = {}
        backend = self.backend_record()
        for m, eng in self.engines.items():
            design, plan = self.designs[m], self.plans[m]
            pool, base = self._pool(m), self._base(m)
            if self.classes[m] == "reason":
                sched = base.schedules[self.variants[m]]
                # stats off ``eng``: for a pool that's the recursive sum
                # over replicas, so dispatch counts / rates stay whole-
                # deployment truths whatever the replica count
                serving = {
                    "batch_size": base.cfg.batch_size,
                    "buckets": tuple(base.cfg.buckets or ()),
                    "max_inflight": base.cfg.max_inflight,
                    "schedule": base.cfg.schedule,
                    "variant": self.variants[m],
                    # the fused-pipeline negotiation outcome for the served
                    # variant, plus the measured (non-warmup) steady-state
                    # rate — real even for engines only ever driven through
                    # the submit/drain protocol (per-group accounting)
                    "fused": {
                        "ok": sched.fused_ok,
                        "equivalence": sched.fused_equivalence,
                        "epsilon": sched.fused_epsilon,
                        "lowering_diff": sched.fused_lowering_diff,
                        "groups": eng.stats["fused_groups"],
                        "fallback_groups":
                            eng.stats["fused_fallback_groups"],
                    },
                    "dispatches": eng.stats["dispatches"],
                    "measured_requests": eng.stats["measured"]["requests"],
                    "problems_per_s": eng.problems_per_s(),
                }
            else:
                serving = {
                    "max_slots": base.cfg.max_slots,
                    "max_len": base.cfg.max_len,
                    "decode_block": base.cfg.decode_block,
                }
            point = self.mesh.get(m)
            out[m] = {
                "class": self.classes[m],
                "design": design.summary() if design is not None else None,
                "searched_points": getattr(design, "searched_points", None),
                "serving": serving,
                "backend": backend,
                # the deployed mesh factorization (data = engine replicas,
                # model = TP degree) with its predicted roofline bound,
                # and the routing/utilization split across replicas
                "mesh": point.record() if point is not None else None,
                "replicas": self.replicas.get(m, 1),
                "per_replica": pool.per_replica() if pool else None,
            }
        # the preflight verdict rides alongside the per-model records so
        # benchmark JSON carries the analysis that cleared the deployment
        out["analysis"] = (self.analysis.to_dict()
                           if self.analysis is not None else None)
        # the overload control plane in force (None = legacy static door)
        ctl = self.controller
        out["control"] = None if ctl is None else {
            "slo_ms": {p: t.total_p99_ms for p, t in ctl.targets.items()},
            "queue_depth": ctl.cfg.queue_depth,
            "shed_policy": ctl.cfg.shed_policy,
            "tick_s": ctl.cfg.tick_s,
            "operating": {m: {"deadline_s": ctl.deadline_s(m),
                              "cap": ctl.cap(m)}
                          for m in sorted(ctl.bound())},
            "ticks": ctl.ticks,
            "decisions": len(ctl.decisions),
        }
        return out

    def summary(self) -> str:
        """One line per model: class, serving knobs, DSE + backend tags."""
        lines = []
        backend = f"backend={self.backend.tag()}" if self.backend else \
            "backend=n/a"
        for m, rec in self.report().items():
            if m in ("analysis", "control"):  # deployment-wide records
                continue
            design = self.designs[m]
            if design is not None:
                dse = (f"dse={design.tag()} "
                       f"({design.searched_points} points)")
            else:
                dse = "dse=n/a (single nn stream)"
            point = self.mesh.get(m)
            mesh = (f"{point.tag()} replicas={rec['replicas']}"
                    if point is not None else "mesh=n/a")
            knobs = " ".join(f"{k}={v}" for k, v in rec["serving"].items())
            lines.append(f"{m} [{rec['class']}]: {knobs} | {dse} | {mesh} "
                         f"| {backend}")
            if rec["per_replica"]:
                split = " ".join(
                    f"r{r['replica']}:{r['groups']}g/{r['requests']}req"
                    f"/{r['share']:.0%}" for r in rec["per_replica"])
                lines.append(f"  {m} replicas: {split}")
        if self.analysis is not None:
            verdict = "PASS" if self.analysis.ok else "FAIL"
            lines.append(f"preflight {verdict}: "
                         f"{len(self.analysis.errors)} error(s), "
                         f"{len(self.analysis.warnings)} warning(s)")
        if self.controller is not None:
            ctl = self.controller
            slos = " ".join(f"{p}<= {t.total_p99_ms:.0f}ms"
                            for p, t in ctl.targets.items()) or "none"
            lines.append(f"control: slo [{slos}] "
                         f"queue_depth={ctl.cfg.queue_depth} "
                         f"shed={ctl.cfg.shed_policy} "
                         f"tick={ctl.cfg.tick_s * 1e3:.0f}ms")
        return "\n".join(lines)

    # -- synthetic traffic + warmup (launcher / benchmark helpers) ----------

    def _streams(self, n: int, seed: int):
        """Per-model lazy request streams + NSAI ground-truth thunks."""
        import numpy as np

        from repro.configs import base as cbase
        from repro.serve.engine import Request

        streams, truths = {}, {}
        for i, m in enumerate(self.engines):
            if self.classes[m] == "reason":
                factory, truth = cbase.REASON_WORKLOADS[m].make_requests(
                    self.configs[m], n, seed=seed + i)
                streams[m], truths[m] = factory(), truth
            else:
                cfg, scfg = self.configs[m], self._base(m).cfg
                plen = max(1, min(16, scfg.max_len - scfg.max_new_tokens))
                rng = np.random.default_rng(seed + i)

                def lm_stream(rng=rng, vocab=cfg.vocab, plen=plen):
                    for uid in range(n):
                        yield Request(uid=uid, prompt=rng.integers(
                            0, vocab, (plen,)).astype(np.int32))

                streams[m] = lm_stream()
        return streams, truths

    def synthetic_traffic(self, n: int, seed: int = 100,
                          priorities: str | Mapping[str, float] | None
                          = None):
        """A merged Poisson arrival feed of ``n`` requests per model at
        the deployment's offered rate.  Returns ``(arrivals, truths)``
        where ``truths[model]()`` lazily materializes ground truth for
        NSAI models (absent for LM models).  ``priorities`` stamps a
        traffic-class mix onto the stream (one class name, or a
        ``{class: weight}`` mapping sampled deterministically — see
        :func:`~repro.serve.frontdoor.with_priorities`)."""
        streams, truths = self._streams(n, seed)
        arrivals = merge_arrivals(*(
            poisson_arrivals(m, s, self.traffic.rate_rps, seed=seed + j)
            for j, (m, s) in enumerate(streams.items())))
        if priorities is not None:
            arrivals = with_priorities(arrivals, priorities, seed=seed)
        return arrivals, truths

    def warmup(self):
        """Compile every serving shape before traffic arrives: each NSAI
        bucket's jit entry and the LM prefill + decode block — so online
        latency percentiles never include jit compile.  Pooled engines
        warm every replica: the jit caches are shared across replicas but
        keyed by device placement, so each replica's device needs its own
        first touch."""
        from repro.configs import base as cbase

        for m, eng in self.engines.items():
            pool = self._pool(m)
            subs = pool.replicas if pool is not None else [eng]
            base = subs[0]
            if self.classes[m] == "reason":
                for sub in subs:
                    for b in base.cfg.buckets or (base.cfg.batch_size,):
                        factory, _ = cbase.REASON_WORKLOADS[m].make_requests(
                            self.configs[m], b, seed=5000 + b)
                        sub.run(factory())
            else:
                for sub in subs:
                    streams, _ = self._streams(base.cfg.max_slots, seed=5000)
                    sub.run(list(streams[m]))
        return self


def _mesh_plan(n_params: float, d_model: int, n_layers: int, seq: int,
               batch: int, ndev: int, replicas, tp: int,
               kv_bytes_per_tok: float = 0.0):
    """Resolve (replica count, deployed MeshPoint) for one model.

    ``replicas="auto"`` lets the serving-mode mesh DSE pick: search the
    whole ``ndev`` pool with the model axis pinned to ``tp`` and take the
    winner's data axis.  An explicit/None replica count is honored as-is
    — the search then runs at ``chips = replicas × tp`` so the recorded
    point describes the factorization actually deployed (its ``bound_s``
    is the per-step roofline prediction for that mesh).
    """
    from repro.core import meshdse

    def pts_at(chips, b):
        pts = meshdse.serving_search(
            n_params, n_params, d_model, n_layers, seq, b,
            devices=chips, kv_bytes_per_tok=kv_bytes_per_tok,
            max_model=tp)
        return [p for p in pts if p.model == tp] or pts

    if replicas == "auto":
        point = pts_at(max(1, ndev), batch)[0]
        return point.data, point
    r = int(replicas or 1)
    # the search drops data axes that don't divide the batch; an explicit
    # replica count is honored regardless, so round the modeled batch up
    b = batch if (batch % r == 0 or batch < r) else -(-batch // r) * r
    pts = pts_at(r * tp, b)
    point = next((p for p in pts if p.data == r and p.model == tp), pts[0])
    return r, point


def deploy(workloads: Iterable[str], traffic: Traffic | None = None,
           budget: Budget | None = None, *, seed: int = 0,
           options: Mapping[str, Mapping[str, Any]] | None = None,
           backend: str | backend_registry.LoweringPlan | None = None,
           preflight: str = "error",
           clock: Callable[[], float] = time.perf_counter,
           sleep: Callable[[float], None] = time.sleep) -> Deployment:
    """Deploy a mixed set of workloads behind one front-door.

    ``workloads``: model names from the runtime registry — NSAI workload
    ids (``configs.base.REASON_WORKLOADS``: nvsa, prae, mimonet, lvrf)
    and/or servable LM arch ids (llama3.2-3b, stablelm-3b, ...), freely
    mixed.  ``options[model]`` passes per-model config kwargs (NSAI:
    ``make_config`` knobs like ``d`` / ``nn_precision`` plus an optional
    ``variant``; LM: ``ServeConfig`` field overrides).

    For each NSAI workload the serving configuration is *derived*, not
    hand-set: the staged pipeline's dataflow graph is traced, explored by
    ``core.dse.explore`` under ``budget.max_pes``, and the winning design
    point mapped to batch buckets / ``max_inflight`` / schedule by
    ``core.dse.serving_plan`` (see the module docstring).

    ``backend``: the kernel-lowering choice for the whole deployment —
    None negotiates against the runtime (honoring ``REPRO_BACKEND``), a
    string is an explicit override spec (``"xla"`` or
    ``"circ_conv=xla,qmatmul=pallas"``), or pass a pre-built
    :class:`~repro.backend.registry.LoweringPlan`.  Negotiation happens
    exactly once here; every NSAI schedule compiles under the resulting
    plan and ``Deployment.report()`` records the per-kernel choices.

    ``preflight``: the static-analysis gate over what was just compiled —
    ``"error"`` (default) runs the cheap preflight tier (per-stage jaxpr
    checks, retrace hazards, registry consistency, the memoized serving
    lint) and raises :class:`~repro.analyze.findings.PreflightError` when
    error-severity findings survive; ``"warn"`` runs it but only records
    the report; ``"off"`` skips it.  Either way the report lands in
    ``Deployment.report()["analysis"]``.
    """
    import jax

    from repro.configs import base as cbase
    from repro.core import dse
    from repro.serve import runtime as rt
    from repro.serve import schedule as sch
    from repro.serve.engine import ServeConfig
    from repro.serve.reason import ReasonConfig

    traffic = traffic or Traffic()
    budget = budget or Budget()
    options = dict(options or {})
    models = rt.resolve_models("frontdoor", workloads)
    if not models:
        raise ValueError("deploy needs at least one workload")
    if preflight not in ("error", "warn", "off"):
        raise ValueError(f"preflight must be 'error', 'warn' or 'off', "
                         f"got {preflight!r}")
    if isinstance(backend, backend_registry.LoweringPlan):
        lowering_plan = backend
    else:
        lowering_plan = backend_registry.negotiate(override=backend)

    engines: dict[str, Any] = {}
    classes: dict[str, str] = {}
    designs: dict[str, Any] = {}
    plans: dict[str, Any] = {}
    configs: dict[str, Any] = {}
    variants: dict[str, str | None] = {}
    mesh: dict[str, Any] = {}
    replicas: dict[str, int] = {}
    ndev = budget.devices or jax.device_count()
    tp_eff = budget.tp or 1
    root = jax.random.PRNGKey(seed)
    for i, m in enumerate(models):
        key = jax.random.fold_in(root, i)
        opts = dict(options.get(m, {}))
        if m in cbase.REASON_WORKLOADS:
            entry = cbase.REASON_WORKLOADS[m]
            variant = opts.pop("variant", None) or entry.variants[0]
            cfg = entry.make_config(**opts)
            # generator step: trace the exact pipeline the schedule will
            # execute (abstract consts — nothing materialized yet) and
            # explore the design space over its dataflow graph
            probe = cbase.compile_reason_schedule(
                m, cfg, variant=variant, batch_size=budget.max_batch,
                trace_graph=False, plan=lowering_plan)
            design = dse.explore(sch.ensure_graph(probe),
                                 max_pes=budget.max_pes)
            plan = dse.serving_plan(design, max_batch=budget.max_batch,
                                    inflight_cap=budget.inflight_cap)
            consts = entry.make_consts(cfg, key)
            # mesh co-search (serving mode): staged pipelines serve one
            # whole pipeline per device, so the model axis is pinned to 1
            # and the winner's data axis is the engine replica count
            n_params = sum(getattr(x, "size", 0)
                           for x in jax.tree.leaves(consts))
            r, point = _mesh_plan(
                float(n_params), getattr(cfg, "d", 128),
                max(1, len(entry.stage_specs(cfg, variant))), seq=1,
                batch=budget.max_batch, ndev=ndev,
                replicas=budget.replicas, tp=1)
            eng = cbase.reason_engine_pool(
                m, cfg,
                ReasonConfig(batch_size=plan.batch_size,
                             schedule=plan.schedule, variant=variant,
                             max_inflight=plan.max_inflight,
                             buckets=plan.buckets),
                consts=consts, variants=(variant,), replicas=r,
                trace_graph=False, plan=lowering_plan)
            # fused-pipeline negotiation: when the compiled schedule's
            # fused variant is provably bit-identical under the deployment
            # plan, serve one dispatch per admission group instead of K
            # (the engine still falls back per-stage if the schedule's
            # negotiation says epsilon — answers never change).  Replicas
            # share one compiled schedule but carry their own cfg copy,
            # so the upgrade applies per replica.
            subs = eng.replicas if hasattr(eng, "replicas") else [eng]
            if plan.schedule == "overlap" and \
                    subs[0].schedules[variant].fused_ok:
                for sub in subs:
                    sub.cfg.schedule = "fused"
            classes[m], designs[m], plans[m] = "reason", design, plan
            variants[m] = variant
        else:
            # resolve_models already validated every name against the
            # frontdoor registry, so non-NSAI names are servable LM archs
            scfg = dataclasses.replace(
                ServeConfig(max_slots=budget.max_slots,
                            max_len=budget.max_len,
                            decode_block=budget.decode_block,
                            max_new_tokens=budget.max_new_tokens), **opts)
            # mesh co-search: LM decode may take a real TP axis through
            # distributed.sharding_rules, so the model axis is budget.tp;
            # the KV term comes from the arch config (bytes per resident
            # token across every layer's K+V, fp32 smoke params)
            from repro.configs import ARCHS
            mcfg = ARCHS[m].make_smoke()
            kv_bytes = (getattr(mcfg, "n_layers", 1) * 2
                        * getattr(mcfg, "n_kv_heads",
                                  getattr(mcfg, "n_heads", 1))
                        * getattr(mcfg, "head_dim", 64) * 4.0)
            r, point = _mesh_plan(
                float(cbase.param_count(ARCHS[m], mcfg)),
                getattr(mcfg, "d_model", 128),
                getattr(mcfg, "n_layers", 1), seq=budget.max_len,
                batch=budget.max_slots, ndev=ndev,
                replicas=budget.replicas, tp=tp_eff,
                kv_bytes_per_tok=kv_bytes)
            eng, cfg = cbase.lm_engine_pool(m, scfg, key=key,
                                            replicas=r, tp=tp_eff)
            classes[m], designs[m], plans[m] = "lm", None, None
            variants[m] = None
        engines[m], configs[m] = eng, cfg
        mesh[m], replicas[m] = point, r

    # preflight gate: the cheap analysis tier over exactly what was just
    # compiled — the schedules the engines will serve, under the one
    # negotiated plan — plus the serving-source lint (mtime-memoized, so
    # repeat deploys pay ~nothing) and the static registry checks.  No
    # kernel probes, no double-trace: those are the CLI/CI tier.
    analysis = None
    if preflight != "off":
        from repro.analyze.preflight import preflight as run_preflight
        from repro.serve.replica import ReplicaPool

        subjects = []
        for m in models:
            if classes[m] != "reason":
                continue
            eng = engines[m]
            base = eng.replicas[0] if isinstance(eng, ReplicaPool) else eng
            subjects.append((base.schedules[variants[m]], configs[m],
                             cbase.REASON_WORKLOADS[m], variants[m]))
        analysis = run_preflight(subjects)
        if preflight == "error" and not analysis.ok:
            from repro.analyze.findings import PreflightError

            raise PreflightError(analysis)

    # overload control plane: requested via the Budget's SLO/queue knobs.
    # The DSE-derived serving plan is the controller's *initial* operating
    # point — the feedback loop adapts deadline/cap from there, and the
    # plan's buckets are the cap steps it may move across.
    controller = None
    if budget.slo_ms is not None or budget.queue_depth is not None:
        validate_shed_policy(budget.shed_policy)
        controller = OverloadController(
            targets=slo_targets(budget.slo_ms),
            cfg=ControlConfig(queue_depth=budget.queue_depth,
                              shed_policy=budget.shed_policy))
        for m in models:
            if classes[m] == "reason":
                cap = plans[m].batch_size
                buckets = tuple(plans[m].buckets or (cap,))
            else:
                cap = budget.max_slots
                buckets = None
            controller.bind(m, deadline_s=traffic.deadline_s, cap=cap,
                            buckets=buckets)

    door = FrontDoor(engines,
                     FrontDoorConfig(deadline_s=traffic.deadline_s,
                                     poll_s=traffic.poll_s),
                     clock=clock, sleep=sleep, controller=controller)
    return Deployment(engines=engines, door=door, classes=classes,
                      designs=designs, plans=plans, configs=configs,
                      variants=variants, traffic=traffic, budget=budget,
                      seed=seed, backend=lowering_plan,
                      options={m: dict(options.get(m, {})) for m in models
                               if options.get(m)},
                      mesh=mesh, replicas=replicas, analysis=analysis,
                      controller=controller)
