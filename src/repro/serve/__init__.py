"""The serving runtime package.

One engine protocol (``serve.runtime.EngineProtocol``) serves every
traffic class: the slot-pool LM ``Engine`` (``serve.engine``), the staged
NSAI ``ReasonEngine`` (``serve.reason``), the deadline-batched
``FrontDoor`` admission layer over any mix of them (``serve.frontdoor``),
and ``deploy()`` — the DSE-driven generator->architecture entry point.

Only lightweight names are imported eagerly; engine modules (which pull
in jax) load on first use.
"""

from repro.serve.deploy import Budget, Deployment, Traffic, deploy
from repro.serve.runtime import (EngineProtocol, GroupRecord,
                                 TRAFFIC_CLASSES, TrafficClass,
                                 resolve_models, work_unit_name, work_units)

__all__ = [
    "Budget", "Deployment", "EngineProtocol", "GroupRecord",
    "TRAFFIC_CLASSES", "Traffic", "TrafficClass", "deploy",
    "resolve_models", "work_unit_name", "work_units",
]
