"""The serving runtime package.

One engine protocol (``serve.runtime.EngineProtocol``) serves every
traffic class: the slot-pool LM ``Engine`` (``serve.engine``), the staged
NSAI ``ReasonEngine`` (``serve.reason``), the deadline-batched
``FrontDoor`` admission layer over any mix of them (``serve.frontdoor``),
``deploy()`` — the DSE-driven generator->architecture entry point, which
also negotiates the kernel :class:`~repro.backend.registry.LoweringPlan`
once per deployment — and golden-trace record/replay (``serve.trace``).

Only lightweight names are imported eagerly; engine modules (which pull
in jax) load on first use.
"""

from repro.serve.deploy import Budget, Deployment, Traffic, deploy
from repro.serve.replica import ReplicaPool
from repro.serve.runtime import (EngineProtocol, GroupRecord,
                                 TRAFFIC_CLASSES, TrafficClass,
                                 resolve_models, work_unit_name, work_units)
from repro.serve.trace import GoldenTrace, ReplayReport, TraceDiff, record

__all__ = [
    "Budget", "Deployment", "EngineProtocol", "GoldenTrace", "GroupRecord",
    "ReplayReport", "ReplicaPool", "TRAFFIC_CLASSES", "TraceDiff", "Traffic",
    "TrafficClass", "deploy", "record", "resolve_models", "work_unit_name",
    "work_units",
]
