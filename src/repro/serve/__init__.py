"""The serving runtime package.

One engine protocol (``serve.runtime.EngineProtocol``) serves every
traffic class: the slot-pool LM ``Engine`` (``serve.engine``), the staged
NSAI ``ReasonEngine`` (``serve.reason``), the deadline-batched
``FrontDoor`` admission layer over any mix of them (``serve.frontdoor``),
``deploy()`` — the DSE-driven generator->architecture entry point, which
also negotiates the kernel :class:`~repro.backend.registry.LoweringPlan`
once per deployment — golden-trace record/replay (``serve.trace``), and
the overload control plane (``serve.control`` / ``serve.slo``): per-class
SLO targets, bounded priority queues with load-shedding, and the
feedback controller that adapts the front-door's operating point online.

Only lightweight names are imported eagerly; engine modules (which pull
in jax) load on first use.
"""

from repro.serve.control import (ClassQueues, ControlConfig,
                                 ControlDecision, OverloadController,
                                 SHED_POLICIES, ShedRecord)
from repro.serve.deploy import Budget, Deployment, Traffic, deploy
from repro.serve.replica import ReplicaPool
from repro.serve.runtime import (EngineProtocol, GroupRecord,
                                 TRAFFIC_CLASSES, TrafficClass,
                                 resolve_models, work_unit_name, work_units)
from repro.serve.slo import (PRIORITIES, SLOEstimator, SLOTarget,
                             slo_targets)
from repro.serve.trace import GoldenTrace, ReplayReport, TraceDiff, record

__all__ = [
    "Budget", "ClassQueues", "ControlConfig", "ControlDecision",
    "Deployment", "EngineProtocol", "GoldenTrace", "GroupRecord",
    "OverloadController", "PRIORITIES", "ReplayReport", "ReplicaPool",
    "SHED_POLICIES", "SLOEstimator", "SLOTarget", "ShedRecord",
    "TRAFFIC_CLASSES", "TraceDiff", "Traffic", "TrafficClass", "deploy",
    "record", "resolve_models", "slo_targets", "work_unit_name",
    "work_units",
]
