"""Data-parallel engine replicas behind one protocol surface.

NSFlow's scalability claim (paper Sec V) is that the generated array keeps
serving heterogeneous NSAI streams as they scale; the serving-side analogue
is *data parallelism over whole engines*: N identical protocol engines —
each with its constants resident on its own device — served as ONE
:class:`~repro.serve.runtime.EngineProtocol` implementation, so the
front-door (and anything else that drives submit/drain) needs no changes
to shard admission groups across devices.

``ReplicaPool`` is that implementation:

- **least-inflight dispatch**: ``submit`` routes each admission group to
  the replica with the fewest dispatched-but-undrained groups (ties break
  to the lowest index, so routing is deterministic for a given arrival
  order).  Each replica keeps its own depth-k in-flight window — the pool
  never collapses them into one queue, so k × N groups can be resident.
- **answer invariance**: answers are bit-identical whichever replica
  serves a request, because every replica is built from the *same*
  constants (same PRNG key) and the engines' outputs depend only on the
  request and the group it was admitted with — never on the device, the
  replica index, or co-resident groups.  ``tests/test_replica.py`` pins
  the 4-replica answer stream to the 1-replica one.
- **merged accounting**: ``stats`` recursively sums the replicas' stats
  trees (so ``measured_rate`` and the warmup/measured split keep
  working), ``drain_*`` merge the per-replica result dicts, and
  :class:`~repro.serve.runtime.GroupRecord`\\ s come back stamped with the
  serving ``replica`` index — the front-door report's per-replica
  utilization breakdown reads it straight off the records.

Placement is the caller's job (``configs.base`` builds per-device
replicas by ``jax.device_put``-ing consts/params onto ``jax.devices()[i %
ndev]``; jit executions follow their committed constants).  The pool
itself is device-agnostic: N replicas on one device still shard load
across N independent in-flight windows, which is exactly what the
determinism tests exploit to run on a single-device host.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Mapping, Sequence

from repro.serve import runtime as rt
from repro.serve.runtime import EngineProtocol, GroupRecord


def _merge_stats(trees: Sequence[Any]):
    """Recursively sum the replicas' stats trees.

    Numbers sum; dicts merge by key (missing keys default to the other
    side); equal-length numeric lists sum elementwise (e.g. the LM
    engine's per-slot ``slots_served``).  Anything non-numeric keeps the
    first replica's value — stats trees hold counters, so that only
    covers identity-like fields.
    """
    trees = [t for t in trees if t is not None]
    if not trees:
        return None
    head = trees[0]
    if isinstance(head, Mapping):
        keys = []
        for t in trees:
            keys += [k for k in t if k not in keys]
        return {k: _merge_stats([t[k] for t in trees if k in t])
                for k in keys}
    if isinstance(head, bool):
        return head
    if isinstance(head, (int, float)):
        return sum(trees)
    if isinstance(head, list) and head and \
            all(isinstance(x, (int, float)) for x in head) and \
            all(len(t) == len(head) for t in trees):
        return [sum(col) for col in zip(*trees)]
    return head


class ReplicaPool:
    """N protocol engines served as one (see module docstring).

    ``replicas`` must be non-empty and homogeneous (same engine class,
    same serving config) — the pool checks only the protocol surface, but
    heterogeneous replicas would break the answer-invariance contract.
    ``clock`` fans out: the front-door saves/sets/restores ``eng.clock``
    around ``serve``, and every replica must stamp records on that same
    clock for queue/service latencies to share an origin.
    """

    def __init__(self, replicas: Sequence[EngineProtocol]):
        replicas = list(replicas)
        if not replicas:
            raise ValueError("ReplicaPool needs at least one replica")
        caps = {r.admission_cap for r in replicas}
        if len(caps) != 1:
            raise ValueError(f"replicas disagree on admission_cap: "
                             f"{sorted(caps)} — the pool routes any group "
                             "to any replica, so caps must match")
        self.replicas = replicas
        self.runs: list = []          # protocol surface; per-replica runs
        # pool-level routing counters, per replica: admission groups and
        # requests dispatched (deploy's report reads these; the per-group
        # truth is GroupRecord.replica on every record)
        self.dispatched_groups = [0] * len(replicas)
        self.dispatched_requests = [0] * len(replicas)

    def __len__(self) -> int:
        return len(self.replicas)

    # -- protocol surface ---------------------------------------------------

    @property
    def clock(self) -> Callable[[], float]:
        return self.replicas[0].clock

    @clock.setter
    def clock(self, clock: Callable[[], float]):
        for r in self.replicas:
            r.clock = clock

    @property
    def wall(self) -> Callable[[], float]:
        """The replicas' real wall-clock (throughput accounting source) —
        engines predating the ``wall`` parameter fall back to
        ``time.perf_counter``."""
        return getattr(self.replicas[0], "wall", time.perf_counter)

    @property
    def admission_cap(self) -> int:
        """Largest group ``submit`` accepts — every replica's cap."""
        return self.replicas[0].admission_cap

    @property
    def inflight(self) -> int:
        """Dispatched-but-undrained groups across every replica."""
        return sum(r.inflight for r in self.replicas)

    @property
    def stats(self) -> dict:
        """The replicas' stats trees, recursively summed."""
        return _merge_stats([r.stats for r in self.replicas])

    @property
    def accepting(self) -> bool:
        """True while at least one replica would take a group without
        blocking — least-inflight routing sends work to that replica."""
        return any(getattr(r, "accepting", True) for r in self.replicas)

    def submit(self, group, **kw) -> GroupRecord:
        """Dispatch one admission group to the least-loaded replica.

        Least-inflight, ties to the lowest index: a burst of back-to-back
        groups round-robins across idle replicas, a slow replica stops
        receiving work until it drains.  The returned record carries the
        chosen ``replica`` index.
        """
        i = min(range(len(self.replicas)),
                key=lambda j: (self.replicas[j].inflight, j))
        rec = self.replicas[i].submit(group, **kw)
        rec.replica = i
        self.dispatched_groups[i] += 1
        self.dispatched_requests[i] += rec.size
        return rec

    def drain_ready(self) -> dict[int, Any]:
        """Non-blocking drain over every replica (merged ``{uid: result}``).

        Every replica gets its ``drain_ready`` call even when an earlier
        one returns results — host-pumped engines (the LM slot pool)
        advance one decode block per call, and starving later replicas of
        pump calls would stall their resident requests.
        """
        out: dict[int, Any] = {}
        for r in self.replicas:
            out.update(r.drain_ready())
        return out

    def drain_all(self) -> dict[int, Any]:
        """Run every replica's in-flight window to completion (merged)."""
        out: dict[int, Any] = {}
        for r in self.replicas:
            out.update(r.drain_all())
        return out

    def observation(self) -> dict[str, Any]:
        """Pool-merged view for the overload controller's tick (see
        :func:`repro.serve.runtime.engine_observation`): total in-flight
        depth, the per-replica split (a hot replica hides behind a pool
        average — the controller's backlog signal shouldn't), and the
        steady-state work rate off the merged stats tree."""
        return {"inflight": self.inflight,
                "inflight_per_replica": [r.inflight for r in self.replicas],
                "work_rate": rt.measured_rate(self.stats)}

    # -- offline + accounting helpers ---------------------------------------

    def run(self, requests, **kw) -> dict[int, Any]:
        """Offline loop over the protocol: admission groups of
        ``admission_cap``, least-inflight routed, then drain everything.

        Unlike the single engines' ``run`` this one accounts per group
        (the protocol path), so the pool needs no run-level stats of its
        own; a per-pool-run record still lands in ``self.runs``.
        """
        import itertools

        t0 = self.wall()
        it = iter(requests)
        n = 0
        while True:
            group = list(itertools.islice(it, self.admission_cap))
            if not group:
                break
            self.submit(group, **kw)
            n += len(group)
        results = self.drain_all()
        dt = self.wall() - t0
        self.runs.append({"requests": len(results), "wall_time_s": dt,
                          "replicas": len(self.replicas)})
        return results

    def measured_rate(self, field: str = "work") -> float:
        """Steady-state pool throughput (work units/s, warmup excluded)."""
        return rt.measured_rate(self.stats, field)

    def problems_per_s(self) -> float:
        """Alias matching ``ReasonEngine`` (work == problems for NSAI)."""
        return self.measured_rate()

    def per_replica(self) -> list[dict]:
        """Routing + utilization counters per replica.

        ``busy_s`` is the replica's own accounted busy time (warmup +
        measured wall); ``share`` its fraction of the pool's dispatched
        work units — together the per-replica utilization breakdown
        ``Deployment.report()`` and the front-door summary surface.
        """
        stats = [r.stats for r in self.replicas]
        total_work = sum(s["measured"]["work"] + s["warmup"]["work"]
                         for s in stats)
        out = []
        for i, (r, s) in enumerate(zip(self.replicas, stats)):
            work = s["measured"]["work"] + s["warmup"]["work"]
            out.append({
                "replica": i,
                "groups": self.dispatched_groups[i],
                "requests": self.dispatched_requests[i],
                "work": work,
                "busy_s": s["measured"]["wall_time_s"]
                + s["warmup"]["wall_time_s"],
                "share": work / total_work if total_work else 0.0,
                "inflight": r.inflight,
            })
        return out

    def reset_stats(self):
        for r in self.replicas:
            r.reset_stats()
        self.runs = []
        self.dispatched_groups = [0] * len(self.replicas)
        self.dispatched_requests = [0] * len(self.replicas)
