"""Workload-generic NSAI serving: N-stage pipelines with host/device overlap.

NSFlow's workload characterization (paper Sec III) is that NSAI inference
is a *heterogeneous pipeline* of nn / vsa / simd streams.  ``ReasonEngine``
is the generic executor for that shape of traffic: it runs any
:class:`~repro.serve.schedule.StagedSchedule` — an ordered list of jitted
stage callables compiled from the workload's dataflow graph by
``serve.schedule.compile_schedule`` — and contains **no workload-specific
stage logic**.  NVSA, PrAE, MIMONet and LVRF all serve through schedules
contributed by the registry in ``configs.base.REASON_WORKLOADS``; adding a
workload means declaring stages + a graph builder there, not forking the
engine.

Requests are admitted in fixed-size batches and flow through the compiled
N-stage software pipeline, double-buffered (two batches resident) so batch
*i*'s device stages overlap batch *i+1*'s host work:

    device:  S₁⁰..S₁ᴺ S₂⁰..S₂ᴺ S₃⁰.. ...       (async queue, never idle)
    host:     stage₂     stage₃     ...         (a full batch ahead)
              collect₀   collect₁  ...

Every host-side step — ingesting the next batch from the request stream
(which may be a lazy generator: rendering/preprocessing then runs inside
the pipeline), staging device arrays, and converting finished answers back
to numpy — runs while the device works through the previous batch, so none
of it sits on the critical path.  On a dataflow array the device stages of
consecutive batches would co-execute on disjoint units (the analytical
model in ``core.dataflow.interloop_overlap``); on one shared host device
co-scheduling them just makes both contend for the same cores, so the
engine drains batch i-1 right before dispatching batch i's first stage
(the schedule's ``drain_stage``) and takes the overlap on the host/device
axis instead.  The ``sequential`` schedule is the naive serve loop
(synchronize after every stage, finish a batch completely before touching
the next) that ``bench_nsai.py`` compares against — the serving analogue
of the paper's Fig. 9 folded-vs-unfolded comparison; it is also where the
per-stage timing breakdown is measured (timing a stage requires blocking
on it).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.schedule import StagedSchedule


@dataclasses.dataclass
class ReasonConfig:
    batch_size: int = 4           # problems per pipeline batch (fixed shape)
    schedule: str = "overlap"     # overlap | sequential
    # Which compiled variant of the workload to run (e.g. "cnn" = neural
    # perception, "oracle" = ground-truth PMFs / symbolic-stream-only).
    # None = the first variant the engine was constructed with.
    variant: str | None = None


@dataclasses.dataclass
class ReasonRequest:
    uid: int
    # RAVEN reasoning traffic (nvsa / prae / lvrf)
    context: np.ndarray | None = None          # (8, H, W, 1) float32
    candidates: np.ndarray | None = None       # (8, H, W, 1) float32
    context_attrs: np.ndarray | None = None    # (8, A) int32 — oracle variant
    candidate_attrs: np.ndarray | None = None  # (8, A) int32
    # superposed-classification traffic (mimonet)
    images: np.ndarray | None = None           # (K, H, W, 1) float32


@dataclasses.dataclass
class ReasonResult:
    uid: int
    # argmax over candidates (int) or per-channel argmax (np.ndarray)
    answer: int | np.ndarray
    answer_logprobs: np.ndarray
    batch: int                    # pipeline batch that served the request
    # workload extras (e.g. per-attribute rule posteriors); None if N/A
    rule_posteriors: np.ndarray | None = None


class ReasonEngine:
    """Generic N-stage double-buffered executor over StagedSchedules.

    ``schedules`` maps variant name -> compiled :class:`StagedSchedule`
    (a single schedule is accepted too).  Stage jit caches live on the
    schedules, so sharing schedules across engines shares compilations.
    ``run(consts, requests)`` feeds every request batch through the
    schedule's stages; ``consts`` is the workload's constant pytree
    (params / codebooks / binding keys) handed to every stage.
    """

    def __init__(self, schedules: StagedSchedule | Mapping[str, StagedSchedule],
                 cfg: ReasonConfig):
        if isinstance(schedules, StagedSchedule):
            schedules = {schedules.variant: schedules}
        if not schedules:
            raise ValueError("engine needs at least one compiled schedule")
        if cfg.schedule not in ("overlap", "sequential"):
            raise ValueError(f"unknown schedule {cfg.schedule!r}")
        if cfg.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.schedules = dict(schedules)
        self.default_variant = cfg.variant or next(iter(self.schedules))
        if self.default_variant not in self.schedules:
            raise ValueError(f"unknown variant {self.default_variant!r}; "
                             f"compiled: {sorted(self.schedules)}")
        self.cfg = cfg
        self.stats = {"requests": 0, "batches": 0, "wall_time_s": 0.0,
                      "stage_time_s": {}}

    # -- host-side staging --------------------------------------------------

    def _ingest(self, req: ReasonRequest, sched: StagedSchedule):
        try:
            return sched.ingest(req)
        except (ValueError, AttributeError, TypeError) as e:
            raise ValueError(
                f"request {req.uid}: cannot ingest for workload "
                f"{sched.workload!r} variant {sched.variant!r}: {e}") from e

    def _stage(self, batch: list[ReasonRequest], sched: StagedSchedule):
        """Stack one admission group and pad to the compiled batch shape.

        Padding replicates the last request so every batch hits the same
        jit cache entry; padded rows are computed and dropped at collect.
        """
        trees = [self._ingest(r, sched) for r in batch]
        pad = self.cfg.batch_size - len(batch)

        def stack(*leaves):
            x = np.stack(leaves)
            if pad:
                x = np.concatenate([x, np.repeat(x[-1:], pad, axis=0)])
            return jnp.asarray(x)

        return jax.tree.map(stack, *trees)

    def _collect(self, results: dict, batch: list[ReasonRequest], out,
                 batch_idx: int, sched: StagedSchedule):
        """Materialize one batch's answers on the host (blocks if pending)."""
        host = jax.tree.map(np.asarray, out)
        for i, req in enumerate(batch):  # padded rows have no request
            fields = sched.collect(host, i)
            results[req.uid] = ReasonResult(uid=req.uid, batch=batch_idx,
                                            **fields)
        self.stats["requests"] += len(batch)

    def _batches(self, requests: Iterable[ReasonRequest]):
        """Pull admission groups lazily — a generator's per-request work
        (rendering, preprocessing) runs inside the pipeline."""
        it = iter(requests)
        seen: set = set()
        while True:
            batch = list(itertools.islice(it, self.cfg.batch_size))
            if not batch:
                return
            for req in batch:
                if req.uid in seen:
                    raise ValueError(f"duplicate request uid {req.uid} "
                                     "(results are keyed by uid)")
                seen.add(req.uid)
            yield batch

    # -- the two schedules --------------------------------------------------

    def run(self, consts, requests: Iterable[ReasonRequest],
            schedule: str | None = None, variant: str | None = None
            ) -> dict[int, "ReasonResult"]:
        """Serve all requests; returns {uid: ReasonResult}.

        ``overlap``: double-buffered — ingest/stage batch i while the
        device runs batch i-1, drain i-1's answers, then dispatch batch i's
        stages asynchronously; host work never blocks the device.
        ``sequential``: synchronize after each stage, one batch at a time,
        accumulating the per-stage timing breakdown.
        ``schedule`` / ``variant`` override the config per call (stage jit
        caches live on the StagedSchedule, so benchmarks can compare
        schedules on one engine instance).
        """
        schedule = schedule or self.cfg.schedule
        variant = variant or self.default_variant
        if schedule not in ("overlap", "sequential"):
            raise ValueError(f"unknown schedule {schedule!r}")
        if variant not in self.schedules:
            raise ValueError(f"unknown variant {variant!r}; "
                             f"compiled: {sorted(self.schedules)}")
        sched = self.schedules[variant]
        sequential = schedule == "sequential"
        stage_time = self.stats["stage_time_s"]
        t_start = time.perf_counter()
        results: dict[int, ReasonResult] = {}
        inflight = None  # (batch, output futures, batch index)
        for bi, batch in enumerate(self._batches(requests)):
            # staging batch i (incl. any lazy per-request preprocessing in
            # the `requests` iterable) overlaps batch i-1 on the device
            bufs = self._stage(batch, sched)
            for si, fn in enumerate(sched.jit_stages):
                if not sequential and inflight is not None \
                        and si == sched.drain_stage:
                    # drain batch i-1 before dispatching batch i:
                    # co-scheduling two batches on one shared host device
                    # only adds contention (see module docstring)
                    self._collect(results, *inflight, sched)
                    inflight = None
                t0 = time.perf_counter()
                bufs = fn(consts, bufs)
                if sequential:
                    jax.block_until_ready(bufs)
                    name = sched.stages[si].name
                    stage_time[name] = stage_time.get(name, 0.0) \
                        + time.perf_counter() - t0
            self.stats["batches"] += 1
            if sequential:
                self._collect(results, batch, bufs, bi, sched)
            else:
                inflight = (batch, bufs, bi)
        if inflight is not None:
            self._collect(results, *inflight, sched)
        self.stats["wall_time_s"] += time.perf_counter() - t_start
        return results

    def problems_per_s(self) -> float:
        if not self.stats["wall_time_s"]:
            return 0.0
        return self.stats["requests"] / self.stats["wall_time_s"]

    def reset_stats(self):
        self.stats.update(requests=0, batches=0, wall_time_s=0.0,
                          stage_time_s={})


def requests_from_batch(batch: dict, start_uid: int = 0
                        ) -> list[ReasonRequest]:
    """Adapt one ``data.raven.generate_batch`` dict into requests."""
    n = len(batch["answer"])
    return [ReasonRequest(
        uid=start_uid + i,
        context=batch["context"][i], candidates=batch["candidates"][i],
        context_attrs=batch["context_attrs"][i],
        candidate_attrs=batch["candidate_attrs"][i]) for i in range(n)]
