"""Two-stream NSAI serving: batched RAVEN reasoning with pipeline overlap.

NSFlow's workload characterization (paper Sec III) is that NSAI inference is
a *heterogeneous pipeline*: a compute-bound neural frontend (ResNet
perception -> attribute PMFs) feeding a memory-bound symbolic stream (FPE
encode -> VSA rule abduction -> rule execution through the circular
convolution kernel). ``core/dataflow.py`` models the steady-state inter-loop
overlap of the two streams analytically (Sec V-B step ③); ``ReasonEngine``
implements the same schedule for real traffic.

Requests are admitted in fixed-size batches and flow through a two-stage
software pipeline, double-buffered (two batches resident) so batch *i*'s
symbolic stage overlaps batch *i+1*'s neural-stream front end:

    device:  N₁ S₁ N₂ S₂ N₃ S₃ ...            (async queue, never idle)
    host:     stage₂   stage₃   stage₄ ...    (a full batch ahead)
              collect₀  collect₁ ...

Every host-side step — ingesting the next batch from the request stream
(which may be a lazy generator: rendering/preprocessing then runs inside
the pipeline), staging device arrays, and converting finished answers back
to numpy — runs while the device works through the previous batch, so none
of it sits on the critical path. On a dataflow array the two device stages
of consecutive batches would co-execute on disjoint units (the analytical
model in ``core.dataflow.interloop_overlap``); on one shared host device
co-scheduling them just makes both contend for the same cores, so the
engine drains batch i-1 right before dispatching batch i's neural stage
and takes the overlap on the host/device axis instead. The ``sequential``
schedule is the naive serve loop (synchronize after every stage, finish a
batch completely before touching the next) that ``bench_nsai.py`` compares
against — the serving analogue of the paper's Fig. 9 folded-vs-unfolded
comparison.

Model plumbing comes from ``configs.base.reason_fns`` (nvsa / prae).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ReasonConfig:
    batch_size: int = 4           # problems per pipeline batch (fixed shape)
    schedule: str = "overlap"     # overlap | sequential
    # cnn = the neural stream; oracle = ground-truth one-hot PMFs
    # (perception bypass: symbolic-stream-only serving). Caveat for cnn:
    # the frontend uses batch-statistics BatchNorm (the seed design — no
    # trainer maintains EMA stats), so a request's answer distribution
    # depends on its admission group: it matches offline ``nvsa.solve``
    # exactly when the group equals the offline batch, and is submission-
    # order invariant only modulo BN batch statistics. The oracle path has
    # no cross-request coupling and is exactly order invariant. Serving
    # with eval-mode BN needs EMA stats in the trainer first (ROADMAP).
    perception: str = "cnn"


@dataclasses.dataclass
class ReasonRequest:
    uid: int
    context: np.ndarray | None = None          # (8, H, W, 1) float32
    candidates: np.ndarray | None = None       # (8, H, W, 1) float32
    context_attrs: np.ndarray | None = None    # (8, A) int32 — oracle mode
    candidate_attrs: np.ndarray | None = None  # (8, A) int32


@dataclasses.dataclass
class ReasonResult:
    uid: int
    answer: int                   # argmax over the 8 candidate panels
    answer_logprobs: np.ndarray   # (8,)
    rule_posteriors: np.ndarray   # (A, R) per-attribute rule posterior
    batch: int                    # pipeline batch that served the request


class ReasonEngine:
    """Batched two-stream reasoning over (neural, symbolic) stage fns.

    ``neural_fn(params, ctx, cand)`` and ``symbolic_fn(codebooks, ctx_pmfs,
    cand_pmfs)`` come from ``configs.base.reason_fns``; both are jitted here
    (jit caches are per-instance — reuse engines). ``oracle_fn`` replaces
    the neural stage when ``cfg.perception == "oracle"``: ground-truth
    one-hot PMFs, i.e. symbolic-stream-only serving.
    """

    def __init__(self, neural_fn: Callable, symbolic_fn: Callable,
                 cfg: ReasonConfig, oracle_fn: Callable | None = None):
        if cfg.schedule not in ("overlap", "sequential"):
            raise ValueError(f"unknown schedule {cfg.schedule!r}")
        if cfg.perception not in ("cnn", "oracle"):
            raise ValueError(f"unknown perception {cfg.perception!r}")
        if cfg.perception == "oracle" and oracle_fn is None:
            raise ValueError("perception='oracle' needs an oracle_fn")
        if cfg.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.cfg = cfg
        self.jit_neural = jax.jit(neural_fn)
        self.jit_symbolic = jax.jit(symbolic_fn)
        self.jit_oracle = jax.jit(oracle_fn) if oracle_fn is not None else None
        self.stats = {
            "requests": 0, "batches": 0, "wall_time_s": 0.0,
            "neural_time_s": 0.0, "symbolic_time_s": 0.0,
        }

    # -- host-side staging --------------------------------------------------

    def _validate(self, req: ReasonRequest, seen: set, perception: str):
        if req.uid in seen:
            raise ValueError(f"duplicate request uid {req.uid} "
                             "(results are keyed by uid)")
        seen.add(req.uid)
        if perception == "oracle":
            if req.context_attrs is None or req.candidate_attrs is None:
                raise ValueError(f"request {req.uid}: oracle perception "
                                 "needs context_attrs/candidate_attrs")
        elif req.context is None or req.candidates is None:
            raise ValueError(f"request {req.uid}: cnn perception needs "
                             "context/candidates images")

    def _stage(self, batch: list[ReasonRequest], perception: str):
        """Stack one admission group and pad to the compiled batch shape.

        Padding replicates the last request so every batch hits the same
        jit cache entry; padded rows are computed and dropped at collect.
        """
        if perception == "oracle":
            ctx = np.stack([r.context_attrs for r in batch]).astype(np.int32)
            cand = np.stack([r.candidate_attrs for r in batch]).astype(np.int32)
        else:
            ctx = np.stack([r.context for r in batch]).astype(np.float32)
            cand = np.stack([r.candidates for r in batch]).astype(np.float32)
        pad = self.cfg.batch_size - len(batch)
        if pad:
            ctx = np.concatenate([ctx, np.repeat(ctx[-1:], pad, axis=0)])
            cand = np.concatenate([cand, np.repeat(cand[-1:], pad, axis=0)])
        return jnp.asarray(ctx), jnp.asarray(cand)

    def _collect(self, results: dict, batch: list[ReasonRequest], out,
                 batch_idx: int):
        """Materialize one batch's answers on the host (blocks if pending)."""
        logp, posts = out
        logp = np.asarray(logp)     # (B, 8)
        posts = np.asarray(posts)   # (A, B, R)
        for i, req in enumerate(batch):  # padded rows have no request
            results[req.uid] = ReasonResult(
                uid=req.uid, answer=int(np.argmax(logp[i])),
                answer_logprobs=logp[i], rule_posteriors=posts[:, i],
                batch=batch_idx)
        self.stats["requests"] += len(batch)

    def _batches(self, requests: Iterable[ReasonRequest], perception: str):
        """Pull admission groups lazily — a generator's per-request work
        (rendering, preprocessing) runs inside the pipeline."""
        it = iter(requests)
        seen: set = set()
        while True:
            batch = list(itertools.islice(it, self.cfg.batch_size))
            if not batch:
                return
            for req in batch:
                self._validate(req, seen, perception)
            yield batch

    # -- the two schedules --------------------------------------------------

    def run(self, params, codebooks, requests: Iterable[ReasonRequest],
            schedule: str | None = None, perception: str | None = None
            ) -> dict[int, "ReasonResult"]:
        """Serve all requests; returns {uid: ReasonResult}.

        ``overlap``: double-buffered — ingest/stage batch i while the
        device runs batch i-1, drain i-1's answers, then dispatch batch i's
        two stages asynchronously; host work never blocks the device.
        ``sequential``: synchronize after each stage, one batch at a time.
        ``schedule`` / ``perception`` override the config per call (jit
        caches are shared, so benchmarks can compare schedules on one
        engine instance).
        """
        schedule = schedule or self.cfg.schedule
        perception = perception or self.cfg.perception
        if schedule not in ("overlap", "sequential"):
            raise ValueError(f"unknown schedule {schedule!r}")
        if perception not in ("cnn", "oracle"):
            raise ValueError(f"unknown perception {perception!r}")
        if perception == "oracle" and self.jit_oracle is None:
            raise ValueError("perception='oracle' needs an oracle_fn")
        perceive = self.jit_oracle if perception == "oracle" \
            else self.jit_neural
        sequential = schedule == "sequential"
        t_start = time.perf_counter()
        results: dict[int, ReasonResult] = {}
        inflight = None  # (batch, symbolic-output futures, batch index)
        for bi, batch in enumerate(self._batches(requests, perception)):
            # staging batch i (incl. any lazy per-request preprocessing in
            # the `requests` iterable) overlaps batch i-1 on the device
            ctx, cand = self._stage(batch, perception)
            if not sequential and inflight is not None:
                # drain batch i-1 before dispatching batch i: co-scheduling
                # two batches on one shared host device only adds
                # contention (see module docstring)
                self._collect(results, *inflight)
            t0 = time.perf_counter()
            pmfs = perceive(params, ctx, cand)
            if sequential:
                jax.block_until_ready(pmfs)
                self.stats["neural_time_s"] += time.perf_counter() - t0
            t0 = time.perf_counter()
            out = self.jit_symbolic(codebooks, *pmfs)
            self.stats["batches"] += 1
            if sequential:
                jax.block_until_ready(out)
                self.stats["symbolic_time_s"] += time.perf_counter() - t0
                self._collect(results, batch, out, bi)
            else:
                inflight = (batch, out, bi)
        if inflight is not None:
            self._collect(results, *inflight)
        self.stats["wall_time_s"] += time.perf_counter() - t_start
        return results

    def problems_per_s(self) -> float:
        if not self.stats["wall_time_s"]:
            return 0.0
        return self.stats["requests"] / self.stats["wall_time_s"]

    def reset_stats(self):
        self.stats.update(requests=0, batches=0, wall_time_s=0.0,
                          neural_time_s=0.0, symbolic_time_s=0.0)


def requests_from_batch(batch: dict, start_uid: int = 0
                        ) -> list[ReasonRequest]:
    """Adapt one ``data.raven.generate_batch`` dict into requests."""
    n = len(batch["answer"])
    return [ReasonRequest(
        uid=start_uid + i,
        context=batch["context"][i], candidates=batch["candidates"][i],
        context_attrs=batch["context_attrs"][i],
        candidate_attrs=batch["candidate_attrs"][i]) for i in range(n)]
