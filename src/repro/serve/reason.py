"""Workload-generic NSAI serving: N-stage pipelines with host/device overlap.

NSFlow's workload characterization (paper Sec III) is that NSAI inference
is a *heterogeneous pipeline* of nn / vsa / simd streams.  ``ReasonEngine``
is the generic executor for that shape of traffic: it runs any
:class:`~repro.serve.schedule.StagedSchedule` — an ordered list of jitted
stage callables compiled from the workload's dataflow graph by
``serve.schedule.compile_schedule`` — and contains **no workload-specific
stage logic**.  NVSA, PrAE, MIMONet and LVRF all serve through schedules
contributed by the registry in ``configs.base.REASON_WORKLOADS``; adding a
workload means declaring stages + a graph builder there, not forking the
engine.

Admission groups flow through the compiled N-stage software pipeline with
a configurable in-flight window (``ReasonConfig.max_inflight`` dispatched-
but-undrained groups resident at once; 1 = PR 2's double buffering), so
group *i*'s device stages overlap group *i+k*'s host work:

    device:  S₁⁰..S₁ᴺ S₂⁰..S₂ᴺ S₃⁰.. ...       (async queue, never idle)
    host:     stage₂     stage₃     ...         (a window ahead)
              collect₀   collect₁  ...

Every host-side step — ingesting the next group from the request stream
(which may be a lazy generator: rendering/preprocessing then runs inside
the pipeline), staging device arrays, and converting finished answers back
to numpy — runs while the device works through the in-flight window, so
none of it sits on the critical path.  Dispatch is genuinely async: a new
group's *entire* pipeline is enqueued on the device before the engine
blocks on anything, and only then is the window trimmed back to
``max_inflight`` by draining the oldest group (``jax.block_until_ready``
happens solely at drain).  The ``fused`` schedule goes one step further
and dispatches the whole pipeline as **one** jit call
(``StagedSchedule.jit_fused``) when the schedule's fused variant was
negotiated bit-identical to the staged one (``fused_ok``); otherwise it
falls back to the per-stage dispatches and counts the group under
``stats["fused_fallback_groups"]``.  The ``sequential`` schedule is the
naive serve loop (synchronize after every stage, finish a group completely
before touching the next) that ``bench_nsai.py`` compares against — the
serving analogue of the paper's Fig. 9 folded-vs-unfolded comparison; it
is also where the per-stage timing breakdown is measured (timing a stage
requires blocking on it).

The engine implements the unified :class:`~repro.serve.runtime.
EngineProtocol` natively — its workload constants (params / codebooks /
binding keys) are bound at construction, so callers schedule traffic, not
model state.  Two entry points:

- ``run(requests)`` — the offline loop: admit fixed-size groups from an
  iterable and serve them all (benchmarks, tests, batch jobs).  It is
  literally a loop over the group-level API below.
- ``submit(group)`` / ``drain_ready()`` / ``drain_all()`` — the
  group-level protocol the **online front-door** (``serve.frontdoor``)
  drives: it forms admission groups by its batch-full-or-deadline policy
  and dispatches each as it closes, with per-group dispatch/done
  timestamps returned as :class:`~repro.serve.runtime.GroupRecord`\\ s and
  finished answers collected from the drain calls (``{uid: result}``).

A partial group is padded to the smallest *covering bucket* of the
schedule's compiled batch sizes (``StagedSchedule.batch_buckets``), not to
the maximum — a 3-request group on a (1, 2, 4, 8)-bucket schedule runs at
batch 4, paying one row of padding instead of five.

Stats are split so jit warmup cannot pollute throughput numbers: a run
that compiles anything (first time a (variant, bucket) shape is executed)
is accounted under ``stats["warmup"]``, steady-state runs under
``stats["measured"]`` (which ``problems_per_s`` reports), and per-run
records (incl. a per-variant stage-time breakdown) append to
``engine.runs``.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import runtime as rt
from repro.serve.runtime import GroupRecord  # re-export (envelope lives there)
from repro.serve.schedule import StagedSchedule

SCHEDULES = ("overlap", "sequential", "fused")


@dataclasses.dataclass
class ReasonConfig:
    batch_size: int = 4           # max problems per admission group
    schedule: str = "overlap"     # overlap | sequential | fused
    # Which compiled variant of the workload to run (e.g. "cnn" = neural
    # perception, "oracle" = ground-truth PMFs / symbolic-stream-only).
    # None = the first variant the engine was constructed with.
    variant: str | None = None
    # Depth of the in-flight window: dispatched-but-undrained groups
    # resident at once before the executor blocks on the oldest.
    # 1 = double buffering (one group on the device while the host stages
    # the next).
    max_inflight: int = 1
    # Compiled batch-size buckets, ascending (None = (batch_size,)): a
    # partial admission group pads to the smallest covering bucket.  Used
    # at schedule-compile time by ``configs.base.reason_engine``.
    buckets: tuple[int, ...] | None = None


@dataclasses.dataclass
class ReasonRequest:
    uid: int
    # RAVEN reasoning traffic (nvsa / prae / lvrf)
    context: np.ndarray | None = None          # (8, H, W, 1) float32
    candidates: np.ndarray | None = None       # (8, H, W, 1) float32
    context_attrs: np.ndarray | None = None    # (8, A) int32 — oracle variant
    candidate_attrs: np.ndarray | None = None  # (8, A) int32
    # superposed-classification traffic (mimonet)
    images: np.ndarray | None = None           # (K, H, W, 1) float32
    # traffic class for overload control (see serve.slo.PRIORITIES);
    # the engine ignores it — the front-door sheds and orders by it
    priority: str = "standard"


@dataclasses.dataclass
class ReasonResult:
    uid: int
    # argmax over candidates (int) or per-channel argmax (np.ndarray)
    answer: int | np.ndarray
    answer_logprobs: np.ndarray
    batch: int                    # pipeline group index that served it
    # workload extras (e.g. per-attribute rule posteriors); None if N/A
    rule_posteriors: np.ndarray | None = None


# GroupRecord note: ``dispatch_t`` is stamped right before the group's
# pipeline is enqueued on the device, and the *whole* pipeline is enqueued
# before the engine blocks on anything — so arrival→dispatch is pure
# queueing (the front-door's admission wait) and dispatch→done is service,
# matching the documented semantics in ``serve.runtime``/``serve.frontdoor``.
# Window backpressure (draining the oldest group once ``max_inflight`` is
# exceeded) happens strictly *after* the new dispatch, while the new group
# is already computing, so it can never inflate the new group's service
# latency.  (Earlier revisions drained mid-pipeline at the schedule's
# ``drain_stage``, which charged the window wait to service whenever
# ``drain_stage > 0``; ``drain_stage`` no longer gates dispatch.)


def _fresh_stats() -> dict:
    return {
        "requests": 0, "batches": 0,
        # device dispatches (jit calls): K per staged group, 1 per fused
        "dispatches": 0,
        # groups served by the single fused jit vs groups that asked for
        # "fused" but fell back per-stage (schedule not negotiated exact)
        "fused_groups": 0, "fused_fallback_groups": 0,
        # cumulative sequential-schedule stage times, keyed per variant so
        # same-named stages of different variants (oracle vs cnn) never
        # merge: {variant: {stage_name: seconds}}
        "stage_time_s": {},
        # wall-time split: runs that compiled a new (variant, bucket)
        # shape land in "warmup", steady-state runs in "measured"
        # (``work`` == requests for reasoning traffic: one problem each)
        **rt.fresh_split_stats(),
    }


class ReasonEngine:
    """Generic N-stage pipelined executor over StagedSchedules.

    ``schedules`` maps variant name -> compiled :class:`StagedSchedule`
    (a single schedule is accepted too).  Stage jit caches live on the
    schedules, so sharing schedules across engines shares compilations.
    ``consts`` is the workload's constant pytree (params / codebooks /
    binding keys) handed to every stage — bound here so the engine
    implements the consts-free :class:`~repro.serve.runtime.
    EngineProtocol` (``configs.base.reason_engine`` binds it for you).
    ``run(requests)`` feeds every request batch through the schedule's
    stages.  ``clock`` is the timestamp source for
    :class:`~repro.serve.runtime.GroupRecord`\\ s (the front-door injects
    its own so queue/service latencies share one origin); ``wall`` is the
    real wall-clock the throughput accounting reads — separate so a
    virtual front-door clock never distorts measured rates, injectable so
    the accounting itself is testable.
    """

    def __init__(self, schedules: StagedSchedule | Mapping[str, StagedSchedule],
                 cfg: ReasonConfig, consts=None, clock=time.perf_counter,
                 wall=time.perf_counter):
        if isinstance(schedules, StagedSchedule):
            schedules = {schedules.variant: schedules}
        if not schedules:
            raise ValueError("engine needs at least one compiled schedule")
        if cfg.schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {cfg.schedule!r}")
        if cfg.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if cfg.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        for s in schedules.values():
            if s.batch_buckets and s.batch_buckets[-1] < cfg.batch_size:
                raise ValueError(
                    f"{s.workload}/{s.variant}: largest compiled bucket "
                    f"{s.batch_buckets[-1]} < batch_size {cfg.batch_size} — "
                    "admission groups would not fit any bucket")
        self.schedules = dict(schedules)
        self.default_variant = cfg.variant or next(iter(self.schedules))
        if self.default_variant not in self.schedules:
            raise ValueError(f"unknown variant {self.default_variant!r}; "
                             f"compiled: {sorted(self.schedules)}")
        self.cfg = cfg
        self.consts = consts
        self.clock = clock
        self.wall = wall
        self.stats = _fresh_stats()
        self.runs: list[dict] = []    # per-run records from run()
        self._inflight: collections.deque = collections.deque()
        self._ready: dict[int, ReasonResult] = {}  # collected, undrained
        self._next_index = 0
        # (variant, bucket, mode) shapes already compiled (mode: the fused
        # jit and the staged jits have separate caches)
        self._warmed: set[tuple[str, int, str]] = set()
        self._cold_run = False
        self._run_stage_time: dict[str, float] = {}
        self._in_run = False          # run() accounts at run level instead
        self._last_acct = float("-inf")  # busy-window edge for group stats

    @property
    def admission_cap(self) -> int:
        """Largest admission group ``submit`` accepts (protocol surface)."""
        return self.cfg.batch_size

    # -- host-side staging --------------------------------------------------

    def _resolve(self, schedule: str | None, variant: str | None):
        schedule = schedule or self.cfg.schedule
        variant = variant or self.default_variant
        if schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {schedule!r}")
        if variant not in self.schedules:
            raise ValueError(f"unknown variant {variant!r}; "
                             f"compiled: {sorted(self.schedules)}")
        return schedule, variant, self.schedules[variant]

    def _ingest(self, req: ReasonRequest, sched: StagedSchedule):
        try:
            return sched.ingest(req)
        except (ValueError, AttributeError, TypeError) as e:
            raise ValueError(
                f"request {req.uid}: cannot ingest for workload "
                f"{sched.workload!r} variant {sched.variant!r}: {e}") from e

    def _stage(self, batch: list[ReasonRequest], sched: StagedSchedule):
        """Stack one admission group and pad to its covering bucket.

        Padding replicates the last request so a group of any size hits a
        compiled jit cache entry; padded rows are computed and dropped at
        collect.  Bucketed schedules pad to the smallest compiled batch
        size that fits; bucket-less schedules keep the single
        ``batch_size`` shape.  Returns ``(device_bufs, bucket)``.
        """
        trees = [self._ingest(r, sched) for r in batch]
        bucket = sched.covering_bucket(len(batch)) if sched.batch_buckets \
            else self.cfg.batch_size
        pad = bucket - len(batch)

        def stack(*leaves):
            x = np.stack(leaves)
            if pad:
                x = np.concatenate([x, np.repeat(x[-1:], pad, axis=0)])
            return jnp.asarray(x)

        return jax.tree.map(stack, *trees), bucket

    def _collect(self, batch: list[ReasonRequest], out,
                 rec: GroupRecord, sched: StagedSchedule,
                 cold: bool = False, t0: float | None = None):
        """Materialize one group's answers on the host (blocks if pending).

        Finished results land in the engine's ready buffer until a drain
        call hands them out.  Outside ``run()`` (the protocol path the
        front-door drives) the group is accounted into the warmup/measured
        split here, keyed off its own cold flag: wall time is the union of
        per-group busy windows ([dispatch, collect] on the real clock,
        clipped so overlapping windows are not double-counted), so
        ``problems_per_s()`` reports a real measured rate for engines that
        never see ``run()``."""
        host = jax.tree.map(np.asarray, out)
        for i, req in enumerate(batch):  # padded rows have no request
            fields = sched.collect(host, i)
            self._ready[req.uid] = ReasonResult(uid=req.uid, batch=rec.index,
                                                **fields)
        rec.done_t = self.clock()
        self.stats["requests"] += len(batch)
        if not self._in_run and t0 is not None:
            now = self.wall()
            kind = "warmup" if cold else "measured"
            self.stats[kind]["requests"] += len(batch)
            self.stats[kind]["work"] += len(batch)
            self.stats[kind]["wall_time_s"] += max(
                0.0, now - max(t0, self._last_acct))
            self._last_acct = now

    def _batches(self, requests: Iterable[ReasonRequest]):
        """Pull admission groups lazily — a generator's per-request work
        (rendering, preprocessing) runs inside the pipeline."""
        it = iter(requests)
        seen: set = set()
        while True:
            batch = list(itertools.islice(it, self.cfg.batch_size))
            if not batch:
                return
            for req in batch:
                if req.uid in seen:
                    raise ValueError(f"duplicate request uid {req.uid} "
                                     "(results are keyed by uid)")
                seen.add(req.uid)
            yield batch

    # -- group-level API (the front-door drives these) ----------------------

    def submit(self, group: list[ReasonRequest],
               schedule: str | None = None, variant: str | None = None
               ) -> GroupRecord:
        """Dispatch one admission group through the compiled pipeline.

        Under ``overlap`` the stages are enqueued asynchronously and the
        returned :class:`GroupRecord` has ``done_t=None``; the new group's
        whole pipeline is dispatched *before* the engine blocks on
        anything, and only then is the in-flight window trimmed back to
        ``cfg.max_inflight`` by draining the oldest group — its record
        (already returned by the earlier ``submit``) gets ``done_t``
        stamped in place, and its answers wait in the ready buffer for the
        next ``drain_*`` call.  ``fused`` behaves like ``overlap`` but
        dispatches the composed pipeline as one jit call when the schedule
        negotiated its fused variant substitutable (``fused_ok``), falling
        back to per-stage dispatch otherwise.  Under ``sequential`` the
        group is served synchronously (accumulating the per-stage timing
        breakdown) and returned complete.
        """
        consts = self.consts
        if consts is None:
            raise ValueError(
                "engine has no consts bound — pass consts= to ReasonEngine "
                "(configs.base.reason_engine binds them for you)")
        schedule, variant, sched = self._resolve(schedule, variant)
        sequential = schedule == "sequential"
        if not group:
            raise ValueError("empty admission group")
        if len(group) > self.cfg.batch_size:
            raise ValueError(f"admission group of {len(group)} exceeds "
                             f"batch_size {self.cfg.batch_size}")
        pending = {u for g, *_ in self._inflight for u in (r.uid for r in g)}
        seen: set = set()
        for req in group:
            if req.uid in self._ready or req.uid in pending \
                    or req.uid in seen:
                raise ValueError(f"duplicate request uid {req.uid} "
                                 "(results are keyed by uid)")
            seen.add(req.uid)
        bufs, bucket = self._stage(group, sched)
        use_fused = False
        if schedule == "fused":
            if sched.fused_ok:
                use_fused = True
            else:
                # fused variant exists but was negotiated only
                # epsilon-equivalent (or was not compiled): serve the group
                # stage-by-stage so answers stay bit-identical
                self.stats["fused_fallback_groups"] += 1
        mode = "fused" if use_fused else "staged"
        cold = (variant, bucket, mode) not in self._warmed
        if cold:
            self._warmed.add((variant, bucket, mode))
            self._cold_run = True
        rec = GroupRecord(uids=tuple(r.uid for r in group),
                          index=self._next_index, variant=variant,
                          bucket=bucket, size=len(group))
        self._next_index += 1
        stage_time = self.stats["stage_time_s"].setdefault(variant, {})
        t0 = self.wall()
        # dispatch the whole pipeline asynchronously FIRST; any blocking
        # (sequential timing, window trimming) happens after, so group i+1
        # is always on the device before the engine waits on group i
        rec.dispatch_t = self.clock()
        if use_fused:
            bufs = sched.jit_fused(consts, bufs)
            self.stats["dispatches"] += 1
            self.stats["fused_groups"] += 1
        else:
            for si, fn in enumerate(sched.jit_stages):
                ts = self.wall()
                bufs = fn(consts, bufs)
                self.stats["dispatches"] += 1
                if sequential:
                    jax.block_until_ready(bufs)
                    name = sched.stages[si].name
                    dt = self.wall() - ts
                    stage_time[name] = stage_time.get(name, 0.0) + dt
                    self._run_stage_time[name] = \
                        self._run_stage_time.get(name, 0.0) + dt
        self.stats["batches"] += 1
        if sequential:
            self._collect(group, bufs, rec, sched, cold=cold, t0=t0)
        else:
            self._inflight.append((group, bufs, rec, sched, cold, t0))
            # window backpressure: trim back down to max_inflight by
            # draining the oldest group(s) — strictly after the new
            # dispatch, so this wait is never the new group's service time
            while len(self._inflight) > self.cfg.max_inflight:
                self._drain_one()
        return rec

    def _drain_one(self) -> GroupRecord | None:
        if not self._inflight:
            return None
        group, bufs, rec, sched, cold, t0 = self._inflight.popleft()
        self._collect(group, bufs, rec, sched, cold=cold, t0=t0)
        return rec

    def _take_ready(self) -> dict[int, "ReasonResult"]:
        out, self._ready = self._ready, {}
        return out

    def drain_all(self) -> dict[int, "ReasonResult"]:
        """Drain every in-flight group, oldest first (blocking), and
        return all finished results ``{uid: ReasonResult}``."""
        while self._inflight:
            self._drain_one()
        return self._take_ready()

    @staticmethod
    def _leaf_ready(leaf) -> bool:
        """Conservative readiness probe for one buffer leaf.

        jax Arrays expose ``is_ready()``; host-side data (numpy / python
        scalars) is ready by definition.  Anything else — including
        donated-buffer surrogates a fused pipeline may leave behind —
        reports *not ready*, so ``drain_ready`` stays non-blocking instead
        of vacuously passing and then blocking inside ``_collect``."""
        probe = getattr(leaf, "is_ready", None)
        if probe is not None:
            return bool(probe())
        return isinstance(leaf, (np.ndarray, np.generic,
                                 int, float, bool, complex))

    def drain_ready(self) -> dict[int, "ReasonResult"]:
        """Collect in-flight groups whose device buffers have already
        materialized — non-blocking, oldest first (the front-door calls
        this while it would otherwise sleep waiting for traffic) — and
        return every finished result ``{uid: ReasonResult}``."""
        while self._inflight:
            _, bufs, _, _, _, _ = self._inflight[0]
            if not all(self._leaf_ready(l) for l in jax.tree.leaves(bufs)):
                break
            self._drain_one()
        return self._take_ready()

    @property
    def inflight(self) -> int:
        """Dispatched-but-undrained admission groups."""
        return len(self._inflight)

    @property
    def accepting(self) -> bool:
        """True while ``submit`` would dispatch without blocking on the
        depth-k in-flight window — the backpressure signal the
        front-door's overload path defers group closes on."""
        return len(self._inflight) < self.cfg.max_inflight

    # -- the offline loop ---------------------------------------------------

    def run(self, requests: Iterable[ReasonRequest],
            schedule: str | None = None, variant: str | None = None
            ) -> dict[int, "ReasonResult"]:
        """Serve all requests; returns {uid: ReasonResult}.

        The offline loop over the group-level protocol: ``overlap`` —
        pipelined: ingest/stage the next group while the device runs the
        in-flight window, drain the oldest group's answers, then dispatch
        the new group's stages asynchronously; host work never blocks the
        device.  ``sequential``: synchronize after each stage, one group
        at a time, accumulating the per-stage timing breakdown.
        ``schedule`` / ``variant`` override the config per call (stage jit
        caches live on the StagedSchedule, so benchmarks can compare
        schedules on one engine instance).

        Appends a per-run record to ``self.runs`` ({schedule, variant,
        requests, wall_time_s, warmup, stage_time_s, problems_per_s});
        runs that jit-compiled a new (variant, bucket) shape are flagged
        ``warmup`` and excluded from the cumulative measured stats that
        ``problems_per_s()`` reports.
        """
        schedule, variant, _ = self._resolve(schedule, variant)
        if self._inflight or self._ready:
            raise ValueError("engine has undrained in-flight groups "
                             "(call drain_all first)")
        self._cold_run = False
        self._run_stage_time = {}
        self._in_run = True   # account at run level, not per group
        t_start = self.wall()
        try:
            for batch in self._batches(requests):
                # staging the next group (incl. any lazy per-request
                # preprocessing in the `requests` iterable) overlaps the
                # in-flight window on the device
                self.submit(batch, schedule=schedule, variant=variant)
            results = self.drain_all()
        finally:
            self._in_run = False
        dt = self.wall() - t_start
        kind = "warmup" if self._cold_run else "measured"
        self.stats[kind]["requests"] += len(results)
        self.stats[kind]["work"] += len(results)
        self.stats[kind]["wall_time_s"] += dt
        self.runs.append({
            "schedule": schedule, "variant": variant,
            "requests": len(results), "wall_time_s": dt,
            "warmup": self._cold_run,
            "stage_time_s": dict(self._run_stage_time),
            "problems_per_s": len(results) / dt if dt else 0.0,
        })
        return results

    @property
    def last_run(self) -> dict | None:
        """Per-run stats record of the most recent ``run()``."""
        return self.runs[-1] if self.runs else None

    def problems_per_s(self) -> float:
        """Measured steady-state throughput — warmup runs (the ones that
        jit-compiled a new shape) are excluded; ``stats["warmup"]`` keeps
        their totals separately, and only-warmup stats fall back to the
        all-runs number (see :func:`repro.serve.runtime.measured_rate`;
        ``work`` == requests for reasoning traffic)."""
        return rt.measured_rate(self.stats)

    def reset_stats(self):
        """Zero the cumulative stats and per-run records (jit caches and
        the warmed-shape set survive — compilations are not forgotten)."""
        self.stats = _fresh_stats()
        self.runs = []


def requests_from_batch(batch: dict, start_uid: int = 0
                        ) -> list[ReasonRequest]:
    """Adapt one ``data.raven.generate_batch`` dict into requests."""
    n = len(batch["answer"])
    return [ReasonRequest(
        uid=start_uid + i,
        context=batch["context"][i], candidates=batch["candidates"][i],
        context_attrs=batch["context_attrs"][i],
        candidate_attrs=batch["candidate_attrs"][i]) for i in range(n)]
