"""Online admission for mixed serving traffic: the deadline-batched front-door.

NSFlow's pitch is *real-time* NSAI acceleration, but an engine that only
accepts pre-collected request lists (``ReasonEngine.run`` / ``Engine.run``)
makes a trickle of traffic pay full-batch latency and a burst pay padding
waste.  This module is the front-door that turns **arrival-timed** online
traffic into admission groups any :class:`~repro.serve.runtime.
EngineProtocol` engine can serve well:

- **batch-full-or-deadline admission**: a group closes the moment it
  reaches the admission cap (``full``) or ``deadline_s`` after its first
  request arrived (``deadline``) — bursts fill batches, trickles wait at
  most one deadline.  When the arrival stream ends, open groups close
  immediately (``flush``).
- **shape bucketing**: a closed partial group is padded by the NSAI
  engine to the smallest *covering bucket* of the schedule's compiled
  batch sizes (``StagedSchedule.batch_buckets``, e.g. 2/4/8) instead of
  the max — see ``pow2_buckets``.  The LM engine's bucket is its slot
  pool.
- **multiplexing over the protocol**: one front-door serves any mix of
  engines — NSAI staged pipelines (nvsa, mimonet, ...) *and* slot-pool LM
  engines (llama3.2-3b, stablelm-3b, ...) — because it only drives the
  unified ``submit`` / ``drain_ready`` / ``drain_all`` surface.  Each
  arrival names its model, groups are formed per model, and every engine
  keeps its own in-flight window on the shared host.
- **per-request latency accounting**: arrival -> dispatch (queueing) and
  dispatch -> answers-on-host (service) per request, with p50/p95/p99
  summaries (:meth:`FrontDoorReport.percentiles`) and per-class
  throughput in each class's own unit (tokens/s for LM rows, problems/s
  for NSAI rows — see :meth:`FrontDoorReport.work_per_s`).

The serve loop is single-threaded and event-driven: it admits due
arrivals, closes groups by the policy, dispatches them asynchronously
through ``submit`` (host staging overlaps device compute), and while
waiting for traffic calls ``drain_ready`` on every engine — which both
collects groups whose device buffers have already materialized (so
``done`` timestamps are not deferred to the next dispatch) *and* lets
engines that need host pumping (the LM slot pool) advance one decode
block per call.  ``clock``/``sleep`` are injectable — tests drive the
policy deterministically on a virtual clock; benchmarks use real time.

Traffic models: :func:`poisson_arrivals` (open-loop Poisson at a given
offered rate), :func:`trace_arrivals` (replay explicit timestamps), and
:func:`merge_arrivals` to interleave per-model streams into one time-
ordered front-door feed (stable on ties: equal timestamps keep each
stream's FIFO order, earlier-argument streams first).
:func:`with_priorities` stamps a priority-class mix onto a stream.

**Overload control** (optional): pass an :class:`~repro.serve.control.
OverloadController` and the front-door (a) keeps its pending queues in
bounded per-priority-class :class:`~repro.serve.control.ClassQueues` —
arrivals beyond the depth bound are *shed* (reject-with-backpressure,
lowest-priority-first) and surfaced in the report as first-class
:class:`~repro.serve.control.ShedRecord` outcomes, and (b) feeds every
completion back to the controller's windowed per-class p99 estimator
and lets it adapt the per-model deadline and bucket cap each control
tick.  Without a controller the behavior is the legacy unbounded FIFO.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Iterable, Iterator, Mapping, Sequence

import numpy as np

from repro.serve import runtime as rt
from repro.serve import slo as slo_mod
from repro.serve.control import (ClassQueues, OverloadController,
                                 ShedRecord)
from repro.serve.runtime import EngineProtocol, GroupRecord
from repro.serve.slo import DEFAULT_PRIORITY, SLOTarget


# ---------------------------------------------------------------------------
# traffic models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArrivalRequest:
    """One request with its offered arrival time (seconds, stream origin).

    ``request`` is any protocol request envelope (``serve.engine.Request``,
    ``serve.reason.ReasonRequest`` — anything the named model's engine
    accepts).  ``priority`` names the traffic class for overload control
    (one of :data:`~repro.serve.slo.PRIORITIES`); ``None`` defers to the
    request envelope's own ``priority`` attribute, defaulting to
    ``standard``."""

    t: float
    model: str
    request: Any
    priority: str | None = None


def poisson_arrivals(model: str, requests: Iterable[Any],
                     rate_rps: float, seed: int = 0, start_s: float = 0.0
                     ) -> Iterator[ArrivalRequest]:
    """Open-loop Poisson traffic: exponential inter-arrival gaps at
    ``rate_rps`` requests/s.  Lazy — each request is pulled (rendered)
    only when its arrival is generated, so preprocessing runs inside the
    serving loop like real ingest."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    rng = np.random.default_rng(seed)
    t = start_s
    for req in requests:
        t += float(rng.exponential(1.0 / rate_rps))
        yield ArrivalRequest(t=t, model=model, request=req)


def trace_arrivals(model: str, times_s: Sequence[float],
                   requests: Iterable[Any]
                   ) -> Iterator[ArrivalRequest]:
    """Replay an explicit arrival-time trace (must be nondecreasing).
    Times and requests must pair up exactly — a length mismatch in either
    direction raises instead of silently dropping traffic."""
    last = -float("inf")
    it = iter(requests)
    for t in times_s:
        if t < last:
            raise ValueError(f"trace times must be nondecreasing "
                             f"({t} after {last})")
        last = t
        try:
            req = next(it)
        except StopIteration:
            raise ValueError("trace has more times than requests") from None
        yield ArrivalRequest(t=float(t), model=model, request=req)
    if next(it, None) is not None:
        raise ValueError("trace has more requests than times "
                         "(the extras would silently never be served)")


def merge_arrivals(*streams: Iterable[ArrivalRequest]
                   ) -> Iterator[ArrivalRequest]:
    """Interleave time-ordered per-model streams into one ordered feed.

    ``heapq.merge`` is stable: arrivals with equal timestamps come out in
    argument order, and each stream's own FIFO order is always preserved —
    simultaneous cross-model arrivals therefore admit deterministically
    (regression-tested; the admission policy depends on it).
    """
    return heapq.merge(*streams, key=lambda a: a.t)


def with_priorities(stream: Iterable[ArrivalRequest],
                    mix: str | Mapping[str, float],
                    seed: int = 0) -> Iterator[ArrivalRequest]:
    """Stamp priority classes onto an arrival stream.

    ``mix`` is either one class name (every arrival gets it) or a
    ``{class: weight}`` mapping sampled per arrival with a seeded rng —
    deterministic, so traced replays shed identically.  Unknown class
    names raise the named :func:`~repro.serve.slo.validate_priority`
    error."""
    if isinstance(mix, str):
        prio = slo_mod.validate_priority(mix)
        for a in stream:
            yield dataclasses.replace(a, priority=prio)
        return
    classes = [slo_mod.validate_priority(c) for c in mix]
    w = np.asarray([float(mix[c]) for c in classes], dtype=float)
    if (w < 0).any() or not w.sum():
        raise ValueError(f"priority mix weights must be >= 0 and "
                         f"sum > 0: {dict(mix)}")
    rng = np.random.default_rng(seed)
    p = w / w.sum()
    for a in stream:
        yield dataclasses.replace(
            a, priority=classes[int(rng.choice(len(classes), p=p))])


def pow2_buckets(max_batch: int, min_bucket: int = 2) -> tuple[int, ...]:
    """Power-of-two batch buckets up to (and always including) max_batch:
    8 -> (2, 4, 8); 6 -> (2, 4, 6).

    ``min_bucket`` defaults to 2, not 1: XLA (CPU) lowers rank-degenerate
    batch-1 matmuls/convs through different accumulation paths, so a
    bucket of 1 is the one compiled shape whose answers can differ from
    the others in final ulps.  With buckets >= 2 a request's answer is
    bit-identical whichever bucket serves it (regression-tested); pass
    ``min_bucket=1`` to trade that for zero padding on singleton groups.
    """
    if max_batch < 1 or min_bucket < 1:
        raise ValueError("max_batch and min_bucket must be >= 1")
    out = []
    b = min_bucket
    while b < max_batch:
        out.append(b)
        b *= 2
    return tuple(out) + (max_batch,)


# ---------------------------------------------------------------------------
# latency accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestLatency:
    """Per-request timing through the front-door (seconds from serve start).

    ``queue_s`` = arrival -> first work dispatched (admission wait + any
    blocking on the in-flight window / slot pool); ``service_s`` =
    dispatch -> answers materialized on the host."""

    uid: int
    model: str
    arrival_s: float
    dispatch_s: float
    done_s: float
    bucket: int
    group_size: int
    close_reason: str             # full | deadline | flush
    priority: str = DEFAULT_PRIORITY

    @property
    def queue_s(self) -> float:
        return self.dispatch_s - self.arrival_s

    @property
    def service_s(self) -> float:
        return self.done_s - self.dispatch_s

    @property
    def total_s(self) -> float:
        return self.done_s - self.arrival_s


@dataclasses.dataclass
class ServedGroup:
    """One admission group as the front-door closed and served it."""

    model: str
    uids: tuple[int, ...]
    bucket: int
    size: int
    close_reason: str
    open_s: float                 # arrival of the group's first request
    close_s: float                # when the admission policy closed it
    dispatch_s: float
    done_s: float
    # which replica of a ReplicaPool served the group (None = unpooled
    # engine); read off the engine's GroupRecord stamp
    replica: int | None = None


@dataclasses.dataclass
class FrontDoorReport:
    """Results + latency accounting of one ``FrontDoor.serve`` call.

    ``results`` maps model -> uid -> the engine's own result type
    (``Result`` with generated ``tokens`` for LM engines, ``ReasonResult``
    with an ``answer`` for NSAI engines) — one report covers both request
    classes.

    Overload-control outcomes are first class: ``shed`` lists every
    rejected request (:class:`~repro.serve.control.ShedRecord` — never a
    silent drop, so ``offered == admitted + shed`` exactly), ``slo``
    holds the targets that were in force, ``decisions`` the controller's
    non-hold actions, and ``queue_depth_max`` the per-model pending
    high-water mark (the boundedness proof the soak gate reads)."""

    results: dict[str, dict[int, Any]]
    latencies: list[RequestLatency]
    groups: list[ServedGroup]
    wall_time_s: float
    shed: list[ShedRecord] = dataclasses.field(default_factory=list)
    slo: dict[str, SLOTarget] = dataclasses.field(default_factory=dict)
    decisions: list = dataclasses.field(default_factory=list)
    queue_depth_max: dict[str, int] = dataclasses.field(
        default_factory=dict)

    def offered(self, model: str | None = None) -> int:
        """Requests that reached the front-door: admitted + shed."""
        admitted = sum(1 for l in self.latencies
                       if model is None or l.model == model)
        return admitted + sum(1 for s in self.shed
                              if model is None or s.model == model)

    def shed_counts(self, model: str | None = None) -> dict[str, int]:
        """Shed requests per priority class."""
        out: dict[str, int] = {}
        for s in self.shed:
            if model is None or s.model == model:
                out[s.priority] = out.get(s.priority, 0) + 1
        return {p: out[p] for p in slo_mod.PRIORITIES if p in out}

    def shed_rate(self, model: str | None = None) -> float:
        offered = self.offered(model)
        n_shed = sum(1 for s in self.shed
                     if model is None or s.model == model)
        return n_shed / offered if offered else 0.0

    def slo_attainment(self, model: str | None = None) -> dict[str, dict]:
        """Exact per-class SLO attainment (see :func:`repro.serve.slo.
        attainment`) against the targets this serve ran under."""
        return slo_mod.attainment(self.latencies, self.slo, model)

    def percentiles(self, field: str = "total_s", model: str | None = None,
                    qs: tuple[int, ...] = (50, 95, 99)) -> dict[str, float]:
        """{p50: ..., p95: ...} over ``field`` (queue_s | service_s |
        total_s), optionally for one model."""
        vals = [getattr(l, field) for l in self.latencies
                if model is None or l.model == model]
        if not vals:
            return {f"p{q}": float("nan") for q in qs}
        return {f"p{q}": float(np.percentile(vals, q)) for q in qs}

    def throughput_rps(self, model: str | None = None) -> float:
        n = sum(1 for l in self.latencies
                if model is None or l.model == model)
        return n / self.wall_time_s if self.wall_time_s else 0.0

    def work_per_s(self, model: str | None = None) -> float:
        """Served throughput in the class's own work unit: generated
        tokens/s for LM models, problems/s for NSAI models (mixing models
        of different classes sums their units — pass ``model`` for a
        meaningful number)."""
        total = sum(rt.work_units(r)
                    for m, res in self.results.items()
                    if model is None or m == model
                    for r in res.values())
        return total / self.wall_time_s if self.wall_time_s else 0.0

    def work_unit(self, model: str) -> str:
        """'tok' (LM) or 'prob' (NSAI) for one model's served results."""
        return rt.work_unit_name(self.results.get(model, {}).values())

    def bucket_histogram(self, model: str | None = None) -> dict[int, int]:
        hist: dict[int, int] = {}
        for g in self.groups:
            if model is None or g.model == model:
                hist[g.bucket] = hist.get(g.bucket, 0) + 1
        return dict(sorted(hist.items()))

    def replica_breakdown(self, model: str | None = None
                          ) -> dict[int, dict] | None:
        """Per-replica utilization out of the merged report.

        ``{replica: {groups, requests, busy_s, share}}`` where ``busy_s``
        sums the replica's dispatch->done service intervals and ``share``
        is its fraction of served requests.  ``None`` when no group was
        served by a :class:`~repro.serve.replica.ReplicaPool` (unpooled
        engines leave ``ServedGroup.replica`` unset).
        """
        groups = [g for g in self.groups
                  if (model is None or g.model == model)
                  and g.replica is not None]
        if not groups:
            return None
        total = sum(g.size for g in groups)
        out: dict[int, dict] = {}
        for g in groups:
            row = out.setdefault(g.replica, {"groups": 0, "requests": 0,
                                             "busy_s": 0.0, "share": 0.0})
            row["groups"] += 1
            row["requests"] += g.size
            row["busy_s"] += g.done_s - g.dispatch_s
        for row in out.values():
            row["share"] = row["requests"] / total if total else 0.0
        return dict(sorted(out.items()))

    def summary(self) -> str:
        lines = []
        for model in sorted(self.results):
            n = len(self.results[model])
            if not n:
                continue
            q = self.percentiles("queue_s", model)
            s = self.percentiles("service_s", model)
            t = self.percentiles("total_s", model)
            hist = ",".join(f"{b}x{c}" for b, c in
                            self.bucket_histogram(model).items())
            lines.append(
                f"{model}: {n} served @ {self.throughput_rps(model):.1f}/s"
                f" ({self.work_per_s(model):.1f} {self.work_unit(model)}/s)"
                f" | queue p50/p95/p99 {q['p50'] * 1e3:.1f}/"
                f"{q['p95'] * 1e3:.1f}/{q['p99'] * 1e3:.1f}ms"
                f" | service p50/p95/p99 {s['p50'] * 1e3:.1f}/"
                f"{s['p95'] * 1e3:.1f}/{s['p99'] * 1e3:.1f}ms"
                f" | total p99 {t['p99'] * 1e3:.1f}ms | buckets {hist}")
            sheds = self.shed_counts(model)
            if sheds:
                parts = " ".join(f"{p}:{c}" for p, c in sheds.items())
                lines.append(
                    f"{model}: shed {sum(sheds.values())} "
                    f"({self.shed_rate(model):.1%} of "
                    f"{self.offered(model)} offered) [{parts}] "
                    f"queue<= {self.queue_depth_max.get(model, 0)}")
            if self.slo:
                att = self.slo_attainment(model)
                parts = " ".join(
                    f"{p}:{row['attainment']:.1%}"
                    f"{'' if row['target_ms'] is None else '@' + format(row['target_ms'], '.0f') + 'ms'}"
                    for p, row in att.items() if row["n"])
                if parts:
                    lines.append(f"{model}: slo attainment {parts}")
            replicas = self.replica_breakdown(model)
            if replicas:
                parts = " ".join(
                    f"r{i}:{row['groups']}g/{row['requests']}req/"
                    f"{row['share']:.0%}" for i, row in replicas.items())
                lines.append(f"{model}: replicas {parts}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the front-door
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FrontDoorConfig:
    # close an admission group this long after its first request arrived
    deadline_s: float = 0.02
    # admission cap per group (None = each engine's ``admission_cap``)
    max_batch: int | None = None
    # while groups are in flight, sleeps are capped at this poll interval
    # so ready groups get drained (and done-stamped) promptly — and so
    # LM engines, which decode one block per drain_ready call, make
    # progress between arrivals
    poll_s: float = 0.002


class FrontDoor:
    """Deadline-batched, shape-bucketed admission over protocol engines.

    ``engines`` maps model name -> any :class:`~repro.serve.runtime.
    EngineProtocol` implementation (``ReasonEngine``, the LM ``Engine``,
    or a mix) — model constants are bound inside each engine, so the
    front-door schedules traffic only.  ``serve`` consumes a time-ordered
    :class:`ArrivalRequest` stream (use :func:`merge_arrivals` for
    several models) and returns a :class:`FrontDoorReport`.

    ``clock``/``sleep`` default to real time; tests inject a virtual pair
    to drive the admission policy deterministically.  The engines' record
    clocks are pointed at the front-door clock for the duration of
    ``serve`` so queue/service latencies share one origin.

    ``controller`` (optional) turns on the overload control plane: the
    DSE-derived static knobs become the controller's *initial* operating
    point, pending queues become bounded priority
    :class:`~repro.serve.control.ClassQueues` with shedding, and the
    controller adapts deadline/bucket-cap each tick from the windowed
    per-class p99 feedback (see :mod:`repro.serve.control`).
    """

    def __init__(self, engines: Mapping[str, EngineProtocol],
                 cfg: FrontDoorConfig | None = None,
                 clock: Callable[[], float] = time.perf_counter,
                 sleep: Callable[[float], None] = time.sleep,
                 controller: OverloadController | None = None):
        if not engines:
            raise ValueError("front-door needs at least one engine")
        cfg = cfg or FrontDoorConfig()
        if cfg.deadline_s < 0:
            raise ValueError("deadline_s must be >= 0")
        self.engines = dict(engines)
        self.cfg = cfg
        self._clock = clock
        self._sleep = sleep
        self.caps = {m: min(cfg.max_batch or eng.admission_cap,
                            eng.admission_cap)
                     for m, eng in self.engines.items()}
        if any(c < 1 for c in self.caps.values()):
            raise ValueError(f"admission caps must be >= 1: {self.caps}")
        self.controller = controller
        if controller is not None:
            for m, cap in self.caps.items():
                if m not in controller.bound():
                    controller.bind(m, deadline_s=cfg.deadline_s, cap=cap,
                                    buckets=pow2_buckets(cap, min_bucket=1))

    def _deadline(self, model: str) -> float:
        if self.controller is not None:
            return self.controller.deadline_s(model)
        return self.cfg.deadline_s

    def _cap(self, model: str) -> int:
        if self.controller is not None:
            return min(self.controller.cap(model), self.caps[model])
        return self.caps[model]

    def _accepting(self, model: str) -> bool:
        """Whether a group close should dispatch now.  Only consulted in
        overload-control mode: deferring closes while the engine's
        in-flight window is full keeps backlog in the front-door's
        *bounded* queue (where the depth bound sheds it) instead of
        blocking inside ``submit`` — that's the backpressure that makes
        reject-with-backpressure possible.  Engines without an
        ``accepting`` signal always dispatch (legacy behavior)."""
        if self.controller is None:
            return True
        return getattr(self.engines[model], "accepting", True)

    def serve(self, arrivals: Iterable[ArrivalRequest]) -> FrontDoorReport:
        """Serve one arrival stream to completion (single-threaded event
        loop; see module docstring for the policy).  An empty stream
        returns a well-formed empty report."""
        saved_clocks = {m: eng.clock for m, eng in self.engines.items()}
        for eng in self.engines.values():
            eng.clock = self._clock
        try:
            return self._serve(arrivals)
        finally:
            for m, eng in self.engines.items():
                eng.clock = saved_clocks[m]

    def _serve(self, arrivals: Iterable[ArrivalRequest]) -> FrontDoorReport:
        ctl = self.controller
        results: dict[str, dict[int, Any]] = {m: {} for m in self.engines}
        # per-model bounded priority queues (unbounded single-class FIFO
        # when no controller — the legacy behavior, byte for byte)
        pending: dict[str, ClassQueues] = \
            {m: (ctl.queues(m) if ctl is not None else ClassQueues())
             for m in self.engines}
        shed: list[ShedRecord] = []
        # serve-lifetime duplicate guard: engines intentionally allow uid
        # reuse after a drain, so a duplicate that slips past a mid-serve
        # drain would silently overwrite the earlier answer in `results`
        seen: dict[str, set] = {m: set() for m in self.engines}
        # (model, rec, close_reason, close_s, [arrival times], [classes])
        submitted: list[tuple[str, GroupRecord, str, float,
                              list[float], list[str]]] = []
        # submitted groups whose completion hasn't been fed back yet
        watch: list[tuple[str, GroupRecord, list[float], list[str]]] = []

        t0 = self._clock()

        def now() -> float:
            return self._clock() - t0

        def close_group(model: str, reason: str):
            group = pending[model].pop(self._cap(model))
            rec = self.engines[model].submit([a.request for a in group])
            entry = (model, rec, reason, now(), [a.t for a in group],
                     [a.priority or DEFAULT_PRIORITY for a in group])
            submitted.append(entry)
            if ctl is not None:
                watch.append((model, rec, entry[4], entry[5]))

        def feedback():
            # feed completions to the windowed estimator and let the
            # controller adapt the operating point if a tick is due
            t = now()
            live = []
            for model, rec, arrs, prios in watch:
                if rec.done_t is None:
                    live.append((model, rec, arrs, prios))
                    continue
                done_s = rec.done_t - t0
                for arr, prio in zip(arrs, prios):
                    ctl.observe(model, prio, done_s - arr, t)
            watch[:] = live
            obs = {m: dict(rt.engine_observation(eng),
                           queue_depth=len(pending[m]))
                   for m, eng in self.engines.items()}
            ctl.maybe_tick(t, obs)

        it = iter(arrivals)
        nxt = next(it, None)
        last_t = -float("inf")
        while True:
            t = now()
            # admit every due arrival (pulling the iterator renders the
            # request — ingest work happens inside the serving loop)
            while nxt is not None and nxt.t <= t:
                if nxt.model not in self.engines:
                    raise ValueError(f"arrival for unknown model "
                                     f"{nxt.model!r} (serving "
                                     f"{sorted(self.engines)})")
                if nxt.t < last_t - 1e-9:
                    raise ValueError("arrival stream is not time-ordered "
                                     f"({nxt.t:.6f} after {last_t:.6f}) — "
                                     "use merge_arrivals")
                last_t = nxt.t
                model = nxt.model
                uid = nxt.request.uid
                if uid in seen[model]:
                    raise ValueError(f"duplicate request uid {uid} for "
                                     f"model {model!r} (results are keyed "
                                     "by uid)")
                seen[model].add(uid)
                prio = nxt.priority or rt.request_priority(nxt.request)
                arrival = dataclasses.replace(nxt, priority=prio)
                rejected = pending[model].offer(arrival, prio, now())
                if rejected is not None:
                    shed.append(rejected)
                nxt = next(it, None)
                while len(pending[model]) >= self._cap(model) \
                        and self._accepting(model):
                    close_group(model, "full")
            if nxt is None:
                # stream over: no future arrival can fill an open group,
                # so holding it to the deadline only adds latency.  Flush
                # in arrival order ACROSS models (oldest open group
                # first), not engine-dict order — cross-model dispatch
                # order must track arrival order
                flushable = [m for m in self.engines if pending[m]]
                while flushable:
                    model = min(flushable,
                                key=lambda m: pending[m].oldest_t)
                    close_group(model, "flush")
                    flushable = [m for m in self.engines if pending[m]]
                break
            t = now()
            # deadline closes, oldest open group first across models so
            # simultaneous expiries dispatch in arrival order; a close is
            # deferred (not skipped) while the engine signals
            # backpressure — the queue keeps aging and sheds at its bound
            deferred = False
            due = sorted(
                (pending[m].oldest_t, m) for m in self.engines
                if pending[m]
                and t >= pending[m].oldest_t + self._deadline(m))
            for _, model in due:
                if not pending[model]:
                    continue
                if self._accepting(model):
                    close_group(model, "deadline")
                else:
                    deferred = True
            if ctl is not None:
                feedback()
            events = [nxt.t] + \
                [pending[m].oldest_t + self._deadline(m)
                 for m in self.engines if pending[m]]
            dt = min(events) - now()
            if dt > 0:
                # the device keeps working while the host waits; collect
                # whatever finished so done-stamps aren't deferred, and
                # let host-pumped engines (LM decode) advance a block
                inflight = 0
                for model, eng in self.engines.items():
                    results[model].update(eng.drain_ready())
                    inflight += eng.inflight
                self._sleep(min(dt, self.cfg.poll_s) if inflight else dt)
            elif deferred:
                # every pending event is past due but the engines are
                # backpressuring: drain to free window room and let time
                # advance one poll, or a virtual clock would livelock
                for model, eng in self.engines.items():
                    results[model].update(eng.drain_ready())
                self._sleep(self.cfg.poll_s)

        for model, eng in self.engines.items():
            results[model].update(eng.drain_all())
        if ctl is not None:
            feedback()
        wall = now()

        latencies: list[RequestLatency] = []
        groups: list[ServedGroup] = []
        for model, rec, reason, close_s, arr_times, prios in submitted:
            if rec.dispatch_t is None or rec.done_t is None:
                raise RuntimeError(
                    f"{model}: engine left group {rec.index} unstamped "
                    f"(dispatch_t={rec.dispatch_t}, done_t={rec.done_t}) "
                    "after drain_all — protocol violation")
            dispatch_s = rec.dispatch_t - t0
            done_s = rec.done_t - t0
            groups.append(ServedGroup(
                model=model, uids=rec.uids, bucket=rec.bucket, size=rec.size,
                close_reason=reason, open_s=min(arr_times), close_s=close_s,
                dispatch_s=dispatch_s, done_s=done_s, replica=rec.replica))
            for uid, arr, prio in zip(rec.uids, arr_times, prios):
                latencies.append(RequestLatency(
                    uid=uid, model=model, arrival_s=arr,
                    dispatch_s=dispatch_s, done_s=done_s, bucket=rec.bucket,
                    group_size=rec.size, close_reason=reason,
                    priority=prio))
        return FrontDoorReport(
            results=results, latencies=latencies, groups=groups,
            wall_time_s=wall, shed=shed,
            slo=dict(ctl.targets) if ctl is not None else {},
            decisions=list(ctl.decisions) if ctl is not None else [],
            queue_depth_max={m: q.depth_max for m, q in pending.items()})
