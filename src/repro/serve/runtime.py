"""The unified serving runtime: one engine protocol for every traffic class.

NSFlow's framing (paper Sec III) is that LM-style neural inference and
neuro-symbolic reasoning are *one* serving problem with heterogeneous
compute streams, not two products.  Before this module the repo had two
disjoint serving APIs — the slot-pool LM :class:`~repro.serve.engine.Engine`
(batch-level ``run()``) and the staged-pipeline
:class:`~repro.serve.reason.ReasonEngine` (``submit``/``drain``) — so the
online front-door could only multiplex NSAI engines.  ``EngineProtocol``
is the single runtime surface both engines now implement natively:

- ``submit(group) -> GroupRecord`` — dispatch one admission group.  The
  engine owns its constants (LM params / NSAI consts are bound at
  construction), so callers schedule *traffic*, not model state.
- ``drain_ready() -> {uid: result}`` — non-blocking: collect whatever has
  already finished (and, for engines that need host pumping like the LM
  slot pool, advance bounded work — one decode block per call).
- ``drain_all() -> {uid: result}`` — run the engine's in-flight window to
  completion and collect everything.
- ``inflight`` — dispatched-but-undrained admission groups.
- ``admission_cap`` — the largest group ``submit`` accepts (NSAI: the
  config batch size; LM: the slot-pool size).
- ``stats`` / ``runs`` — warmup-split accounting: wall time of runs that
  jit-compiled a new shape lands under ``stats["warmup"]``, steady-state
  runs under ``stats["measured"]`` (see :func:`fresh_split_stats`), with
  per-run records appended to ``engine.runs``.
- ``clock`` — timestamp source for :class:`GroupRecord` stamps; the
  front-door points every engine at one clock so queue/service latencies
  share an origin.

The *request/result envelope* is structural, not nominal: any request
object with a ``uid`` (``serve.engine.Request``, ``serve.reason.
ReasonRequest``) and any result with a ``uid`` plus its payload
(``tokens`` for LM, ``answer``/``answer_logprobs`` for NSAI) flow through
the same front-door.  :func:`work_units` maps a result to its throughput
unit — generated tokens for LM rows, one problem for NSAI rows — which is
how one :class:`~repro.serve.frontdoor.FrontDoorReport` reports tokens/s
and problems/s side by side.

``TRAFFIC_CLASSES`` is the runtime registry the launcher derives its
``--workload`` / ``--models`` choices from; ``repro.serve.deploy`` builds
protocol engines for any mix of entries and closes the paper's
generator -> architecture loop (``core.dse.explore`` output configures the
serving runtime).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Mapping, Protocol, Sequence, \
    runtime_checkable


@dataclasses.dataclass
class GroupRecord:
    """Provenance + timing of one dispatched admission group.

    ``dispatch_t`` is stamped (engine clock) when the group's first work is
    enqueued on the device — the staged pipeline's first stage, or the LM
    engine's prefill of the group's first admitted request.  ``done_t``
    stays None until every request of the group has its answer
    materialized on the host, so arrival -> dispatch is queueing and
    dispatch -> done is service.  ``bucket`` is the compiled batch shape
    the group ran at (NSAI: the covering batch bucket; LM: the slot-pool
    width the decode batch is compiled for).
    """

    uids: tuple[int, ...]
    index: int                    # engine-lifetime group counter
    variant: str
    bucket: int                   # compiled batch size the group ran at
    size: int                     # real requests in the group (<= bucket)
    dispatch_t: float | None = None
    done_t: float | None = None
    # which replica of a ReplicaPool served the group (None = the engine
    # is not pooled); stamped by ``serve.replica.ReplicaPool.submit``
    replica: int | None = None


@runtime_checkable
class RequestLike(Protocol):
    """Anything submittable: the envelope only pins the uid."""

    uid: int


@runtime_checkable
class ResultLike(Protocol):
    """Anything drainable: results are keyed and reported by uid."""

    uid: int


class EngineProtocol(Protocol):
    """The one serving-runtime API (see module docstring).

    Both ``serve.engine.Engine`` and ``serve.reason.ReasonEngine``
    implement this structurally; ``isinstance(eng, EngineProtocol)`` is
    intentionally not used for dispatch — the front-door just drives the
    methods.
    """

    stats: dict
    runs: list
    clock: Callable[[], float]

    @property
    def admission_cap(self) -> int: ...          # pragma: no cover

    @property
    def inflight(self) -> int: ...               # pragma: no cover

    def submit(self, group: Sequence[RequestLike]) -> GroupRecord:
        ...                                      # pragma: no cover

    def drain_ready(self) -> dict[int, Any]: ...  # pragma: no cover

    def drain_all(self) -> dict[int, Any]: ...    # pragma: no cover


def fresh_split_stats() -> dict:
    """The warmup/measured wall-time split both engines account under.

    A run that jit-compiles a new shape (first touch of a (variant,
    bucket) pipeline shape, a new padded prefill length, the first decode
    block) lands under ``warmup``; steady-state runs land under
    ``measured`` — so throughput helpers never fold compile time into the
    denominator.  ``work`` counts the class's throughput unit: problems
    for NSAI engines, generated tokens for LM engines.
    """
    return {
        "measured": {"requests": 0, "work": 0, "wall_time_s": 0.0},
        "warmup": {"requests": 0, "work": 0, "wall_time_s": 0.0},
    }


def measured_rate(stats: Mapping, field: str = "work") -> float:
    """Steady-state ``field``-per-second from a warmup-split stats dict.

    Warmup runs are excluded; if *only* warmup runs exist (e.g. a single
    run that first-touched a shape), falls back to the warmup totals
    rather than reporting 0 — check ``stats["measured"]["requests"]`` to
    tell the cases apart.
    """
    m, w = stats["measured"], stats["warmup"]
    if m["wall_time_s"]:
        return m[field] / m["wall_time_s"]
    if w["wall_time_s"]:
        return w[field] / w["wall_time_s"]
    return 0.0


def request_priority(request: Any) -> str:
    """Priority class of a request envelope.

    The envelope is structural (like ``uid``): any request may carry a
    ``priority`` attribute naming one of :data:`~repro.serve.slo.
    PRIORITIES`; envelopes without one serve as ``standard``.  The
    front-door validates the class at admission (named error), so
    engines never see an unknown class."""
    from repro.serve.slo import DEFAULT_PRIORITY

    return getattr(request, "priority", None) or DEFAULT_PRIORITY


def engine_observation(engine: Any) -> dict[str, Any]:
    """What the overload controller sees of one engine each tick.

    Prefers the engine's own ``observation()`` (``ReplicaPool`` merges
    across replicas there); otherwise derives the generic view from the
    protocol surface.  ``work_rate`` is the steady-state throughput in
    the engine's own unit (see :func:`measured_rate`)."""
    obs = getattr(engine, "observation", None)
    if callable(obs):
        return obs()
    return {"inflight": engine.inflight,
            "work_rate": measured_rate(engine.stats)}


def work_units(result: Any) -> int:
    """Throughput units one result carries: generated tokens for LM
    results, 1 problem for NSAI results."""
    tokens = getattr(result, "tokens", None)
    return len(tokens) if tokens is not None else 1


def work_unit_name(results: Iterable[Any]) -> str:
    """'tok' when any result carries generated tokens, else 'prob'."""
    return "tok" if any(getattr(r, "tokens", None) is not None
                        for r in results) else "prob"


# ---------------------------------------------------------------------------
# the runtime registry (launcher --workload / --models choices derive here)
# ---------------------------------------------------------------------------


def _lm_model_ids() -> tuple[str, ...]:
    """Arch ids the slot-pool Engine can serve (token-in/token-out kinds)."""
    from repro.configs import ARCHS

    return tuple(sorted(a for a, spec in ARCHS.items()
                        if spec.kind in ("lm", "rwkv", "griffin")))


def _reason_model_ids() -> tuple[str, ...]:
    from repro.configs.base import REASON_WORKLOADS

    return tuple(REASON_WORKLOADS)


def _all_model_ids() -> tuple[str, ...]:
    return _reason_model_ids() + _lm_model_ids()


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One entry of the runtime registry: a serving traffic class."""

    name: str
    describe: str
    models: Callable[[], tuple[str, ...]]   # servable model ids (lazy)


TRAFFIC_CLASSES: dict[str, TrafficClass] = {
    "lm": TrafficClass(
        "lm", "continuous-batching generation through the slot-pool Engine",
        _lm_model_ids),
    "reason": TrafficClass(
        "reason", "batched NSAI reasoning through the staged-pipeline "
                  "ReasonEngine", _reason_model_ids),
    "frontdoor": TrafficClass(
        "frontdoor", "online mixed LM+NSAI traffic: DSE-deployed engines "
                     "behind one deadline-batched front-door",
        _all_model_ids),
}


def resolve_models(workload: str, models: Iterable[str]) -> tuple[str, ...]:
    """Validate a model list against a traffic class's registry entry."""
    tc = TRAFFIC_CLASSES.get(workload)
    if tc is None:
        raise KeyError(f"unknown workload {workload!r}; "
                       f"available: {tuple(TRAFFIC_CLASSES)}")
    known = tc.models()
    out = tuple(models)
    bad = [m for m in out if m not in known]
    if bad:
        raise ValueError(f"{workload}: unknown models {bad}; "
                         f"servable: {known}")
    return out
