"""Per-traffic-class SLO targets and windowed online attainment.

The serving stack advertises *real-time* NSAI: the claim only means
something as a service level objective — "p99 arrival→done latency for
interactive traffic stays under X ms at the advertised capacity".  This
module holds the vocabulary the overload control plane
(:mod:`repro.serve.control`) speaks:

- **priority classes** (:data:`PRIORITIES`): every request envelope
  carries one of a small ranked set of traffic classes.  Rank order is
  the shedding order — under overload the front-door sheds
  lowest-priority-first, so ``interactive`` traffic keeps its SLO while
  ``batch`` absorbs the rejects.
- **targets** (:class:`SLOTarget`): a per-class total-latency p99 bound
  plus the attainment fraction that must meet it.
- **online estimation** (:class:`SLOEstimator`): a windowed per
  (model, class) p99 estimate the feedback controller reads each tick.
  Pure data structure — observations carry their own timestamps, no
  clock is read here (enforced by analyzer rule NSF105).
- **report-side attainment** (:func:`attainment`): exact per-class
  attainment over a finished :class:`~repro.serve.frontdoor.
  FrontDoorReport`'s latencies, for benches and CI gates.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, Mapping

import numpy as np

# Ranked traffic classes, highest priority first.  The index in this
# tuple is the shed rank: under overload the control plane sheds from
# the *end* of this tuple first.
PRIORITIES: tuple[str, ...] = ("interactive", "standard", "batch")
PRIORITY_RANK: dict[str, int] = {p: i for i, p in enumerate(PRIORITIES)}
DEFAULT_PRIORITY = "standard"


def validate_priority(name: str) -> str:
    """Return ``name`` if it is a known priority class, else raise a
    named ValueError listing the valid classes."""
    if name not in PRIORITY_RANK:
        raise ValueError(f"unknown priority class {name!r} "
                         f"(known: {', '.join(PRIORITIES)})")
    return name


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """One traffic class's objective: windowed/report p99 of total
    (arrival→done) latency must stay ≤ ``total_p99_ms``, and at least
    ``attainment`` of requests must individually meet it."""

    total_p99_ms: float
    attainment: float = 0.99

    def __post_init__(self):
        if self.total_p99_ms <= 0:
            raise ValueError(f"total_p99_ms must be > 0, "
                             f"got {self.total_p99_ms}")
        if not 0.0 < self.attainment <= 1.0:
            raise ValueError(f"attainment must be in (0, 1], "
                             f"got {self.attainment}")

    def met_by(self, total_s: float) -> bool:
        return total_s * 1e3 <= self.total_p99_ms


def slo_targets(spec: float | Mapping[str, float] | None,
                ) -> dict[str, SLOTarget]:
    """Build per-class targets from a scalar or per-class ms spec.

    A scalar ``x`` is the *interactive* p99 target; ``standard`` gets a
    conventional 4x relaxation and ``batch`` runs best-effort (no
    target).  A mapping pins classes explicitly (unknown class names
    raise); ``None`` means no objectives at all."""
    if spec is None:
        return {}
    if isinstance(spec, Mapping):
        return {validate_priority(k): SLOTarget(total_p99_ms=float(v))
                for k, v in spec.items()}
    x = float(spec)
    return {"interactive": SLOTarget(total_p99_ms=x),
            "standard": SLOTarget(total_p99_ms=4.0 * x)}


class SLOEstimator:
    """Windowed per (model, priority) total-latency estimator.

    ``observe`` appends one completed request; ``p99_ms`` reads the
    current window.  The window is a fixed-size deque (last ``window``
    completions), so the estimate tracks the *recent* regime — exactly
    what a feedback controller wants under bursty load, where a
    lifetime percentile would average the burst away."""

    def __init__(self, targets: Mapping[str, SLOTarget] | None = None,
                 window: int = 128):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.targets = dict(targets or {})
        self.window = window
        self._obs: dict[tuple[str, str], deque] = {}

    def observe(self, model: str, priority: str, total_s: float,
                now: float) -> None:
        key = (model, priority)
        dq = self._obs.get(key)
        if dq is None:
            dq = self._obs[key] = deque(maxlen=self.window)
        dq.append((now, total_s))

    def count(self, model: str, priority: str | None = None) -> int:
        return sum(len(dq) for (m, p), dq in self._obs.items()
                   if m == model and (priority is None or p == priority))

    def p99_ms(self, model: str, priority: str | None = None) -> float:
        vals = [s for (m, p), dq in self._obs.items()
                if m == model and (priority is None or p == priority)
                for _, s in dq]
        if not vals:
            return float("nan")
        return float(np.percentile(vals, 99)) * 1e3

    def snapshot(self, model: str) -> dict[str, dict]:
        """Per-priority window state the controller reads each tick:
        ``{priority: {n, p99_ms, target_ms, ok}}`` (``target_ms``/``ok``
        are None for classes without an objective)."""
        out: dict[str, dict] = {}
        for p in PRIORITIES:
            n = self.count(model, p)
            if not n and p not in self.targets:
                continue
            p99 = self.p99_ms(model, p)
            tgt = self.targets.get(p)
            out[p] = {"n": n, "p99_ms": p99,
                      "target_ms": tgt.total_p99_ms if tgt else None,
                      "ok": (None if tgt is None or not n
                             else bool(p99 <= tgt.total_p99_ms))}
        return out


def attainment(latencies: Iterable, targets: Mapping[str, SLOTarget],
               model: str | None = None) -> dict[str, dict]:
    """Exact per-class SLO attainment over finished request latencies.

    ``latencies`` is any iterable of objects with ``model``,
    ``priority`` and ``total_s`` (e.g. :class:`~repro.serve.frontdoor.
    RequestLatency`).  Returns ``{priority: {n, met, attainment,
    target_ms, ok}}`` for every class with a target or traffic; ``ok``
    is None for classes without an objective."""
    counts: dict[str, list[int]] = {}
    for lat in latencies:
        if model is not None and lat.model != model:
            continue
        prio = getattr(lat, "priority", DEFAULT_PRIORITY)
        row = counts.setdefault(prio, [0, 0])
        row[0] += 1
        tgt = targets.get(prio)
        if tgt is None or tgt.met_by(lat.total_s):
            row[1] += 1
    out: dict[str, dict] = {}
    for prio in PRIORITIES:
        if prio not in counts and prio not in targets:
            continue
        n, met = counts.get(prio, [0, 0])
        tgt = targets.get(prio)
        frac = met / n if n else float("nan")
        out[prio] = {
            "n": n, "met": met, "attainment": frac,
            "target_ms": tgt.total_p99_ms if tgt else None,
            "ok": (None if tgt is None
                   else bool(n and frac >= tgt.attainment)),
        }
    return out
