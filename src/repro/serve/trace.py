"""Golden-trace record/replay for the serving stack.

NSFlow validates generated accelerators against golden vectors: the same
stimulus is driven through the reference model and the lowered design, and
the outputs are diffed bit-for-bit.  This module is the serving-side
analogue for *backend lowerings*: record what a deployment actually served
— the admission groups the front-door formed, every request payload, and
every answer — then replay the exact same groups offline through an
arbitrary :class:`~repro.backend.registry.LoweringPlan` and diff.

The tolerance of the diff is not a magic constant: it comes from the
lowering registry's equivalence classes via
:func:`repro.backend.registry.replay_tolerance`.  Replaying under the same
per-kernel lowering tags demands **bit-exact** answers (same grouping +
same lowering = same floats); replaying under a different plan (e.g. the
all-XLA fallback) is held to the max declared epsilon of the kernels whose
lowering changed.

Format: one JSONL file.  A ``header`` line carries the recorded plan's
per-kernel tags plus the ``deploy()`` spec (workloads / seed / options /
budget / traffic) so ``replay()`` can rebuild the same models; ``request``
lines carry base64 payload arrays with sha256 digests; ``group`` lines the
admission groups in dispatch order; ``result`` lines the answers.

    dep = deploy(["nvsa"], ...)
    arrivals, _ = dep.synthetic_traffic(32)
    report, trace = record(dep, arrivals, "golden.jsonl")
    ...
    trace = GoldenTrace.load("golden.jsonl")
    rep = trace.replay(backend="xla")     # forced all-XLA fallback plan
    diff = trace.diff(rep)
    assert diff.ok, diff.describe()
"""

from __future__ import annotations

import base64
import dataclasses
import hashlib
import json
from typing import Any, Iterable, Iterator

import numpy as np

from repro.backend import registry
from repro.serve.frontdoor import ArrivalRequest, FrontDoorReport

TRACE_VERSION = 1

# result fields diffed per traffic class; anything not listed here
# (timing, slot / batch indices) is process-dependent and recorded for
# provenance only
_DIFF_FIELDS = {
    "reason": ("answer", "answer_logprobs", "rule_posteriors"),
    "lm": ("tokens",),
}
# of those, the float-valued ones (epsilon applies); the rest are exact
# regardless of plan (argmax answers, token ids)
_FLOAT_FIELDS = ("answer_logprobs", "rule_posteriors")


# ---------------------------------------------------------------------------
# array / payload (de)serialization
# ---------------------------------------------------------------------------


def _enc_array(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


def _dec_array(d: dict) -> np.ndarray:
    buf = base64.b64decode(d["data"])
    return np.frombuffer(buf, dtype=np.dtype(d["dtype"])).reshape(d["shape"])


def _enc_fields(obj) -> tuple[dict, dict]:
    """Split a request/result dataclass into (arrays, scalar meta)."""
    arrays, meta = {}, {}
    for f in dataclasses.fields(obj):
        v = getattr(obj, f.name)
        if v is None:
            continue
        if isinstance(v, np.ndarray):
            arrays[f.name] = v
        elif hasattr(v, "shape") and hasattr(v, "dtype"):  # jax array
            arrays[f.name] = np.asarray(v)
        elif isinstance(v, (bool, int, float, str, np.integer, np.floating)):
            meta[f.name] = v.item() if isinstance(v, np.generic) else v
        elif isinstance(v, (list, tuple)) and all(
                isinstance(x, (int, np.integer)) for x in v):
            meta[f.name] = [int(x) for x in v]
    return arrays, meta


def _digest(arrays: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for name in sorted(arrays):
        h.update(name.encode())
        h.update(np.ascontiguousarray(arrays[name]).tobytes())
    return h.hexdigest()


def _payload_line(kind: str, model: str, obj) -> dict:
    arrays, meta = _enc_fields(obj)
    return {"kind": kind, "model": model, "uid": int(obj.uid),
            "meta": {k: v for k, v in meta.items() if k != "uid"},
            "arrays": {k: _enc_array(v) for k, v in arrays.items()},
            "digest": _digest(arrays)}


def _decode_payload(line: dict) -> dict:
    fields = dict(line["meta"])
    for k, v in line["arrays"].items():
        fields[k] = _dec_array(v)
    return fields


def _build_request(cls_name: str, uid: int, fields: dict):
    if cls_name == "reason":
        from repro.serve.reason import ReasonRequest

        return ReasonRequest(uid=uid, **fields)
    from repro.serve.engine import Request

    return Request(uid=uid, **fields)


# ---------------------------------------------------------------------------
# recording
# ---------------------------------------------------------------------------


def _tap(arrivals: Iterable[ArrivalRequest], store: dict
         ) -> Iterator[ArrivalRequest]:
    """Tee an arrival stream, stashing payloads by (model, uid).  The
    front-door report only carries uids; the recorder needs the arrays."""
    for a in arrivals:
        store[(a.model, a.request.uid)] = a.request
        yield a


def record(deployment, arrivals: Iterable[ArrivalRequest], path: str
           ) -> tuple[FrontDoorReport, "GoldenTrace"]:
    """Serve ``arrivals`` through the deployment's front-door and write a
    golden trace of everything served to ``path`` (JSONL).

    Returns ``(report, trace)`` — the normal :class:`FrontDoorReport` plus
    the in-memory :class:`GoldenTrace` (identical to ``GoldenTrace.load
    (path)``).
    """
    payloads: dict[tuple[str, int], Any] = {}
    report = deployment.serve(_tap(arrivals, payloads))

    header = {
        "kind": "header", "version": TRACE_VERSION,
        "backend": deployment.backend_record(),
        "models": {m: {"class": deployment.classes[m],
                       "variant": deployment.variants[m]}
                   for m in deployment.engines},
        "deploy": {
            "workloads": list(deployment.engines),
            "seed": deployment.seed,
            "options": deployment.options,
            "budget": dataclasses.asdict(deployment.budget),
            "traffic": dataclasses.asdict(deployment.traffic),
        },
    }
    lines: list[dict] = [header]
    served: set[tuple[str, int]] = set()
    for g in report.groups:
        served.update((g.model, u) for u in g.uids)
        lines.append({"kind": "group", "model": g.model,
                      "uids": list(g.uids), "bucket": g.bucket,
                      "size": g.size, "close_reason": g.close_reason})
    for (m, uid) in sorted(served):
        lines.append(_payload_line("request", m, payloads[(m, uid)]))
        lines.append(_payload_line("result", m, report.results[m][uid]))
    with open(path, "w") as f:
        for line in lines:
            f.write(json.dumps(line) + "\n")
    return report, GoldenTrace.from_lines(lines, path=path)


# ---------------------------------------------------------------------------
# replay + diff
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ReplayReport:
    """One offline replay: results per (model, uid) + the plan used."""

    results: dict[tuple[str, int], Any]
    plan: registry.LoweringPlan


@dataclasses.dataclass
class FieldDiff:
    model: str
    uid: int
    field: str
    max_abs_err: float
    exact_mismatch: bool


@dataclasses.dataclass
class TraceDiff:
    """Outcome of diffing a replay against the recorded golden answers.

    ``tolerance`` is :func:`registry.replay_tolerance` of the recorded vs
    replayed per-kernel tags: 0.0 (bit-exact required) when the plans
    match, else the max declared epsilon over the kernels that changed.
    """

    tolerance: float
    recorded_tags: dict[str, str]
    replayed_tags: dict[str, str]
    n_compared: int
    max_abs_err: float
    failures: list[FieldDiff]

    @property
    def ok(self) -> bool:
        return not self.failures

    def describe(self) -> str:
        mode = "bit-exact" if self.tolerance == 0.0 \
            else f"epsilon={self.tolerance:g}"
        head = (f"replay diff [{mode}]: {self.n_compared} results, "
                f"max |err|={self.max_abs_err:.3g}, "
                f"{len(self.failures)} failures")
        tail = "".join(
            f"\n  {f.model}/{f.uid}.{f.field}: "
            + ("exact mismatch" if f.exact_mismatch
               else f"|err|={f.max_abs_err:.3g}")
            for f in self.failures[:8])
        return head + tail


@dataclasses.dataclass
class GoldenTrace:
    """A loaded golden trace: header + requests + groups + answers."""

    header: dict
    requests: dict[tuple[str, int], dict]
    results: dict[tuple[str, int], dict]
    groups: list[dict]
    path: str | None = None

    @classmethod
    def from_lines(cls, lines: Iterable[dict], path: str | None = None
                   ) -> "GoldenTrace":
        header, requests, results, groups = None, {}, {}, []
        for line in lines:
            kind = line["kind"]
            if kind == "header":
                if line["version"] != TRACE_VERSION:
                    raise ValueError(
                        f"golden trace version {line['version']} != "
                        f"{TRACE_VERSION}")
                header = line
            elif kind == "group":
                groups.append(line)
            elif kind == "request":
                requests[(line["model"], line["uid"])] = line
            elif kind == "result":
                results[(line["model"], line["uid"])] = line
        if header is None:
            raise ValueError("golden trace has no header line")
        return cls(header=header, requests=requests, results=results,
                   groups=groups, path=path)

    @classmethod
    def load(cls, path: str) -> "GoldenTrace":
        with open(path) as f:
            lines = [json.loads(l) for l in f if l.strip()]
        return cls.from_lines(lines, path=path)

    @property
    def recorded_tags(self) -> dict[str, str]:
        return dict(self.header["backend"]["lowerings"])

    # -- replay -------------------------------------------------------------

    def _resolve_plan(self, backend) -> registry.LoweringPlan:
        if isinstance(backend, registry.LoweringPlan):
            return backend
        return registry.negotiate(override=backend)

    def replay(self, backend: str | registry.LoweringPlan | None = None,
               deployment=None) -> ReplayReport:
        """Re-serve the recorded admission groups through a lowering plan.

        ``backend``: None renegotiates against the runtime (honoring
        ``REPRO_BACKEND``), a string forces an override spec, or pass a
        plan directly.  ``deployment``: reuse an existing deployment's
        engines (its own negotiated plan wins); None re-deploys from the
        recorded spec — same workloads / seed / options, so NSAI consts
        are regenerated identically from the seed-derived PRNG keys.

        Grouping is preserved exactly: each recorded group is submitted
        as one admission group (same covering bucket → same padding →
        same compiled shapes), then drained before the next.
        """
        if deployment is None:
            from repro.serve.deploy import Budget, Traffic, deploy

            spec = self.header["deploy"]
            plan = self._resolve_plan(backend)
            deployment = deploy(
                spec["workloads"], Traffic(**spec["traffic"]),
                Budget(**spec["budget"]), seed=spec["seed"],
                options=spec["options"], backend=plan)
        else:
            plan = deployment.backend or self._resolve_plan(backend)

        out: dict[tuple[str, int], Any] = {}
        for g in self.groups:
            m = g["model"]
            eng = deployment.engines[m]
            group = [
                _build_request(
                    self.header["models"][m]["class"], uid,
                    _decode_payload(self.requests[(m, uid)]))
                for uid in g["uids"]]
            eng.submit(group)
            out.update({(m, uid): r for uid, r in eng.drain_all().items()})
        return ReplayReport(results=out, plan=plan)

    # -- diff ---------------------------------------------------------------

    def diff(self, replay: ReplayReport,
             tolerance: float | None = None) -> TraceDiff:
        """Diff a replay against the recorded answers.

        ``tolerance`` defaults to ``registry.replay_tolerance(recorded,
        replayed)``: bit-exact for identical per-kernel tags, else the
        max declared epsilon over the changed kernels.  Integer-valued
        fields (answers, token ids) must match exactly under any plan.
        """
        replayed_tags = replay.plan.tags()
        if tolerance is None:
            tolerance = registry.replay_tolerance(self.recorded_tags,
                                                  replayed_tags)
        failures: list[FieldDiff] = []
        max_err, n = 0.0, 0
        for key, line in sorted(self.results.items()):
            model, uid = key
            got = replay.results.get(key)
            if got is None:
                failures.append(FieldDiff(model, uid, "<missing>", np.inf,
                                          True))
                continue
            n += 1
            cls_name = self.header["models"][model]["class"]
            recorded = _decode_payload(line)
            got_arrays, got_meta = _enc_fields(got)
            got_fields = {**got_meta, **got_arrays}
            for field in _DIFF_FIELDS[cls_name]:
                want, have = recorded.get(field), got_fields.get(field)
                if want is None and have is None:
                    continue
                if want is None or have is None:
                    failures.append(FieldDiff(model, uid, field, np.inf,
                                              True))
                    continue
                want, have = np.asarray(want), np.asarray(have)
                if want.shape != have.shape:
                    failures.append(FieldDiff(model, uid, field, np.inf,
                                              True))
                    continue
                if field in _FLOAT_FIELDS and tolerance > 0.0:
                    err = float(np.max(np.abs(
                        want.astype(np.float64) - have.astype(np.float64)))
                        if want.size else 0.0)
                    max_err = max(max_err, err)
                    if err > tolerance:
                        failures.append(FieldDiff(model, uid, field, err,
                                                  False))
                elif not np.array_equal(want, have):
                    err = float(np.max(np.abs(
                        want.astype(np.float64) - have.astype(np.float64)))
                        if np.issubdtype(want.dtype, np.number)
                        and want.size else np.inf)
                    max_err = max(max_err, err if np.isfinite(err) else 0.0)
                    failures.append(FieldDiff(model, uid, field, err, True))
        return TraceDiff(tolerance=tolerance, recorded_tags=self.recorded_tags,
                         replayed_tags=replayed_tags, n_compared=n,
                         max_abs_err=max_err, failures=failures)

    def replay_and_diff(self, backend=None, deployment=None) -> TraceDiff:
        """``diff(replay(...))`` in one call."""
        return self.diff(self.replay(backend=backend, deployment=deployment))
