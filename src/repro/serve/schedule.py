"""Schedule compilation: lower a dataflow graph to an executable pipeline.

NSFlow's design generator (paper Sec V-B) identifies workload data
dependencies and emits an optimized dataflow architecture; this module is
the serving-side realization of the same lowering.  ``compile_schedule``
takes a workload's *stage list* — jax-traceable callables with declared
stream tags (nn / vsa / simd, the paper's unit taxonomy) — and emits a
:class:`StagedSchedule`:

  - an ordered tuple of **jit-able stage callables** (one jit boundary per
    stage: the boundaries are exactly the points where the generic executor
    in ``serve.reason.ReasonEngine`` may drain / overlap),
  - a **fused whole-pipeline variant** (``jit_fused``): a single jit of the
    composed stages with the staged input buffer donated, so one admission
    group costs one dispatch instead of K.  The fused trace is negotiated
    against the staged one through the active
    :class:`~repro.backend.registry.LoweringPlan`: ``compile_schedule``
    records which kernel lowerings each trace selects
    (``registry.record_selections``) and declares the fused variant
    ``exact`` (bit-identical — the executor may substitute it freely) or
    ``epsilon`` (a fused-only kernel routed to a non-exact lowering — the
    executor falls back stage-by-stage unless fusion was forced),
  - **inter-stage buffer specs** (pytree shapes + byte counts, from
    ``jax.eval_shape`` chained through the stages — the serving analogue of
    the memory-cost annotation, Sec V-B step ⑤),
  - a traced :class:`~repro.core.dataflow.DataflowGraph` built by running
    ``core.trace`` on the composed pipeline's jaxpr (steps ①–③: critical
    path, depth assignment, inter-loop overlap model), plus per-stage op
    statistics from tracing each stage alone,
  - the **host/device overlap points** the executor honors (which host
    steps run while the device works, and where the previous batch is
    drained).

Stream tags are *declared* by the workload and *audited* against the trace:
at smoke scale XLA lowers blockwise circular convolution to gather +
dot_general (so a flops-dominance classifier would mislabel the symbolic
stream as ``nn``), which is exactly the "tracing is too fine-grained" case
the declared tags resolve.  The audit result per stage is kept on the
schedule (``stage_costs``) so benchmarks and tests can inspect both views.

The correspondence with the analytical side: ``core.dataflow.build`` on the
same graph drives the DSE; ``interloop_overlap`` predicts the steady-state
pipeline speedup that ``benchmarks/bench_nsai.py`` measures on the compiled
schedule (its overlap-vs-sequential gate).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import numpy as np

from repro.backend import registry
from repro.core import dataflow as dfl
from repro.core import trace as trace_mod
from repro.core.opgraph import OpGraph

STREAMS = ("nn", "vsa", "simd")

# Host-side steps the generic executor overlaps with device compute, in
# pipeline order.  ``ingest``: pulling + preprocessing requests from the
# (possibly lazy) stream; ``stage``: stacking/padding to the compiled batch
# shape and device transfer; ``collect``: materializing the *previous*
# batch's answers.  All three run while the device works through the
# in-flight batch — the host/device realization of inter-loop overlap
# (paper Sec V-B step ③).
HOST_OVERLAP_POINTS = ("ingest", "stage", "collect")


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One pipeline stage: a jax-traceable callable with a stream tag.

    ``fn(consts, bufs) -> bufs`` — ``consts`` is the workload's constant
    pytree (params / codebooks / keys), ``bufs`` the previous stage's
    output pytree (stage 0 receives the staged request batch).
    """

    name: str
    stream: str        # nn | vsa | simd
    fn: Callable[[Any, Any], Any]

    def __post_init__(self):
        if self.stream not in STREAMS:
            raise ValueError(f"stage {self.name!r}: unknown stream "
                             f"{self.stream!r} (want one of {STREAMS})")


@dataclasses.dataclass(frozen=True)
class BufferSpec:
    """Inter-stage buffer: pytree of ShapeDtypeStructs + total bytes."""

    shapes: Any
    nbytes: int

    @staticmethod
    def from_tree(tree) -> "BufferSpec":
        leaves = jax.tree.leaves(tree)
        nbytes = int(sum(np.prod(l.shape) * np.dtype(l.dtype).itemsize
                         for l in leaves))
        return BufferSpec(shapes=tree, nbytes=nbytes)


@dataclasses.dataclass
class StagedSchedule:
    """An executable pipeline compiled from a workload's dataflow.

    ``jit_stages[i]`` is ``jax.jit(stages[i].fn)``; jit caches live on the
    schedule, so reuse schedules (engines share them per variant).  When
    input specs are known, ``buffers[0]`` describes the staged input batch
    and ``buffers[i + 1]`` the output of stage ``i`` (so ``len(buffers) ==
    len(stages) + 1``).  ``drain_stage`` is the stage index before whose
    dispatch the
    executor drains the previous in-flight batch (0 = PR 2's schedule:
    collect batch i-1 right before batch i's first device stage, so host
    work never blocks the device and co-scheduling contention is avoided).
    """

    workload: str
    variant: str
    stages: tuple[StageSpec, ...]
    jit_stages: tuple[Callable, ...]
    ingest: Callable                      # fn(request) -> pytree of np arrays
    collect: Callable                     # fn(host_out, i) -> result fields
    buffers: tuple[BufferSpec, ...] = ()  # input buffer + per-stage outputs
    stage_costs: tuple[dict, ...] = ()    # per-stage traced op statistics
    graph: dfl.DataflowGraph | None = None
    source: str = "declared"              # declared | trace
    drain_stage: int = 0
    host_overlap: tuple[str, ...] = HOST_OVERLAP_POINTS
    # compiled batch-size buckets, ascending; () = the single input_specs
    # batch size.  A partial admission group is padded to the smallest
    # covering bucket instead of the max (each bucket is its own jit cache
    # entry on the shared jit_stages).  ``buffers``/``stage_costs`` describe
    # the largest bucket.
    batch_buckets: tuple[int, ...] = ()
    # kept for lazy cost tracing (``predicted_overlap`` on schedules
    # compiled with ``trace_graph=False``): abstract consts + stage-0 specs
    input_specs: Any = None
    consts_spec: Any = None
    # the LoweringPlan baked into jit_stages: every stage traces (and
    # therefore compiles) under this plan, so the kernel lowerings a
    # deployment negotiated are pinned per schedule, independent of
    # whatever plan is active when the executor later calls the jits.
    plan: registry.LoweringPlan | None = None
    # -- fused whole-pipeline variant (one dispatch per group) -------------
    # ``jit_fused`` is a single jit of the composed (possibly substituted,
    # see ``fused_stages``) pipeline with the input buffer donated.
    # ``fused_equivalence`` is the negotiated conformance class of the
    # fused trace versus the staged one under ``plan``: "exact" when both
    # traces route every kernel through exact lowerings wherever they
    # differ (the executor substitutes the fused path freely), "epsilon"
    # when a differing kernel sits on a non-exact lowering
    # (``fused_epsilon`` = the max declared tolerance; the executor falls
    # back stage-by-stage unless ``fused_forced``).
    # ``fused_lowering_diff`` names the kernels whose selections differ.
    jit_fused: Callable | None = None
    fused_stages: tuple[StageSpec, ...] = ()
    fused_forced: bool = False
    fused_equivalence: str | None = None   # exact | epsilon | None
    fused_epsilon: float = 0.0
    fused_lowering_diff: tuple[str, ...] = ()

    @property
    def stage_names(self) -> tuple[str, ...]:
        return tuple(s.name for s in self.stages)

    @property
    def streams(self) -> tuple[str, ...]:
        return tuple(s.stream for s in self.stages)

    @property
    def fused_ok(self) -> bool:
        """May the executor substitute the fused pipeline for the staged
        one?  True when a fused jit exists and is either negotiated exact
        or explicitly forced (``compile_schedule(fused=True)``)."""
        return self.jit_fused is not None and (
            self.fused_forced or self.fused_equivalence == "exact")

    def covering_bucket(self, n: int) -> int:
        """Smallest compiled batch bucket that fits ``n`` requests."""
        if not self.batch_buckets:
            return n
        for b in self.batch_buckets:
            if b >= n:
                return b
        raise ValueError(
            f"{self.workload}/{self.variant}: admission group of {n} "
            f"exceeds the largest compiled bucket {self.batch_buckets[-1]}")

    def describe(self) -> str:
        """One-line pipeline rendering: name[stream] -> name[stream]."""
        parts = []
        for i, s in enumerate(self.stages):
            buf = ""
            if i < len(self.stages) - 1:
                buf = f" --{_fmt_bytes(self.buffers[i + 1].nbytes)}--> " \
                    if self.buffers else " -> "
            parts.append(f"{s.name}[{s.stream}]{buf}")
        return "".join(parts)


def _fmt_bytes(n: int) -> str:
    # 1023.95 threshold: anything that would render as "1024.0" after the
    # one-decimal rounding is promoted to the next unit (1048575 bytes is
    # "1.0MB", not "1024.0KB")
    x = float(n)
    for unit in ("B", "KB", "MB"):
        if x < (1024 if unit == "B" else 1023.95):
            return f"{x:.0f}B" if unit == "B" else f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}GB"


def _graph_stats(g: OpGraph) -> dict:
    """Summarize a traced stage subgraph for the stream-tag audit."""
    flops = {k: g.total_flops(k) for k in STREAMS}
    total = sum(flops.values())
    dominant = max(flops, key=flops.get) if total else "simd"
    # Pallas/fft vsa nodes prove a symbolic stream even when the gather
    # fallback hides the flops inside dot_general (see module docstring)
    has_vsa = any(n.kind == "vsa" for n in g)
    return {
        "nodes": len(g), "flops": flops, "bytes": g.total_bytes(),
        "dominant": dominant, "has_vsa_nodes": has_vsa,
    }


def compose_stages(stages: tuple[StageSpec, ...]) -> Callable:
    """The whole pipeline as one callable — what ``jit_fused`` compiles and
    what ``trace_pipeline`` traces (the DataflowGraph already proves this
    composition is what the staged executor computes)."""

    def composed(consts, bufs):
        for s in stages:
            bufs = s.fn(consts, bufs)
        return bufs

    return composed


def trace_pipeline(stages: tuple[StageSpec, ...], consts, input_specs
                   ) -> dfl.DataflowGraph:
    """Trace the composed pipeline's jaxpr into a DataflowGraph (steps ①–③).

    This is ``core.trace`` on the model's jaxpr: the same graph the DSE
    consumes, built from the exact computation the schedule will execute.
    """
    opgraph = trace_mod.extract(compose_stages(stages), consts, input_specs)
    return dfl.build(opgraph)


def _fused_conformance(staged_sel: list, fused_sel: list
                       ) -> tuple[str, float, tuple[str, ...]]:
    """Negotiate the fused trace's equivalence class vs the staged trace.

    Both inputs are ``(kernel, lowering_name)`` selection logs from
    ``registry.record_selections``.  Kernels whose selection *sets* agree
    are bit-identical by construction (same lowerings, same shapes, same
    plan).  For each kernel that differs — typically a fused-only kernel
    like ``unbind_classify`` replacing the staged ``circ_conv`` + dense
    pair — the class is "exact" only if every lowering either side selected
    is exact; otherwise "epsilon" at the max declared tolerance.
    """
    staged: dict[str, set] = {}
    for kern, low in staged_sel:
        staged.setdefault(kern, set()).add(low)
    fused: dict[str, set] = {}
    for kern, low in fused_sel:
        fused.setdefault(kern, set()).add(low)
    diff = sorted(k for k in set(staged) | set(fused)
                  if staged.get(k, set()) != fused.get(k, set()))
    eps, exact = 0.0, True
    for k in diff:
        spec = registry.KERNELS[k]
        for name in staged.get(k, set()) | fused.get(k, set()):
            low = spec.by_name(name)
            if low.equivalence != "exact":
                exact = False
                eps = max(eps, low.epsilon)
    return ("exact" if exact else "epsilon"), eps, tuple(diff)


def _abstract(tree):
    """ShapeDtypeStruct skeleton of a pytree (non-array leaves pass through)."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        if hasattr(x, "shape") and hasattr(x, "dtype") else x, tree)


def _plan_scoped(fn: Callable, plan: registry.LoweringPlan) -> Callable:
    """Bind a stage fn to a LoweringPlan: tracing (and hence the lowering
    choices jit bakes into its cache) always happens under ``plan``."""

    @functools.wraps(fn)
    def scoped(consts, bufs):
        with registry.use_plan(plan):
            return fn(consts, bufs)

    return scoped


def compile_schedule(workload: str, stages: tuple[StageSpec, ...] | list,
                     ingest: Callable, collect: Callable, *,
                     variant: str = "default", consts=None, input_specs=None,
                     graph: OpGraph | None = None, trace_graph: bool = True,
                     batch_buckets: tuple[int, ...] = (),
                     plan: registry.LoweringPlan | None = None,
                     fused: bool | str = "auto",
                     fused_stages: tuple[StageSpec, ...] | list | None = None
                     ) -> StagedSchedule:
    """Lower a stage list (+ its dataflow graph) to a StagedSchedule.

    ``input_specs``: pytree of ``jax.ShapeDtypeStruct`` for one staged
    request batch (stage 0's input).  When given, inter-stage buffer specs
    are derived by chaining ``jax.eval_shape`` through the stages, and —
    unless ``trace_graph`` is False (fast construction: no jaxpr walks,
    schedule still fully executable; ``predicted_overlap`` traces lazily
    on first use) — each stage plus the composed pipeline are traced with
    ``core.trace``: per-stage op statistics for the stream-tag audit, and
    a :class:`DataflowGraph` for provenance (``graph`` may instead supply
    a declared paper-scale ``OpGraph``, e.g. from ``core.workloads``,
    where tracing the reduced executable model would under-size the
    graph).  ``consts`` may be real arrays or ShapeDtypeStructs; it is
    only inspected abstractly.

    ``batch_buckets``: ascending compiled batch sizes (``input_specs``
    must describe the largest); the executor pads a partial admission
    group to the smallest covering bucket instead of the max.

    ``plan``: the :class:`~repro.backend.registry.LoweringPlan` the
    schedule compiles under (None = the plan active now, via
    ``registry.get_plan()``).  Stage fns are wrapped so both the buffer/
    cost tracing here and the later jit tracing happen under that plan.

    ``fused``: "auto" (default) also compiles the whole-pipeline fused
    variant and negotiates its equivalence class against the staged trace
    (the executor only substitutes it when bit-identical); ``True`` forces
    the fused path regardless of class; ``False`` skips it.
    ``fused_stages``: an alternate stage list for the fused trace (e.g.
    MIMONet's unbind+classify collapsed into the fused kernel) — requires
    ``input_specs`` so the output spec can be proven equal to the staged
    pipeline's.
    """
    stages = tuple(stages)
    if not stages:
        raise ValueError("schedule needs at least one stage")
    names = [s.name for s in stages]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate stage names: {names}")
    batch_buckets = tuple(batch_buckets)
    if batch_buckets:
        if list(batch_buckets) != sorted(set(batch_buckets)) \
                or batch_buckets[0] < 1:
            raise ValueError(f"batch_buckets must be ascending positive "
                             f"sizes, got {batch_buckets}")
    if fused not in (True, False, "auto"):
        raise ValueError(f"fused must be True, False or 'auto', got {fused!r}")
    if fused_stages is not None and input_specs is None:
        raise ValueError(
            f"{workload}/{variant}: an alternate fused stage list needs "
            "input_specs to prove its output spec matches the staged "
            "pipeline's")
    if plan is None:
        plan = registry.get_plan()
    stages = tuple(dataclasses.replace(s, fn=_plan_scoped(s.fn, plan))
                   for s in stages)
    fused_specs = stages
    if fused_stages is not None:
        fused_specs = tuple(dataclasses.replace(s, fn=_plan_scoped(s.fn, plan))
                            for s in fused_stages)

    buffers: tuple[BufferSpec, ...] = ()
    stage_costs: tuple[dict, ...] = ()
    df: dfl.DataflowGraph | None = None
    source = "declared"
    staged_sel: list = []
    staged_out = None
    if input_specs is not None:
        bufs = [BufferSpec.from_tree(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), input_specs))]
        costs = []
        spec = input_specs
        # record which kernel lowerings the staged trace selects — the
        # fused trace below is diffed against this set (selections happen
        # in the wrappers' python dispatch layer, so abstract tracing
        # exercises exactly the lowerings that will serve)
        with registry.record_selections() as staged_sel:
            for s in stages:
                spec = jax.eval_shape(s.fn, consts, spec)
                bufs.append(BufferSpec.from_tree(spec))
                if trace_graph:
                    costs.append(_graph_stats(trace_mod.extract(
                        s.fn, consts, bufs[-2].shapes)))
        staged_out = spec
        buffers = tuple(bufs)
        stage_costs = tuple(costs)
        if graph is not None:
            df = dfl.build(graph)
        elif trace_graph:
            df = trace_pipeline(stages, consts, input_specs)
            source = "trace"
    elif graph is not None:
        df = dfl.build(graph)

    # -- fused whole-pipeline variant --------------------------------------
    jit_fused = None
    fused_equivalence: str | None = None
    fused_eps = 0.0
    fused_diff: tuple[str, ...] = ()
    if fused:
        composed = compose_stages(fused_specs)
        if input_specs is not None:
            with registry.record_selections() as fused_sel:
                fused_out = jax.eval_shape(composed, consts, input_specs)
            fo_l, fo_t = jax.tree.flatten(fused_out)
            st_l, st_t = jax.tree.flatten(staged_out)
            if fo_t != st_t or any(
                    a.shape != b.shape or a.dtype != b.dtype
                    for a, b in zip(fo_l, st_l)):
                raise ValueError(
                    f"{workload}/{variant}: fused pipeline output spec does "
                    f"not match the staged pipeline's")
            fused_equivalence, fused_eps, fused_diff = _fused_conformance(
                staged_sel, fused_sel)
        else:
            # same stage fns composed under the same plan: trivially exact
            fused_equivalence, fused_eps = "exact", 0.0
        # donate the staged input buffer so XLA reuses it for the
        # inter-stage intermediates (CPU does not implement donation)
        donate = (1,) if plan.platform != "cpu" else ()
        jit_fused = jax.jit(composed, donate_argnums=donate)

    return StagedSchedule(
        workload=workload, variant=variant, stages=stages,
        jit_stages=tuple(jax.jit(s.fn) for s in stages),
        ingest=ingest, collect=collect, buffers=buffers,
        stage_costs=stage_costs, graph=df, source=source,
        batch_buckets=batch_buckets,
        input_specs=_abstract(input_specs) if input_specs is not None
        else None,
        consts_spec=_abstract(consts) if input_specs is not None else None,
        plan=plan,
        jit_fused=jit_fused, fused_stages=fused_specs,
        fused_forced=fused is True, fused_equivalence=fused_equivalence,
        fused_epsilon=fused_eps, fused_lowering_diff=fused_diff)


def _ensure_stage_costs(schedule: StagedSchedule):
    """Lazily trace per-stage costs (+ the composed-pipeline graph) for
    schedules compiled with ``input_specs`` but ``trace_graph=False``;
    memoized on the schedule."""
    if schedule.stage_costs or schedule.input_specs is None:
        return
    costs = []
    spec = schedule.input_specs
    for s in schedule.stages:
        costs.append(_graph_stats(
            trace_mod.extract(s.fn, schedule.consts_spec, spec)))
        spec = jax.eval_shape(s.fn, schedule.consts_spec, spec)
    schedule.stage_costs = tuple(costs)
    if schedule.graph is None:
        schedule.graph = trace_pipeline(schedule.stages, schedule.consts_spec,
                                        schedule.input_specs)
        schedule.source = "trace"


def ensure_graph(schedule: StagedSchedule) -> dfl.DataflowGraph:
    """The schedule's :class:`DataflowGraph`, tracing lazily (memoized) for
    schedules compiled with ``trace_graph=False`` — this is what
    ``repro.serve.deploy`` hands to ``core.dse.explore`` to derive the
    serving configuration from the workload's dataflow dependencies."""
    if schedule.graph is None:
        _ensure_stage_costs(schedule)
    if schedule.graph is None:
        raise ValueError(
            f"{schedule.workload}/{schedule.variant}: schedule was compiled "
            "without input_specs — no graph to trace")
    return schedule.graph


def predicted_overlap(schedule: StagedSchedule, n_batches: int = 2) -> dict:
    """Analytical overlap prediction for the compiled schedule.

    Splits the traced per-stage costs into the NN-stream prefix vs the
    symbolic tail and runs ``core.dataflow.interloop_overlap`` — the same
    step-③ model the DSE uses — so benchmarks can print predicted next to
    measured speedups.  Works on ``trace_graph=False`` schedules too:
    stage costs are traced lazily on first use.
    """
    _ensure_stage_costs(schedule)
    if not schedule.stage_costs:
        raise ValueError("schedule was compiled without input_specs "
                         "(no stage costs to trace)")
    t_nn = sum(sum(c["flops"].values()) for s, c in
               zip(schedule.stages, schedule.stage_costs) if s.stream == "nn")
    t_sy = sum(sum(c["flops"].values()) for s, c in
               zip(schedule.stages, schedule.stage_costs) if s.stream != "nn")
    if schedule.graph is not None:
        return dfl.interloop_overlap(schedule.graph, max(1, t_nn),
                                     max(1, t_sy), n_loops=n_batches)
    stage = max(t_nn, t_sy, 1)
    return {"pipelined": t_nn + (n_batches - 1) * stage + t_sy,
            "sequential": n_batches * (t_nn + t_sy),
            "speedup": (n_batches * (t_nn + t_sy)) /
                       max(1, t_nn + (n_batches - 1) * stage + t_sy),
            "bubble": 0.0}
