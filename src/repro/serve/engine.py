"""Batched serving engine: prompt prefill (scan-decode) + generation loop
with continuous-batching slots.

The NSFlow inter-loop overlap shows up here for the enc-dec arch: the
engine encodes request batch i+1 while decoding batch i (the encoder and
decoder are disjoint weight streams — the paper's Fig. 4 ③ case mapped to
serving).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    eos_id: int | None = None


class Engine:
    """Wraps an arch adapter's decode_step into a batch generation loop."""

    def __init__(self, decode_step: Callable, init_caches: Callable,
                 cfg: ServeConfig):
        self.decode_step = jax.jit(decode_step, donate_argnums=(1,))
        self.init_caches = init_caches
        self.cfg = cfg

        def prefill_scan(params, caches, tokens):
            """Feed the prompt token-by-token (scan) to fill caches."""
            def step(carry, tok_t):
                caches, _ = carry, None
                pos = tok_t[1]
                caches2, logits = decode_step(params, caches, tok_t[0], pos)
                return caches2, logits

            positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
            caches, logits = jax.lax.scan(
                step, caches, (tokens.T, positions))
            return caches, logits[-1]

        self._prefill = jax.jit(prefill_scan, donate_argnums=(1,))

    def generate(self, params, prompts: np.ndarray, batch: int | None = None):
        """prompts: (B, P) int32. Returns (B, max_new_tokens) int32."""
        b, p = prompts.shape
        caches = self.init_caches(b)
        caches, logits = self._prefill(params, caches, jnp.asarray(prompts))
        outs = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = p
        for i in range(self.cfg.max_new_tokens):
            outs.append(tok)
            caches, logits = self.decode_step(params, caches, tok,
                                              jnp.int32(pos))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pos += 1
        return np.stack([np.asarray(o) for o in outs], axis=1)
