"""Slot-based continuous-batching serving engine.

The engine owns a fixed pool of ``max_slots`` KV-cache slots sized for
``max_len`` tokens each. Requests wait in a FIFO queue and are admitted the
moment a slot frees up (continuous batching): admission runs a ragged,
padding-masked prefill for the whole admission group at once, then the decode
loop resumes with every live slot at its own position — the per-slot ``pos``
vector is threaded through ``decode_step`` (see ``nn.attention.decode_step``).

Decode dispatches ``decode_block`` tokens per XLA call via ``jax.lax.scan``
(the seed engine paid one dispatch per token, which on CPU/accelerator alike
is dominated by launch overhead). Inside the scan each slot samples with
temperature / top-k from its own PRNG stream, emits EOS, retires early, and
keeps emitting ``pad_id`` until the block ends; retired slots are refilled
from the queue at the next block boundary.

This is the NSFlow inter-loop overlap story mapped onto serving: admission
(prefill) of waiting requests and decode of resident requests are disjoint
compute streams scheduled back-to-back over one shared slot pool.

The engine implements the unified :class:`~repro.serve.runtime.
EngineProtocol` natively — model parameters are bound at construction, so
callers schedule *traffic*, not model state:

- ``submit(group)`` dispatches one admission group: requests join the FIFO
  queue and free slots are prefilled immediately (the group's
  :class:`~repro.serve.runtime.GroupRecord` gets ``dispatch_t`` stamped at
  the prefill of its first admitted request).
- ``drain_ready()`` advances bounded work — one decode block, with freed
  slots refilled at the boundary — and hands out whatever requests have
  finished (``{uid: Result}``).  The front-door calls it while it would
  otherwise sleep waiting for traffic, which is how decode makes progress
  between arrivals in the single-threaded serve loop.
- ``drain_all()`` runs queue + resident slots to completion.
- ``run(requests)`` is the offline loop over the three calls above
  (admission groups of ``admission_cap``, then drain everything) — token
  streams are byte-identical to serving the same uids online because
  sampling is keyed by (seed, uid, token index), never by slot, admission
  order, or co-residents.

Stats are split so jit warmup cannot pollute throughput numbers: a run that
compiled a new shape (the first decode block, a new padded prefill length)
is accounted under ``stats["warmup"]``, steady-state runs under
``stats["measured"]`` (which ``tokens_per_s()`` reports), with per-run
records in ``engine.runs`` — mirroring ``ReasonEngine``.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import runtime as rt
from repro.serve.runtime import GroupRecord


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32      # default per-request generation budget
    temperature: float = 0.0      # 0 = greedy, > 0 = categorical sampling
    top_k: int | None = None      # restrict sampling to the k best logits
    eos_id: int | None = None     # stop + retire the slot when sampled
    pad_id: int = 0               # emitted by retired slots after EOS
    max_slots: int = 4            # KV slot pool size == decode batch
    max_len: int = 128            # per-slot KV capacity (prompt + new tokens)
    decode_block: int = 8         # tokens fused into one scan dispatch
    prefill_bucket: int = 16      # pad prompt scans to a multiple of this
    # Sampling PRNG: every request gets its own stream derived from
    # (seed, uid), and each token folds in a per-request counter — so the
    # tokens a request samples depend only on (seed, uid, prompt), never on
    # which slot it landed in, which requests are co-resident, or the
    # admission order. Engine.run is therefore submission-order invariant.
    seed: int = 0
    # Positional KV caches (linear and ring-buffer/windowed alike) tolerate
    # ragged padded prefill: per-slot positions are clamped to the prompt
    # length, so pad steps only rewrite the one entry at position plen,
    # which the first decode step overwrites before attending. One bucketed
    # scan therefore serves the whole admission group. Cumulative recurrent
    # state (rwkv wkv, griffin lru/conv) would still be corrupted by the
    # extra pad steps — set True to prefill each distinct prompt length with
    # an exact-length scan instead (more dispatches, state-safe).
    stateful_prefill: bool = False


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int | None = None  # falls back to ServeConfig default
    # traffic class for overload control (see serve.slo.PRIORITIES);
    # the engine ignores it — the front-door sheds and orders by it
    priority: str = "standard"


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray        # generated ids, EOS included when hit
    prompt_len: int
    finished_by_eos: bool
    slot: int                 # which slot served the request


@dataclasses.dataclass
class _Slot:
    request: Request | None = None
    tokens: list = dataclasses.field(default_factory=list)
    budget: int = 0
    served: int = 0           # requests completed by this slot (reuse stat)


def _fresh_stats(max_slots: int) -> dict:
    return {
        "requests": 0, "tokens": 0, "decode_blocks": 0,
        "slot_steps": 0, "active_slot_steps": 0, "prefills": 0,
        "decode_time_s": 0.0, "wall_time_s": 0.0,
        "slots_served": [0] * max_slots,
        # wall-time split: runs that compiled a new shape (first decode
        # block, new padded prefill length) land in "warmup", steady-state
        # runs in "measured" (``work`` == generated tokens for LM traffic)
        **rt.fresh_split_stats(),
    }


class Engine:
    """Continuous-batching generation over an arch adapter's decode_step.

    ``decode_step(params, caches, token (B,), pos (B,)) -> (caches, logits)``
    must accept a per-slot position vector. ``init_caches(batch)`` allocates
    a zeroed cache pytree whose leaves carry a batch axis; for positional KV
    caches its per-slot capacity must be at least ``cfg.max_len`` (the engine
    cannot see the length axis generically — ``configs.base.serve_fns`` takes
    the same ``max_len``, pass one value to both).

    ``params`` is the model's parameter pytree, bound at construction so the
    engine implements the params-free :class:`~repro.serve.runtime.
    EngineProtocol` (``configs.base.lm_engine`` binds it for you).  ``clock``
    is the timestamp source for :class:`~repro.serve.runtime.GroupRecord`
    stamps (the front-door injects its own so queue/service latencies share
    one origin); ``wall`` is the real wall-clock the throughput accounting
    reads — separate so a virtual front-door clock never distorts measured
    rates, injectable so the accounting itself is testable.
    """

    def __init__(self, decode_step: Callable, init_caches: Callable,
                 cfg: ServeConfig, params=None, clock=time.perf_counter,
                 wall=time.perf_counter):
        # configs.base.serve_fns tags init_caches for archs whose cumulative
        # recurrent state would be silently corrupted by bucketed pad steps —
        # honor the tag so no caller has to remember to set the flag
        if getattr(init_caches, "stateful_prefill", False) \
                and not cfg.stateful_prefill:
            cfg = dataclasses.replace(cfg, stateful_prefill=True)
        self.cfg = cfg
        self.init_caches = init_caches
        self.params = params
        self.clock = clock
        self.wall = wall
        self._raw_decode_step = decode_step
        # batch axis per cache leaf: the one axis whose size tracks `batch`
        # (probed at 2 vs 1 so any max_slots >= 1 works)
        big = jax.eval_shape(lambda: init_caches(2))
        small = jax.eval_shape(lambda: init_caches(1))

        def batch_axis(path, a, b):
            for i, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return i
            raise ValueError(
                f"cache leaf {jax.tree_util.keystr(path)} has shape {a.shape} "
                "at any batch size — every leaf needs an axis that tracks the "
                "slot count (shared/global state is unsupported)")

        self._batch_axes = jax.tree_util.tree_map_with_path(batch_axis,
                                                            big, small)

        self._decode_block = jax.jit(self._make_decode_block(),
                                     donate_argnums=(1,))
        self._prefill = jax.jit(self._make_prefill(), donate_argnums=(1,))
        # donating the pool lets XLA update admitted rows in place instead of
        # copying the whole KV pool per admission (leaves whose batch axis is
        # not leading may still warn as non-donatable; that's benign)
        self._merge = jax.jit(self._make_merge(), donate_argnums=(0,))
        self._sample_jit = jax.jit(self._sample)
        self.stats = _fresh_stats(cfg.max_slots)
        self.runs: list[dict] = []    # per-run records from run()
        # protocol state: FIFO queue, lazily-allocated slot pool, finished
        # results awaiting a drain call, and open (undrained) group records
        self._queue: collections.deque = collections.deque()
        self._slots = [_Slot() for _ in range(cfg.max_slots)]
        self._caches = None           # allocated on first submit
        self._state: dict | None = None
        self._ready: dict[int, Result] = {}
        self._resident: set[int] = set()   # queued or slot-resident uids
        self._open: list[GroupRecord] = []
        self._rec_left: dict[int, int] = {}    # rec.index -> unfinished uids
        self._uid_rec: dict[int, GroupRecord] = {}
        self._next_index = 0
        self._warmed: set = set()     # compiled shapes (prefill len, decode)
        self._cold_run = False

    # -- device-side pieces -------------------------------------------------

    def _sample(self, logits: jax.Array, keys: jax.Array) -> jax.Array:
        """Greedy when temperature == 0, else per-slot top-k categorical.

        ``keys``: (B, 2) uint32 — one PRNG key per slot, already folded with
        the request's token counter (per-request streams, see ServeConfig).
        """
        cfg = self.cfg
        if cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits.astype(jnp.float32) / cfg.temperature
        if cfg.top_k is not None:
            k = min(cfg.top_k, scaled.shape[-1])
            kth = jax.lax.top_k(scaled, k)[0][..., -1:]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)

    def _request_key(self, uid: int) -> np.ndarray:
        """Per-request PRNG stream root: fold the uid into the engine seed.

        Folded in two 32-bit halves so uids differing anywhere in their low
        64 bits (incl. the sign bit) get distinct streams.
        """
        key = jax.random.PRNGKey(self.cfg.seed)
        key = jax.random.fold_in(key, np.uint32(uid & 0xFFFFFFFF))
        return np.asarray(
            jax.random.fold_in(key, np.uint32((uid >> 32) & 0xFFFFFFFF)))

    def _make_prefill(self):
        """Ragged-prompt prefill: (B, P) right-padded tokens + (B,) lengths.

        Scans the prompt through decode_step to populate a scratch cache.
        Per-slot positions are clamped to the prompt length, so every pad
        step past a slot's length rewrites the single cache entry at
        position ``plen`` — the first decode step (also at ``plen``) then
        overwrites it with real K/V before attending. Unclamped positions
        would march past ``plen`` and, on ring-buffer (sliding-window) KV
        caches, wrap around and clobber real entries whenever the padded
        scan length exceeds the window. Returns (caches, last-real-token
        logits per slot).
        """
        decode_step = self._raw_decode_step

        def prefill(params, caches, tokens, plens):
            def step(caches, inp):
                tok_t, t = inp
                pos = jnp.minimum(t, plens)  # (B,): freeze pad steps at plen
                caches, logits = decode_step(params, caches, tok_t, pos)
                return caches, logits

            positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
            caches, logits = jax.lax.scan(step, caches, (tokens.T, positions))
            # logits: (P, B, V) -> last real prompt token's logits per slot
            idx = jnp.clip(plens - 1, 0, tokens.shape[1] - 1)
            last = jnp.take_along_axis(
                logits, idx[None, :, None], axis=0)[0]
            return caches, last

        return prefill

    def _make_merge(self):
        """Copy admitted slots' rows from scratch caches into the pool."""
        batch_axes = self._batch_axes

        def merge(pool, scratch, admit_mask):
            def one(axis, dst, src):
                shape = [1] * dst.ndim
                shape[axis] = dst.shape[axis]
                return jnp.where(admit_mask.reshape(shape), src, dst)

            return jax.tree.map(one, batch_axes, pool, scratch)

        return merge

    def _make_decode_block(self):
        cfg = self.cfg
        decode_step = self._raw_decode_step
        eos = cfg.eos_id

        def block(params, caches, tok, pos, active, budget, keys, gen):
            def step(carry, _):
                caches, tok, pos, active, budget, gen = carry
                caches, logits = decode_step(params, caches, tok, pos)
                sub = jax.vmap(jax.random.fold_in)(keys, gen)
                nxt = self._sample(logits, sub)
                emit = jnp.where(active, nxt, cfg.pad_id)
                pos = jnp.where(active, pos + 1, pos)
                gen = jnp.where(active, gen + 1, gen)
                budget = jnp.where(active, budget - 1, budget)
                alive = active & (budget > 0) & (pos < cfg.max_len)
                if eos is not None:
                    alive = alive & (emit != eos)
                return (caches, emit, pos, alive, budget, gen), (emit, active)

            carry = (caches, tok, pos, active, budget, gen)
            carry, (toks, valid) = jax.lax.scan(step, carry, None,
                                                length=cfg.decode_block)
            caches, tok, pos, active, budget, gen = carry
            return caches, tok, pos, active, budget, gen, toks, valid

        return block

    # -- host-side scheduling ----------------------------------------------

    def _budget(self, req: Request) -> int:
        cfg = self.cfg
        return (req.max_new_tokens if req.max_new_tokens is not None
                else cfg.max_new_tokens)

    def _validate(self, req: Request):
        plen, budget = len(np.asarray(req.prompt).reshape(-1)), self._budget(req)
        if plen == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if budget < 1:
            raise ValueError(f"request {req.uid}: max_new_tokens must be >= 1")
        if plen + budget > self.cfg.max_len:
            raise ValueError(
                f"request {req.uid}: prompt {plen} + budget {budget} "
                f"exceeds max_len {self.cfg.max_len}")

    def _ensure_pool(self):
        if self._caches is None:
            cfg = self.cfg
            self._caches = self.init_caches(cfg.max_slots)
            self._state = {
                "tok": np.full((cfg.max_slots,), cfg.pad_id, np.int32),
                "pos": np.zeros((cfg.max_slots,), np.int32),
                "active": np.zeros((cfg.max_slots,), bool),
                "budget": np.zeros((cfg.max_slots,), np.int32),
                # per-slot PRNG stream roots (keyed by the resident
                # request's uid) + per-request token counters — see
                # ServeConfig.seed
                "keys": np.zeros((cfg.max_slots, 2), np.uint32),
                "gen": np.zeros((cfg.max_slots,), np.int32),
            }

    def _active(self) -> bool:
        return self._state is not None and bool(self._state["active"].any())

    def _admit(self):
        """Fill free slots from the queue with one ragged batched prefill."""
        cfg = self.cfg
        slots, state = self._slots, self._state
        free = [i for i, s in enumerate(slots) if s.request is None]
        if not free or not self._queue:
            return
        group = []
        while free and self._queue:
            group.append((free.pop(0), self._queue.popleft()))
        for slot_idx, req in group:
            slots[slot_idx].request = req
            slots[slot_idx].tokens = []
            slots[slot_idx].budget = self._budget(req)

        if cfg.stateful_prefill:
            # one exact-length scan per distinct prompt length (state-safe)
            by_len: dict[int, list] = {}
            for slot_idx, req in group:
                by_len.setdefault(len(req.prompt), []).append((slot_idx, req))
            plan = [(items, length) for length, items in sorted(by_len.items())]
        else:
            plen_max = max(len(r.prompt) for _, r in group)
            bucket = cfg.prefill_bucket
            plan = [(group, -(-plen_max // bucket) * bucket)]

        for items, padded in plan:
            shape_key = ("prefill", padded)
            if shape_key not in self._warmed:
                self._warmed.add(shape_key)
                self._cold_run = True
            tokens = np.full((cfg.max_slots, padded), cfg.pad_id, np.int32)
            plens = np.zeros((cfg.max_slots,), np.int32)
            admit = np.zeros((cfg.max_slots,), bool)
            for slot_idx, req in items:
                p = np.asarray(req.prompt, np.int32).reshape(-1)
                tokens[slot_idx, : len(p)] = p
                plens[slot_idx] = len(p)
                admit[slot_idx] = True
                # the group's first work hits the device here
                rec = self._uid_rec.get(req.uid)
                if rec is not None and rec.dispatch_t is None:
                    rec.dispatch_t = self.clock()

            scratch = self.init_caches(cfg.max_slots)
            scratch, last_logits = self._prefill(self.params, scratch,
                                                 jnp.asarray(tokens),
                                                 jnp.asarray(plens))
            self._caches = self._merge(self._caches, scratch,
                                       jnp.asarray(admit))
            self.stats["prefills"] += 1

            # first token: sample from each admitted request's own stream at
            # counter 0 (non-admitted rows are computed but never read)
            for slot_idx, req in items:
                state["keys"][slot_idx] = self._request_key(req.uid)
                state["gen"][slot_idx] = 0
            sub = jax.vmap(jax.random.fold_in)(jnp.asarray(state["keys"]),
                                               jnp.asarray(state["gen"]))
            first = np.asarray(self._sample_jit(last_logits, sub))
            for slot_idx, req in items:
                state["tok"][slot_idx] = first[slot_idx]
                state["pos"][slot_idx] = plens[slot_idx]
                state["active"][slot_idx] = True
                state["budget"][slot_idx] = slots[slot_idx].budget
                state["gen"][slot_idx] = 1
            # a first token can already finish the request (EOS / budget 1)
            for slot_idx, req in items:
                self._push_token(slot_idx, int(first[slot_idx]))

    def _push_token(self, i: int, token: int):
        """Record one generated token; retire the slot when done."""
        cfg = self.cfg
        slot, state = self._slots[i], self._state
        slot.tokens.append(token)
        state["budget"][i] -= 1
        hit_eos = cfg.eos_id is not None and token == cfg.eos_id
        if hit_eos or state["budget"][i] <= 0:
            req = slot.request
            self._ready[req.uid] = Result(
                uid=req.uid, tokens=np.asarray(slot.tokens, np.int32),
                prompt_len=len(req.prompt), finished_by_eos=hit_eos, slot=i)
            self.stats["requests"] += 1
            self.stats["tokens"] += len(slot.tokens)
            self.stats["slots_served"][i] += 1
            slot.served += 1
            slot.request = None
            state["active"][i] = False
            self._resident.discard(req.uid)
            rec = self._uid_rec.pop(req.uid, None)
            if rec is not None:
                self._rec_left[rec.index] -= 1
                if not self._rec_left[rec.index]:
                    del self._rec_left[rec.index]
                    rec.done_t = self.clock()
                    self._open.remove(rec)

    def _decode_once(self):
        """One fused decode block over the resident slots."""
        if "decode" not in self._warmed:
            self._warmed.add("decode")
            self._cold_run = True
        state, slots = self._state, self._slots
        t0 = self.wall()
        (caches, tok, pos, active, budget, gen, toks, valid) = \
            self._decode_block(
                self.params, self._caches, jnp.asarray(state["tok"]),
                jnp.asarray(state["pos"]), jnp.asarray(state["active"]),
                jnp.asarray(state["budget"]), jnp.asarray(state["keys"]),
                jnp.asarray(state["gen"]))
        self._caches = caches
        toks, valid = np.asarray(toks), np.asarray(valid)
        self.stats["decode_time_s"] += self.wall() - t0
        self.stats["decode_blocks"] += 1
        self.stats["slot_steps"] += toks.size
        self.stats["active_slot_steps"] += int(valid.sum())
        state["tok"] = np.array(tok)  # copies: host mirrors stay writable
        state["pos"] = np.array(pos)
        state["gen"] = np.array(gen)
        # replay emissions on the host mirror (handles retirement)
        for k in range(toks.shape[0]):
            for i in np.nonzero(valid[k])[0]:
                if slots[i].request is not None:
                    self._push_token(int(i), int(toks[k, i]))

    def _step(self):
        """One scheduler step: admit waiting requests, decode one block,
        refill freed slots at the boundary."""
        self._admit()
        if self._active():
            self._decode_once()
            self._admit()

    def _take_ready(self) -> dict[int, Result]:
        out, self._ready = self._ready, {}
        return out

    # -- group-level API (the front-door drives these) ----------------------

    @property
    def admission_cap(self) -> int:
        """Largest admission group ``submit`` accepts (the slot pool)."""
        return self.cfg.max_slots

    @property
    def inflight(self) -> int:
        """Dispatched-but-undrained admission groups."""
        return len(self._open)

    @property
    def accepting(self) -> bool:
        """True while ``submit`` would start real work promptly: no
        earlier requests are still queued waiting for slots.  The
        front-door's overload path defers group closes on this signal so
        backlog accumulates in its bounded (sheddable) queue instead of
        the engine's unbounded one."""
        return not self._queue

    def submit(self, group: Sequence[Request]) -> GroupRecord:
        """Dispatch one admission group: enqueue, prefill what fits.

        Requests that don't fit the free slots wait in the FIFO queue and
        are prefilled as slots retire (during ``drain_*`` calls).  The
        returned :class:`GroupRecord` gets ``dispatch_t`` stamped at the
        prefill of the group's first admitted request and ``done_t`` when
        its last request finishes.
        """
        group = list(group)
        if self.params is None:
            raise ValueError(
                "engine has no params bound — pass params= to Engine "
                "(configs.base.lm_engine binds them for you)")
        if not group:
            raise ValueError("empty admission group")
        if len(group) > self.admission_cap:
            raise ValueError(f"admission group of {len(group)} exceeds "
                             f"the {self.admission_cap}-slot pool")
        for req in group:
            self._validate(req)
        uids = [r.uid for r in group]
        dupes = sorted({u for u in uids if uids.count(u) > 1} |
                       {u for u in uids
                        if u in self._resident or u in self._ready})
        if dupes:
            raise ValueError(f"duplicate request uids: {dupes} "
                             "(results are keyed by uid)")
        self._ensure_pool()
        rec = GroupRecord(uids=tuple(uids), index=self._next_index,
                          variant="lm", bucket=self.cfg.max_slots,
                          size=len(group))
        self._next_index += 1
        self._open.append(rec)
        self._rec_left[rec.index] = len(group)
        for req in group:
            self._uid_rec[req.uid] = rec
            self._resident.add(req.uid)
        self._queue.extend(group)
        self._admit()
        return rec

    def drain_ready(self) -> dict[int, Result]:
        """Advance bounded work — one decode block, freed slots refilled —
        and return every finished result ``{uid: Result}``.  The
        front-door calls this while it would otherwise sleep waiting for
        traffic; decode progress between arrivals happens here."""
        if self._queue or self._active():
            self._step()
        return self._take_ready()

    def drain_all(self) -> dict[int, Result]:
        """Serve queue + resident slots to completion (blocking) and
        return all finished results ``{uid: Result}``."""
        while self._queue or self._active():
            self._step()
        return self._take_ready()

    # -- the offline loop ---------------------------------------------------

    def run(self, requests: Iterable[Request]) -> dict[int, Result]:
        """Serve all requests to completion; returns {uid: Result}.

        The offline loop over the group-level protocol: admission groups
        of ``admission_cap`` are submitted (the first fills the slot pool
        with one ragged prefill, the rest queue), then ``drain_all`` runs
        the continuous-batching loop — byte-identical to the pre-protocol
        monolithic loop because admission order and the per-request
        sampling streams are unchanged.

        Appends a per-run record to ``self.runs`` ({requests, tokens,
        wall_time_s, warmup, tokens_per_s}); runs that jit-compiled a new
        shape are flagged ``warmup`` and excluded from the cumulative
        measured stats that ``tokens_per_s()`` reports.
        """
        reqs = list(requests)
        for req in reqs:  # fail fast, before any request is served
            self._validate(req)
        uids = [req.uid for req in reqs]
        if len(set(uids)) != len(uids):
            dupes = sorted({u for u in uids if uids.count(u) > 1})
            raise ValueError(f"duplicate request uids: {dupes} "
                             "(results are keyed by uid)")
        if self._open or self._queue or self._active() or self._ready:
            raise ValueError("engine has undrained in-flight requests "
                             "(call drain_all first)")
        self._cold_run = False
        tok0 = self.stats["tokens"]
        t_start = self.wall()
        cap = self.admission_cap
        for i in range(0, len(reqs), cap):
            self.submit(reqs[i: i + cap])
        results = self.drain_all()
        dt = self.wall() - t_start
        toks = self.stats["tokens"] - tok0
        self.stats["wall_time_s"] += dt
        kind = "warmup" if self._cold_run else "measured"
        self.stats[kind]["requests"] += len(results)
        self.stats[kind]["work"] += toks
        self.stats[kind]["wall_time_s"] += dt
        self.runs.append({
            "requests": len(results), "tokens": toks, "wall_time_s": dt,
            "warmup": self._cold_run,
            "tokens_per_s": toks / dt if dt else 0.0,
        })
        return results

    @property
    def last_run(self) -> dict | None:
        """Per-run stats record of the most recent ``run()``."""
        return self.runs[-1] if self.runs else None

    # -- convenience APIs ---------------------------------------------------

    def generate(self, prompts, max_new_tokens: int | None = None
                 ) -> np.ndarray:
        """Batch API: prompts (B, P) array or list of ragged 1-D arrays.

        Returns (B, max_new_tokens) int32, pad_id-filled after EOS.
        """
        cfg = self.cfg
        budget = max_new_tokens if max_new_tokens is not None \
            else cfg.max_new_tokens
        prompts = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        reqs = [Request(uid=i, prompt=p, max_new_tokens=budget)
                for i, p in enumerate(prompts)]
        results = self.run(reqs)
        out = np.full((len(prompts), budget), cfg.pad_id, np.int32)
        for uid, res in results.items():
            out[uid, : len(res.tokens)] = res.tokens
        return out

    def utilization(self) -> float:
        """Fraction of decode slot-steps spent on live requests."""
        if not self.stats["slot_steps"]:
            return 0.0
        return self.stats["active_slot_steps"] / self.stats["slot_steps"]

    def tokens_per_s(self) -> float:
        """Measured steady-state generation throughput — warmup runs (the
        ones that jit-compiled a new shape) are excluded; falls back to
        the warmup totals when only warmup runs exist (see
        :func:`repro.serve.runtime.measured_rate`)."""
        return rt.measured_rate(self.stats)

    def reset_stats(self):
        """Zero the cumulative stats and per-run records (jit caches and
        the warmed-shape set survive — compilations are not forgotten)."""
        self.stats = _fresh_stats(self.cfg.max_slots)
        self.runs = []


class LockstepEngine:
    """The seed engine: one XLA dispatch per token, greedy, no EOS handling.

    Kept as the benchmark baseline for ``benchmarks/bench_serve.py`` — do not
    use for serving (it predates the runtime protocol and takes params
    explicitly).
    """

    def __init__(self, decode_step: Callable, init_caches: Callable,
                 cfg: ServeConfig):
        self.decode_step = jax.jit(decode_step, donate_argnums=(1,))
        self.init_caches = init_caches
        self.cfg = cfg

        def prefill_scan(params, caches, tokens):
            def step(caches, tok_t):
                caches, logits = decode_step(params, caches, tok_t[0], tok_t[1])
                return caches, logits

            positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
            caches, logits = jax.lax.scan(step, caches, (tokens.T, positions))
            return caches, logits[-1]

        self._prefill = jax.jit(prefill_scan, donate_argnums=(1,))

    def generate(self, params, prompts: np.ndarray) -> np.ndarray:
        """prompts: (B, P) int32 (uniform length). Returns (B, new) int32."""
        b, p = prompts.shape
        caches = self.init_caches(b)
        caches, logits = self._prefill(params, caches, jnp.asarray(prompts))
        outs = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = p
        for _ in range(self.cfg.max_new_tokens):
            outs.append(tok)
            caches, logits = self.decode_step(params, caches, tok,
                                              jnp.int32(pos))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pos += 1
        return np.stack([np.asarray(o) for o in outs], axis=1)
