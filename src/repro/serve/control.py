"""Overload control plane: bounded priority queues + SLO feedback.

`deploy()` derives the front-door's admission parameters (deadline,
bucket cap, in-flight depth) from the DSE once — the right *initial*
operating point, but production traffic is bursty and diurnal, and a
static point either wastes capacity at 3am or melts at the noon burst.
This module closes the loop:

- :class:`ClassQueues` — per-model pending queues, one FIFO per
  priority class (:data:`~repro.serve.slo.PRIORITIES`), with a total
  depth bound and **reject-with-backpressure shedding**: when the bound
  is hit the queue either evicts the newest request of the lowest
  priority class (``lowest-priority`` policy — an interactive arrival
  pushes out queued batch work) or rejects the arrival itself
  (``tail-drop``).  Every shed is a first-class :class:`ShedRecord`,
  never a silent drop.
- :class:`OverloadController` — an AIMD-style feedback loop over the
  front-door knobs, ticked on the serving clock: each ``tick_s`` it
  reads the windowed per-class p99 (:class:`~repro.serve.slo.
  SLOEstimator`) against the targets and adapts the per-model admission
  deadline and bucket cap.  SLO violated → cut the deadline
  (multiplicative decrease) and, if the queue shows sustained backlog,
  step the bucket cap *up* (amortize dispatch overhead: throughput
  mode) — otherwise step it *down* (stop waiting for stragglers:
  latency mode).  Healthy with headroom → relax the deadline back
  (multiplicative increase) and drift the cap toward the DSE point.

Everything here is pure policy over explicit ``now`` timestamps — this
module never reads a clock (no ``time`` import; analyzer rule NSF105
enforces it), so every decision is deterministic under the virtual
clock and the soak bench's two-run bit-identical gate holds.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Mapping

from repro.serve.slo import (DEFAULT_PRIORITY, PRIORITIES, PRIORITY_RANK,
                             SLOEstimator, SLOTarget, validate_priority)

__all__ = [
    "SHED_POLICIES", "ShedRecord", "ClassQueues", "ControlConfig",
    "ControlDecision", "OverloadController", "validate_shed_policy",
    "DEFAULT_PRIORITY",
]

# lowest-priority: a full queue evicts the newest request of the lowest
#   priority class strictly below the arrival (push-out); arrivals at
#   the bottom class shed themselves.
# tail-drop: a full queue always sheds the arriving request.
SHED_POLICIES: tuple[str, ...] = ("lowest-priority", "tail-drop")


def validate_shed_policy(name: str) -> str:
    if name not in SHED_POLICIES:
        raise ValueError(f"unknown shed policy {name!r} "
                         f"(known: {', '.join(SHED_POLICIES)})")
    return name


@dataclasses.dataclass(frozen=True)
class ShedRecord:
    """One rejected request — the backpressure signal, fully accounted.

    ``reason`` is ``queue-full`` (the arrival itself was rejected) or
    ``pushout`` (a queued lower-priority request was evicted to admit a
    higher-priority arrival).  ``arrival_s``/``shed_s`` are seconds on
    the serving clock origin."""

    uid: int
    model: str
    priority: str
    arrival_s: float
    shed_s: float
    reason: str                   # queue-full | pushout


class ClassQueues:
    """Bounded per-priority pending queues for one model.

    ``depth`` bounds the *total* queued requests across classes
    (``None`` = unbounded, the legacy front-door behavior).  ``offer``
    admits or sheds per the policy and returns the :class:`ShedRecord`
    if anything was shed; ``pop`` drains up to ``k`` requests in
    priority order (then FIFO within a class).  A high-water mark
    (``depth_max``) proves boundedness in the soak gate."""

    def __init__(self, depth: int | None = None,
                 policy: str = "lowest-priority"):
        if depth is not None and depth < 1:
            raise ValueError(f"queue depth bound must be >= 1, got {depth}")
        self.depth = depth
        self.policy = validate_shed_policy(policy)
        self._queues: dict[str, deque] = {p: deque() for p in PRIORITIES}
        self.depth_max = 0

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def counts(self) -> dict[str, int]:
        return {p: len(q) for p, q in self._queues.items() if q}

    @property
    def oldest_t(self) -> float | None:
        heads = [q[0].t for q in self._queues.values() if q]
        return min(heads) if heads else None

    def _evict_for(self, rank: int) -> tuple[Any, str] | None:
        """Newest queued item of the lowest class strictly below
        ``rank``, removed (with its class) — None if nothing outranked."""
        for p in reversed(PRIORITIES):
            if PRIORITY_RANK[p] <= rank:
                return None
            q = self._queues[p]
            if q:
                return q.pop(), p
        return None

    def offer(self, item: Any, priority: str, now: float,
              ) -> ShedRecord | None:
        """Enqueue ``item`` (an arrival with ``.t``/``.request.uid``)
        under ``priority``; returns the shed record if the bound forced
        a rejection (the arrival itself, or a lower-priority victim the
        arrival pushed out)."""
        prio = validate_priority(priority)
        shed = None
        if self.depth is not None and len(self) >= self.depth:
            evicted = (self._evict_for(PRIORITY_RANK[prio])
                       if self.policy == "lowest-priority" else None)
            if evicted is None:
                return ShedRecord(
                    uid=item.request.uid, model=item.model, priority=prio,
                    arrival_s=item.t, shed_s=now, reason="queue-full")
            victim, vclass = evicted
            shed = ShedRecord(
                uid=victim.request.uid, model=victim.model,
                priority=vclass, arrival_s=victim.t,
                shed_s=now, reason="pushout")
        self._queues[prio].append(item)
        self.depth_max = max(self.depth_max, len(self))
        return shed

    def pop(self, k: int) -> list[Any]:
        """Drain up to ``k`` items, priority order then FIFO."""
        out: list[Any] = []
        for p in PRIORITIES:
            q = self._queues[p]
            while q and len(out) < k:
                out.append(q.popleft())
        return out


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """Feedback-loop tuning.  Defaults are deliberately gentle: halve
    the deadline on violation, relax it back 1.25x per healthy tick,
    only call the window healthy below 70% of target."""

    tick_s: float = 0.05          # controller period on the serving clock
    window: int = 128             # SLOEstimator window per (model, class)
    min_obs: int = 8              # ignore classes with fewer completions
    headroom: float = 0.7         # p99 <= headroom*target counts healthy
    decrease: float = 0.5         # deadline multiplier on SLO violation
    increase: float = 1.25        # deadline multiplier when healthy
    min_deadline_s: float = 1e-3
    max_deadline_s: float = 0.2
    queue_depth: int | None = None   # per-model pending bound (None = off)
    shed_policy: str = "lowest-priority"
    adapt: bool = True            # False = observe/shed only, fixed knobs

    def __post_init__(self):
        if self.tick_s <= 0:
            raise ValueError(f"tick_s must be > 0, got {self.tick_s}")
        if not 0.0 < self.decrease < 1.0:
            raise ValueError(f"decrease must be in (0, 1), "
                             f"got {self.decrease}")
        if self.increase <= 1.0:
            raise ValueError(f"increase must be > 1, got {self.increase}")
        if not 0.0 < self.headroom <= 1.0:
            raise ValueError(f"headroom must be in (0, 1], "
                             f"got {self.headroom}")
        if not 0 < self.min_deadline_s <= self.max_deadline_s:
            raise ValueError(
                f"need 0 < min_deadline_s <= max_deadline_s, got "
                f"{self.min_deadline_s}..{self.max_deadline_s}")
        if self.queue_depth is not None and self.queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1 or None, "
                             f"got {self.queue_depth}")
        validate_shed_policy(self.shed_policy)


@dataclasses.dataclass(frozen=True)
class ControlDecision:
    """One non-hold controller action, for the report/soak artifact."""

    t: float
    model: str
    action: str                   # tighten | throughput | relax
    deadline_s: float             # new operating point after the action
    cap: int
    p99_ms: float                 # pooled windowed p99 at decision time
    queue_depth: int
    inflight: int


class _Operating:
    """Mutable per-model operating point (not a dataclass: the analyzer
    treats frozen config types as immutable policy, this is state)."""

    __slots__ = ("deadline_s", "cap", "buckets", "deadline0", "cap0")

    def __init__(self, deadline_s: float, cap: int,
                 buckets: tuple[int, ...]):
        self.deadline_s = deadline_s
        self.cap = cap
        self.buckets = buckets
        self.deadline0 = deadline_s
        self.cap0 = cap


class OverloadController:
    """SLO feedback over the front-door's per-model admission knobs.

    Bind each model to its DSE-derived initial operating point
    (``bind``), feed completed-request latencies (``observe``) and tick
    on the serving clock (``maybe_tick``).  ``deadline_s(model)`` /
    ``cap(model)`` are the live knobs the front-door reads each loop.
    """

    def __init__(self, targets: Mapping[str, SLOTarget] | None = None,
                 cfg: ControlConfig | None = None):
        self.cfg = cfg or ControlConfig()
        self.targets = dict(targets or {})
        self.estimator = SLOEstimator(self.targets, window=self.cfg.window)
        self.decisions: list[ControlDecision] = []
        self.ticks = 0
        self._op: dict[str, _Operating] = {}
        self._next_tick: float | None = None

    # -- operating points ---------------------------------------------

    def bind(self, model: str, deadline_s: float, cap: int,
             buckets: tuple[int, ...] | None = None) -> None:
        """Set ``model``'s initial operating point (idempotent: a model
        already bound keeps its live state)."""
        if model in self._op:
            return
        if cap < 1:
            raise ValueError(f"cap must be >= 1, got {cap}")
        chain = tuple(sorted(set(buckets or ()) | {cap}))
        chain = tuple(b for b in chain if b <= cap) or (cap,)
        dl = min(max(deadline_s, self.cfg.min_deadline_s),
                 self.cfg.max_deadline_s)
        self._op[model] = _Operating(dl, cap, chain)

    def bound(self) -> set[str]:
        return set(self._op)

    def deadline_s(self, model: str) -> float:
        return self._op[model].deadline_s

    def cap(self, model: str) -> int:
        return self._op[model].cap

    def queues(self, model: str) -> ClassQueues:
        """A bounded pending-queue set per this controller's policy."""
        return ClassQueues(depth=self.cfg.queue_depth,
                           policy=self.cfg.shed_policy)

    # -- feedback ------------------------------------------------------

    def observe(self, model: str, priority: str, total_s: float,
                now: float) -> None:
        self.estimator.observe(model, priority, total_s, now)

    def maybe_tick(self, now: float, obs: Mapping[str, Mapping[str, Any]],
                   ) -> list[ControlDecision]:
        """Run one control tick if ``tick_s`` elapsed since the last.
        ``obs`` maps model -> {queue_depth, inflight} (pool-merged, see
        :func:`repro.serve.runtime.engine_observation`)."""
        if self._next_tick is None:
            self._next_tick = now + self.cfg.tick_s
            return []
        if now < self._next_tick:
            return []
        # fixed cadence (not now + tick_s): ticks stay phase-locked to
        # the serving clock regardless of loop jitter, which keeps the
        # decision trace bit-identical across runs
        while self._next_tick <= now:
            self._next_tick += self.cfg.tick_s
        return self.tick(now, obs)

    def tick(self, now: float, obs: Mapping[str, Mapping[str, Any]],
             ) -> list[ControlDecision]:
        self.ticks += 1
        out: list[ControlDecision] = []
        if not self.cfg.adapt or not self.targets:
            return out
        for model in sorted(self._op):
            op = self._op[model]
            snap = self.estimator.snapshot(model)
            judged = [(row["p99_ms"], row["target_ms"])
                      for row in snap.values()
                      if row["target_ms"] is not None
                      and row["n"] >= self.cfg.min_obs]
            if not judged:
                continue
            violated = any(p99 > tgt for p99, tgt in judged)
            healthy = all(p99 <= self.cfg.headroom * tgt
                          for p99, tgt in judged)
            o = obs.get(model, {})
            qd = int(o.get("queue_depth", 0))
            infl = int(o.get("inflight", 0))
            action = None
            if violated:
                op.deadline_s = max(self.cfg.min_deadline_s,
                                    op.deadline_s * self.cfg.decrease)
                if qd >= op.cap:
                    # sustained backlog: the door is throughput-bound —
                    # bigger groups amortize dispatch overhead
                    op.cap = self._step(op, +1)
                    action = "throughput"
                else:
                    # shallow queue: latency-bound — stop holding groups
                    # open for stragglers
                    op.cap = self._step(op, -1)
                    action = "tighten"
            elif healthy:
                relaxed = min(self.cfg.max_deadline_s,
                              op.deadline_s * self.cfg.increase)
                drifted = (self._step(op, +1) if op.cap < op.cap0
                           else self._step(op, -1) if op.cap > op.cap0
                           else op.cap)
                if relaxed != op.deadline_s or drifted != op.cap:
                    op.deadline_s, op.cap = relaxed, drifted
                    action = "relax"
            if action is not None:
                out.append(ControlDecision(
                    t=now, model=model, action=action,
                    deadline_s=op.deadline_s, cap=op.cap,
                    p99_ms=self.estimator.p99_ms(model),
                    queue_depth=qd, inflight=infl))
        self.decisions.extend(out)
        return out

    @staticmethod
    def _step(op: _Operating, direction: int) -> int:
        chain = op.buckets
        i = chain.index(op.cap) if op.cap in chain else 0
        return chain[min(max(i + direction, 0), len(chain) - 1)]
