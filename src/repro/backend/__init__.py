"""Backend negotiation: one lowering registry from kernels to ``deploy()``.

``repro.backend.registry`` owns every compute-path decision the stack
makes — which lowering (compiled Pallas, Pallas interpret, XLA reference)
serves each heterogeneous kernel on the current platform.  Kernel wrappers
consult the *active* :class:`~repro.backend.registry.LoweringPlan` instead
of private platform tests; ``repro.serve.deploy`` negotiates a plan once
per deployment and records it; ``REPRO_BACKEND`` forces fallbacks for
graceful-degradation runs.
"""

from repro.backend.registry import (KERNELS, KernelSpec, Lowering,
                                    LoweringPlan, active, get_plan,
                                    negotiate, replay_tolerance, use_plan)

__all__ = [
    "KERNELS", "KernelSpec", "Lowering", "LoweringPlan", "active",
    "get_plan", "negotiate", "replay_tolerance", "use_plan",
]
