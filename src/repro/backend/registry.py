"""The lowering registry: per-kernel backend negotiation as a first layer.

NSFlow's versatility claim (paper Sec III) is that one framework picks the
right compute path *per heterogeneous kernel* and stays correct while doing
it.  Before this module the reproduction had three lowerings per kernel —
compiled Pallas, Pallas interpret mode, and the exact XLA reference — but
selection was scattered: four private ``_interpret()`` copies in
``kernels/*/ops.py`` (whose ``!= "tpu"`` test silently forced GPUs into
interpret mode), a separate size/pow2 threshold in ``vsa/ops.py``, and no
record anywhere of which path actually served traffic.

This registry makes lowering selection one explicit layer:

- every kernel (``circ_conv``, ``qmatmul``, ``simd_fused``,
  ``flash_attn`` — plus the VSA gather reference, registered as
  ``circ_conv``'s ``xla`` lowering) declares its :class:`Lowering`\\ s with
  capability predicates: which platforms may negotiate them, pow2 / size
  constraints, and an **equivalence class** versus the kernel's exact XLA
  reference (``exact`` = bit-identical, ``epsilon`` = within a declared
  tolerance — what trace replay diffs against, see ``serve.trace``);
- :func:`negotiate` probes the runtime platform (``jax.default_backend()``)
  and returns an explicit :class:`LoweringPlan` — a per-kernel *fallback
  chain* whose head is the preferred lowering and whose tail always ends in
  the universally-feasible ``xla`` reference;
- the plan is overridable via ``REPRO_BACKEND`` (``xla`` | ``interpret`` |
  ``pallas``, or per-kernel ``circ_conv=xla,qmatmul=pallas``) for
  forced-fallback / graceful-degradation runs;
- kernel wrappers call :func:`active` at trace time with their call-site
  capabilities (block dim ``d``), and the plan picks the first feasible
  lowering in the chain — so a non-pow2 ``d`` degrades past the compiled
  Pallas lowering (whose Mosaic tiling is only validated on pow2 block
  dims >= 8) instead of crashing the circulant builder; the interpreter
  serves any shape, bit-for-bit with the kernel semantics.

``serve.schedule.compile_schedule`` scopes every compiled stage to a plan
(the plan active while the stage's jaxpr is traced is the plan that serves
it), ``serve.deploy.deploy()`` negotiates once per deployment and records
the per-kernel tags in ``Deployment.report()``, and ``serve.trace`` replays
recorded traffic under arbitrary plans, diffing by the equivalence class
declared here.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Iterator, Mapping

PLATFORMS = ("cpu", "gpu", "tpu")
ENV_VAR = "REPRO_BACKEND"


def _is_pow2(d: int) -> bool:
    return d > 0 and (d & (d - 1)) == 0


@dataclasses.dataclass(frozen=True)
class Lowering:
    """One registered compute path for a kernel.

    ``name`` is the lowering tag recorded in plans, bench rows and traces:
    ``pallas`` (compiled Pallas kernel), ``interpret`` (same kernel under
    the Pallas interpreter — the CPU correctness path), or ``xla`` (the
    pure-jnp reference, the oracle every other lowering conforms to).

    Capability predicates: ``platforms`` gates *negotiation* (which
    platforms may prefer this lowering); ``requires_pow2`` / ``min_size``
    gate *call sites* (the Pallas circulant builder needs a power-of-two
    block dim).  ``equivalence`` declares the conformance class versus the
    kernel's ``xla`` reference: ``exact`` means bit-identical outputs,
    ``epsilon`` means agreement within ``epsilon`` — the tolerance
    golden-trace replay applies when two plans route a kernel differently.
    """

    kernel: str
    name: str                      # pallas | interpret | xla
    platforms: tuple[str, ...]     # where negotiate() may prefer this
    interpret: bool = False        # Pallas interpreter flag (xla: unused)
    equivalence: str = "exact"     # exact | epsilon (vs the xla reference)
    epsilon: float = 0.0
    requires_pow2: bool = False    # last-dim must be a power of two
    min_size: int = 0              # minimum last-dim size (0 = none)
    note: str = ""

    def __post_init__(self):
        if self.equivalence not in ("exact", "epsilon"):
            raise ValueError(f"{self.kernel}/{self.name}: equivalence must "
                             f"be 'exact' or 'epsilon'")
        if self.equivalence == "epsilon" and self.epsilon <= 0:
            raise ValueError(f"{self.kernel}/{self.name}: epsilon class "
                             "needs epsilon > 0")

    @property
    def is_ref(self) -> bool:
        """True for the XLA reference path (no Pallas kernel involved)."""
        return self.name == "xla"

    def feasible(self, *, size: int | None = None) -> bool:
        """Call-site capability check (shape constraints only)."""
        if self.requires_pow2 or self.min_size:
            if size is None:
                return False
            if self.requires_pow2 and not _is_pow2(size):
                return False
            if size < self.min_size:
                return False
        return True


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Registry entry: a kernel and its lowerings in preference order.

    ``dispatch_min_size`` is the perf threshold historically buried in
    ``vsa/ops.py``: below it the XLA reference beats the kernel on every
    platform, so *dispatch-level* selection (``vsa.bind`` /
    ``vsa.match_prob``) prefers the reference for small block dims even
    when the kernel is feasible.  Kernel-level wrappers ignore it (callers
    who reached ``kernels/*/ops.py`` asked for the kernel).
    """

    name: str
    describe: str
    lowerings: tuple[Lowering, ...]
    dispatch_min_size: int = 0

    def __post_init__(self):
        names = [l.name for l in self.lowerings]
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate lowering names {names}")
        if "xla" not in names:
            raise ValueError(f"{self.name}: needs an 'xla' reference "
                             "lowering as the universal fallback")

    def by_name(self, name: str) -> Lowering:
        for l in self.lowerings:
            if l.name == name:
                return l
        raise KeyError(f"kernel {self.name!r} has no lowering {name!r}; "
                       f"registered: {[l.name for l in self.lowerings]}")


def _pallas_family(kernel: str, *, epsilon: float, requires_pow2=False,
                   min_size=0, note="") -> tuple[Lowering, Lowering]:
    """The compiled/interpret pair every Pallas kernel registers: compiled
    on accelerators (TPU *and* GPU — the old ``!= "tpu"`` test wrongly
    forced GPUs into the interpreter), interpret mode on CPU.

    Shape constraints (``requires_pow2`` / ``min_size``) gate only the
    *compiled* lowering: Mosaic's tiling for these kernels is validated on
    pow2 block dims, so off-shape call sites degrade to the reference on
    accelerators.  The interpreter executes the same kernel semantics in
    plain XLA and is conformant at any shape — the registry's earlier
    claim that the circulant builder itself needs pow2 was disproven by
    the kernel-vs-registry consistency check (NSF006): interpret output is
    bit-identical to the gather reference at non-pow2 / small block dims.
    """
    return (
        Lowering(kernel=kernel, name="pallas", platforms=("tpu", "gpu"),
                 interpret=False, equivalence="epsilon", epsilon=epsilon,
                 requires_pow2=requires_pow2, min_size=min_size, note=note),
        Lowering(kernel=kernel, name="interpret", platforms=("cpu",),
                 interpret=True, equivalence="epsilon", epsilon=epsilon,
                 note="Pallas interpreter (CPU correctness path; any shape)"),
    )


KERNELS: dict[str, KernelSpec] = {
    "circ_conv": KernelSpec(
        name="circ_conv",
        describe="blockwise circular conv/corr (VSA bind/unbind) via the "
                 "circulant-matmul Pallas kernel",
        lowerings=_pallas_family(
            "circ_conv", epsilon=1e-3, requires_pow2=True, min_size=8,
            note="Mosaic tiling validated on pow2 block dims >= 8") + (
            Lowering(kernel="circ_conv", name="xla", platforms=PLATFORMS,
                     note="exact gather reference (vsa.ops.circ_conv_ref)"),
        ),
        dispatch_min_size=128),
    "qmatmul": KernelSpec(
        name="qmatmul",
        describe="quantized int8/packed-int4 matmul (mixed-precision "
                 "attribute heads)",
        lowerings=_pallas_family("qmatmul", epsilon=1e-3) + (
            Lowering(kernel="qmatmul", name="xla", platforms=PLATFORMS,
                     note="integer-exact reference (qmatmul_ref)"),
        )),
    "simd_fused": KernelSpec(
        name="simd_fused",
        describe="fused normalize/dot/softmax match_prob (the SIMD unit)",
        lowerings=_pallas_family("simd_fused", epsilon=1e-3) + (
            Lowering(kernel="simd_fused", name="xla", platforms=PLATFORMS,
                     note="similarity_matrix + softmax reference"),
        ),
        dispatch_min_size=128),
    "flash_attn": KernelSpec(
        name="flash_attn",
        describe="flash attention over (B, S, H, hd) with GQA",
        lowerings=_pallas_family("flash_attn", epsilon=3e-2) + (
            Lowering(kernel="flash_attn", name="xla", platforms=PLATFORMS,
                     note="materialized-scores reference"),
        )),
    "unbind_classify": KernelSpec(
        name="unbind_classify",
        describe="fused VSA unbind (circular correlation) -> dense classify "
                 "head; one launch for the symbolic tail of the pipeline",
        lowerings=_pallas_family(
            "unbind_classify", epsilon=1e-3, requires_pow2=True, min_size=8,
            note="Mosaic tiling validated on pow2 block dims >= 8") + (
            Lowering(kernel="unbind_classify", name="xla", platforms=PLATFORMS,
                     note="exact gather unbind + dense reference"),
        ),
        dispatch_min_size=128),
}


@dataclasses.dataclass(frozen=True)
class LoweringPlan:
    """An explicit, negotiated per-kernel lowering assignment.

    ``chains[kernel]`` is the fallback chain for that kernel, preference
    first; the last entry is always feasible (the ``xla`` reference).
    ``select`` resolves a call site against the chain; ``tags()`` is the
    per-kernel headline choice — what deployments record and traces diff.
    """

    platform: str
    chains: Mapping[str, tuple[Lowering, ...]]
    source: str = "negotiated"     # negotiated | env:... | override:...

    def select(self, kernel: str, *, size: int | None = None,
               dispatch: bool = False) -> Lowering:
        """First feasible lowering in ``kernel``'s chain for this call.

        ``dispatch=True`` additionally applies the kernel's
        ``dispatch_min_size`` perf threshold (the ``vsa.bind`` /
        ``vsa.match_prob`` level of selection); kernel-level wrappers call
        without it.
        """
        spec = KERNELS.get(kernel)
        if spec is None:
            raise KeyError(f"unknown kernel {kernel!r}; "
                           f"registered: {tuple(KERNELS)}")
        floor = spec.dispatch_min_size if dispatch else 0
        for low in self.chains[kernel]:
            if not low.feasible(size=size):
                continue
            if floor and not low.is_ref and (size is None or size < floor):
                continue
            for rec in _RECORDERS:
                rec.append((kernel, low.name))
            return low
        raise RuntimeError(f"{kernel}: no feasible lowering for size={size} "
                           f"in chain {[l.name for l in self.chains[kernel]]}")

    def lowering(self, kernel: str) -> Lowering:
        """The headline (preferred) lowering for ``kernel``."""
        return self.chains[kernel][0]

    def run_interpret(self, low: Lowering) -> bool:
        """The Pallas ``interpret=`` flag to execute ``low`` with *here*.

        A forced override can put a compiled-Pallas lowering on a CPU host
        (e.g. ``REPRO_BACKEND=pallas`` in CI): Mosaic cannot compile for
        CPU, so execution degrades to the interpreter while the plan keeps
        the forced tag — graceful degradation, not a crash.
        """
        return low.interpret or self.platform == "cpu"

    def tags(self) -> dict[str, str]:
        """Per-kernel headline lowering names, e.g. {'circ_conv': 'xla'}."""
        return {k: chain[0].name for k, chain in self.chains.items()}

    def tag(self) -> str:
        """Compact one-token plan tag for bench rows / summaries:
        ``cpu/interpret`` when every kernel agrees, else
        ``cpu/circ_conv:xla+qmatmul:interpret+...``."""
        tags = self.tags()
        if len(set(tags.values())) == 1:
            return f"{self.platform}/{next(iter(tags.values()))}"
        return self.platform + "/" + "+".join(
            f"{k}:{v}" for k, v in sorted(tags.items()))


def _parse_override(spec: str) -> dict[str, str]:
    """``"xla"`` -> {'*': 'xla'}; ``"circ_conv=xla,qmatmul=pallas"`` ->
    per-kernel map.  Unknown kernels / lowerings raise with the choices."""
    forced: dict[str, str] = {}
    for part in (p.strip() for p in spec.split(",") if p.strip()):
        if "=" in part:
            kernel, _, name = part.partition("=")
            kernel, name = kernel.strip(), name.strip()
            if kernel not in KERNELS:
                raise ValueError(
                    f"{ENV_VAR}: unknown kernel {kernel!r} "
                    f"(registered: {tuple(KERNELS)})")
            KERNELS[kernel].by_name(name)  # validates the lowering name
            forced[kernel] = name
        else:
            for spec_ in KERNELS.values():
                spec_.by_name(part)  # every kernel must register the name
            forced["*"] = part
    return forced


def negotiate(platform: str | None = None,
              override: str | None = None) -> LoweringPlan:
    """Probe the runtime and return an explicit :class:`LoweringPlan`.

    ``platform``: ``cpu`` | ``gpu`` | ``tpu`` (None = probe
    ``jax.default_backend()``).  ``override``: a ``REPRO_BACKEND``-style
    spec forcing lowerings (None = read the env var; "" = no override).
    Forced lowerings skip the platform predicate (that is the point of a
    forced-fallback run) but keep the ``xla`` reference as the terminal
    fallback for call sites the forced lowering cannot serve (non-pow2
    block dims).  Unknown platforms negotiate the all-``xla`` plan —
    graceful degradation on backends no Pallas lowering claims.
    """
    if platform is None:
        import jax

        platform = jax.default_backend()
    source = "negotiated"
    if override is None:
        override = os.environ.get(ENV_VAR, "")
        if override:
            source = f"env:{override}"
    elif override:
        source = f"override:{override}"
    forced = _parse_override(override) if override else {}

    chains: dict[str, tuple[Lowering, ...]] = {}
    for kname, spec in KERNELS.items():
        ref = spec.by_name("xla")
        force = forced.get(kname, forced.get("*"))
        if force is not None:
            head = spec.by_name(force)
            chain = (head,) if head is ref else (head, ref)
        else:
            chain = tuple(l for l in spec.lowerings
                          if platform in l.platforms and l is not ref)
            chain = chain + (ref,)
        chains[kname] = chain
    return LoweringPlan(platform=platform, chains=chains, source=source)


# ---------------------------------------------------------------------------
# the active plan (what kernel wrappers consult at trace time)
# ---------------------------------------------------------------------------

_STACK: list[LoweringPlan] = []
_DEFAULT: list[LoweringPlan | None] = [None]
_RECORDERS: list[list] = []


@contextlib.contextmanager
def record_selections() -> Iterator[list]:
    """Capture every ``(kernel, lowering_name)`` pair any plan's ``select``
    resolves while the scope is open.

    Kernel wrappers consult the plan in their Python dispatch layer (outside
    the inner jits), so tracing a stage under ``jax.eval_shape`` exercises
    exactly the selections that will serve it.  ``serve.schedule`` records
    the staged and fused traces separately and diffs the two sets to decide
    whether the fused pipeline is bit-equal to the staged one (identical
    selections, or diffs confined to ``exact`` lowerings) or only
    epsilon-equivalent — the negotiation behind ``StagedSchedule.fused_ok``.
    """
    rec: list = []
    _RECORDERS.append(rec)
    try:
        yield rec
    finally:
        _RECORDERS.remove(rec)


def get_plan() -> LoweringPlan:
    """The active plan: innermost :func:`use_plan` scope, else the
    process-default plan (negotiated lazily once; re-negotiated whenever
    ``REPRO_BACKEND`` changes so env-forced subprocess runs just work)."""
    if _STACK:
        return _STACK[-1]
    env = os.environ.get(ENV_VAR, "")
    cached = _DEFAULT[0]
    if cached is None or (env and cached.source != f"env:{env}") \
            or (not env and cached.source.startswith("env:")):
        _DEFAULT[0] = negotiate()
    return _DEFAULT[0]


@contextlib.contextmanager
def use_plan(plan: LoweringPlan) -> Iterator[LoweringPlan]:
    """Scope the active plan — ``serve.schedule`` wraps every compiled
    stage in this so each schedule's jaxprs trace under its own plan."""
    _STACK.append(plan)
    try:
        yield plan
    finally:
        _STACK.pop()


def active(kernel: str, *, size: int | None = None,
           dispatch: bool = False) -> Lowering:
    """``get_plan().select(...)`` — the one call every kernel wrapper makes."""
    return get_plan().select(kernel, size=size, dispatch=dispatch)


def replay_tolerance(recorded: Mapping[str, str],
                     replayed: Mapping[str, str]) -> float:
    """Numeric tolerance for diffing traffic served under two plans.

    0.0 when every kernel kept its lowering (the plans are equivalent —
    replay must be **bit-exact**); otherwise the max declared ``epsilon``
    over the kernels whose lowering changed (each side's class counts:
    swapping ``interpret`` for ``xla`` diffs at ``interpret``'s epsilon).
    Kernels absent from either map are treated as unchanged.
    """
    tol = 0.0
    for kernel, new in replayed.items():
        old = recorded.get(kernel, new)
        if old == new:
            continue
        spec = KERNELS[kernel]
        tol = max(tol, spec.by_name(old).epsilon, spec.by_name(new).epsilon)
    return tol
