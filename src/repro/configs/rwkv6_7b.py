"""rwkv6-7b (Finch) — 32L d4096 attn-free, d_ff 14336 vocab 65536."""
from repro.configs.base import ArchSpec
from repro.models.rwkv6 import RWKVConfig


def full() -> RWKVConfig:
    return RWKVConfig(name="rwkv6-7b", n_layers=32, d_model=4096,
                      d_ff=14336, vocab=65536, head_dim=64, chunk=64)


def smoke() -> RWKVConfig:
    return RWKVConfig(name="rwkv6-smoke", n_layers=2, d_model=64, d_ff=128,
                      vocab=256, head_dim=16, chunk=8, remat=False)


ARCH = ArchSpec(
    id="rwkv6-7b", family="ssm", kind="rwkv",
    make_full=full, make_smoke=smoke, supports_long=True,
    note="Strongest NSFlow analogue in the LM pool: memory-bound WKV "
         "recurrence stream vs MXU channel-mix stream (DESIGN.md §4). "
         "O(1)-state decode -> long_500k runs.",
    source="arXiv:2404.05892",
)
