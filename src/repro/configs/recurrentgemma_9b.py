"""recurrentgemma-9b — 38L d4096 RG-LRU + local attn (1:2), kv=1, w=2048."""
from repro.configs.base import ArchSpec
from repro.models.griffin import GriffinConfig


def full() -> GriffinConfig:
    return GriffinConfig(name="recurrentgemma-9b", n_layers=38, d_model=4096,
                         n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256000,
                         window=2048, lru_width=4096)


def smoke() -> GriffinConfig:
    return GriffinConfig(name="recurrentgemma-smoke", n_layers=3, d_model=64,
                         n_heads=4, n_kv_heads=1, d_ff=128, vocab=256,
                         window=16, lru_width=64, remat=False)


ARCH = ArchSpec(
    id="recurrentgemma-9b", family="hybrid", kind="griffin",
    make_full=full, make_smoke=smoke, supports_long=True,
    note="2:1 recurrent:attention heterogeneous mix — NSFlow folding "
         "applies. Bounded state (LRU + window ring) -> long_500k runs.",
    source="arXiv:2402.19427",
)
