"""ArchSpec — the uniform adapter every assigned architecture implements.

The launcher, dry-run, trainer, and smoke tests all consume this interface;
adding an architecture = one config file defining an ArchSpec.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.nn import init as nninit


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str                   # moe | dense | ssm | hybrid | vlm | audio
    kind: str                     # lm | rwkv | griffin | vlm | encdec
    make_full: Callable[[], Any]
    make_smoke: Callable[[], Any]
    supports_long: bool = False
    fsdp: bool = False            # shard the non-TP weight dim over data
    opt_8bit: bool = False        # quantized AdamW moments
    note: str = ""
    source: str = ""


def _mod(kind: str):
    if kind == "lm":
        from repro.models import lm as m
    elif kind == "rwkv":
        from repro.models import rwkv6 as m
    elif kind == "griffin":
        from repro.models import griffin as m
    elif kind == "vlm":
        from repro.models import vlm as m
    elif kind == "encdec":
        from repro.models import encdec as m
    else:
        raise ValueError(kind)
    return m


def model_spec(arch: ArchSpec, cfg):
    m = _mod(arch.kind)
    return {"lm": getattr(m, "lm_spec", None), "rwkv": getattr(m, "rwkv_spec", None),
            "griffin": getattr(m, "griffin_spec", None),
            "vlm": getattr(m, "vlm_spec", None),
            "encdec": getattr(m, "encdec_spec", None)}[arch.kind](cfg)


def loss_fn(arch: ArchSpec, cfg):
    m = _mod(arch.kind)
    return lambda params, batch: m.loss_fn(params, cfg, batch)


def _dm(cfg, kind: str) -> int:
    return cfg.lm.d_model if kind == "vlm" else cfg.d_model


def train_batch_specs(arch: ArchSpec, cfg, shape: ShapeSpec):
    """ShapeDtypeStructs for one global training batch."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if arch.kind == "vlm":
        return {
            "patch_embeds": jax.ShapeDtypeStruct((b, cfg.n_img_tokens,
                                                  cfg.lm.d_model), jnp.bfloat16),
            "tokens": tok, "targets": tok,
        }
    if arch.kind == "encdec":
        half = s // 2
        return {
            "frames": jax.ShapeDtypeStruct((b, half, cfg.d_model), jnp.bfloat16),
            "tgt_tokens": jax.ShapeDtypeStruct((b, half), jnp.int32),
            "tgt_targets": jax.ShapeDtypeStruct((b, half), jnp.int32),
        }
    return {"tokens": tok, "targets": tok}


def prefill_fn(arch: ArchSpec, cfg):
    """Full-context forward returning last-token logits (inference-prefill)."""
    m = _mod(arch.kind)
    if arch.kind == "lm":
        def f(params, tokens):
            hidden, _ = m.forward(params, cfg, tokens)
            return m.lm_logits(params, cfg, hidden[:, -1:])[:, 0]
    elif arch.kind == "rwkv":
        def f(params, tokens):
            hidden = m.forward(params, cfg, tokens)
            from repro.nn import layers
            return layers.dense(params["head"], hidden[:, -1], cfg.compute_dtype)
    elif arch.kind == "griffin":
        def f(params, tokens):
            hidden = m.forward(params, cfg, tokens)
            from repro.nn import layers
            return layers.logits(params["embed"], hidden[:, -1], cfg.compute_dtype)
    elif arch.kind == "vlm":
        def f(params, batch):
            hidden, _ = m.forward(params, cfg, batch["patch_embeds"], batch["tokens"])
            from repro.models import lm as lmm
            return lmm.lm_logits(params, cfg.lm, hidden[:, -1:])[:, 0]
    else:  # encdec
        def f(params, frames):
            enc = m.encode(params, cfg, frames)
            from repro.nn import layers
            return jnp.mean(enc, axis=1)  # encoder summary (decoder starts empty)
    return f


def prefill_input_specs(arch: ArchSpec, cfg, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    if arch.kind == "vlm":
        return ({"patch_embeds": jax.ShapeDtypeStruct(
            (b, cfg.n_img_tokens, cfg.lm.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)},)
    if arch.kind == "encdec":
        return (jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),)
    return (jax.ShapeDtypeStruct((b, s), jnp.int32),)


def decode_fn(arch: ArchSpec, cfg):
    m = _mod(arch.kind)
    def f(params, caches, token, pos):
        return m.decode_step(params, cfg, caches, token, pos)
    return f


def decode_state_specs(arch: ArchSpec, cfg, shape: ShapeSpec):
    """(caches, token, pos) ShapeDtypeStructs for one decode step."""
    m = _mod(arch.kind)
    b, s = shape.global_batch, shape.seq_len
    if arch.kind == "rwkv":
        caches = m.state_shapes(cfg, b)
    elif arch.kind == "griffin":
        caches = m.state_shapes(cfg, b, s)
    elif arch.kind == "encdec":
        caches = m.cache_shapes(cfg, b, min(s, 4096), src_len=s)
    elif arch.kind == "vlm":
        caches = m.cache_shapes(cfg, b, s)
    else:
        caches = m.cache_shapes(cfg, b, s)
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return caches, token, pos


def serve_fns(arch: ArchSpec, cfg, max_len: int):
    """(decode_step, init_caches) pair for the continuous-batching Engine.

    ``decode_step`` accepts a per-slot (B,) position vector (or a scalar);
    ``init_caches(batch)`` allocates zeroed decode state with ``max_len``
    KV capacity per slot. Stateful kinds (rwkv, griffin) carry O(1) or
    windowed state and ignore/modulo the position as appropriate; their
    cumulative state cannot absorb bucketed prefill pad steps, so
    ``init_caches`` is tagged ``stateful_prefill = True`` and the Engine
    forces exact-length prefill scans (no caller needs to re-derive the
    arch kind).
    """
    m = _mod(arch.kind)
    step = decode_fn(arch, cfg)
    if arch.kind == "lm":
        init = lambda batch: m.init_caches(cfg, batch, max_len)
    elif arch.kind == "rwkv":
        init = lambda batch: m.init_state(cfg, batch)
    elif arch.kind == "griffin":
        init = lambda batch: m.init_state(cfg, batch, max_len)
    else:
        raise NotImplementedError(
            f"{arch.kind}: serving needs non-token inputs (patch embeddings / "
            "encoder frames) — use the model module's encode/decode directly")
    init.stateful_prefill = arch.kind in ("rwkv", "griffin")
    return step, init


# ---------------------------------------------------------------------------
# NSAI reasoning traffic (serve.reason.ReasonEngine)
# ---------------------------------------------------------------------------

REASON_MODELS = ("nvsa", "prae")


def reason_fns(model: str, cfg):
    """(neural_fn, oracle_fn, symbolic_fn) for the two-stream ReasonEngine.

    The serving analogue of ``serve_fns`` for reasoning traffic. ``cfg`` is
    an ``NVSAConfig`` for both models — PrAE shares the CNN perception
    frontend and only the symbolic stream differs (PMF-table abduction
    instead of VSA algebra).

    - ``neural_fn(params, ctx (N,8,H,W,1), cand (N,8,H,W,1))`` — frontend
      perception, batched across the admission group; returns per-attribute
      tuples of (N, 8, V) PMFs for context and candidate panels. Groups
      context and candidate panels exactly like the offline
      ``models.nvsa.solve`` so a full-set batch is bit-identical to it.
    - ``oracle_fn(params, ctx_attrs (N,8,A), cand_attrs (N,8,A))`` — ground
      truth one-hot PMFs (perception bypass: symbolic-stream-only serving
      and the accuracy-1.0 conformance tests).
    - ``symbolic_fn(codebooks, ctx_pmfs, cand_pmfs)`` — abduction +
      execution; returns (answer logprobs (N, 8), rule posteriors (A,N,R)).
      ``codebooks`` is the static VSA memory for nvsa, ignored for prae.
    """
    from repro.models import nvsa as nv

    if model not in REASON_MODELS:
        raise KeyError(f"unknown reasoning model {model!r}; "
                       f"available: {REASON_MODELS}")

    def neural(params, ctx, cand):
        n, _, h, w, c = ctx.shape
        ctx_p, _ = nv.frontend_pmfs(params, cfg, ctx.reshape(n * 8, h, w, c))
        cand_p, _ = nv.frontend_pmfs(params, cfg, cand.reshape(n * 8, h, w, c))
        return (tuple(p.reshape(n, 8, -1) for p in ctx_p),
                tuple(p.reshape(n, 8, -1) for p in cand_p))

    def oracle(params, ctx_attrs, cand_attrs):
        del params
        return (tuple(nv.oracle_pmfs(cfg, ctx_attrs)),
                tuple(nv.oracle_pmfs(cfg, cand_attrs)))

    if model == "nvsa":
        def symbolic(codebooks, ctx_pmfs, cand_pmfs):
            codebooks = nv.quantize_codebooks(cfg, codebooks)
            return nv.reason(cfg, codebooks, list(ctx_pmfs), list(cand_pmfs))
    else:  # prae
        from repro.models import prae as pr

        pcfg = pr.PrAEConfig(raven=cfg.raven)

        def symbolic(codebooks, ctx_pmfs, cand_pmfs):
            del codebooks  # PrAE's symbolic engine is PMF-native
            return pr.solve_from_pmfs(pcfg, list(ctx_pmfs), list(cand_pmfs))

    return neural, oracle, symbolic


def param_count(arch: ArchSpec, cfg) -> int:
    return nninit.param_count(model_spec(arch, cfg))


def active_param_count(arch: ArchSpec, cfg) -> int:
    """MoE-aware active parameters per token (for MODEL_FLOPS = 6·N_active·D)."""
    import numpy as np

    spec = model_spec(arch, cfg)
    moe_cfg = getattr(cfg, "moe", None)
    if moe_cfg is None:
        return nninit.param_count(spec)
    total = 0
    for p in jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, nninit.P)):
        n = int(np.prod(p.shape))
        if "experts" in p.axes:  # routed-expert weight: top_k of E active
            n = n * moe_cfg.top_k // moe_cfg.n_experts
        total += n
    return total
