"""ArchSpec — the uniform adapter every assigned architecture implements.

The launcher, dry-run, trainer, and smoke tests all consume this interface;
adding an architecture = one config file defining an ArchSpec.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.nn import init as nninit


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str                   # moe | dense | ssm | hybrid | vlm | audio
    kind: str                     # lm | rwkv | griffin | vlm | encdec
    make_full: Callable[[], Any]
    make_smoke: Callable[[], Any]
    supports_long: bool = False
    fsdp: bool = False            # shard the non-TP weight dim over data
    opt_8bit: bool = False        # quantized AdamW moments
    note: str = ""
    source: str = ""


def _mod(kind: str):
    if kind == "lm":
        from repro.models import lm as m
    elif kind == "rwkv":
        from repro.models import rwkv6 as m
    elif kind == "griffin":
        from repro.models import griffin as m
    elif kind == "vlm":
        from repro.models import vlm as m
    elif kind == "encdec":
        from repro.models import encdec as m
    else:
        raise ValueError(kind)
    return m


def model_spec(arch: ArchSpec, cfg):
    m = _mod(arch.kind)
    return {"lm": getattr(m, "lm_spec", None), "rwkv": getattr(m, "rwkv_spec", None),
            "griffin": getattr(m, "griffin_spec", None),
            "vlm": getattr(m, "vlm_spec", None),
            "encdec": getattr(m, "encdec_spec", None)}[arch.kind](cfg)


def loss_fn(arch: ArchSpec, cfg):
    m = _mod(arch.kind)
    return lambda params, batch: m.loss_fn(params, cfg, batch)


def _dm(cfg, kind: str) -> int:
    return cfg.lm.d_model if kind == "vlm" else cfg.d_model


def train_batch_specs(arch: ArchSpec, cfg, shape: ShapeSpec):
    """ShapeDtypeStructs for one global training batch."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if arch.kind == "vlm":
        return {
            "patch_embeds": jax.ShapeDtypeStruct((b, cfg.n_img_tokens,
                                                  cfg.lm.d_model), jnp.bfloat16),
            "tokens": tok, "targets": tok,
        }
    if arch.kind == "encdec":
        half = s // 2
        return {
            "frames": jax.ShapeDtypeStruct((b, half, cfg.d_model), jnp.bfloat16),
            "tgt_tokens": jax.ShapeDtypeStruct((b, half), jnp.int32),
            "tgt_targets": jax.ShapeDtypeStruct((b, half), jnp.int32),
        }
    return {"tokens": tok, "targets": tok}


def prefill_fn(arch: ArchSpec, cfg):
    """Full-context forward returning last-token logits (inference-prefill)."""
    m = _mod(arch.kind)
    if arch.kind == "lm":
        def f(params, tokens):
            hidden, _ = m.forward(params, cfg, tokens)
            return m.lm_logits(params, cfg, hidden[:, -1:])[:, 0]
    elif arch.kind == "rwkv":
        def f(params, tokens):
            hidden = m.forward(params, cfg, tokens)
            from repro.nn import layers
            return layers.dense(params["head"], hidden[:, -1], cfg.compute_dtype)
    elif arch.kind == "griffin":
        def f(params, tokens):
            hidden = m.forward(params, cfg, tokens)
            from repro.nn import layers
            return layers.logits(params["embed"], hidden[:, -1], cfg.compute_dtype)
    elif arch.kind == "vlm":
        def f(params, batch):
            hidden, _ = m.forward(params, cfg, batch["patch_embeds"], batch["tokens"])
            from repro.models import lm as lmm
            return lmm.lm_logits(params, cfg.lm, hidden[:, -1:])[:, 0]
    else:  # encdec
        def f(params, frames):
            enc = m.encode(params, cfg, frames)
            from repro.nn import layers
            return jnp.mean(enc, axis=1)  # encoder summary (decoder starts empty)
    return f


def prefill_input_specs(arch: ArchSpec, cfg, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    if arch.kind == "vlm":
        return ({"patch_embeds": jax.ShapeDtypeStruct(
            (b, cfg.n_img_tokens, cfg.lm.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)},)
    if arch.kind == "encdec":
        return (jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),)
    return (jax.ShapeDtypeStruct((b, s), jnp.int32),)


def decode_fn(arch: ArchSpec, cfg):
    m = _mod(arch.kind)
    def f(params, caches, token, pos):
        return m.decode_step(params, cfg, caches, token, pos)
    return f


def decode_state_specs(arch: ArchSpec, cfg, shape: ShapeSpec):
    """(caches, token, pos) ShapeDtypeStructs for one decode step."""
    m = _mod(arch.kind)
    b, s = shape.global_batch, shape.seq_len
    if arch.kind == "rwkv":
        caches = m.state_shapes(cfg, b)
    elif arch.kind == "griffin":
        caches = m.state_shapes(cfg, b, s)
    elif arch.kind == "encdec":
        caches = m.cache_shapes(cfg, b, min(s, 4096), src_len=s)
    elif arch.kind == "vlm":
        caches = m.cache_shapes(cfg, b, s)
    else:
        caches = m.cache_shapes(cfg, b, s)
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return caches, token, pos


def serve_fns(arch: ArchSpec, cfg, max_len: int):
    """(decode_step, init_caches) pair for the continuous-batching Engine.

    ``decode_step`` accepts a per-slot (B,) position vector (or a scalar);
    ``init_caches(batch)`` allocates zeroed decode state with ``max_len``
    KV capacity per slot. Stateful kinds (rwkv, griffin) carry O(1) or
    windowed state and ignore/modulo the position as appropriate; their
    cumulative state cannot absorb bucketed prefill pad steps, so
    ``init_caches`` is tagged ``stateful_prefill = True`` and the Engine
    forces exact-length prefill scans (no caller needs to re-derive the
    arch kind).
    """
    m = _mod(arch.kind)
    step = decode_fn(arch, cfg)
    if arch.kind == "lm":
        init = lambda batch: m.init_caches(cfg, batch, max_len)
    elif arch.kind == "rwkv":
        init = lambda batch: m.init_state(cfg, batch)
    elif arch.kind == "griffin":
        init = lambda batch: m.init_state(cfg, batch, max_len)
    else:
        raise NotImplementedError(
            f"{arch.kind}: serving needs non-token inputs (patch embeddings / "
            "encoder frames) — use the model module's encode/decode directly")
    init.stateful_prefill = arch.kind in ("rwkv", "griffin")
    return step, init


# ---------------------------------------------------------------------------
# NSAI reasoning traffic: the workload registry
# (serve.schedule.compile_schedule -> serve.reason.ReasonEngine)
# ---------------------------------------------------------------------------
#
# Each entry declares how a workload serves: its pipeline *stage functions*
# (jax-traceable, with nn/vsa/simd stream tags), the staged-batch input
# specs, the constants every stage receives, request ingest/collect
# adapters, and a synthetic-traffic generator.  ``compile_reason_schedule``
# lowers an entry to an executable ``StagedSchedule`` (tracing the composed
# stages with ``core.trace`` into the same DataflowGraph the DSE consumes),
# and the generic ``ReasonEngine`` runs it.  Adding a workload = one
# registry entry; the engine, launcher, examples and benchmarks all derive
# their model lists from ``REASON_WORKLOADS``.


@dataclasses.dataclass(frozen=True)
class ReasonWorkload:
    """Registry entry: everything a workload contributes to the serving path.

    - ``variants``: named pipeline variants (first = default).  RAVEN
      reasoners expose ``cnn`` (neural perception) and ``oracle``
      (ground-truth PMFs: symbolic-stream-only serving).
    - ``make_config(**kw)``: config from generic launcher knobs (``d``,
      ``nn_precision``, ``symb_precision``); inapplicable knobs ignored.
    - ``make_consts(cfg, key)``: the constant pytree handed to every stage
      (params / codebooks / binding keys).
    - ``stage_specs(cfg, variant)``: ordered ``StageSpec`` tuple.
    - ``input_specs(cfg, batch_size, variant)``: ShapeDtypeStruct pytree of
      one staged batch (stage 0's input).
    - ``ingest(cfg, variant)``: per-request host adapter -> input pytree.
    - ``collect(cfg)``: ``(host_out, i) -> ReasonResult fields`` adapter.
    - ``paper_graph()``: the published-scale ``OpGraph`` from
      ``core.workloads`` (None -> trace only), for the analytic side.
    - ``fused_stage_specs(cfg, variant)``: optional alternate stage list
      for the whole-pipeline fused jit (e.g. MIMONet's unbind+classify
      collapsed into the fused kernel); None -> the fused jit composes
      ``stage_specs`` as-is.
    - ``make_requests(cfg, n, seed)``: ``(stream_factory, truth)`` where
      ``stream_factory()`` yields requests lazily (rendering runs inside
      the pipeline) and ``truth()`` lazily materializes ground truth.
    - ``score(results, truth_values)``: serving accuracy.
    """

    name: str
    describe: str
    variants: tuple[str, ...]
    make_config: Callable[..., Any]
    make_consts: Callable[[Any, jax.Array], Any]
    stage_specs: Callable[[Any, str], tuple]
    input_specs: Callable[[Any, int, str], Any]
    ingest: Callable[[Any, str], Callable]
    collect: Callable[[Any], Callable]
    make_requests: Callable[[Any, int, int], tuple]
    score: Callable[[dict, Any], float]
    paper_graph: Callable[[], Any] | None = None
    fused_stage_specs: Callable[[Any, str], tuple] | None = None


def _require(req, field: str):
    val = getattr(req, field)
    if val is None:
        raise ValueError(f"needs ReasonRequest.{field}")
    return val


def _raven_ingest(cfg, variant: str) -> Callable:
    import numpy as np

    if variant == "oracle":
        return lambda r: (
            np.asarray(_require(r, "context_attrs"), np.int32),
            np.asarray(_require(r, "candidate_attrs"), np.int32))
    return lambda r: (
        np.asarray(_require(r, "context"), np.float32),
        np.asarray(_require(r, "candidates"), np.float32))


def _raven_collect(cfg) -> Callable:
    import numpy as np

    def collect(host_out, i):
        logp, posts = host_out  # (B, 8), (A, B, R)
        return {"answer": int(np.argmax(logp[i])), "answer_logprobs": logp[i],
                "rule_posteriors": posts[:, i]}

    return collect


def _raven_input_specs(cfg, batch_size: int, variant: str):
    hw = cfg.raven.image_size
    a = cfg.raven.n_attrs
    if variant == "oracle":
        spec = jax.ShapeDtypeStruct((batch_size, 8, a), jnp.int32)
    else:
        spec = jax.ShapeDtypeStruct((batch_size, 8, hw, hw, 1), jnp.float32)
    return (spec, spec)


def _raven_requests(cfg, n: int, seed: int):
    """Lazy RAVEN request stream + lazily-materialized answers.  Answers
    are captured as the stream is pulled, so scoring after a serve run
    costs no second render pass."""
    import numpy as np

    from repro.data import raven

    answers: dict[int, int] = {}

    def factory():
        from repro.serve.reason import ReasonRequest

        for i in range(n):
            p = raven.generate_problem(cfg.raven, seed=seed + i)
            answers[i] = int(p["answer"])
            yield ReasonRequest(
                uid=i, context=p["context"], candidates=p["candidates"],
                context_attrs=p["context_attrs"],
                candidate_attrs=p["candidate_attrs"])

    def truth():
        for i in range(n):  # only re-render what was never pulled
            if i not in answers:
                answers[i] = int(raven.generate_problem(
                    cfg.raven, seed=seed + i)["answer"])
        return np.array([answers[i] for i in range(n)])

    return factory, truth


def _mean_match_score(results: dict, truth_values) -> float:
    """Mean answer==truth (elementwise for per-channel answer arrays)."""
    import numpy as np

    return float(np.mean([results[i].answer == truth_values[i]
                          for i in range(len(truth_values))]))


def _nvsa_frontend_stage(cfg, consts_key: str = "params"):
    """Shared CNN perception stage (NVSA frontend; eval-mode BN, so a
    request's PMFs are independent of its admission group).  ``consts_key``
    selects the frontend params in the workload's consts pytree (LVRF
    carries them under ``"frontend"`` beside its learned rules)."""
    from repro.models import nvsa as nv
    from repro.serve.schedule import StageSpec

    def frontend(consts, bufs):
        ctx, cand = bufs
        n, _, h, w, c = ctx.shape
        p = consts[consts_key]
        ctx_p, _ = nv.frontend_pmfs(p, cfg, ctx.reshape(n * 8, h, w, c))
        cand_p, _ = nv.frontend_pmfs(p, cfg, cand.reshape(n * 8, h, w, c))
        return (tuple(x.reshape(n, 8, -1) for x in ctx_p),
                tuple(x.reshape(n, 8, -1) for x in cand_p))

    return StageSpec("frontend", "nn", frontend)


def _oracle_stage(cfg):
    """Ground-truth one-hot PMFs (perception bypass: symbolic-only serving)."""
    from repro.models import nvsa as nv
    from repro.serve.schedule import StageSpec

    def oracle(consts, bufs):
        ctx_attrs, cand_attrs = bufs
        return (tuple(nv.oracle_pmfs(cfg, ctx_attrs)),
                tuple(nv.oracle_pmfs(cfg, cand_attrs)))

    return StageSpec("oracle", "simd", oracle)


# -- nvsa -------------------------------------------------------------------


def _nvsa_config(d: int = 128, nn_precision: str = "fp32",
                 symb_precision: str = "fp32", **_):
    from repro.models import nvsa as nv

    return nv.NVSAConfig(d=d, nn_precision=nn_precision,
                         symb_precision=symb_precision,
                         use_qmatmul=nn_precision in ("int8", "int4"))


def _nvsa_consts(cfg, key):
    from repro.models import nvsa as nv
    from repro.nn import init as nninit

    k1, k2 = jax.random.split(key)
    return {"params": nninit.materialize(nv.nvsa_spec(cfg), k1),
            "books": nv.nvsa_codebooks(cfg, k2)}


def _nvsa_stages(cfg, variant: str):
    from repro.models import nvsa as nv
    from repro.serve.schedule import StageSpec

    def symbolic(consts, bufs):
        ctx_pmfs, cand_pmfs = bufs
        books = nv.quantize_codebooks(cfg, consts["books"])
        return nv.reason(cfg, books, list(ctx_pmfs), list(cand_pmfs))

    first = _oracle_stage(cfg) if variant == "oracle" \
        else _nvsa_frontend_stage(cfg)
    return (first, StageSpec("symbolic", "vsa", symbolic))


# -- prae -------------------------------------------------------------------


def _prae_stages(cfg, variant: str):
    # PrAE shares the CNN perception frontend (cfg is an NVSAConfig); its
    # symbolic engine is PMF-native — scatter/shift/reduce, SIMD-shaped
    from repro.models import prae as pr
    from repro.serve.schedule import StageSpec

    pcfg = pr.PrAEConfig(raven=cfg.raven)

    def symbolic(consts, bufs):
        ctx_pmfs, cand_pmfs = bufs
        return pr.solve_from_pmfs(pcfg, list(ctx_pmfs), list(cand_pmfs))

    first = _oracle_stage(cfg) if variant == "oracle" \
        else _nvsa_frontend_stage(cfg)
    return (first, StageSpec("symbolic", "simd", symbolic))


# -- mimonet ----------------------------------------------------------------


def _mimonet_config(d: int = 128, **_):
    from repro.models import mimonet as mm

    return mm.MIMONetConfig(d=d)


def _mimonet_consts(cfg, key):
    from repro.models import mimonet as mm
    from repro.nn import init as nninit

    k1, k2 = jax.random.split(key)
    return {"params": nninit.materialize(mm.mimonet_spec(cfg), k1),
            "keys": mm.mimonet_keys(cfg, k2)}


def _mimonet_stages(cfg, variant: str):
    from repro.models import mimonet as mm
    from repro.serve.schedule import StageSpec

    return (
        StageSpec("encode", "nn",
                  lambda c, images: mm.encode(c["params"], cfg, images)),
        StageSpec("superpose", "vsa",
                  lambda c, codes: mm.superpose(c["keys"], codes)),
        StageSpec("trunk", "nn",
                  lambda c, x: mm.trunk(c["params"], x)),
        StageSpec("unbind", "vsa",
                  lambda c, x: mm.unbind(c["keys"], cfg, x)),
        StageSpec("classify", "simd",
                  lambda c, u: mm.classify(c["params"], u)),
    )


def _mimonet_fused_stages(cfg, variant: str):
    """Fused-pipeline stage list: the symbolic tail (unbind -> classify)
    collapses into the registry's fused ``unbind_classify`` kernel — one
    launch instead of two.  Only the fused jit composes this list; the
    staged schedule keeps the 5-stage pipeline, and ``compile_schedule``
    proves the two traces' lowerings equivalent before the executor may
    substitute one for the other."""
    from repro.models import mimonet as mm
    from repro.serve.schedule import StageSpec

    return _mimonet_stages(cfg, variant)[:3] + (
        StageSpec("unbind_classify", "simd",
                  lambda c, x: mm.unbind_classify(c["params"], c["keys"],
                                                  cfg, x)),
    )


def _mimonet_input_specs(cfg, batch_size: int, variant: str):
    hw = cfg.raven.image_size
    return jax.ShapeDtypeStruct(
        (batch_size, cfg.n_channels, hw, hw, 1), jnp.float32)


def _mimonet_ingest(cfg, variant: str):
    import numpy as np

    return lambda r: np.asarray(_require(r, "images"), np.float32)


def _mimonet_collect(cfg):
    import numpy as np

    def collect(host_out, i):
        logits = host_out[i]  # (K, n_classes)
        shifted = logits - logits.max(-1, keepdims=True)
        logp = shifted - np.log(np.exp(shifted).sum(-1, keepdims=True))
        return {"answer": np.argmax(logits, -1), "answer_logprobs": logp,
                "rule_posteriors": None}

    return collect


def _mimonet_requests(cfg, n: int, seed: int):
    """K-channel superposed-classification traffic from rendered RAVEN
    panels; truth = per-channel shape-type labels, captured alongside the
    rendered panels (no second render pass at scoring time)."""
    from repro.data import raven

    k = cfg.n_channels
    cache: dict = {}

    def _panels():
        if not cache:
            # 16 rendered panels per problem (8 ctx + 8 cand)
            probs = (n * k + 15) // 16
            cache["imgs"], cache["attrs"] = raven.panel_dataset(
                cfg.raven, seed=seed, n_problems=probs)
        return cache["imgs"], cache["attrs"]

    def factory():
        from repro.serve.reason import ReasonRequest

        imgs, _ = _panels()
        for i in range(n):
            yield ReasonRequest(uid=i, images=imgs[i * k:(i + 1) * k])

    def truth():
        _, attrs = _panels()
        return attrs[: n * k, 0].reshape(n, k)  # attr 0 = shape type

    return factory, truth


# -- lvrf -------------------------------------------------------------------


def _lvrf_config(d: int = 128, **_):
    from repro.models import lvrf as lv

    return lv.LVRFConfig(d=d)


def _lvrf_frontend_cfg(cfg):
    """NVSA-frontend config for LVRF's CNN perception (shared ResNet
    frontend; the symbolic side is LVRF's learned rules)."""
    from repro.models import nvsa as nv

    return nv.NVSAConfig(raven=cfg.raven)


def _lvrf_consts(cfg, key):
    from repro.models import lvrf as lv
    from repro.models import nvsa as nv
    from repro.nn import init as nninit

    k1, k2, k3 = jax.random.split(key, 3)
    fcfg = _lvrf_frontend_cfg(cfg)
    return {"params": nninit.materialize(lv.lvrf_spec(cfg), k1),
            "books": lv.lvrf_codebooks(cfg, k2),
            "frontend": nninit.materialize(nv.nvsa_spec(fcfg), k3)}


def _lvrf_stages(cfg, variant: str):
    from repro.models import lvrf as lv
    from repro.serve.schedule import StageSpec

    def abduce(consts, bufs):
        ctx_pmfs, cand_pmfs = bufs
        codes = lv.encode_codes(consts["books"], cfg, list(ctx_pmfs))
        posts = lv.abduce(consts["params"], cfg, codes)
        return (codes, posts, cand_pmfs)

    def execute(consts, bufs):
        codes, posts, cand_pmfs = bufs
        logp = lv.execute(consts["params"], consts["books"], cfg, codes,
                          posts, list(cand_pmfs))
        return (logp, posts)

    first = _oracle_stage(cfg) if variant == "oracle" \
        else _nvsa_frontend_stage(_lvrf_frontend_cfg(cfg),
                                  consts_key="frontend")
    return (first, StageSpec("abduce", "vsa", abduce),
            StageSpec("execute", "vsa", execute))


def _paper_graph(name: str):
    def build():
        from repro.core import workloads

        return workloads.WORKLOADS[name]()

    return build


REASON_WORKLOADS: dict[str, ReasonWorkload] = {
    "nvsa": ReasonWorkload(
        name="nvsa",
        describe="NVSA: ResNet perception -> FPE/VSA rule abduction -> "
                 "circ-conv rule execution (RAVEN)",
        variants=("cnn", "oracle"),
        make_config=_nvsa_config, make_consts=_nvsa_consts,
        stage_specs=_nvsa_stages, input_specs=_raven_input_specs,
        ingest=_raven_ingest, collect=_raven_collect,
        make_requests=_raven_requests, score=_mean_match_score,
        paper_graph=_paper_graph("nvsa")),
    "prae": ReasonWorkload(
        name="prae",
        describe="PrAE: shared CNN perception -> PMF-table abduction/"
                 "execution (SIMD-shaped symbolic stream)",
        variants=("cnn", "oracle"),
        make_config=_nvsa_config, make_consts=_nvsa_consts,
        stage_specs=_prae_stages, input_specs=_raven_input_specs,
        ingest=_raven_ingest, collect=_raven_collect,
        make_requests=_raven_requests, score=_mean_match_score),
    "mimonet": ReasonWorkload(
        name="mimonet",
        describe="MIMONet: K-channel superposed classification — bind -> "
                 "shared NN trunk -> unbind/classify",
        variants=("default",),
        make_config=_mimonet_config, make_consts=_mimonet_consts,
        stage_specs=_mimonet_stages, input_specs=_mimonet_input_specs,
        ingest=_mimonet_ingest, collect=_mimonet_collect,
        make_requests=_mimonet_requests, score=_mean_match_score,
        paper_graph=_paper_graph("mimonet"),
        fused_stage_specs=_mimonet_fused_stages),
    "lvrf": ReasonWorkload(
        name="lvrf",
        describe="LVRF: frontend -> learned-rule posterior -> posterior-"
                 "weighted circ-conv execution (RAVEN)",
        variants=("cnn", "oracle"),
        make_config=_lvrf_config, make_consts=_lvrf_consts,
        stage_specs=_lvrf_stages, input_specs=_raven_input_specs,
        ingest=_raven_ingest, collect=_raven_collect,
        make_requests=_raven_requests, score=_mean_match_score,
        paper_graph=_paper_graph("lvrf")),
}

# model lists everywhere (launcher --model choices, examples, benchmarks)
# derive from the registry — adding a workload is one entry above
REASON_MODELS = tuple(REASON_WORKLOADS)


def compile_reason_schedule(model: str, cfg, variant: str | None = None,
                            consts=None,
                            batch_size: int | tuple[int, ...] = 4,
                            trace_graph: bool = True, plan=None,
                            fused: bool | str = "auto"):
    """Lower one registry entry to an executable ``StagedSchedule``.

    ``consts`` may be the real constant pytree (params/codebooks) or None —
    then the entry's ``make_consts`` is abstractly evaluated for shapes
    only (nothing is materialized).  The compiled schedule carries the
    inter-stage buffer specs and the DataflowGraph traced from the composed
    stages (``trace_graph=False`` skips tracing for fast construction).

    ``batch_size`` may be a tuple of batch-size buckets (e.g. ``(1, 2,
    4, 8)``): the schedule's ``input_specs``/buffers describe the largest,
    and the engine pads a partial admission group to the smallest covering
    bucket instead of the max.

    ``plan``: a :class:`~repro.backend.registry.LoweringPlan` to compile
    under (None = the active plan); recorded on the schedule.

    ``fused``: forwarded to ``compile_schedule`` ("auto" also compiles the
    whole-pipeline fused jit and negotiates its equivalence class; the
    entry's ``fused_stage_specs``, when declared, supplies the fused-only
    stage list, e.g. the ``unbind_classify`` kernel).
    """
    from repro.serve import schedule as sch

    if model not in REASON_WORKLOADS:
        raise KeyError(f"unknown reasoning workload {model!r}; "
                       f"available: {tuple(REASON_WORKLOADS)}")
    entry = REASON_WORKLOADS[model]
    variant = variant or entry.variants[0]
    if variant not in entry.variants:
        raise KeyError(f"{model}: unknown variant {variant!r}; "
                       f"available: {entry.variants}")
    if consts is None:
        consts = jax.eval_shape(lambda k: entry.make_consts(cfg, k),
                                jax.random.PRNGKey(0))
    buckets = tuple(sorted(set(batch_size))) \
        if isinstance(batch_size, (tuple, list)) else ()
    max_batch = buckets[-1] if buckets else batch_size
    fused_stages = entry.fused_stage_specs(cfg, variant) \
        if entry.fused_stage_specs is not None else None
    return sch.compile_schedule(
        model, entry.stage_specs(cfg, variant),
        entry.ingest(cfg, variant), entry.collect(cfg), variant=variant,
        consts=consts,
        input_specs=entry.input_specs(cfg, max_batch, variant),
        trace_graph=trace_graph, batch_buckets=buckets, plan=plan,
        fused=fused, fused_stages=fused_stages)


def reason_engine(model: str, cfg, reason_cfg=None, consts=None,
                  variants: tuple[str, ...] | None = None,
                  trace_graph: bool = True, plan=None):
    """Compile all (or the given) variants of a workload and wrap them in
    the generic N-stage ``ReasonEngine``.  ``reason_cfg.buckets`` (when
    set) compiles every variant with that tuple of batch-size buckets.
    ``consts`` (the workload's constant pytree) is bound onto the engine,
    which therefore implements the consts-free runtime protocol; with
    ``consts=None`` the schedules compile against abstract shapes and the
    engine can only be inspected, not served."""
    from repro.serve.reason import ReasonConfig, ReasonEngine

    entry = REASON_WORKLOADS.get(model)
    if entry is None:
        raise KeyError(f"unknown reasoning workload {model!r}; "
                       f"available: {tuple(REASON_WORKLOADS)}")
    reason_cfg = reason_cfg or ReasonConfig()
    schedules = {
        v: compile_reason_schedule(
            model, cfg, variant=v, consts=consts,
            batch_size=reason_cfg.buckets or reason_cfg.batch_size,
            trace_graph=trace_graph, plan=plan)
        for v in (variants or entry.variants)}
    return ReasonEngine(schedules, reason_cfg, consts=consts)


def reason_engine_pool(model: str, cfg, reason_cfg=None, consts=None,
                       variants: tuple[str, ...] | None = None,
                       replicas: int = 1, trace_graph: bool = False,
                       plan=None):
    """``replicas`` data-parallel :func:`reason_engine` copies behind one
    :class:`~repro.serve.replica.ReplicaPool`.

    Each replica gets the *same* constants (bit-identical answers
    whichever replica serves a request) ``jax.device_put`` onto its own
    device — ``jax.devices()[i % ndev]`` — so jit executions of different
    replicas land on different devices and overlap (fake host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` work the same
    way).  All replicas share ONE compiled schedule dict: stage jit caches
    live on the ``StagedSchedule``, so the pipeline compiles once per
    device, not once per replica.  ``replicas=1`` returns the bare engine
    (no pool indirection on the single-replica path)."""
    import dataclasses as _dc

    import jax as _jax

    from repro.serve.reason import ReasonConfig, ReasonEngine
    from repro.serve.replica import ReplicaPool

    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    reason_cfg = reason_cfg or ReasonConfig()
    if replicas == 1:
        return reason_engine(model, cfg, reason_cfg, consts=consts,
                             variants=variants, trace_graph=trace_graph,
                             plan=plan)
    if consts is None:
        raise ValueError("a replica pool needs real consts (answers must "
                         "be replica-invariant, so every replica binds the "
                         "same materialized constants)")
    devs = _jax.devices()
    engines = []
    schedules = None
    for i in range(replicas):
        c = _jax.device_put(consts, devs[i % len(devs)])
        rcfg = _dc.replace(reason_cfg)
        if schedules is None:
            eng = reason_engine(model, cfg, rcfg, consts=c,
                                variants=variants, trace_graph=trace_graph,
                                plan=plan)
            schedules = eng.schedules
        else:
            eng = ReasonEngine(schedules, rcfg, consts=c)
        engines.append(eng)
    return ReplicaPool(engines)


def lm_engine(arch_id: str, serve_cfg=None, key=None, tp: int = 1,
              device=None):
    """Materialize a smoke-scale arch and wrap it in the slot-pool LM
    ``Engine`` with params bound — the LM counterpart of
    :func:`reason_engine`, so both engine classes come out implementing
    the unified runtime protocol.  Returns ``(engine, model_cfg)``
    (callers need ``model_cfg.vocab`` to build token traffic).

    ``tp > 1`` binds the params tensor-parallel over a ``(data=1,
    model=tp)`` host mesh through ``distributed.sharding_rules``
    (``TP_RULES`` with the ``FALLBACK_TP_AXES`` escape for shapes whose
    preferred axis does not divide; the fallback size floor is disabled so
    smoke-scale params shard too).  The engine itself is unchanged: its
    jits follow the committed param shardings, so decode runs SPMD over
    the mesh — and stays token-for-token identical to single-device
    (greedy argmax over ulp-level psum reordering; regression-tested).
    Needs ``tp <= jax.device_count()`` (fake host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

    ``device`` pins the (unsharded) params onto one device — the
    data-parallel replica path (mutually exclusive with ``tp > 1``)."""
    import jax as _jax

    from repro.configs import ARCHS
    from repro.serve.engine import Engine, ServeConfig

    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if tp > 1 and device is not None:
        raise ValueError("pass tp= (tensor-parallel) or device= (replica "
                         "placement), not both")
    arch = ARCHS[arch_id]
    cfg = arch.make_smoke()
    serve_cfg = serve_cfg or ServeConfig()
    spec = model_spec(arch, cfg)
    params = nninit.materialize(spec,
                                key if key is not None
                                else _jax.random.PRNGKey(0))
    if tp > 1:
        if tp > len(_jax.devices()):
            raise ValueError(
                f"tp={tp} exceeds jax.device_count()={len(_jax.devices())} "
                "— on CPU, fake a mesh with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={tp}")
        from repro.distributed import sharding_rules as sr
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(data=1, model=tp)
        shardings = sr.param_shardings(spec, mesh, fsdp=arch.fsdp,
                                       min_shard_elems=0)
        params = _jax.tree.map(_jax.device_put, params, shardings)
    elif device is not None:
        params = _jax.device_put(params, device)
    step, init_caches = serve_fns(arch, cfg, max_len=serve_cfg.max_len)
    return Engine(step, init_caches, serve_cfg, params=params), cfg


def lm_engine_pool(arch_id: str, serve_cfg=None, key=None,
                   replicas: int = 1, tp: int = 1):
    """``replicas`` data-parallel LM engines behind one ``ReplicaPool``
    (each replica's params on its own device, same PRNG key so token
    streams are replica-invariant), or a single (optionally
    tensor-parallel) engine when ``replicas == 1``.  Returns ``(engine,
    model_cfg)`` like :func:`lm_engine`."""
    import jax as _jax

    from repro.serve.replica import ReplicaPool

    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    if replicas > 1 and tp > 1:
        raise ValueError(
            f"replicas={replicas} with tp={tp}: combined data x tensor "
            "parallel LM serving is not wired up — pick one axis")
    if replicas == 1:
        return lm_engine(arch_id, serve_cfg, key=key, tp=tp)
    devs = _jax.devices()
    engines, cfg = [], None
    for i in range(replicas):
        eng, cfg = lm_engine(arch_id, serve_cfg, key=key,
                             device=devs[i % len(devs)])
        engines.append(eng)
    return ReplicaPool(engines), cfg


def param_count(arch: ArchSpec, cfg) -> int:
    return nninit.param_count(model_spec(arch, cfg))


def active_param_count(arch: ArchSpec, cfg) -> int:
    """MoE-aware active parameters per token (for MODEL_FLOPS = 6·N_active·D)."""
    import numpy as np

    spec = model_spec(arch, cfg)
    moe_cfg = getattr(cfg, "moe", None)
    if moe_cfg is None:
        return nninit.param_count(spec)
    total = 0
    for p in jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, nninit.P)):
        n = int(np.prod(p.shape))
        if "experts" in p.axes:  # routed-expert weight: top_k of E active
            n = n * moe_cfg.top_k // moe_cfg.n_experts
        total += n
    return total
