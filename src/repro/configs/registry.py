"""Architecture registry: --arch <id> resolves here."""
from repro.configs.granite_moe_1b_a400m import ARCH as granite_moe
from repro.configs.deepseek_v3_671b import ARCH as deepseek_v3
from repro.configs.llama3_2_3b import ARCH as llama32
from repro.configs.stablelm_3b import ARCH as stablelm
from repro.configs.gemma3_12b import ARCH as gemma3
from repro.configs.starcoder2_3b import ARCH as starcoder2
from repro.configs.rwkv6_7b import ARCH as rwkv6
from repro.configs.recurrentgemma_9b import ARCH as recurrentgemma
from repro.configs.internvl2_26b import ARCH as internvl2
from repro.configs.seamless_m4t_large_v2 import ARCH as seamless

ARCHS = {a.id: a for a in [
    granite_moe, deepseek_v3, llama32, stablelm, gemma3, starcoder2,
    rwkv6, recurrentgemma, internvl2, seamless,
]}


def get_arch(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]
