"""deepseek-v3-671b — 61L d7168 128H MLA, 1 shared + 256 routed top-8, MTP.

[arXiv:2412.19437] MLA: q_lora 1536, kv_lora 512, nope 128 / rope 64,
v_head 128; first 3 layers dense (d_ff 18432); expert d_ff 2048.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.attention import MLAConfig
from repro.nn.moe import MoEConfig


def full() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
        n_kv_heads=128, head_dim=128, d_ff=2048, vocab=129280,
        attn_kind="mla",
        mla=MLAConfig(d_model=7168, n_heads=128, q_lora_rank=1536,
                      kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                      v_head_dim=128),
        first_k_dense=3, dense_d_ff=18432,
        moe=MoEConfig(d_model=7168, d_ff=2048, n_experts=256, top_k=8,
                      n_shared=1, shared_d_ff=2048, capacity_factor=1.25),
        mtp=True, tie_embeddings=False, rope_base=10000.0,
        param_dtype=jnp.bfloat16,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=32, vocab=256,
        attn_kind="mla",
        mla=MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        first_k_dense=1, dense_d_ff=128,
        moe=MoEConfig(d_model=64, d_ff=32, n_experts=4, top_k=2, n_shared=1,
                      shared_d_ff=32, capacity_factor=2.0),
        mtp=True, tie_embeddings=False, remat=False,
    )


ARCH = ArchSpec(
    id="deepseek-v3-671b", family="moe", kind="lm",
    make_full=full, make_smoke=smoke, fsdp=True, opt_8bit=True,
    note="MLA compressed KV cache; EP over model axis; MTP exercises "
         "inter-loop overlap. FSDP + 8-bit AdamW to fit 16 GB/chip.",
    source="arXiv:2412.19437",
)
