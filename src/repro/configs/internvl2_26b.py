"""internvl2-26b — InternViT (STUB) + InternLM2-20B-class backbone:
48L d6144 48H (kv8) d_ff 16384 vocab 92553. [arXiv:2404.16821]"""
from repro.configs.base import ArchSpec
from repro.models.lm import LMConfig
from repro.models.vlm import VLMConfig


def full() -> VLMConfig:
    return VLMConfig(
        lm=LMConfig(name="internvl2-26b", n_layers=48, d_model=6144,
                    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384,
                    vocab=92553, tie_embeddings=False),
        n_img_tokens=1024,
    )


def smoke() -> VLMConfig:
    return VLMConfig(
        lm=LMConfig(name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
                    tie_embeddings=False, remat=False),
        n_img_tokens=16,
    )


ARCH = ArchSpec(
    id="internvl2-26b", family="vlm", kind="vlm",
    make_full=full, make_smoke=smoke, fsdp=True,
    note="ViT frontend stubbed (input_specs supplies patch embeddings per "
         "brief). Perception->reasoning critical path = the paper's "
         "inter-loop overlap case. long_500k skipped (full attention).",
    source="arXiv:2404.16821",
)
