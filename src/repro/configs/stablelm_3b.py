"""stablelm-3b — 32L d2560 32H (kv32=MHA) d_ff 6912 vocab 50304, 25% rotary."""
from repro.configs.base import ArchSpec
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig(
        name="stablelm-3b", n_layers=32, d_model=2560, n_heads=32,
        n_kv_heads=32, head_dim=80, d_ff=6912, vocab=50304,
        rotary_pct=0.25, tie_embeddings=False,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="stablelm-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=96, vocab=256, rotary_pct=0.25,
        tie_embeddings=False, remat=False,
    )


ARCH = ArchSpec(
    id="stablelm-3b", family="dense", kind="lm",
    make_full=full, make_smoke=smoke,
    note="MHA (kv=heads): largest per-token KV cache of the dense set. "
         "long_500k skipped (pure full attention). RMSNorm stands in for "
         "LayerNorm (dims per assignment).",
    source="hf:stabilityai/stablelm-2-1_6b (scaled per assignment)",
)
