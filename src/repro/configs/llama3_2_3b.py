"""llama3.2-3b — 28L d3072 24H (kv8) d_ff 8192 vocab 128256. [hf:meta-llama]"""
from repro.configs.base import ArchSpec
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig(
        name="llama3.2-3b", n_layers=28, d_model=3072, n_heads=24,
        n_kv_heads=8, head_dim=128, d_ff=8192, vocab=128256,
        rope_base=500000.0, tie_embeddings=True,
        # §Perf iter 2: at 3B/256-chip scale activations fit HBM without
        # remat -> -20% compute term on train_4k (results/perf/*iter2.json)
        remat=False,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="llama3.2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, remat=False,
    )


ARCH = ArchSpec(
    id="llama3.2-3b", family="dense", kind="lm",
    make_full=full, make_smoke=smoke,
    note="Single dense kernel class: NSFlow folding inapplicable; DSE/"
         "memory-planner only (DESIGN.md §4). long_500k skipped "
         "(pure full attention).",
    source="hf:meta-llama/Llama-3.2-1B (scaled per assignment)",
)
