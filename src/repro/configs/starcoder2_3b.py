"""starcoder2-3b — 30L d3072 24H (kv2) d_ff 12288 vocab 49152, window 4096."""
from repro.configs.base import ArchSpec
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig(
        name="starcoder2-3b", n_layers=30, d_model=3072, n_heads=24,
        n_kv_heads=2, head_dim=128, d_ff=12288, vocab=49152,
        pattern=("local",), window=4096, rope_base=999999.0,
        act="gelu", qkv_bias=True, tie_embeddings=True,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        pattern=("local",), window=16, act="gelu", qkv_bias=True, remat=False,
    )


ARCH = ArchSpec(
    id="starcoder2-3b", family="dense", kind="lm",
    make_full=full, make_smoke=smoke,
    note="Sliding-window (4096) GQA kv=2. long_500k skipped per assignment "
         "grouping (dense family); window caches would bound state.",
    source="arXiv:2402.19173",
)
