from repro.configs.registry import ARCHS, get_arch
from repro.configs.shapes import SHAPES

__all__ = ["ARCHS", "get_arch", "SHAPES"]
