"""granite-moe-1b-a400m — 24L d1024 16H (kv8) MoE 32e top-8, d_ff(expert)=512.

[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.moe import MoEConfig


def full() -> LMConfig:
    return LMConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=8, head_dim=64, d_ff=512, vocab=49155,
        moe=MoEConfig(d_model=1024, d_ff=512, n_experts=32, top_k=8),
        tie_embeddings=True, rope_base=10000.0,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=32, vocab=256,
        moe=MoEConfig(d_model=64, d_ff=32, n_experts=4, top_k=2,
                      capacity_factor=2.0),
        tie_embeddings=True, remat=False,
    )


ARCH = ArchSpec(
    id="granite-moe-1b-a400m", family="moe", kind="lm",
    make_full=full, make_smoke=smoke,
    note="Heterogeneous router/expert kernel mix; NSFlow folding applies "
         "(DESIGN.md §4).",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
