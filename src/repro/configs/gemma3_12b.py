"""gemma3-12b — 48L d3840 16H (kv8) d_ff 15360 vocab 262144, 5:1 local:global.

Local window 1024 @ rope 10k; global rope 1M; qk-norm; (1+w) RMSNorm.
"""
from repro.configs.base import ArchSpec
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig(
        name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16,
        n_kv_heads=8, head_dim=256, d_ff=15360, vocab=262144,
        pattern=("local", "local", "local", "local", "local", "global"),
        window=1024, rope_base=1_000_000.0, rope_base_local=10_000.0,
        qk_norm=True, norm_offset=1.0, embed_scale=True, tie_embeddings=True,
        act="geglu",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="gemma3-smoke", n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=256,
        pattern=("local", "local", "local", "local", "local", "global"),
        window=16, qk_norm=True, norm_offset=1.0, embed_scale=True,
        act="geglu", remat=False,
    )


ARCH = ArchSpec(
    id="gemma3-12b", family="dense", kind="lm",
    make_full=full, make_smoke=smoke, supports_long=True,
    note="Two kernel classes (banded vs full attention) -> dataflow-graph "
         "scheduling applies. long_500k RUNS: 5/6 layers are window-1024 "
         "ring caches; only 8 global layers hold the long cache.",
    source="hf:google/gemma-3-1b-pt (scaled per assignment)",
)
