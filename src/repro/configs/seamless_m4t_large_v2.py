"""seamless-m4t-large-v2 — enc-dec 24L+24L d1024 16H (kv16) d_ff 8192
vocab 256206; speech frontend STUB (frame embeddings). [arXiv:2308.11596]"""
from repro.configs.base import ArchSpec
from repro.models.encdec import EncDecConfig


def full() -> EncDecConfig:
    return EncDecConfig(name="seamless-m4t-large-v2", n_enc_layers=24,
                        n_dec_layers=24, d_model=1024, n_heads=16,
                        n_kv_heads=16, d_ff=8192, vocab=256206)


def smoke() -> EncDecConfig:
    return EncDecConfig(name="seamless-smoke", n_enc_layers=2, n_dec_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                        vocab=256, remat=False)


ARCH = ArchSpec(
    id="seamless-m4t-large-v2", family="audio", kind="encdec",
    make_full=full, make_smoke=smoke,
    note="Encoder/decoder = two dependent streams (the paper's critical-"
         "path case); serving overlaps encode(i+1) with decode(i). Speech "
         "frontend stubbed per brief. long_500k skipped (full attention).",
    source="arXiv:2308.11596",
)
