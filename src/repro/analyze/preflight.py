"""The orchestrated preflight pass — what ``deploy()`` and the CLI run.

:func:`preflight` composes the four check families over a set of
*subjects* (compiled schedules with their configs/workload entries) plus
the AST lint and the registry checks.  Two cost tiers share this one
entry point:

* ``deploy()`` runs the cheap tier on every deployment: jaxpr artifact
  checks over the schedules it just compiled, the (mtime-memoized) lint
  over ``serve/``, and the static registry checks.  No kernels execute,
  nothing compiles.
* the CLI (``python -m repro.analyze``) runs the full tier: every
  declared (workload × bucket × backend-plan) combination, double-trace
  determinism, and the empirical kernel probes.
"""

from __future__ import annotations

import os
from typing import Iterable

from repro.analyze import lint as lint_mod
from repro.analyze import registry_check
from repro.analyze.artifacts import check_schedule
from repro.analyze.findings import AnalysisReport
from repro.analyze.retrace import check_retrace

_REPRO_ROOT = os.path.normpath(
    os.path.join(os.path.dirname(__file__), os.pardir))
_SERVE_DIR = os.path.join(_REPRO_ROOT, "serve")


def preflight(subjects: Iterable = (), *, lint_root: str | None = None,
              probe: bool = False, double_trace: bool = False
              ) -> AnalysisReport:
    """Run every preflight family and return the merged report.

    ``subjects``: iterables of ``(sched, cfg, entry, variant)`` — ``cfg``
    /``entry``/``variant`` may be None (artifact checks still run; the
    cross-bucket spec check needs the entry).  ``lint_root`` defaults to
    the serving sources.  ``probe``/``double_trace`` enable the expensive
    tier (empirical kernel probes, double-trace determinism).
    """
    report = AnalysisReport()
    report.merge(lint_mod.lint_tree(lint_root or _SERVE_DIR))
    report.merge(registry_check.check_registry(probe=probe))
    for subject in subjects:
        sched, cfg, entry, variant = (tuple(subject) + (None,) * 4)[:4]
        report.merge(check_schedule(sched, cfg=cfg))
        report.merge(check_retrace(sched, entry=entry, cfg=cfg,
                                   variant=variant,
                                   double_trace=double_trace))
        report.covered("schedules")
    return report
