"""Repo-specific AST lint over the serving sources (NSF101–NSF104).

These are rules a generic linter cannot know:

* **NSF101** — the serving stack is virtual-clock-driven: every timestamp
  must come from an injectable ``clock``/``wall`` parameter so the
  front-door, the soak benches and the tests can replace time.  A raw
  ``time.perf_counter()`` (or ``time.time/monotonic/sleep``) *call* in
  ``serve/`` silently anchors stats to the host clock.  Parameter
  defaults like ``clock=time.perf_counter`` are attribute references,
  not calls, and pass.
* **NSF102** — inside a jit-traced function body every value is a
  tracer; ``np.asarray``/``np.array``/``jax.device_get`` forces a
  device→host sync per trace and breaks donation.  Jit-traced bodies
  are found structurally: ``@jax.jit``-decorated functions, functions
  whose *name* (or ``self.<method>``) is passed to ``jax.jit(...)``, and
  inner functions returned by ``_make_*`` builder methods (the engine
  convention — the builder's return value is handed straight to jit).
* **NSF103** — per-request RNG must derive from the root seed via
  ``fold_in`` (the ``(seed, uid, index)`` contract); a bare
  ``PRNGKey(...)`` with no ``fold_in`` in the same function means every
  request shares one stream.
* **NSF104** — ``EngineProtocol.submit`` implementations must stamp
  ``rec.dispatch_t`` (directly, via a same-class helper such as
  ``_admit``, or by delegating to another engine's ``.submit``) and must
  stamp it *before* any blocking call, or queue/service latency
  attribution silently charges the wait to the wrong side.
  ``typing.Protocol`` classes are declarations, not implementations, and
  are skipped.
* **NSF105** — overload-control hygiene, two halves.  (a) Every append
  to a queue-like container (name containing queue/pending/inflight/
  backlog/waiting, or the LM engine's ``_open``) in ``serve/`` must be
  *dominated by a bound check*: the same function must compare a
  ``len(...)`` or a cap/depth/bound/limit/max-named value — an
  unchecked queue append is exactly the unbounded-growth failure mode
  the overload control plane exists to prevent.  (b) Control-plane
  modules (``control.py`` / ``slo.py`` / ``sim.py``) may not reference
  ``time`` at all — not even as a parameter default, which NSF101
  permits elsewhere: policy decisions and the soak bench must be
  bit-deterministic under the injected virtual clock, so these modules
  take explicit ``clock``/``now`` arguments or no time source at all.

Only :data:`SERVE_RULES` apply under ``src/repro/serve``; elsewhere in
the tree only the scope-safe NSF102 runs (training code legitimately
builds un-folded init keys, benches legitimately read the host clock).
Results are memoized per ``(path, mtime)`` so ``deploy()`` preflight can
call this on every deployment for free.
"""

from __future__ import annotations

import ast
import os

from repro.analyze.findings import AnalysisReport, Finding, finding

_CLOCK_ATTRS = {"time", "perf_counter", "monotonic", "sleep",
                "process_time"}
# (module alias, attribute) calls that force device->host sync in a trace
_HOST_CALLS = {("np", "asarray"), ("np", "array"),
               ("numpy", "asarray"), ("numpy", "array"),
               ("onp", "asarray"), ("onp", "array"),
               ("jax", "device_get")}
_BLOCKING_ATTRS = {"block_until_ready", "drain_all", "drain_ready",
                   "_drain_one", "result", "join", "sleep"}
# NSF105 (a): queue-like container names whose append sites need a bound
# check, and the value names a Compare counts as a bound
_QUEUE_NAME_HINTS = ("queue", "pending", "inflight", "backlog", "waiting")
_QUEUE_NAMES_EXACT = {"_open"}
_APPEND_ATTRS = {"append", "extend", "appendleft"}
_BOUND_NAME_HINTS = ("cap", "depth", "bound", "limit", "max")
# NSF105 (b): control-plane modules with the strict no-time contract
_CONTROL_PLANE_FILES = {"control.py", "slo.py", "sim.py"}

SERVE_RULES = ("NSF101", "NSF102", "NSF103", "NSF104", "NSF105")
GENERAL_RULES = ("NSF102",)

_CACHE: dict[str, tuple[float, tuple[str, ...], tuple[Finding, ...]]] = {}


def _attr_chain(node: ast.expr) -> list[str]:
    """`jax.random.PRNGKey` -> ["jax", "random", "PRNGKey"] (best effort)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def _is_jax_jit(node: ast.expr) -> bool:
    return _attr_chain(node)[-2:] == ["jax", "jit"] or \
        (isinstance(node, ast.Name) and node.id == "jit")


def _jit_decorated(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        if _is_jax_jit(dec):
            return True
        if isinstance(dec, ast.Call):
            if _is_jax_jit(dec.func):
                return True
            # functools.partial(jax.jit, ...)
            if _attr_chain(dec.func)[-1:] == ["partial"] and dec.args \
                    and _is_jax_jit(dec.args[0]):
                return True
    return False


def _jitted_names(tree: ast.AST) -> set[str]:
    """Function/method names handed to a ``jax.jit(...)`` call site."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jax_jit(node.func) \
                and node.args:
            target = node.args[0]
            if isinstance(target, ast.Name):
                names.add(target.id)
            elif isinstance(target, ast.Attribute):
                names.add(target.attr)   # jax.jit(self._sample)
    return names


def _traced_functions(tree: ast.AST) -> list[ast.FunctionDef]:
    """Every function whose body jit traces (see module docstring)."""
    jitted = _jitted_names(tree)
    traced: list[ast.FunctionDef] = []
    seen: set[int] = set()

    def add(fn: ast.FunctionDef):
        if id(fn) not in seen:
            seen.add(id(fn))
            traced.append(fn)

    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        if _jit_decorated(node) or node.name in jitted:
            add(node)
        if node.name.startswith("_make_"):
            for sub in ast.walk(node):
                if isinstance(sub, ast.FunctionDef) and sub is not node:
                    add(sub)
    return traced


def _check_clock_calls(tree: ast.AST, rel: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if len(chain) == 2 and chain[0] == "time" \
                    and chain[1] in _CLOCK_ATTRS:
                out.append(finding(
                    "NSF101", f"{rel}:{node.lineno}",
                    f"raw time.{chain[1]}() call — read the injectable "
                    "clock/wall parameter instead (defaults may still be "
                    "time.perf_counter)"))
    return out


def _check_host_materialization(tree: ast.AST, rel: str) -> list[Finding]:
    out = []
    for fn in _traced_functions(tree):
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                if len(chain) == 2 and tuple(chain) in _HOST_CALLS:
                    out.append(finding(
                        "NSF102", f"{rel}:{node.lineno}",
                        f"{'.'.join(chain)}() inside jit-traced "
                        f"{fn.name!r} — forces a host sync per trace; "
                        "keep traced bodies jnp-only"))
    return out


def _check_rng_derivation(tree: ast.AST, rel: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        key_lines = [
            sub.lineno for sub in ast.walk(node)
            if isinstance(sub, ast.Call)
            and _attr_chain(sub.func)[-1:] == ["PRNGKey"]]
        if not key_lines:
            continue
        folds = any(isinstance(sub, ast.Attribute) and sub.attr == "fold_in"
                    for sub in ast.walk(node))
        if not folds:
            out.append(finding(
                "NSF103", f"{rel}:{key_lines[0]}",
                f"{node.name!r} builds a PRNGKey but never fold_in-derives "
                "from it — per-request streams must come from "
                "(seed, uid, index)"))
    return out


def _is_protocol(cls: ast.ClassDef) -> bool:
    return any(_attr_chain(b)[-1:] == ["Protocol"] for b in cls.bases)


def _stamps_dispatch_t(fn: ast.FunctionDef) -> int | None:
    """Line of the first ``<x>.dispatch_t = ...`` store in fn, else None."""
    lines = []
    for node in ast.walk(fn):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Attribute) and t.attr == "dispatch_t":
                lines.append(node.lineno)
    return min(lines) if lines else None


def _check_dispatch_stamp(tree: ast.AST, rel: str) -> list[Finding]:
    out = []
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef) or _is_protocol(cls):
            continue
        methods = {n.name: n for n in cls.body
                   if isinstance(n, ast.FunctionDef)}
        submit = methods.get("submit")
        if submit is None:
            continue
        body = [n for n in submit.body
                if not (isinstance(n, ast.Expr)
                        and isinstance(n.value, (ast.Constant, ast.Ellipsis)))]
        if not body:
            continue   # stub body (shouldn't happen outside Protocols)

        stampers = {m for m, f in methods.items()
                    if _stamps_dispatch_t(f) is not None}
        # one transitive hop: helpers that call a stamping helper
        stampers |= {
            m for m, f in methods.items()
            if any(isinstance(n, ast.Call)
                   and isinstance(n.func, ast.Attribute)
                   and isinstance(n.func.value, ast.Name)
                   and n.func.value.id == "self"
                   and n.func.attr in stampers
                   for n in ast.walk(f))}

        stamp_line = _stamps_dispatch_t(submit)
        delegate_line = None
        block_line = None
        for node in ast.walk(submit):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute):
                if f.attr == "submit" and not (
                        isinstance(f.value, ast.Name)
                        and f.value.id == "self"):
                    delegate_line = min(delegate_line or node.lineno,
                                        node.lineno)
                if isinstance(f.value, ast.Name) and f.value.id == "self" \
                        and f.attr in stampers:
                    stamp_line = min(stamp_line or node.lineno, node.lineno)
                if f.attr in _BLOCKING_ATTRS:
                    block_line = min(block_line or node.lineno, node.lineno)

        where = f"{rel}:{submit.lineno}"
        if stamp_line is None and delegate_line is None:
            out.append(finding(
                "NSF104", where,
                f"{cls.name}.submit never stamps dispatch_t (directly, via "
                "a self-method, or by delegating to another .submit) — "
                "latency attribution needs the dispatch timestamp"))
        elif block_line is not None and stamp_line is not None \
                and block_line < stamp_line:
            out.append(finding(
                "NSF104", f"{rel}:{block_line}",
                f"{cls.name}.submit blocks before stamping dispatch_t "
                f"(block at line {block_line}, stamp at {stamp_line}) — "
                "the wait would be charged to queueing, not service"))
    return out


def _container_name(node: ast.expr) -> str | None:
    """The container identifier of an append target: ``self._queue`` ->
    ``_queue``; ``pending[model]`` -> ``pending``; ``q`` -> ``q``."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_queue_name(name: str | None) -> bool:
    if name is None:
        return False
    low = name.lower()
    return name in _QUEUE_NAMES_EXACT or \
        any(h in low for h in _QUEUE_NAME_HINTS)


def _scope_nodes(fn: ast.AST):
    """Nodes of ``fn``'s own scope (nested function bodies excluded — a
    bound check inside a closure doesn't dominate the outer append)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def _has_bound_check(fn: ast.AST) -> bool:
    """A Compare in fn's scope involving len(...) or a bound-named value."""
    for node in _scope_nodes(fn):
        if not isinstance(node, ast.Compare):
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and \
                    isinstance(sub.func, ast.Name) and sub.func.id == "len":
                return True
            name = sub.attr if isinstance(sub, ast.Attribute) else \
                sub.id if isinstance(sub, ast.Name) else None
            if name and any(h in name.lower() for h in _BOUND_NAME_HINTS):
                return True
    return False


def _check_overload_hygiene(tree: ast.AST, rel: str) -> list[Finding]:
    out = []
    # (a) queue appends must be dominated by a bound check
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        appends = [
            node for node in _scope_nodes(fn)
            if isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in _APPEND_ATTRS
            and _is_queue_name(_container_name(node.func.value))]
        if appends and not _has_bound_check(fn):
            for node in appends:
                out.append(finding(
                    "NSF105", f"{rel}:{node.lineno}",
                    f"queue append ({_container_name(node.func.value)}."
                    f"{node.func.attr}) in {fn.name!r} with no bound "
                    "check in the same function — unbounded queue growth "
                    "under overload; compare len()/a cap before growing"))
    # (b) control-plane modules must not reference time at all
    if os.path.basename(rel) in _CONTROL_PLANE_FILES:
        for node in ast.walk(tree):
            bad_line = None
            what = None
            if isinstance(node, ast.Import) and \
                    any(a.name.split(".")[0] == "time" for a in node.names):
                bad_line, what = node.lineno, "import time"
            elif isinstance(node, ast.ImportFrom) and \
                    (node.module or "").split(".")[0] == "time":
                bad_line, what = node.lineno, "from time import ..."
            elif isinstance(node, ast.Attribute):
                chain = _attr_chain(node)
                if len(chain) == 2 and chain[0] == "time" \
                        and chain[1] in _CLOCK_ATTRS:
                    bad_line, what = node.lineno, f"time.{chain[1]} reference"
            if bad_line is not None:
                out.append(finding(
                    "NSF105", f"{rel}:{bad_line}",
                    f"{what} in a control-plane module — policy must be "
                    "deterministic under the virtual clock: take explicit "
                    "clock/now parameters (no time.* even as a default)"))
    return out


_RULE_CHECKS = {
    "NSF101": _check_clock_calls,
    "NSF102": _check_host_materialization,
    "NSF103": _check_rng_derivation,
    "NSF104": _check_dispatch_stamp,
    "NSF105": _check_overload_hygiene,
}


def rules_for_path(path: str) -> tuple[str, ...]:
    """Serve sources get the full serving rule set; the rest of the tree
    gets only the scope-safe rules."""
    norm = path.replace(os.sep, "/")
    if "/serve/" in norm or norm.endswith("/serve"):
        return SERVE_RULES
    return GENERAL_RULES


def lint_file(path: str, rules: tuple[str, ...] | None = None,
              root: str | None = None) -> list[Finding]:
    """Lint one source file; memoized on (path, mtime, rules)."""
    rules = tuple(rules if rules is not None else rules_for_path(path))
    mtime = os.path.getmtime(path)
    hit = _CACHE.get(path)
    if hit is not None and hit[0] == mtime and hit[1] == rules:
        return list(hit[2])
    rel = os.path.relpath(path, root) if root else path
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    out: list[Finding] = []
    for rule in rules:
        out.extend(_RULE_CHECKS[rule](tree, rel))
    _CACHE[path] = (mtime, rules, tuple(out))
    return out


def lint_tree(root: str) -> AnalysisReport:
    """Lint every ``*.py`` under ``root`` (rule set chosen per path)."""
    report = AnalysisReport()
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for name in sorted(filenames):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            report.extend(lint_file(path, root=root))
            report.covered("lint_files")
    return report
