"""Registry-vs-kernel consistency (NSF006) and dispatch floors (NSF007).

The lowering registry makes *claims* about the kernels — which shapes a
lowering can serve, how far its output may drift from the exact XLA
reference, when the kernel stops paying for itself.  Nothing else in the
stack verifies those claims; this module does, two ways:

* **static** — every ``kernels/<name>/`` package must be registered and
  vice versa; every preference chain must terminate in the ``xla``
  reference; kernels sharing the circulant builder (``circ_conv`` /
  ``unbind_classify``) must declare identical compiled-Pallas shape
  predicates (a fix to one that skips the twin is exactly the drift this
  check exists to catch).
* **empirical** (``probe=True``, CLI/tests — deploy()'s cheap preflight
  skips it) — run the shape-constrained kernels' interpret lowering
  against the exact reference at feasible *and* declared-infeasible
  sizes: a conformant output at an "infeasible" size proves the
  predicate over-strict (this check is what demoted the registry's old
  claim that the circulant builder itself needs pow2 dims — only the
  compiled Mosaic path does); an error above the declared epsilon at a
  feasible size proves the equivalence class wrong.

NSF007 cross-checks declared ``dispatch_min_size`` floors against the
source tree: a floor nobody applies (no ``dispatch=True`` call site) is
dead perf policy; a ``dispatch=True`` site for a floorless kernel is a
no-op flag — both warnings.
"""

from __future__ import annotations

import os
import re

import numpy as np

from repro.analyze.findings import AnalysisReport, finding
from repro.backend import registry
from repro.backend.registry import KERNELS

_KERNELS_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "kernels")
_SRC_ROOT = os.path.normpath(
    os.path.join(os.path.dirname(__file__), os.pardir))

# kernels built on the same Pallas machinery must agree on the compiled
# lowering's shape predicate — a constraint correction that skips the twin
# is drift
_TWINS = (("circ_conv", "unbind_classify"),)

# sizes the probe sweeps: non-pow2 / sub-min_size (declared infeasible for
# the constrained kernels) and pow2 controls
_PROBE_SIZES = (5, 12, 33, 8, 32)


def check_static() -> AnalysisReport:
    report = AnalysisReport()
    kernels_dir = os.path.normpath(_KERNELS_DIR)
    dirs = sorted(
        d for d in os.listdir(kernels_dir)
        if os.path.isdir(os.path.join(kernels_dir, d))
        and os.path.exists(os.path.join(kernels_dir, d, "ops.py")))
    for d in dirs:
        if d not in KERNELS:
            report.findings.append(finding(
                "NSF006", f"kernels/{d}",
                "kernel package has no registry entry — its lowerings are "
                "invisible to negotiation and trace replay"))
    for name in KERNELS:
        if name not in dirs:
            report.findings.append(finding(
                "NSF006", f"registry/{name}",
                "registry entry has no kernels/ package (ops.py) behind "
                "it"))
    for name, spec in KERNELS.items():
        if not spec.lowerings[-1].is_ref:
            report.findings.append(finding(
                "NSF006", f"registry/{name}",
                "preference order does not end in the xla reference — "
                "negotiated chains would lose the universal fallback"))
    for a, b in _TWINS:
        try:
            pa = KERNELS[a].by_name("pallas")
            pb = KERNELS[b].by_name("pallas")
        except KeyError:
            continue
        if (pa.requires_pow2, pa.min_size) != (pb.requires_pow2,
                                               pb.min_size):
            report.findings.append(finding(
                "NSF006", f"registry/{a}+{b}",
                f"twin kernels disagree on compiled-Pallas shape "
                f"predicates (pow2={pa.requires_pow2}/min={pa.min_size} "
                f"vs pow2={pb.requires_pow2}/min={pb.min_size}) — they "
                "share the circulant builder, so one of the declarations "
                "is wrong"))
    report.covered("registry_static", len(KERNELS))
    return report


# -- empirical probes ---------------------------------------------------------


def _probe_circ_conv(d: int):
    import jax

    from repro.kernels.circ_conv import ops as cops

    key = jax.random.PRNGKey(d)
    a = jax.random.normal(key, (2, 2, d))
    b = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, d))
    return np.asarray(cops.circ_bind(a, b, "conv"))


def _probe_unbind_classify(d: int):
    import jax

    from repro.kernels.unbind_classify import ops as uops

    k, blocks, n, c = 3, 2, 4, 5
    key = jax.random.PRNGKey(d)
    keys = jax.random.normal(key, (k, blocks, d))
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, blocks * d))
    w = jax.random.normal(jax.random.fold_in(key, 2), (blocks * d, c)) * 0.1
    b = jax.random.normal(jax.random.fold_in(key, 3), (c,)) * 0.1
    return np.asarray(uops.unbind_classify({"w": w, "b": b}, keys, x))


_PROBES = {
    "circ_conv": _probe_circ_conv,
    "unbind_classify": _probe_unbind_classify,
}


def _run_under(kernel: str, lowering: str, fn, size: int):
    plan = registry.negotiate(platform="cpu",
                              override=f"{kernel}={lowering}")
    with registry.use_plan(plan), registry.record_selections() as rec:
        out = fn(size)
    served = {low for k, low in rec if k == kernel}
    return out, served


def check_probes() -> AnalysisReport:
    """Interpret-vs-reference sweep for the shape-constrained kernels."""
    report = AnalysisReport()
    for kernel, fn in _PROBES.items():
        spec = KERNELS[kernel]
        try:
            low = spec.by_name("interpret")
        except KeyError:
            continue
        eps = max(low.epsilon, 1e-5)
        for size in _PROBE_SIZES:
            ref_out, _ = _run_under(kernel, "xla", fn, size)
            got, served = _run_under(kernel, "interpret", fn, size)
            err = float(np.max(np.abs(got - ref_out)))
            where = f"{kernel}/interpret@d={size}"
            report.covered("kernel_probes")
            if "interpret" not in served:
                # the forced-interpret plan fell through to the reference:
                # the predicate declared this size infeasible.  Run the
                # kernel entry point directly — if it conforms, the
                # declaration is over-strict.
                direct = _direct_interpret(kernel, size)
                if direct is not None \
                        and float(np.max(np.abs(direct - ref_out))) <= eps:
                    report.findings.append(finding(
                        "NSF006", where,
                        f"declared infeasible at d={size} but the "
                        "interpret kernel is conformant there — the "
                        "capability predicate is over-strict"))
                continue
            if err > eps:
                report.findings.append(finding(
                    "NSF006", where,
                    f"interpret lowering drifts {err:.2e} from the exact "
                    f"reference at d={size} — above the declared epsilon "
                    f"class {low.epsilon:g}"))
    return report


def _direct_interpret(kernel: str, d: int):
    """Call the kernel entry point in interpret mode, bypassing the plan."""
    import jax

    if kernel == "circ_conv":
        from repro.kernels.circ_conv import kernel as ck

        key = jax.random.PRNGKey(d)
        a = jax.random.normal(key, (2, 2, d))
        b = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, d))
        try:
            return np.asarray(ck.circ_elem(a, b, mode="conv",
                                           interpret=True))
        except Exception:  # noqa: BLE001 — infeasible-for-real is fine
            return None
    if kernel == "unbind_classify":
        from repro.kernels.unbind_classify import ops as uops

        try:
            k, blocks, n, c = 3, 2, 4, 5
            key = jax.random.PRNGKey(d)
            keys = jax.random.normal(key, (k, blocks, d))
            x = jax.random.normal(jax.random.fold_in(key, 1),
                                  (n, blocks * d))
            w = jax.random.normal(jax.random.fold_in(key, 2),
                                  (blocks * d, c)) * 0.1
            b = jax.random.normal(jax.random.fold_in(key, 3), (c,)) * 0.1
            return np.asarray(uops.unbind_classify(
                {"w": w, "b": b}, keys, x, use_kernel=True))
        except Exception:  # noqa: BLE001
            return None
    return None


# -- NSF007: dispatch floors vs call sites ------------------------------------

_DISPATCH_RE = re.compile(
    r"""(?:active|select)\(\s*["'](?P<kernel>\w+)["'][^)]*dispatch=True""",
    re.S)


def check_dispatch_floors(src_root: str | None = None) -> AnalysisReport:
    report = AnalysisReport()
    root = src_root or _SRC_ROOT
    sites: set[str] = set()
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for name in filenames:
            if not name.endswith(".py"):
                continue
            with open(os.path.join(dirpath, name)) as f:
                for m in _DISPATCH_RE.finditer(f.read()):
                    sites.add(m.group("kernel"))
    for name, spec in KERNELS.items():
        if spec.dispatch_min_size and name not in sites:
            report.findings.append(finding(
                "NSF007", f"registry/{name}",
                f"declares dispatch_min_size={spec.dispatch_min_size} but "
                "no dispatch=True call site exists in src/ — the perf "
                "floor is dead policy"))
        if not spec.dispatch_min_size and name in sites:
            report.findings.append(finding(
                "NSF007", f"registry/{name}",
                "has dispatch=True call sites but no dispatch_min_size "
                "floor — the flag is a no-op there"))
    report.covered("dispatch_floors", len(KERNELS))
    return report


def check_registry(probe: bool = False) -> AnalysisReport:
    """NSF006 static (+ empirical when ``probe``) and NSF007."""
    report = check_static()
    report.merge(check_dispatch_floors())
    if probe:
        report.merge(check_probes())
    return report
