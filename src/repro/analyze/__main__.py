"""Preflight CLI: ``python -m repro.analyze [--workload all] [--format json]``.

Runs the *full* static-analysis matrix: every requested NSAI workload ×
variant × backend plan is compiled (abstract — no params materialize)
across the declared batch buckets, then checked for precision flow,
host round-trips, donation, retrace hazards (including double-trace
determinism), registry consistency (including empirical kernel probes),
dispatch floors, and the serving-source AST lint.  Exit code 0 iff no
error-severity finding survives; warnings never fail the run.

The CI ``static-analysis`` leg runs ``--workload all --format json`` and
uploads the findings JSON next to the ``BENCH_*.json`` artifacts.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_REPRO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _build_subjects(models, d, buckets, plan_names, log):
    from repro.backend import registry
    from repro.configs.base import (REASON_WORKLOADS,
                                    compile_reason_schedule)

    subjects = []
    for model in models:
        entry = REASON_WORKLOADS[model]
        cfg = entry.make_config(d=d)
        for variant in entry.variants:
            for plan_name in plan_names:
                override = "" if plan_name == "negotiated" else plan_name
                plan = registry.negotiate(override=override)
                log(f"compiling {model}/{variant} under "
                    f"{plan.tag()} (buckets {buckets})")
                sched = compile_reason_schedule(
                    model, cfg, variant, batch_size=buckets,
                    trace_graph=False, plan=plan)
                subjects.append((sched, cfg, entry, variant))
    return subjects


def main(argv=None) -> int:
    from repro.analyze.preflight import preflight

    p = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="Preflight static analysis over the serving stack")
    p.add_argument("--workload", default="all",
                   help="comma list of NSAI workloads, or 'all' "
                        "(default), or 'none' for lint+registry only")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--out", default=None,
                   help="also write the JSON findings to this path")
    p.add_argument("--d", type=int, default=32,
                   help="block dim for the compiled configs (default 32)")
    p.add_argument("--buckets", default="1,2,4",
                   help="batch-size buckets to compile (default 1,2,4)")
    p.add_argument("--plans", default="negotiated,xla,interpret",
                   help="backend plans to compile each schedule under")
    p.add_argument("--lint-root", default=_REPRO_ROOT,
                   help="source tree for the AST lint (default: the "
                        "repro package)")
    p.add_argument("--no-probe", action="store_true",
                   help="skip the empirical kernel probes")
    p.add_argument("--no-double-trace", action="store_true",
                   help="skip the double-trace determinism proof")
    args = p.parse_args(argv)

    def log(msg):
        if args.format == "text":
            print(f"[analyze] {msg}", file=sys.stderr)

    from repro.configs.base import REASON_WORKLOADS

    if args.workload == "all":
        models = list(REASON_WORKLOADS)
    elif args.workload == "none":
        models = []
    else:
        models = [m.strip() for m in args.workload.split(",") if m.strip()]
        unknown = [m for m in models if m not in REASON_WORKLOADS]
        if unknown:
            p.error(f"unknown workload(s) {unknown}; "
                    f"available: {tuple(REASON_WORKLOADS)}")
    buckets = tuple(int(b) for b in args.buckets.split(","))
    plan_names = [s.strip() for s in args.plans.split(",") if s.strip()]

    subjects = _build_subjects(models, args.d, buckets, plan_names, log)
    report = preflight(subjects, lint_root=args.lint_root,
                       probe=not args.no_probe,
                       double_trace=not args.no_double_trace)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(report.to_json(indent=2))
    if args.format == "json":
        print(report.to_json(indent=2))
    else:
        print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
