"""Preflight static analysis over the serving stack.

Two halves (see ISSUE/README "Preflight static analysis"):

* artifact analysis over what the stack already produces — per-stage
  jaxpr checks (:mod:`repro.analyze.artifacts`), retrace-hazard proofs
  (:mod:`repro.analyze.retrace`), registry-vs-kernel consistency
  (:mod:`repro.analyze.registry_check`);
* a repo-specific AST lint over the serving sources
  (:mod:`repro.analyze.lint`).

Entry points: :func:`preflight` (what ``deploy()`` runs), the CLI
``python -m repro.analyze`` (the full matrix incl. empirical kernel
probes and double-trace determinism), and the individual check modules.
"""

from repro.analyze.findings import (AnalysisReport, Finding,
                                    PreflightError, RULES, finding)
from repro.analyze.lint import lint_file, lint_tree
from repro.analyze.preflight import preflight

__all__ = ["AnalysisReport", "Finding", "PreflightError", "RULES",
           "finding", "lint_file", "lint_tree", "preflight"]
