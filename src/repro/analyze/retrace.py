"""Retrace-hazard detection over bucketed schedules (NSF005).

The serving stack's latency model assumes a *closed* jaxpr-signature
set: every admissible admission-group size maps onto a compiled bucket,
every bucket's specs differ from its siblings only in the batch axis,
and tracing a stage twice yields the same jaxpr.  Break any of those and
the engine recompiles mid-traffic — the classic tail-latency cliff no
bench catches until production.  Three checks:

* **bucket closure** — ``covering_bucket(n)`` must resolve inside the
  declared bucket set for every group size up to the largest bucket;
* **batch-axis invariance** — across buckets, each input-spec leaf may
  vary only in axis 0 (and axis 0 must equal the bucket): a non-batch
  axis derived from the group size means unboundedly many signatures;
* **double-trace determinism** (``double_trace=True``, the CLI/test
  mode) — each stage is traced twice and the jaxprs compared as strings;
  any Python-side state leaking into the trace (a counter, a host RNG
  draw baked in as a constant) shows up as a diff and would retrace
  per admission group.
"""

from __future__ import annotations

import re

import jax

from repro.analyze.findings import AnalysisReport, finding
from repro.backend import registry


def check_bucket_closure(sched, where) -> list:
    out = []
    buckets = tuple(sched.batch_buckets)
    if not buckets:
        return out
    for n in range(1, max(buckets) + 1):
        try:
            b = sched.covering_bucket(n)
        except Exception as e:  # noqa: BLE001 — any raise is the finding
            out.append(finding(
                "NSF005", where,
                f"covering_bucket({n}) raises ({e}) — admission groups of "
                f"{n} have no compiled bucket in {buckets}"))
            continue
        if b not in buckets:
            out.append(finding(
                "NSF005", where,
                f"covering_bucket({n}) = {b} is not a declared bucket "
                f"{buckets} — the group would trace a fresh signature"))
    return out


def check_bucket_specs(entry, cfg, variant, buckets, where) -> list:
    """Batch-axis invariance of ``entry.input_specs`` across buckets."""
    out = []
    if not buckets:
        return out
    per_bucket = {}
    for b in buckets:
        specs = entry.input_specs(cfg, b, variant)
        per_bucket[b] = {jax.tree_util.keystr(path): leaf
                         for path, leaf in
                         jax.tree_util.tree_flatten_with_path(specs)[0]}
    keys = {b: set(m) for b, m in per_bucket.items()}
    if len({frozenset(k) for k in keys.values()}) != 1:
        out.append(finding(
            "NSF005", where,
            f"input-spec structure differs across buckets {buckets} — "
            "the stage signature set is not closed"))
        return out
    b0 = buckets[0]
    for key, leaf0 in per_bucket[b0].items():
        for b in buckets:
            leaf = per_bucket[b][key]
            if leaf.dtype != leaf0.dtype:
                out.append(finding(
                    "NSF005", f"{where}{key}",
                    f"dtype varies across buckets ({leaf0.dtype} at "
                    f"bucket {b0}, {leaf.dtype} at {b})"))
                break
            if not leaf.shape or leaf.shape[0] != b:
                out.append(finding(
                    "NSF005", f"{where}{key}",
                    f"leading axis {leaf.shape} at bucket {b} is not the "
                    "bucket size — the batch axis contract is broken"))
                break
            if leaf.shape[1:] != leaf0.shape[1:]:
                out.append(finding(
                    "NSF005", f"{where}{key}",
                    f"non-batch axes vary with the bucket "
                    f"({leaf0.shape} at {b0} vs {leaf.shape} at {b}) — "
                    "group size leaks into a non-batch dimension, so the "
                    "signature set is unbounded"))
                break
    return out


_ADDR = re.compile(r"0x[0-9a-f]+")


def _fresh_trace(fn, consts, bufs) -> str:
    """One genuine retrace: JAX caches traces by function identity +
    avals, so tracing ``fn`` twice directly would compare a trace with
    itself.  A throwaway wrapper defeats the cache; object addresses in
    the rendering (e.g. ``custom_jvp_call``'s thunk params) are masked —
    fresh-per-trace closures are expected, leaked *values* are not."""
    text = str(jax.make_jaxpr(lambda c, b: fn(c, b))(consts, bufs))
    return _ADDR.sub("0x", text)


def check_trace_determinism(sched, where) -> list:
    """Trace every stage twice; differing jaxprs = a retrace per group."""
    out = []
    if sched.input_specs is None or sched.consts_spec is None:
        return out
    plan = sched.plan or registry.get_plan()
    bufs = sched.input_specs
    with registry.use_plan(plan):
        for stage in sched.stages:
            first = _fresh_trace(stage.fn, sched.consts_spec, bufs)
            second = _fresh_trace(stage.fn, sched.consts_spec, bufs)
            if first != second:
                out.append(finding(
                    "NSF005", f"{where}/{stage.name}",
                    f"stage {stage.name!r} traces differently on "
                    "consecutive traces — Python-side state leaks into "
                    "the jaxpr, so every admission group recompiles"))
            bufs = jax.eval_shape(stage.fn, sched.consts_spec, bufs)
    return out


def check_retrace(sched, entry=None, cfg=None, variant: str | None = None,
                  double_trace: bool = False) -> AnalysisReport:
    """All retrace-hazard checks for one compiled schedule.

    ``entry``/``cfg`` (a ``REASON_WORKLOADS`` entry and its config)
    enable the cross-bucket spec check; ``double_trace`` adds the
    determinism proof (CLI/tests — deploy()'s cheap preflight skips it).
    """
    report = AnalysisReport()
    where = f"{sched.workload}/{sched.variant}"
    report.extend(check_bucket_closure(sched, where))
    report.covered("bucket_closure")
    if entry is not None and cfg is not None and sched.batch_buckets:
        report.extend(check_bucket_specs(
            entry, cfg, variant or sched.variant,
            tuple(sched.batch_buckets), where))
        report.covered("bucket_specs")
    if double_trace:
        report.extend(check_trace_determinism(sched, where))
        report.covered("double_trace")
    return report
