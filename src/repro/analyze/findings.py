"""Finding/report datatypes + the preflight rule catalog.

Every check in ``repro.analyze`` emits :class:`Finding`\\ s with a stable
rule ID (``NSF0xx`` = artifact analysis over compiled schedules / jaxprs /
the lowering registry, ``NSF1xx`` = AST lint over the serving sources).
IDs are append-only: a retired rule keeps its number so historical JSON
artifacts stay interpretable.

:class:`AnalysisReport` is the aggregation every entry point returns —
the CLI (``python -m repro.analyze``), ``deploy(preflight=...)`` and the
tests all consume the same structure.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable

SEVERITIES = ("error", "warning", "info")

# rule id -> (default severity, one-line description).  The catalog is the
# single source of truth the README table and the CLI listing render from.
RULES: dict[str, tuple[str, str]] = {
    "NSF001": ("error",
               "precision flow: silent f64 upcast, or a float downcast "
               "inside an int-quantized symbolic stage"),
    "NSF002": ("warning",
               "fake_quant amax reductions of equal rank disagree on axes "
               "within one stage (mixed global/per-problem scales)"),
    "NSF003": ("error",
               "host callback / transfer primitive inside a compiled hot "
               "stage body"),
    "NSF004": ("error",
               "fused-pipeline donation disagrees with the schedule's "
               "platform (missing donor annotation off-CPU, or a CPU "
               "schedule that donates)"),
    "NSF005": ("error",
               "retrace hazard: bucket set not closed over admissible "
               "group sizes, non-batch shape variation across buckets, "
               "or a nondeterministic stage trace"),
    "NSF006": ("error",
               "registry capability predicate disagrees with the kernel "
               "(unregistered kernel dir, over-strict shape predicate, "
               "epsilon class tighter than observed error)"),
    "NSF007": ("warning",
               "dispatch_min_size floor with no dispatch-level call site "
               "(or a dispatch call site on a floorless kernel)"),
    "NSF101": ("error",
               "raw wall-clock call (time.*) outside an injectable "
               "clock/wall parameter default"),
    "NSF102": ("error",
               "host materialization (np.asarray / jax.device_get) inside "
               "a jit-traced function body"),
    "NSF103": ("error",
               "PRNGKey built without fold_in derivation in the same "
               "scope (requests would share one stream)"),
    "NSF104": ("error",
               "EngineProtocol implementation never stamps dispatch_t, or "
               "blocks before stamping it in submit()"),
    "NSF105": ("error",
               "overload-control hygiene: a queue append in serve/ not "
               "dominated by a bound check in the same function, or any "
               "time.* reference in a control-plane module (control/slo/"
               "sim must take explicit clocks)"),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One preflight finding.

    ``where`` is a stable location string: ``path:line`` for lint rules,
    ``workload/variant[/stage]`` for artifact rules, ``kernel/lowering``
    for registry rules.
    """

    rule: str
    severity: str
    where: str
    message: str

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"unknown rule id {self.rule!r}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"{self.rule} [{self.severity}] {self.where}: {self.message}"


def finding(rule: str, where: str, message: str,
            severity: str | None = None) -> Finding:
    """Build a finding at the rule's default severity (overridable)."""
    default = RULES.get(rule, ("error",))[0]  # Finding validates the rule
    return Finding(rule=rule, severity=severity or default,
                   where=where, message=message)


class PreflightError(RuntimeError):
    """Raised by ``deploy(preflight="error")`` when errors survive.

    Carries the full :class:`AnalysisReport` as ``.report`` so callers
    (and tests) can inspect exactly which rules fired without reparsing
    the exception text.
    """

    def __init__(self, report: "AnalysisReport"):
        self.report = report
        super().__init__("preflight failed:\n" + report.render())


@dataclasses.dataclass
class AnalysisReport:
    """Aggregated preflight outcome (what every entry point returns)."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    # what was covered: check names -> how many subjects each examined
    # (schedules traced, files linted, lowerings probed) so "no findings"
    # is distinguishable from "nothing ran"
    coverage: dict = dataclasses.field(default_factory=dict)

    def extend(self, more: Iterable[Finding]):
        self.findings.extend(more)

    def merge(self, other: "AnalysisReport") -> "AnalysisReport":
        self.findings.extend(other.findings)
        for k, v in other.coverage.items():
            self.coverage[k] = self.coverage.get(k, 0) + v
        return self

    def covered(self, check: str, n: int = 1):
        self.coverage[check] = self.coverage.get(check, 0) + n

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def ok(self) -> bool:
        """True when no error-severity finding survived."""
        return not self.errors

    def by_rule(self) -> dict[str, list[Finding]]:
        out: dict[str, list[Finding]] = {}
        for f in self.findings:
            out.setdefault(f.rule, []).append(f)
        return out

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "coverage": dict(self.coverage),
            "findings": [f.to_dict() for f in self.findings],
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    def render(self) -> str:
        """Human-readable multi-line rendering (the CLI text format)."""
        lines = []
        for f in sorted(self.findings,
                        key=lambda f: (SEVERITIES.index(f.severity), f.rule,
                                       f.where)):
            lines.append(f.render())
        cov = ", ".join(f"{k}={v}" for k, v in sorted(self.coverage.items()))
        verdict = "PASS" if self.ok else "FAIL"
        lines.append(f"preflight {verdict}: {len(self.errors)} error(s), "
                     f"{len(self.warnings)} warning(s) [{cov}]")
        return "\n".join(lines)
