"""Per-stage jaxpr checks over compiled StagedSchedules (NSF001–NSF004).

A :class:`~repro.serve.schedule.StagedSchedule` carries everything needed
to re-derive the artifacts a deployment serves: abstract input/consts
specs, the raw stage callables, the lowering plan they trace under, and
the fused jit.  These checks retrace each stage with
:func:`jax.make_jaxpr` (abstract — no compile, no device work) and walk
the equation graph:

* **NSF001 precision flow** — any ``convert_element_type`` introducing
  float64 is an error (the stack is f32/bf16/int; a silent x64 upcast
  doubles every buffer and detunes every kernel); a float32→bf16/f16
  downcast inside a symbolic (``vsa``/``simd``) stage whose config
  declares int-quantized ``symb_precision`` — or an ``nn`` stage under
  int ``nn_precision`` — is an error too: the fake-quant int emulation is
  defined *in f32*, so a half-precision cast silently drops below the
  declared precision class.
* **NSF002 fake_quant axis consistency** — ``fake_quant`` lowers to
  ``abs`` feeding ``reduce_max``; two reductions of equal input rank with
  different axes in one stage mean one tensor quantizes per-problem and
  a same-shaped one globally (a request's numerics would depend on its
  admission group) — warning.
* **NSF003 host round-trips** — callback/infeed/outfeed primitives in a
  hot stage body block the device per dispatch.
* **NSF004 donation** — off-CPU schedules must donate the fused
  pipeline's inter-stage buffer (the lowered text carries an aliasing
  annotation), CPU schedules must not (XLA:CPU ignores donation and
  warns); either mismatch means ``compile_schedule``'s donation policy
  and the artifact disagree.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.analyze.findings import AnalysisReport, finding
from repro.backend import registry

_CALLBACK_MARKERS = ("callback", "infeed", "outfeed", "outside_call",
                     "debug_print")


def _subjaxprs(val):
    if hasattr(val, "eqns"):            # core.Jaxpr
        yield val
    elif hasattr(val, "jaxpr"):         # ClosedJaxpr
        yield val.jaxpr
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _subjaxprs(v)


def walk_eqns(jaxpr):
    """Every equation, recursing into pjit/scan/cond inner jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _subjaxprs(val):
                yield from walk_eqns(sub)


def stage_jaxprs(sched):
    """Yield ``(stage, jaxpr)`` per stage, chaining abstract specs.

    Traces under the schedule's own lowering plan so the jaxprs are the
    ones the deployment actually serves.  Stage ``i``'s input spec is
    stage ``i-1``'s output spec (stage 0 takes the staged batch).
    """
    if sched.input_specs is None or sched.consts_spec is None:
        return
    plan = sched.plan or registry.get_plan()
    bufs = sched.input_specs
    with registry.use_plan(plan):
        for stage in sched.stages:
            yield stage, jax.make_jaxpr(stage.fn)(sched.consts_spec, bufs)
            bufs = jax.eval_shape(stage.fn, sched.consts_spec, bufs)


def _declared_precision(cfg, stream: str) -> str | None:
    """The config's declared precision class for a stage's stream."""
    attr = "nn_precision" if stream == "nn" else "symb_precision"
    return getattr(cfg, attr, None)


def _check_stage_precision(stage, jaxpr, cfg, where) -> list:
    out = []
    declared = _declared_precision(cfg, stage.stream) if cfg is not None \
        else None
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        new = np.dtype(eqn.params.get("new_dtype"))
        old = eqn.invars[0].aval.dtype if eqn.invars else None
        if new == np.float64:
            out.append(finding(
                "NSF001", where,
                f"stage {stage.name!r} converts {old} -> float64 — silent "
                "x64 upcast in a hot stage body (doubles the buffer, "
                "detunes every kernel epsilon)"))
        elif declared in ("int8", "int4") and old == np.float32 \
                and new in (np.dtype("bfloat16"), np.float16):
            out.append(finding(
                "NSF001", where,
                f"stage {stage.name!r} ({stage.stream} stream) downcasts "
                f"float32 -> {new} while the config declares "
                f"{stage.stream}-stream precision {declared!r} — fake-quant "
                "int emulation is defined in f32; this cast drops below "
                "the declared class"))
    return out


def _check_stage_fake_quant(stage, jaxpr, where) -> list:
    abs_outs = set()
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name == "abs":
            abs_outs.update(id(v) for v in eqn.outvars)
    seen: dict[int, set[tuple]] = {}
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name == "reduce_max" and eqn.invars \
                and id(eqn.invars[0]) in abs_outs:
            rank = len(eqn.invars[0].aval.shape)
            axes = tuple(eqn.params.get("axes", ()))
            seen.setdefault(rank, set()).add(axes)
    out = []
    for rank, axes_set in seen.items():
        if len(axes_set) > 1:
            out.append(finding(
                "NSF002", where,
                f"stage {stage.name!r}: fake_quant amax reductions over "
                f"rank-{rank} inputs disagree on axes "
                f"({sorted(axes_set)}) — mixed global/per-problem scales "
                "make a request's numerics depend on its admission group"))
    return out


def _check_stage_callbacks(stage, jaxpr, where) -> list:
    out = []
    for eqn in walk_eqns(jaxpr):
        name = eqn.primitive.name
        if any(m in name for m in _CALLBACK_MARKERS):
            out.append(finding(
                "NSF003", where,
                f"stage {stage.name!r} contains host primitive {name!r} — "
                "a device->host round-trip per dispatch in a hot stage "
                "body"))
    return out


def check_donation(sched, where) -> list:
    """NSF004: the fused pipeline's donation must match the platform."""
    if sched.jit_fused is None or sched.input_specs is None \
            or sched.consts_spec is None:
        return []
    plan = sched.plan or registry.get_plan()
    with registry.use_plan(plan):
        text = sched.jit_fused.lower(sched.consts_spec,
                                     sched.input_specs).as_text()
    donated = text.count("aliasing_output") + text.count("jax.buffer_donor")
    if plan.platform != "cpu" and not donated:
        return [finding(
            "NSF004", where,
            f"fused pipeline on {plan.platform!r} carries no donation "
            "annotation — the inter-stage buffer is copied per group "
            "instead of updated in place")]
    if plan.platform == "cpu" and donated:
        return [finding(
            "NSF004", where,
            "fused pipeline donates its input buffer on CPU — XLA:CPU "
            "ignores donation and warns per compile; compile_schedule "
            "should pass donate_argnums=() off-accelerator",
            severity="warning")]
    return []


def check_schedule(sched, cfg=None, where: str | None = None
                   ) -> AnalysisReport:
    """All artifact checks over one compiled schedule."""
    report = AnalysisReport()
    where = where or f"{sched.workload}/{sched.variant}"
    for stage, jaxpr in stage_jaxprs(sched):
        stage_where = f"{where}/{stage.name}"
        report.extend(_check_stage_precision(stage, jaxpr, cfg, stage_where))
        report.extend(_check_stage_fake_quant(stage, jaxpr, stage_where))
        report.extend(_check_stage_callbacks(stage, jaxpr, stage_where))
        report.covered("stage_jaxprs")
    report.extend(check_donation(sched, where))
    if sched.jit_fused is not None:
        report.covered("fused_donation")
    return report
