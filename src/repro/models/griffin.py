"""Griffin / RecurrentGemma: RG-LRU recurrent blocks + local attention, 1:2.

Assigned arch ``recurrentgemma-9b``: 38L, d_model 4096, MQA (kv=1) window
2048, d_ff 12288, vocab 256000; pattern (recurrent, recurrent, attention).
Decode state: RG-LRU hidden (D,) + conv1d carry per recurrent layer, and a
window-bounded ring KV cache per attention layer — sub-quadratic, so the
``long_500k`` cell runs here.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import attention as attn
from repro.nn import layers, ssm
from repro.models.lm import _xent, _stack_spec


@dataclasses.dataclass(frozen=True)
class GriffinConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    lru_width: int | None = None
    window: int = 2048
    conv_width: int = 4
    pattern: tuple[str, ...] = ("rec", "rec", "attn")
    rope_base: float = 10000.0
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.bfloat16
    remat: bool = True
    scan_unroll: int = 1

    @property
    def rnn_d(self) -> int:
        return self.lru_width or self.d_model

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    def attn_cfg(self) -> attn.AttnConfig:
        return attn.AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                               self.hd, rope_base=self.rope_base,
                               window=self.window)

    def lru(self) -> ssm.RGLRUConfig:
        return ssm.RGLRUConfig(self.rnn_d)

    def plan(self):
        descs = tuple(self.pattern[i % len(self.pattern)]
                      for i in range(self.n_layers))
        u = len(self.pattern)
        reps = self.n_layers // u
        return descs[: reps * u][:u], reps, descs[reps * u:]


def _rec_spec(cfg: GriffinConfig):
    d, r = cfg.d_model, cfg.rnn_d
    return {
        "ln": layers.rmsnorm_spec(d, cfg.param_dtype),
        "in_x": layers.dense_spec(d, r, ("embed", "mlp"), dtype=cfg.param_dtype),
        "in_gate": layers.dense_spec(d, r, ("embed", "mlp"), dtype=cfg.param_dtype),
        "conv": layers.conv1d_spec(r, cfg.conv_width, cfg.param_dtype),
        "lru": ssm.rglru_spec(cfg.lru(), cfg.param_dtype),
        "out": layers.dense_spec(r, d, ("mlp", "embed"), dtype=cfg.param_dtype),
        "ln2": layers.rmsnorm_spec(d, cfg.param_dtype),
        "mlp": layers.glu_mlp_spec(d, cfg.d_ff, cfg.param_dtype),
    }


def _attn_spec(cfg: GriffinConfig):
    return {
        "ln": layers.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "attn": attn.gqa_spec(cfg.attn_cfg(), cfg.param_dtype),
        "ln2": layers.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "mlp": layers.glu_mlp_spec(cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def griffin_spec(cfg: GriffinConfig):
    unit, reps, tail = cfg.plan()
    unit_spec = {f"u{i}": (_rec_spec(cfg) if k == "rec" else _attn_spec(cfg))
                 for i, k in enumerate(unit)}
    return {
        "embed": layers.embedding_spec(cfg.vocab, cfg.d_model, cfg.param_dtype),
        "final_norm": layers.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "body": _stack_spec(unit_spec, reps),
        "tail": [(_rec_spec(cfg) if k == "rec" else _attn_spec(cfg))
                 for k in tail],
    }


def _rec_fwd(cfg: GriffinConfig, p, x):
    h = layers.rmsnorm(p["ln"], x)
    gate = jax.nn.gelu(layers.dense(p["in_gate"], h, cfg.compute_dtype))
    xr = layers.dense(p["in_x"], h, cfg.compute_dtype)
    xr = layers.causal_conv1d(p["conv"], xr, cfg.compute_dtype)
    hr, _ = ssm.rglru(p["lru"], cfg.lru(), xr)
    x = x + layers.dense(p["out"], hr * gate, cfg.compute_dtype)
    h = layers.rmsnorm(p["ln2"], x)
    return x + layers.glu_mlp(p["mlp"], h, compute_dtype=cfg.compute_dtype)


def _attn_fwd(cfg: GriffinConfig, p, x, positions):
    h = layers.rmsnorm(p["ln"], x)
    x = x + attn.attention(p["attn"], cfg.attn_cfg(), h, positions,
                           cfg.compute_dtype)
    h = layers.rmsnorm(p["ln2"], x)
    return x + layers.glu_mlp(p["mlp"], h, compute_dtype=cfg.compute_dtype)


def forward(params, cfg: GriffinConfig, tokens: jax.Array):
    unit, reps, tail = cfg.plan()
    positions = jnp.arange(tokens.shape[1])
    x = layers.embedding(params["embed"], tokens, cfg.compute_dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)

    def unit_fwd(x, up):
        for i, k in enumerate(unit):
            x = _rec_fwd(cfg, up[f"u{i}"], x) if k == "rec" else \
                _attn_fwd(cfg, up[f"u{i}"], x, positions)
        return x, 0.0

    body = jax.checkpoint(unit_fwd) if cfg.remat else unit_fwd
    x, _ = jax.lax.scan(body, x, params["body"], unroll=cfg.scan_unroll)
    for p, k in zip(params["tail"], tail):
        x = _rec_fwd(cfg, p, x) if k == "rec" else _attn_fwd(cfg, p, x, positions)
    return layers.rmsnorm(params["final_norm"], x)


def loss_fn(params, cfg: GriffinConfig, batch) -> jax.Array:
    hidden = forward(params, cfg, batch["tokens"])
    logits = layers.logits(params["embed"], hidden, cfg.compute_dtype)
    return _xent(logits, batch["targets"])


def _rec_state(cfg: GriffinConfig, batch: int):
    return {
        "lru": jax.ShapeDtypeStruct((batch, cfg.rnn_d), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, cfg.rnn_d),
                                     jnp.bfloat16),
    }


def state_shapes(cfg: GriffinConfig, batch: int, max_len: int):
    unit, reps, tail = cfg.plan()
    unit_state = {f"u{i}": (_rec_state(cfg, batch) if k == "rec"
                            else attn.kv_cache_shape(cfg.attn_cfg(), batch, max_len))
                  for i, k in enumerate(unit)}
    return {
        "body": jax.tree.map(lambda s: jax.ShapeDtypeStruct((reps,) + s.shape,
                                                            s.dtype), unit_state),
        "tail": [(_rec_state(cfg, batch) if k == "rec"
                  else attn.kv_cache_shape(cfg.attn_cfg(), batch, max_len))
                 for k in tail],
    }


def init_state(cfg: GriffinConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        state_shapes(cfg, batch, max_len))


def decode_step(params, cfg: GriffinConfig, state, token: jax.Array,
                pos: jax.Array):
    unit, reps, tail = cfg.plan()
    x = layers.embedding(params["embed"], token, cfg.compute_dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)

    def rec_step(p, st, x):
        h = layers.rmsnorm(p["ln"], x)
        gate = jax.nn.gelu(layers.dense(p["in_gate"], h, cfg.compute_dtype))
        xr = layers.dense(p["in_x"], h, cfg.compute_dtype)
        conv_st, xr = layers.causal_conv1d_step(p["conv"], st["conv"], xr)
        lru_st, hr = ssm.rglru_step(p["lru"], cfg.lru(), st["lru"], xr)
        x = x + layers.dense(p["out"], hr * gate, cfg.compute_dtype)
        h = layers.rmsnorm(p["ln2"], x)
        x = x + layers.glu_mlp(p["mlp"], h, compute_dtype=cfg.compute_dtype)
        return {"lru": lru_st, "conv": conv_st.astype(jnp.bfloat16)}, x

    def attn_step(p, st, x):
        h = layers.rmsnorm(p["ln"], x)
        st, a = attn.decode_step(p["attn"], cfg.attn_cfg(), st, h, pos,
                                 cfg.compute_dtype)
        x = x + a
        h = layers.rmsnorm(p["ln2"], x)
        return st, x + layers.glu_mlp(p["mlp"], h, compute_dtype=cfg.compute_dtype)

    def unit_step(x, scanned):
        up, ust = scanned
        new = {}
        for i, k in enumerate(unit):
            if k == "rec":
                new[f"u{i}"], x = rec_step(up[f"u{i}"], ust[f"u{i}"], x)
            else:
                new[f"u{i}"], x = attn_step(up[f"u{i}"], ust[f"u{i}"], x)
        return x, new

    x, body_state = jax.lax.scan(unit_step, x, (params["body"], state["body"]),
                                 unroll=cfg.scan_unroll)
    new_tail = []
    for p, st, k in zip(params["tail"], state["tail"], tail):
        if k == "rec":
            st, x = rec_step(p, st, x)
        else:
            st, x = attn_step(p, st, x)
        new_tail.append(st)
    x = layers.rmsnorm(params["final_norm"], x)
    logits = layers.logits(params["embed"], x, cfg.compute_dtype)
    return {"body": body_state, "tail": new_tail}, logits
