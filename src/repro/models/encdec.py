"""Seamless-M4T-v2-class encoder-decoder backbone (speech-to-text).

Per the brief the speech frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, S_src, D) as if the w2v-BERT conformer
feature extractor had run. The backbone below is the full enc-dec
transformer: bidirectional encoder + causal decoder with cross-attention.
Decode shapes exercise the decoder with a self-attention cache plus static
encoder K/V — the paper's "critical path between two streams" case
(DESIGN.md §4): the serving schedule overlaps encode(batch i+1) with
decode(batch i).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import attention as attn
from repro.nn import layers
from repro.models.lm import _xent, _stack_spec


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    rope_base: float = 10000.0
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.bfloat16
    remat: bool = True
    scan_unroll: int = 1

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    def attn_cfg(self) -> attn.AttnConfig:
        return attn.AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                               self.hd, rope_base=self.rope_base)


def _enc_layer_spec(cfg: EncDecConfig):
    return {
        "ln1": layers.layernorm_spec(cfg.d_model, cfg.param_dtype),
        "attn": attn.gqa_spec(cfg.attn_cfg(), cfg.param_dtype),
        "ln2": layers.layernorm_spec(cfg.d_model, cfg.param_dtype),
        "mlp": layers.mlp_spec(cfg.d_model, cfg.d_ff, cfg.param_dtype, bias=True),
    }


def _dec_layer_spec(cfg: EncDecConfig):
    spec = _enc_layer_spec(cfg)
    spec["ln_x"] = layers.layernorm_spec(cfg.d_model, cfg.param_dtype)
    spec["xattn"] = attn.gqa_spec(cfg.attn_cfg(), cfg.param_dtype)
    return spec


def encdec_spec(cfg: EncDecConfig):
    return {
        "embed": layers.embedding_spec(cfg.vocab, cfg.d_model, cfg.param_dtype),
        "enc": _stack_spec(_enc_layer_spec(cfg), cfg.n_enc_layers),
        "dec": _stack_spec(_dec_layer_spec(cfg), cfg.n_dec_layers),
        "enc_norm": layers.layernorm_spec(cfg.d_model, cfg.param_dtype),
        "dec_norm": layers.layernorm_spec(cfg.d_model, cfg.param_dtype),
    }


def encode(params, cfg: EncDecConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_src, D) stub frontend embeddings -> encoder states."""
    positions = jnp.arange(frames.shape[1])
    acfg = cfg.attn_cfg()
    x = frames.astype(cfg.compute_dtype)

    def layer(x, p):
        h = layers.layernorm(p["ln1"], x)
        q, k, v = attn.gqa_project(p["attn"], acfg, h, positions,
                                   cfg.compute_dtype)
        groups = acfg.n_heads // acfg.n_kv_heads
        k, v = attn._repeat_kv(k, groups), attn._repeat_kv(v, groups)
        mask = jnp.ones((x.shape[1], x.shape[1]), bool)  # bidirectional
        o = attn.attend_full(q, k, v, mask, acfg.scale)
        x = x + jnp.einsum("bshe,hed->bsd", o,
                           p["attn"]["wo"].astype(cfg.compute_dtype))
        h = layers.layernorm(p["ln2"], x)
        return x + layers.mlp(p["mlp"], h, compute_dtype=cfg.compute_dtype), 0.0

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, _ = jax.lax.scan(body, x, params["enc"], unroll=cfg.scan_unroll)
    return layers.layernorm(params["enc_norm"], x)


def decode_train(params, cfg: EncDecConfig, enc_out: jax.Array,
                 tgt_tokens: jax.Array) -> jax.Array:
    positions = jnp.arange(tgt_tokens.shape[1])
    acfg = cfg.attn_cfg()
    x = layers.embedding(params["embed"], tgt_tokens, cfg.compute_dtype)

    def layer(x, p):
        h = layers.layernorm(p["ln1"], x)
        x = x + attn.attention(p["attn"], acfg, h, positions, cfg.compute_dtype)
        h = layers.layernorm(p["ln_x"], x)
        enc_kv = attn.encode_kv(p["xattn"], acfg, enc_out, cfg.compute_dtype)
        x = x + attn.cross_attention(p["xattn"], acfg, h, enc_kv,
                                     cfg.compute_dtype)
        h = layers.layernorm(p["ln2"], x)
        return x + layers.mlp(p["mlp"], h, compute_dtype=cfg.compute_dtype), 0.0

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, _ = jax.lax.scan(body, x, params["dec"], unroll=cfg.scan_unroll)
    return layers.layernorm(params["dec_norm"], x)


def loss_fn(params, cfg: EncDecConfig, batch) -> jax.Array:
    """batch: {frames (B,Ssrc,D), tgt_tokens (B,Stgt), tgt_targets}."""
    enc_out = encode(params, cfg, batch["frames"])
    hidden = decode_train(params, cfg, enc_out, batch["tgt_tokens"])
    logits = layers.logits(params["embed"], hidden, cfg.compute_dtype)
    return _xent(logits, batch["tgt_targets"])


def cache_shapes(cfg: EncDecConfig, batch: int, max_len: int, src_len: int):
    acfg = cfg.attn_cfg()
    per_layer = {
        "self": attn.kv_cache_shape(acfg, batch, max_len),
        "cross": {
            "k": jax.ShapeDtypeStruct((batch, src_len, cfg.n_kv_heads, cfg.hd),
                                      jnp.bfloat16),
            "v": jax.ShapeDtypeStruct((batch, src_len, cfg.n_kv_heads, cfg.hd),
                                      jnp.bfloat16),
        },
    }
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_dec_layers,) + s.shape, s.dtype),
        per_layer)


def init_caches(params, cfg: EncDecConfig, enc_out: jax.Array, max_len: int):
    """Build decode caches: empty self-attn cache + precomputed cross K/V."""
    acfg = cfg.attn_cfg()
    b = enc_out.shape[0]

    def per_layer(p, _):
        kv = attn.encode_kv(p["xattn"], acfg, enc_out, cfg.compute_dtype)
        return _, {"self": attn.init_kv_cache(acfg, b, max_len),
                   "cross": jax.tree.map(lambda x: x.astype(jnp.bfloat16), kv)}

    _, caches = jax.lax.scan(lambda c, p: per_layer(p, c), 0, params["dec"])
    return caches


def decode_step(params, cfg: EncDecConfig, caches, token: jax.Array,
                pos: jax.Array):
    acfg = cfg.attn_cfg()
    x = layers.embedding(params["embed"], token, cfg.compute_dtype)

    def layer(x, scanned):
        p, c = scanned
        h = layers.layernorm(p["ln1"], x)
        self_c, a = attn.decode_step(p["attn"], acfg, c["self"], h, pos,
                                     cfg.compute_dtype)
        x = x + a
        h = layers.layernorm(p["ln_x"], x)
        xa = attn.cross_attention(p["xattn"], acfg, h[:, None, :], c["cross"],
                                  cfg.compute_dtype)[:, 0]
        x = x + xa
        h = layers.layernorm(p["ln2"], x)
        x = x + layers.mlp(p["mlp"], h[:, None, :],
                           compute_dtype=cfg.compute_dtype)[:, 0]
        return x, {"self": self_c, "cross": c["cross"]}

    x, new_caches = jax.lax.scan(layer, x, (params["dec"], caches),
                                 unroll=cfg.scan_unroll)
    x = layers.layernorm(params["dec_norm"], x)
    return new_caches, layers.logits(params["embed"], x, cfg.compute_dtype)
