"""InternVL2-style VLM backbone: LLM over [image patch embeds ‖ text tokens].

Per the assignment brief the modality frontend is a STUB — ``input_specs``
supplies precomputed patch embeddings (B, n_img, d_model) as if InternViT +
the MLP projector had run; the assigned backbone (InternLM2-20B class) is
the full transformer below. Training computes loss on text positions only;
decode is standard LM decode over a cache whose prefix holds image tokens.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    lm: lm.LMConfig
    n_img_tokens: int = 1024


def vlm_spec(cfg: VLMConfig):
    return lm.lm_spec(cfg.lm)


def forward(params, cfg: VLMConfig, patch_embeds: jax.Array, tokens: jax.Array):
    """patch_embeds: (B, N_img, D) [stub frontend output]; tokens: (B, S)."""
    c = cfg.lm
    x_txt = layers.embedding(params["embed"], tokens, c.compute_dtype)
    x = jnp.concatenate([patch_embeds.astype(c.compute_dtype), x_txt], axis=1)
    # reuse the LM body on pre-built embeddings
    plan = lm.stage_plan(c)
    positions = jnp.arange(x.shape[1])
    aux_total = 0.0
    for p, (a, f) in zip(params["prefix"], plan.prefix):
        x, aux = lm._layer_fwd(c, a, f, p, x, positions)
        aux_total += aux
    if plan.repeats:
        def unit_fwd(x, up):
            aux_u = 0.0
            for i, (a, f) in enumerate(plan.unit):
                x, aux = lm._layer_fwd(c, a, f, up[f"u{i}"], x, positions)
                aux_u += aux
            return x, aux_u
        if c.remat:
            unit_fwd = jax.checkpoint(unit_fwd)
        x, auxs = jax.lax.scan(unit_fwd, x, params["body"], unroll=c.scan_unroll)
        aux_total += jnp.sum(auxs)
    for p, (a, f) in zip(params["tail"], plan.tail):
        x, aux = lm._layer_fwd(c, a, f, p, x, positions)
        aux_total += aux
    x = layers.rmsnorm(params["final_norm"], x, offset=c.norm_offset)
    return x, aux_total


def loss_fn(params, cfg: VLMConfig, batch) -> jax.Array:
    """batch: {patch_embeds, tokens, targets} — loss on text span only."""
    hidden, aux = forward(params, cfg, batch["patch_embeds"], batch["tokens"])
    text_hidden = hidden[:, cfg.n_img_tokens:, :]
    logits = lm.lm_logits(params, cfg.lm, text_hidden)
    return lm._xent(logits, batch["targets"]) + 0.01 * aux


# decode: identical machinery to the text LM (image prefix lives in cache)
cache_shapes = lambda cfg, batch, max_len: lm.cache_shapes(cfg.lm, batch, max_len)
init_caches = lambda cfg, batch, max_len: lm.init_caches(cfg.lm, batch, max_len)


def decode_step(params, cfg: VLMConfig, caches, token, pos):
    return lm.decode_step(params, cfg.lm, caches, token, pos)
