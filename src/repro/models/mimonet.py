"""MIMONet — computation in superposition (Menet et al., NeurIPS'23), in JAX.

K inputs are VSA-bound with per-channel keys, bundled into ONE superposed
code, pushed through a single shared trunk (one forward pass for K inputs),
then unbound per channel and classified. The binding/unbinding steps are the
paper's circular-convolution kernels; the trunk is the NN stream.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.data.raven import RavenConfig
from repro.nn import init as nninit
from repro.nn import layers, resnet
from repro.vsa import ops as vsa


@dataclasses.dataclass(frozen=True)
class MIMONetConfig:
    raven: RavenConfig = RavenConfig()
    n_channels: int = 2     # K superposed inputs
    blocks: int = 4
    d: int = 128
    cnn_width: int = 8
    trunk_layers: int = 2
    trunk_hidden: int = 1024
    n_classes: int = 5      # classify shape type


def mimonet_spec(cfg: MIMONetConfig):
    code_dim = cfg.blocks * cfg.d
    rcfg = resnet.ResNetConfig(in_channels=1, width=cfg.cnn_width,
                               out_dim=code_dim)
    trunk = []
    for _ in range(cfg.trunk_layers):
        trunk.append({
            "up": layers.dense_spec(code_dim, cfg.trunk_hidden, ("embed", "mlp"),
                                    bias=True),
            "down": layers.dense_spec(cfg.trunk_hidden, code_dim, ("mlp", "embed"),
                                      bias=True),
        })
    return {
        "encoder": resnet.resnet_spec(rcfg),
        "trunk": trunk,
        "head": layers.dense_spec(code_dim, cfg.n_classes, ("embed", None), bias=True),
    }


def mimonet_keys(cfg: MIMONetConfig, key: jax.Array):
    """Static unitary binding keys, one per MIMO channel (exactly invertible)."""
    return vsa.unitary_codebook(key, cfg.n_channels, cfg.blocks, cfg.d)


@functools.partial(jax.jit, static_argnames=("cfg", "train"))
def forward(params, keys, cfg: MIMONetConfig, images: jax.Array, train: bool = False):
    """images: (N, K, H, W, 1) -> logits (N, K, n_classes).

    ONE trunk pass for all K channels — that is the MIMONet claim.
    """
    n, k, h, w, c = images.shape
    rcfg = resnet.ResNetConfig(in_channels=1, width=cfg.cnn_width,
                               out_dim=cfg.blocks * cfg.d)
    feats = resnet.resnet(params["encoder"], rcfg, images.reshape(n * k, h, w, c),
                          train=True, compute_dtype=jnp.float32)  # stateless BN
    codes = feats.reshape(n, k, cfg.blocks, cfg.d)
    bound = vsa.bind(codes, keys[None])                      # per-channel keying
    superposed = jnp.sum(bound, axis=1).reshape(n, -1)       # bundle: (N, B*d)
    x = superposed
    for lyr in params["trunk"]:
        hdn = jax.nn.gelu(layers.dense(lyr["up"], x, jnp.float32))
        x = x + layers.dense(lyr["down"], hdn, jnp.float32)  # residual trunk
    out_codes = x.reshape(n, 1, cfg.blocks, cfg.d)
    unbound = vsa.unbind(jnp.broadcast_to(keys[None], (n, k, cfg.blocks, cfg.d)),
                         jnp.broadcast_to(out_codes, (n, k, cfg.blocks, cfg.d)))
    return layers.dense(params["head"], unbound.reshape(n, k, -1), jnp.float32)


def loss_fn(params, keys, cfg: MIMONetConfig, images: jax.Array, labels: jax.Array):
    logits = forward(params, keys, cfg, images, train=True)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))


def accuracy(params, keys, cfg: MIMONetConfig, images, labels) -> float:
    logits = forward(params, keys, cfg, images)
    return float(jnp.mean(jnp.argmax(logits, -1) == labels))
