"""MIMONet — computation in superposition (Menet et al., NeurIPS'23), in JAX.

K inputs are VSA-bound with per-channel keys, bundled into ONE superposed
code, pushed through a single shared trunk (one forward pass for K inputs),
then unbound per channel and classified. The binding/unbinding steps are the
paper's circular-convolution kernels; the trunk is the NN stream.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.backend import registry
from repro.data.raven import RavenConfig
from repro.kernels.unbind_classify import ops as uc_ops
from repro.nn import init as nninit
from repro.nn import layers, resnet
from repro.vsa import ops as vsa


@dataclasses.dataclass(frozen=True)
class MIMONetConfig:
    raven: RavenConfig = RavenConfig()
    n_channels: int = 2     # K superposed inputs
    blocks: int = 4
    d: int = 128
    cnn_width: int = 8
    trunk_layers: int = 2
    trunk_hidden: int = 1024
    n_classes: int = 5      # classify shape type


def mimonet_spec(cfg: MIMONetConfig):
    code_dim = cfg.blocks * cfg.d
    rcfg = resnet.ResNetConfig(in_channels=1, width=cfg.cnn_width,
                               out_dim=code_dim)
    trunk = []
    for _ in range(cfg.trunk_layers):
        trunk.append({
            "up": layers.dense_spec(code_dim, cfg.trunk_hidden, ("embed", "mlp"),
                                    bias=True),
            "down": layers.dense_spec(cfg.trunk_hidden, code_dim, ("mlp", "embed"),
                                      bias=True),
        })
    return {
        "encoder": resnet.resnet_spec(rcfg),
        "trunk": trunk,
        "head": layers.dense_spec(code_dim, cfg.n_classes, ("embed", None), bias=True),
    }


def mimonet_keys(cfg: MIMONetConfig, key: jax.Array):
    """Static unitary binding keys, one per MIMO channel (exactly invertible)."""
    return vsa.unitary_codebook(key, cfg.n_channels, cfg.blocks, cfg.d)


# -- pipeline stages (the serving schedule binds these 1:1) -----------------
# encode (nn) -> superpose (vsa) -> trunk (nn) -> unbind (vsa) -> classify
# (simd) — the three-stream pipeline the serving schedule compiles.


def encode(params, cfg: MIMONetConfig, images: jax.Array, train: bool = False,
           bn_stats: dict | None = None):
    """images: (N, K, H, W, 1) -> per-channel codes (N, K, blocks, d).

    ``train=False`` evaluates BN with running stats so a served request's
    codes are independent of its admission group; ``train=True`` uses batch
    statistics and records them in ``bn_stats`` for the trainer's EMA
    update (``apply_bn_stats``).
    """
    n, k, h, w, c = images.shape
    rcfg = resnet.ResNetConfig(in_channels=1, width=cfg.cnn_width,
                               out_dim=cfg.blocks * cfg.d)
    feats = resnet.resnet(params["encoder"], rcfg, images.reshape(n * k, h, w, c),
                          train=train, compute_dtype=jnp.float32,
                          bn_stats=bn_stats)
    return feats.reshape(n, k, cfg.blocks, cfg.d)


def superpose(keys, codes: jax.Array) -> jax.Array:
    """Bind each channel with its key and bundle: (N, K, B, d) -> (N, B*d)."""
    n = codes.shape[0]
    bound = vsa.bind(codes, keys[None])                      # per-channel keying
    return jnp.sum(bound, axis=1).reshape(n, -1)             # bundle: (N, B*d)


def trunk(params, x: jax.Array) -> jax.Array:
    """ONE residual-MLP pass over the superposed code — the MIMONet claim."""
    for lyr in params["trunk"]:
        hdn = jax.nn.gelu(layers.dense(lyr["up"], x, jnp.float32))
        x = x + layers.dense(lyr["down"], hdn, jnp.float32)  # residual trunk
    return x


def unbind(keys, cfg: MIMONetConfig, x: jax.Array) -> jax.Array:
    """Recover per-channel codes from the trunk output: (N, B*d) ->
    (N, K, blocks*d)."""
    n, k = x.shape[0], cfg.n_channels
    out_codes = x.reshape(n, 1, cfg.blocks, cfg.d)
    unbound = vsa.unbind(jnp.broadcast_to(keys[None], (n, k, cfg.blocks, cfg.d)),
                         jnp.broadcast_to(out_codes, (n, k, cfg.blocks, cfg.d)))
    return unbound.reshape(n, k, -1)


def classify(params, unbound: jax.Array) -> jax.Array:
    """Per-channel head: (N, K, blocks*d) -> logits (N, K, n_classes)."""
    return layers.dense(params["head"], unbound, jnp.float32)


def unbind_classify(params, keys, cfg: MIMONetConfig, x: jax.Array,
                    use_kernel: bool | None = None) -> jax.Array:
    """Fused symbolic tail: (N, B*d) -> logits (N, K, n_classes).

    One launch for unbind + classify when the plan negotiates the
    ``unbind_classify`` kernel; the reference route is literally
    ``classify(unbind(...))``, so below the dispatch threshold this is
    bit-identical to the staged pair.
    """
    if use_kernel is None:
        use_kernel = not registry.active("unbind_classify", size=cfg.d,
                                         dispatch=True).is_ref
    if not use_kernel:
        return classify(params, unbind(keys, cfg, x))
    return uc_ops.unbind_classify(params["head"], keys, x)


@functools.partial(jax.jit, static_argnames=("cfg", "train"))
def forward(params, keys, cfg: MIMONetConfig, images: jax.Array, train: bool = False):
    """images: (N, K, H, W, 1) -> logits (N, K, n_classes).

    Composes the five pipeline stages in one jit — the offline reference
    the compiled serving schedule must match.
    """
    codes = encode(params, cfg, images, train=train)
    x = trunk(params, superpose(keys, codes))
    return classify(params, unbind(keys, cfg, x))


def loss_fn(params, keys, cfg: MIMONetConfig, images: jax.Array, labels: jax.Array):
    """Per-channel CE.  Returns ``(loss, bn_stats)`` — fold the aux BN
    batch statistics into the running stats with ``apply_bn_stats`` so
    eval-mode serving sees trained statistics (mirrors the NVSA trainer)."""
    bn_stats: dict = {}
    codes = encode(params, cfg, images, train=True, bn_stats=bn_stats)
    logits = classify(params, unbind(keys, cfg,
                                     trunk(params, superpose(keys, codes))))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1)), \
        bn_stats


def apply_bn_stats(params, bn_stats: dict, momentum: float = 0.9):
    """EMA-fold one step's encoder BN batch statistics into the running
    stats (functional — returns a new params tree)."""
    return {**params,
            "encoder": layers.bn_apply_stats(params["encoder"], bn_stats,
                                             momentum)}


def accuracy(params, keys, cfg: MIMONetConfig, images, labels) -> float:
    logits = forward(params, keys, cfg, images)
    return float(jnp.mean(jnp.argmax(logits, -1) == labels))
