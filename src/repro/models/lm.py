"""Generic decoder-only transformer LM covering the dense / MoE / MLA /
local:global assigned architectures.

Layer heterogeneity (gemma3's 5:1 local:global, deepseek's 3-dense prefix +
MoE body) is expressed as a repeating *pattern unit*: parameters for one
unit are stacked over the repeat count and the body runs as one
``lax.scan`` — so a 61-layer model lowers to unit-sized HLO regardless of
depth (this is what keeps the 512-device dry-run compile tractable).

Three entry points per model:
  loss_fn(params, batch)          — training loss (causal LM)
  prefill(params, tokens)         — returns (logits_last, caches)
  decode_step(params, caches, token, pos)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn import attention as attn
from repro.nn import init as nninit
from repro.nn import layers, moe as moe_mod
from repro.nn.init import P


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    rope_base: float = 10000.0
    rope_base_local: float = 10000.0
    rotary_pct: float = 1.0
    attn_kind: str = "gqa"              # gqa | mla
    mla: attn.MLAConfig | None = None
    window: int | None = None           # sliding window for "local" layers
    pattern: tuple[str, ...] = ("global",)  # repeating attention pattern unit
    first_k_dense: int = 0              # deepseek: dense-FFN prefix depth
    dense_d_ff: int | None = None       # FFN width of the dense prefix
    moe: moe_mod.MoEConfig | None = None
    act: str = "swiglu"                 # swiglu | geglu | gelu
    norm_offset: float = 0.0            # gemma-style (1 + scale)
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = True
    mtp: bool = False                   # deepseek multi-token prediction head
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    logit_softcap: float | None = None
    embed_scale: bool = False           # gemma: embeddings × sqrt(d_model)
    scan_unroll: int = 1  # >= repeats fully unrolls (calibration / perf knob)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_cfg(self, kind: str) -> attn.AttnConfig:
        local = kind == "local"
        return attn.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            rope_base=self.rope_base_local if local else self.rope_base,
            rotary_dim=int(self.hd * self.rotary_pct) or None,
            window=self.window if local else None,
            qkv_bias=self.qkv_bias, qk_norm=self.qk_norm,
        )


# ---------------------------------------------------------------------------
# Stage structure: (prefix unrolled layers, scanned pattern unit × repeats)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StagePlan:
    prefix: tuple[tuple[str, str], ...]   # (attn_kind, ffn_kind) per layer
    unit: tuple[tuple[str, str], ...]
    repeats: int
    tail: tuple[tuple[str, str], ...]


def stage_plan(cfg: LMConfig) -> StagePlan:
    descs = []
    for i in range(cfg.n_layers):
        akind = cfg.pattern[i % len(cfg.pattern)]
        fkind = "dense" if (cfg.moe is None or i < cfg.first_k_dense) else "moe"
        descs.append((akind, fkind))
    prefix = tuple(descs[: cfg.first_k_dense])
    body = descs[cfg.first_k_dense:]
    # find the smallest unit length that tiles the body
    for u in range(1, min(len(cfg.pattern) * 2 + 1, max(2, len(body))) + 1):
        reps = len(body) // u
        if reps >= 1 and all(body[i] == body[i % u] for i in range(reps * u)):
            tail = tuple(body[reps * u:])
            return StagePlan(prefix, tuple(body[:u]), reps, tail)
    return StagePlan(prefix, tuple(), 0, tuple(body))


def _layer_spec(cfg: LMConfig, akind: str, fkind: str):
    dt = cfg.param_dtype
    spec = {
        "ln1": layers.rmsnorm_spec(cfg.d_model, dt),
        "ln2": layers.rmsnorm_spec(cfg.d_model, dt),
    }
    if cfg.attn_kind == "mla":
        spec["attn"] = attn.mla_spec(cfg.mla, dt)
    else:
        spec["attn"] = attn.gqa_spec(cfg.attn_cfg(akind), dt)
    if fkind == "moe":
        spec["ffn"] = moe_mod.moe_spec(cfg.moe, dt)
    else:
        d_ff = cfg.dense_d_ff or cfg.d_ff
        if cfg.act in ("swiglu", "geglu"):
            spec["ffn"] = layers.glu_mlp_spec(cfg.d_model, d_ff, dt)
        else:
            spec["ffn"] = layers.mlp_spec(cfg.d_model, d_ff, dt, bias=cfg.qkv_bias)
    return spec


def _stack_spec(spec, n: int):
    """Prepend a (scanned) layer axis to every P in a spec tree."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale,
                    p.dtype, p.constant),
        spec, is_leaf=lambda x: isinstance(x, P))


def lm_spec(cfg: LMConfig):
    plan = stage_plan(cfg)
    spec = {
        "embed": layers.embedding_spec(cfg.vocab, cfg.d_model, cfg.param_dtype),
        "final_norm": layers.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "prefix": [_layer_spec(cfg, a, f) for a, f in plan.prefix],
        "tail": [_layer_spec(cfg, a, f) for a, f in plan.tail],
    }
    if plan.repeats:
        unit = {f"u{i}": _layer_spec(cfg, a, f) for i, (a, f) in enumerate(plan.unit)}
        spec["body"] = _stack_spec(unit, plan.repeats)
    if not cfg.tie_embeddings:
        spec["lm_head"] = layers.dense_spec(cfg.d_model, cfg.vocab,
                                            ("embed", "vocab"), dtype=cfg.param_dtype)
    if cfg.mtp:
        spec["mtp"] = {
            "proj": layers.dense_spec(2 * cfg.d_model, cfg.d_model,
                                      ("embed", "embed2"), dtype=cfg.param_dtype),
            "layer": _layer_spec(cfg, cfg.pattern[0],
                                 "moe" if cfg.moe else "dense"),
            "norm": layers.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        }
    return spec


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _ffn(cfg: LMConfig, params, fkind: str, x):
    if fkind == "moe":
        y, aux = moe_mod.moe_block(params, cfg.moe, x, cfg.compute_dtype)
        return y, aux
    if cfg.act == "swiglu":
        return layers.glu_mlp(params, x, layers.swiglu, cfg.compute_dtype), 0.0
    if cfg.act == "geglu":
        return layers.glu_mlp(params, x, layers.geglu, cfg.compute_dtype), 0.0
    return layers.mlp(params, x, jax.nn.gelu, cfg.compute_dtype), 0.0


def _layer_fwd(cfg: LMConfig, akind: str, fkind: str, params, x, positions):
    h = layers.rmsnorm(params["ln1"], x, offset=cfg.norm_offset)
    if cfg.attn_kind == "mla":
        a = attn.mla_attention(params["attn"], cfg.mla, h, positions,
                               cfg.compute_dtype)
    else:
        a = attn.attention(params["attn"], cfg.attn_cfg(akind), h, positions,
                           cfg.compute_dtype)
    x = x + a
    h = layers.rmsnorm(params["ln2"], x, offset=cfg.norm_offset)
    f, aux = _ffn(cfg, params["ffn"], fkind, h)
    return x + f, aux


def forward(params, cfg: LMConfig, tokens: jax.Array):
    """tokens: (B, S) -> (hidden (B, S, D), aux_loss)."""
    plan = stage_plan(cfg)
    positions = jnp.arange(tokens.shape[1])
    x = layers.embedding(params["embed"], tokens, cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    aux_total = 0.0

    for p, (a, f) in zip(params["prefix"], plan.prefix):
        x, aux = _layer_fwd(cfg, a, f, p, x, positions)
        aux_total = aux_total + aux

    if plan.repeats:
        def unit_fwd(x, unit_params):
            aux_u = 0.0
            for i, (a, f) in enumerate(plan.unit):
                x, aux = _layer_fwd(cfg, a, f, unit_params[f"u{i}"], x, positions)
                aux_u = aux_u + aux
            return x, aux_u
        if cfg.remat:
            unit_fwd = jax.checkpoint(unit_fwd)
        x, auxs = jax.lax.scan(unit_fwd, x, params["body"],
                               unroll=cfg.scan_unroll)
        aux_total = aux_total + jnp.sum(auxs)

    for p, (a, f) in zip(params["tail"], plan.tail):
        x, aux = _layer_fwd(cfg, a, f, p, x, positions)
        aux_total = aux_total + aux

    x = layers.rmsnorm(params["final_norm"], x, offset=cfg.norm_offset)
    return x, aux_total


def lm_logits(params, cfg: LMConfig, hidden: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        out = layers.logits(params["embed"], hidden, cfg.compute_dtype)
    else:
        out = layers.dense(params["lm_head"], hidden, cfg.compute_dtype)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        out = jnp.tanh(out.astype(jnp.float32) / c) * c
    return out


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, targets[..., None], axis=-1))


def loss_fn(params, cfg: LMConfig, batch) -> jax.Array:
    """batch: {tokens (B,S), targets (B,S)} -> scalar loss."""
    hidden, aux = forward(params, cfg, batch["tokens"])
    loss = _xent(lm_logits(params, cfg, hidden), batch["targets"])
    if cfg.mtp:
        # DeepSeek MTP: one extra depth predicting token t+2 from
        # (hidden_t, embed(target_t)) — sequential-causal variant.
        emb_next = layers.embedding(params["embed"], batch["targets"],
                                    cfg.compute_dtype)
        h2 = layers.dense(params["mtp"]["proj"],
                          jnp.concatenate([hidden, emb_next], axis=-1),
                          cfg.compute_dtype)
        h2, _ = _layer_fwd(cfg, cfg.pattern[0], "moe" if cfg.moe else "dense",
                           params["mtp"]["layer"], h2,
                           jnp.arange(hidden.shape[1]))
        h2 = layers.rmsnorm(params["mtp"]["norm"], h2, offset=cfg.norm_offset)
        mtp_logits = lm_logits(params, cfg, h2[:, :-1])
        loss = loss + 0.3 * _xent(mtp_logits, batch["targets"][:, 1:])
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode with stacked caches
# ---------------------------------------------------------------------------


def _layer_cache_shape(cfg: LMConfig, akind: str, batch: int, max_len: int):
    if cfg.attn_kind == "mla":
        return attn.mla_cache_shape(cfg.mla, batch, max_len)
    return attn.kv_cache_shape(cfg.attn_cfg(akind), batch, max_len)


def cache_shapes(cfg: LMConfig, batch: int, max_len: int):
    plan = stage_plan(cfg)
    shapes = {
        "prefix": [_layer_cache_shape(cfg, a, batch, max_len)
                   for a, _ in plan.prefix],
        "tail": [_layer_cache_shape(cfg, a, batch, max_len)
                 for a, _ in plan.tail],
    }
    if plan.repeats:
        unit = {f"u{i}": _layer_cache_shape(cfg, a, batch, max_len)
                for i, (a, _) in enumerate(plan.unit)}
        shapes["body"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((plan.repeats,) + s.shape, s.dtype),
            unit)
    return shapes


def init_caches(cfg: LMConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, max_len))


def _layer_decode(cfg: LMConfig, akind: str, fkind: str, params, cache, x_t, pos):
    h = layers.rmsnorm(params["ln1"], x_t, offset=cfg.norm_offset)
    if cfg.attn_kind == "mla":
        cache, a = attn.mla_decode_step(params["attn"], cfg.mla, cache, h, pos,
                                        cfg.compute_dtype)
    else:
        cache, a = attn.decode_step(params["attn"], cfg.attn_cfg(akind), cache,
                                    h, pos, cfg.compute_dtype)
    x_t = x_t + a
    h = layers.rmsnorm(params["ln2"], x_t, offset=cfg.norm_offset)
    f, _ = _ffn(cfg, params["ffn"], fkind, h[:, None, :])
    return cache, x_t + f[:, 0]


def decode_step(params, cfg: LMConfig, caches, token: jax.Array, pos: jax.Array):
    """token: (B,) int32; pos: scalar int32. Returns (new_caches, logits (B, V))."""
    plan = stage_plan(cfg)
    x = layers.embedding(params["embed"], token, cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    new_prefix = []
    for p, c, (a, f) in zip(params["prefix"], caches["prefix"], plan.prefix):
        c, x = _layer_decode(cfg, a, f, p, c, x, pos)
        new_prefix.append(c)
    new_caches = {"prefix": new_prefix, "tail": []}
    if plan.repeats:
        def unit_step(x, scanned):
            unit_params, unit_cache = scanned
            new_cache = {}
            for i, (a, f) in enumerate(plan.unit):
                ci, x = _layer_decode(cfg, a, f, unit_params[f"u{i}"],
                                      unit_cache[f"u{i}"], x, pos)
                new_cache[f"u{i}"] = ci
            return x, new_cache
        x, body_cache = jax.lax.scan(unit_step, x, (params["body"], caches["body"]),
                                     unroll=cfg.scan_unroll)
        new_caches["body"] = body_cache
    for p, c, (a, f) in zip(params["tail"], caches["tail"], plan.tail):
        c, x = _layer_decode(cfg, a, f, p, c, x, pos)
        new_caches["tail"].append(c)
    x = layers.rmsnorm(params["final_norm"], x, offset=cfg.norm_offset)
    return new_caches, lm_logits(params, cfg, x)


def prefill(params, cfg: LMConfig, tokens: jax.Array, max_len: int | None = None):
    """Run the full context, return (last-token logits, populated caches).

    Implemented as forward + cache writeback via a vectorized projection
    pass per layer (no token loop)."""
    b, s = tokens.shape
    max_len = max_len or s
    hidden, _ = forward(params, cfg, tokens)
    # populate caches by re-projecting K/V per layer (cheap vs attention)
    caches = init_caches(cfg, b, max_len)
    logits = lm_logits(params, cfg, hidden[:, -1:])[:, 0]
    return logits, caches
