"""NVSA — Neuro-Vector-Symbolic Architecture (Hersche et al. 2023), in JAX.

Pipeline (paper Tab. I / Listing 1):
  neuro:    ResNet frontend -> per-attribute PMFs over discrete values
  symbolic: FPE block-code encoding -> VSA rule abduction (which RPM rule
            explains rows 1-2?) -> rule execution on row 3 via circular
            conv/corr (the paper's key kernels) -> candidate match_prob

Mixed precision (paper Sec IV-D / Tab. IV): the NN stream runs fake-quant
int8, the symbolic stream int4 — precision is a config knob so the Tab. IV
sweep is one loop.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.data.raven import RavenConfig, N_RULES
from repro.nn import init as nninit
from repro.nn import layers, resnet
from repro.vsa import fpe, ops as vsa


@dataclasses.dataclass(frozen=True)
class NVSAConfig:
    raven: RavenConfig = RavenConfig()
    blocks: int = 4
    d: int = 256
    cnn_width: int = 16
    cnn_feat: int = 128
    rule_temp: float = 0.1
    answer_temp: float = 0.05
    nn_precision: str = "fp32"    # fp32 | bf16 | int8 | int4
    symb_precision: str = "fp32"  # fp32 | bf16 | int8 | int4
    # Route the attribute heads through the Pallas quantized matmul
    # (kernels/qmatmul) instead of fake-quant einsum when nn_precision is
    # int8/int4 — the served mixed-precision path (Tab. IV on real kernels).
    use_qmatmul: bool = False


# ---------------------------------------------------------------------------
# Parameters (trained) and codebooks (static, seed-derived)
# ---------------------------------------------------------------------------


def nvsa_spec(cfg: NVSAConfig):
    rcfg = resnet.ResNetConfig(in_channels=1, width=cfg.cnn_width,
                               out_dim=cfg.cnn_feat)
    heads = {
        f"attr{i}": layers.dense_spec(cfg.cnn_feat, n, ("mlp", None), bias=True)
        for i, n in enumerate(cfg.raven.attr_sizes)
    }
    return {"frontend": resnet.resnet_spec(rcfg), "heads": heads}


def nvsa_codebooks(cfg: NVSAConfig, key: jax.Array):
    """Static VSA memory: FPE codebooks per attribute + shift codes + roles."""
    keys = jax.random.split(key, cfg.raven.n_attrs + 1)
    books, shifts = [], []
    for i, n in enumerate(cfg.raven.attr_sizes):
        phase = fpe.fpe_base_phase(keys[i], cfg.blocks, cfg.d)
        # values up to 2n-2 occur under arith_plus predictions
        books.append(fpe.fpe_codebook(phase, 2 * n - 1, cfg.d))
        shifts.append(fpe.fpe_encode(phase, jnp.array([1.0, -1.0]), cfg.d))
    roles = vsa.random_codebook(keys[-1], cfg.raven.n_attrs, cfg.blocks, cfg.d)
    return {"books": books, "shifts": shifts, "roles": roles}


# ---------------------------------------------------------------------------
# Precision emulation (Tab. IV)
# ---------------------------------------------------------------------------

_BITS = {"int8": 8, "int4": 4}


def fake_quant(x: jax.Array, precision: str,
               axes: tuple[int, ...] | None = None) -> jax.Array:
    """Symmetric fake quantization.  ``axes=None`` scales by the global
    amax (weights / static codebooks); pass reduction ``axes`` for
    per-slice scales — activations in the serving path quantize per
    problem so a request's numerics never depend on its admission group."""
    if precision == "fp32":
        return x
    if precision == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    bits = _BITS[precision]
    qmax = 2.0 ** (bits - 1) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=axes, keepdims=axes is not None),
                       1e-12)
    scale = amax / qmax
    return jnp.round(x / scale).clip(-qmax - 1, qmax) * scale


def quant_tree(tree, precision: str):
    return jax.tree.map(lambda x: fake_quant(x, precision)
                        if x.dtype in (jnp.float32, jnp.bfloat16) else x, tree)


def quantize_codebooks(cfg: NVSAConfig, codebooks):
    """Static VSA memory at cfg.symb_precision (no-op for fp32/bf16).

    Shared by the offline ``solve`` path and the serving symbolic stream so
    both quantize identically (the served-vs-offline equivalence tests rely
    on this).
    """
    if cfg.symb_precision not in _BITS:
        return codebooks
    sy = cfg.symb_precision
    return {
        "books": [fake_quant(b, sy) for b in codebooks["books"]],
        "shifts": [fake_quant(s, sy) for s in codebooks["shifts"]],
        "roles": fake_quant(codebooks["roles"], sy),
    }


def nvsa_memory_bytes(cfg: NVSAConfig, params) -> int:
    """Model memory footprint at the configured mixed precision (Tab. IV)."""
    bits_nn = {"fp32": 32, "bf16": 16, "int8": 8, "int4": 4}[cfg.nn_precision]
    bits_sy = {"fp32": 32, "bf16": 16, "int8": 8, "int4": 4}[cfg.symb_precision]
    nn_elems = sum(x.size for x in jax.tree.leaves(params))
    sy_elems = sum((2 * n - 1) * cfg.blocks * cfg.d for n in cfg.raven.attr_sizes)
    sy_elems += (2 * cfg.raven.n_attrs + cfg.raven.n_attrs) * cfg.blocks * cfg.d
    return (nn_elems * bits_nn + sy_elems * bits_sy) // 8


# ---------------------------------------------------------------------------
# Neuro frontend
# ---------------------------------------------------------------------------


def frontend_pmfs(params, cfg: NVSAConfig, images: jax.Array,
                  train: bool = False, bn_stats: dict | None = None):
    """images: (N, H, W, 1) -> list of (N, V_attr) PMFs (+ logits).

    ``train=False`` (the serving / ``solve`` default) evaluates BatchNorm
    with the EMA running stats carried in ``params`` — each image's PMFs
    are independent of the rest of the batch, so a served request's answer
    does not depend on its admission group.  ``train=True`` uses batch
    statistics and records them in ``bn_stats`` for the trainer's
    functional EMA update (``frontend_apply_bn_stats``).
    """
    p = params
    if cfg.nn_precision in _BITS:
        p = quant_tree(params, cfg.nn_precision)
    compute_dtype = jnp.bfloat16 if cfg.nn_precision == "bf16" else jnp.float32
    rcfg = resnet.ResNetConfig(in_channels=1, width=cfg.cnn_width,
                               out_dim=cfg.cnn_feat)
    feats = resnet.resnet(p["frontend"], rcfg, images, train=train,
                          compute_dtype=compute_dtype, bn_stats=bn_stats)
    feats = jax.nn.relu(feats)
    if cfg.use_qmatmul and cfg.nn_precision in _BITS:
        # heads on the Pallas qmatmul kernel: int8 activations (per-row
        # scales) x int8/packed-int4 weights (per-column scales)
        from repro.kernels.qmatmul import ops as qops

        bits = _BITS[cfg.nn_precision]
        logits = []
        for i in range(cfg.raven.n_attrs):
            h = p["heads"][f"attr{i}"]
            y = qops.qdense(feats.astype(jnp.float32),
                            h["w"].astype(jnp.float32), bits_w=bits,
                            out_dtype=jnp.float32)
            logits.append(y + h["b"].astype(jnp.float32))
    else:
        logits = [layers.dense(p["heads"][f"attr{i}"], feats,
                               compute_dtype).astype(jnp.float32)
                  for i in range(cfg.raven.n_attrs)]
    return [jax.nn.softmax(l, axis=-1) for l in logits], logits


def frontend_loss(params, cfg: NVSAConfig, images: jax.Array, attrs: jax.Array):
    """Supervised attribute CE (the NVSA frontend training objective).

    Returns ``(loss, bn_stats)`` — the aux BN batch statistics feed the
    trainer's EMA update so eval-mode BN has running stats to use.
    """
    bn_stats: dict = {}
    _, logits = frontend_pmfs(params, cfg, images, train=True,
                              bn_stats=bn_stats)
    loss = 0.0
    for i, l in enumerate(logits):
        logp = jax.nn.log_softmax(l, axis=-1)
        loss = loss - jnp.mean(jnp.take_along_axis(logp, attrs[:, i: i + 1], axis=1))
    return loss / cfg.raven.n_attrs, bn_stats


def frontend_apply_bn_stats(params, bn_stats: dict, momentum: float = 0.9):
    """EMA-fold one step's BN batch statistics into the frontend's running
    stats (functional — returns a new params tree)."""
    return {**params,
            "frontend": layers.bn_apply_stats(params["frontend"], bn_stats,
                                              momentum)}


# ---------------------------------------------------------------------------
# Symbolic reasoning (VSA)
# ---------------------------------------------------------------------------


def _pmf_to_code(pmf: jax.Array, book: jax.Array, n: int) -> jax.Array:
    """Probability-weighted superposition: (N, V) × (Vbig, B, d) -> (N, B, d).
    Only the first ``n`` book entries correspond to observable values."""
    return jnp.einsum("nv,vbd->nbd", pmf, book[:n])


def _rule_predict(rule_idx: int, c1: jax.Array, c2: jax.Array, shifts: jax.Array):
    """Predict row's 3rd code from first two under each RPM rule (FPE algebra)."""
    if rule_idx == 0:  # constant
        return c2
    if rule_idx == 1:  # progression +1
        return vsa.bind(c2, shifts[0][None])
    if rule_idx == 2:  # progression -1
        return vsa.bind(c2, shifts[1][None])
    if rule_idx == 3:  # arithmetic a3 = a1 + a2
        return vsa.bind(c1, c2)
    # arithmetic a3 = a1 - a2  (spectral conj subtraction)
    return vsa.unbind(c2, c1)


def reason(cfg: NVSAConfig, codebooks, ctx_pmfs, cand_pmfs):
    """Symbolic stage.

    ctx_pmfs:  list per attr of (N, 8, V) PMFs for the context panels
    cand_pmfs: list per attr of (N, 8, V) PMFs for the candidate panels
    Returns (answer_logprobs (N, 8), rule_probs (n_attr, N, R)).
    """
    n = ctx_pmfs[0].shape[0]
    rule_probs_all = []
    pred_codes = []  # per attr: (N, B, d) predicted 9th-panel code
    for ai in range(cfg.raven.n_attrs):
        book = codebooks["books"][ai]
        shifts = codebooks["shifts"][ai]
        n_vals = cfg.raven.attr_sizes[ai]
        pmf = ctx_pmfs[ai]  # (N, 8, V)
        codes = _pmf_to_code(pmf.reshape(n * 8, -1), book, n_vals)
        codes = codes.reshape(n, 8, cfg.blocks, cfg.d)
        # score each rule on the two complete rows
        scores = []
        for r in range(N_RULES):
            s = 0.0
            for r0 in (0, 3):
                pred = _rule_predict(r, codes[:, r0], codes[:, r0 + 1], shifts)
                s = s + vsa.similarity(pred, codes[:, r0 + 2])
            scores.append(s / 2.0)
        scores = jnp.stack(scores, axis=-1)  # (N, R)
        rule_prob = jax.nn.softmax(scores / cfg.rule_temp, axis=-1)
        rule_probs_all.append(rule_prob)
        # execute all rules on row 3, mix by posterior
        preds = jnp.stack(
            [_rule_predict(r, codes[:, 6], codes[:, 7], shifts)
             for r in range(N_RULES)], axis=1)  # (N, R, B, d)
        pred_codes.append(jnp.einsum("nr,nrbd->nbd", rule_prob, preds))

    # compose panel-level codes with attribute roles, compare to candidates
    roles = codebooks["roles"]  # (A, B, d)
    pred_panel = sum(
        vsa.bind(pred_codes[ai], roles[ai][None])
        for ai in range(cfg.raven.n_attrs))  # (N, B, d)
    cand_codes = []
    for ai in range(cfg.raven.n_attrs):
        book = codebooks["books"][ai]
        n_vals = cfg.raven.attr_sizes[ai]
        c = _pmf_to_code(cand_pmfs[ai].reshape(n * 8, -1), book, n_vals)
        cand_codes.append(vsa.bind(c.reshape(n, 8, cfg.blocks, cfg.d),
                                   roles[ai][None, None]))
    cand_panel = sum(cand_codes)  # (N, 8, B, d)

    if cfg.symb_precision in _BITS:
        # per-problem activation scales (axis 0 = batch): the quantized
        # symbolic stream stays independent of the admission group
        pred_panel = fake_quant(pred_panel, cfg.symb_precision,
                                axes=tuple(range(1, pred_panel.ndim)))
        cand_panel = fake_quant(cand_panel, cfg.symb_precision,
                                axes=tuple(range(1, cand_panel.ndim)))

    sims = jax.vmap(lambda q, c: vsa.similarity(q[None], c))(pred_panel, cand_panel)
    logp = jax.nn.log_softmax(sims / cfg.answer_temp, axis=-1)
    return logp, jnp.stack(rule_probs_all)


# ---------------------------------------------------------------------------
# End-to-end
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def solve(params, codebooks, cfg: NVSAConfig, context: jax.Array,
          candidates: jax.Array):
    """context: (N, 8, H, W, 1); candidates: (N, 8, H, W, 1).

    Returns (answer_logprobs (N, 8), rule_probs (A, N, R)).
    """
    n, _, h, w, c = context.shape
    codebooks = quantize_codebooks(cfg, codebooks)
    ctx_pmfs, _ = frontend_pmfs(params, cfg, context.reshape(n * 8, h, w, c))
    cand_pmfs, _ = frontend_pmfs(params, cfg, candidates.reshape(n * 8, h, w, c))
    ctx_pmfs = [p.reshape(n, 8, -1) for p in ctx_pmfs]
    cand_pmfs = [p.reshape(n, 8, -1) for p in cand_pmfs]
    return reason(cfg, codebooks, ctx_pmfs, cand_pmfs)


def accuracy(params, codebooks, cfg: NVSAConfig, batch) -> tuple[float, float]:
    """Returns (answer accuracy, rule accuracy)."""
    logp, rule_probs = solve(params, codebooks, cfg,
                             jnp.asarray(batch["context"]),
                             jnp.asarray(batch["candidates"]))
    ans_acc = jnp.mean(jnp.argmax(logp, -1) == jnp.asarray(batch["answer"]))
    rules_pred = jnp.argmax(rule_probs, -1)  # (A, N)
    rule_acc = jnp.mean(rules_pred.T == jnp.asarray(batch["rules"]))
    return float(ans_acc), float(rule_acc)


def oracle_pmfs(cfg: NVSAConfig, attrs: jax.Array):
    """Ground-truth one-hot PMFs (symbolic-only upper bound, used in tests)."""
    return [jax.nn.one_hot(attrs[..., i], n)
            for i, n in enumerate(cfg.raven.attr_sizes)]
