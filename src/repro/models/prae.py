"""PrAE — Probabilistic Abduction and Execution (Zhang et al., CVPR'21), in JAX.

Unlike NVSA/LVRF, PrAE's symbolic engine operates directly on attribute
*probability tables*: rules transform PMFs (progression = index shift,
arithmetic = discrete [cross-]correlation of distributions), abduction
scores rules by the likelihood they assign to the observed third panel, and
execution produces the 9th-panel PMF. This gives the DAG a symbolic stream
with a different op mix (scatter/shift/reduce — SIMD-unit shaped, no MXU)
— exercising NSFlow's claim of generality across NSAI workloads.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.data.raven import RavenConfig, N_RULES


@dataclasses.dataclass(frozen=True)
class PrAEConfig:
    raven: RavenConfig = RavenConfig()
    rule_temp: float = 0.1
    answer_temp: float = 0.05
    eps: float = 1e-6


def _shift_pmf(p: jax.Array, delta: int) -> jax.Array:
    """Progression: P(v) -> P(v - delta) with wraparound (matches generator)."""
    return jnp.roll(p, delta, axis=-1)


def _conv_pmf(p: jax.Array, q: jax.Array) -> jax.Array:
    """Arithmetic plus: distribution of a1 + a2 (mod n, matches generator)."""
    n = p.shape[-1]
    idx = (jnp.arange(n)[:, None] - jnp.arange(n)[None, :]) % n  # (v, k): v-k
    # out[v] = sum_k p[k] q[(v - k) % n]
    return jnp.einsum("...k,...vk->...v", p, q[..., idx])


def _corr_pmf(p: jax.Array, q: jax.Array) -> jax.Array:
    """Arithmetic minus: distribution of a1 - a2 (mod n)."""
    n = p.shape[-1]
    idx = (jnp.arange(n)[:, None] + jnp.arange(n)[None, :]) % n
    # out[v] = sum_k q[k] p[(v + k) % n]
    return jnp.einsum("...k,...vk->...v", q, p[..., idx])


def rule_execute(rule_idx: int, p1: jax.Array, p2: jax.Array) -> jax.Array:
    if rule_idx == 0:
        return p2
    if rule_idx == 1:
        return _shift_pmf(p2, 1)
    if rule_idx == 2:
        return _shift_pmf(p2, -1)
    if rule_idx == 3:
        return _conv_pmf(p1, p2)
    return _corr_pmf(p1, p2)


@functools.partial(jax.jit, static_argnames=("cfg",))
def solve_from_pmfs(cfg: PrAEConfig, ctx_pmfs, cand_pmfs):
    """Pure probabilistic abduction+execution.

    ctx_pmfs / cand_pmfs: lists per attr of (N, 8, V).
    Returns (answer logprobs (N, 8), rule posteriors (A, N, R)).
    """
    total = 0.0
    posts = []
    for ai in range(cfg.raven.n_attrs):
        pm = ctx_pmfs[ai]
        # abduction: likelihood of observed third panel under each rule
        logits = []
        for r in range(N_RULES):
            ll = 0.0
            for r0 in (0, 3):
                pred = rule_execute(r, pm[:, r0], pm[:, r0 + 1])
                # expected log-likelihood of observed PMF under prediction
                ll = ll + jnp.sum(pm[:, r0 + 2] * jnp.log(pred + cfg.eps), axis=-1)
            logits.append(ll / 2.0)
        logits = jnp.stack(logits, axis=-1)  # (N, R)
        post = jax.nn.softmax(logits / cfg.rule_temp, axis=-1)
        posts.append(post)
        # execution on row 3
        preds = jnp.stack([rule_execute(r, pm[:, 6], pm[:, 7])
                           for r in range(N_RULES)], axis=1)  # (N, R, V)
        pred9 = jnp.einsum("nr,nrv->nv", post, preds)
        pred9 = pred9 / jnp.maximum(pred9.sum(-1, keepdims=True), cfg.eps)
        # candidate scoring: cross-entropy against predicted PMF
        score = jnp.einsum("npv,nv->np", cand_pmfs[ai], jnp.log(pred9 + cfg.eps))
        total = total + score
    logp = jax.nn.log_softmax(total / cfg.answer_temp, axis=-1)
    return logp, jnp.stack(posts)


def accuracy(cfg: PrAEConfig, ctx_pmfs, cand_pmfs, answers, rules=None):
    logp, posts = solve_from_pmfs(cfg, ctx_pmfs, cand_pmfs)
    acc = float(jnp.mean(jnp.argmax(logp, -1) == answers))
    racc = None
    if rules is not None:
        racc = float(jnp.mean(jnp.argmax(posts, -1).T == rules))
    return acc, racc
