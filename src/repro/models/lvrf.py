"""LVRF — Learn-VRF: probabilistic abduction with *learned* VSA rules
(Hersche et al., NeurIPS'23), in JAX.

Where NVSA executes a fixed rule set, LVRF learns a codebook of rule
vectors: a rule ``R_k`` maps a row's first two panel codes to a predicted
third code via binding. Abduction = softmax posterior over rules from the
two complete context rows; execution = posterior-weighted binding on row 3.
All rule applications are the paper's circular-convolution kernels, with
*learned* operands.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.data.raven import RavenConfig
from repro.nn.init import P
from repro.vsa import fpe, ops as vsa


@dataclasses.dataclass(frozen=True)
class LVRFConfig:
    raven: RavenConfig = RavenConfig()
    blocks: int = 4
    d: int = 128
    n_rules: int = 8          # learned rule slots (>= true rule count)
    rule_temp: float = 0.1
    answer_temp: float = 0.05


def lvrf_spec(cfg: LVRFConfig):
    """Learned parameters: rule codebook + pair-role codes, per attribute."""
    a = cfg.raven.n_attrs
    return {
        "rules": P((a, cfg.n_rules, cfg.blocks, cfg.d),
                   (None, None, None, None), init="normal", scale=1.0 / cfg.d),
        "role1": P((a, cfg.blocks, cfg.d), (None, None, None), init="normal",
                   scale=1.0 / math.sqrt(cfg.d)),
        "role2": P((a, cfg.blocks, cfg.d), (None, None, None), init="normal",
                   scale=1.0 / math.sqrt(cfg.d)),
    }


def lvrf_codebooks(cfg: LVRFConfig, key: jax.Array):
    """Static FPE value codebooks (shared with NVSA-style encoding)."""
    keys = jax.random.split(key, cfg.raven.n_attrs)
    books = []
    for i, n in enumerate(cfg.raven.attr_sizes):
        phase = fpe.fpe_base_phase(keys[i], cfg.blocks, cfg.d)
        books.append(fpe.fpe_codebook(phase, 2 * n - 1, cfg.d))
    return books


def _pair_code(c1, c2, role1, role2):
    """Row context code: bind each panel code with its positional role."""
    return vsa.bind(c1, role1) + vsa.bind(c2, role2)


def _apply_rules(pair, rules):
    """pair: (N, B, d); rules: (R, B, d) -> (N, R, B, d) predicted codes."""
    n = pair.shape[0]
    r = rules.shape[0]
    pairs = jnp.broadcast_to(pair[:, None], (n, r) + pair.shape[1:])
    rules_b = jnp.broadcast_to(rules[None], (n, r) + rules.shape[1:])
    return vsa.bind(pairs, rules_b)


# -- pipeline stages (the serving schedule binds these) ---------------------
# frontend PMFs -> encode+abduce (learned-rule posterior) -> execute
# (posterior-weighted circ-conv execution + candidate match)


def encode_codes(books, cfg: LVRFConfig, pmfs) -> jax.Array:
    """PMF lists (per attr, (N, 8, V)) -> stacked codes (A, N, 8, B, d)."""
    return jnp.stack([
        jnp.einsum("npv,vbd->npbd", pmfs[ai],
                   books[ai][: cfg.raven.attr_sizes[ai]])
        for ai in range(cfg.raven.n_attrs)])


def abduce(params, cfg: LVRFConfig, codes: jax.Array) -> jax.Array:
    """Rule posteriors from the two complete rows: (A, N, 8, B, d) ->
    (A, N, R).  All rule applications are circular convolutions with
    *learned* operands."""
    posts = []
    for ai in range(cfg.raven.n_attrs):
        rules = params["rules"][ai]
        r1, r2 = params["role1"][ai][None], params["role2"][ai][None]
        post_logits = 0.0
        for r0 in (0, 3):
            pair = _pair_code(codes[ai][:, r0], codes[ai][:, r0 + 1], r1, r2)
            preds = _apply_rules(pair, rules)  # (N, R, B, d)
            sims = jax.vmap(lambda p, t: vsa.similarity(p, t[None]))(
                preds, codes[ai][:, r0 + 2])  # (N, R)
            post_logits = post_logits + sims / cfg.rule_temp
        posts.append(jax.nn.softmax(post_logits, axis=-1))
    return jnp.stack(posts)


def execute(params, books, cfg: LVRFConfig, codes: jax.Array,
            posts: jax.Array, cand_pmfs) -> jax.Array:
    """Posterior-weighted rule execution on row 3 + candidate match:
    -> answer logprobs (N, 8)."""
    total_sims = 0.0
    for ai in range(cfg.raven.n_attrs):
        rules = params["rules"][ai]
        r1, r2 = params["role1"][ai][None], params["role2"][ai][None]
        pair3 = _pair_code(codes[ai][:, 6], codes[ai][:, 7], r1, r2)
        preds3 = _apply_rules(pair3, rules)
        pred = jnp.einsum("nr,nrbd->nbd", posts[ai], preds3)
        cand = jnp.einsum("npv,vbd->npbd", cand_pmfs[ai],
                          books[ai][: cfg.raven.attr_sizes[ai]])
        sims = jax.vmap(lambda q, c: vsa.similarity(q[None], c))(pred, cand)
        total_sims = total_sims + sims
    return jax.nn.log_softmax(total_sims / cfg.answer_temp, axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg",))
def solve_from_pmfs(params, books, cfg: LVRFConfig, ctx_pmfs, cand_pmfs):
    """ctx_pmfs/cand_pmfs: lists per attr of (N, 8, V). Returns
    (answer logprobs (N, 8), rule posteriors (A, N, R)).  Composes the
    pipeline stages in one jit — the offline reference the compiled
    serving schedule must match."""
    codes = encode_codes(books, cfg, ctx_pmfs)
    posts = abduce(params, cfg, codes)
    return execute(params, books, cfg, codes, posts, cand_pmfs), posts


def loss_fn(params, books, cfg: LVRFConfig, ctx_pmfs, cand_pmfs, answers):
    logp, _ = solve_from_pmfs(params, books, cfg, ctx_pmfs, cand_pmfs)
    return -jnp.mean(jnp.take_along_axis(logp, answers[:, None], axis=1))


def accuracy(params, books, cfg: LVRFConfig, ctx_pmfs, cand_pmfs, answers) -> float:
    logp, _ = solve_from_pmfs(params, books, cfg, ctx_pmfs, cand_pmfs)
    return float(jnp.mean(jnp.argmax(logp, -1) == answers))
