"""RWKV-6 (Finch) language model — attention-free, O(1)-state decode.

Assigned arch ``rwkv6-7b``: 32L, d_model 4096, d_ff 14336, vocab 65536.
The per-layer state is (heads, 64, 64) + token-shift carries, so the
``long_500k`` decode cell runs with constant memory — the arch family the
shape note directs long-context decode at.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import init as nninit
from repro.nn import layers, ssm
from repro.nn.init import P
from repro.models.lm import _xent, _stack_spec


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    head_dim: int = 64
    chunk: int = 16
    impl: str = "chunked"
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.bfloat16
    remat: bool = True
    scan_unroll: int = 1

    def tm(self) -> ssm.RWKV6Config:
        return ssm.RWKV6Config(self.d_model, self.head_dim, chunk=self.chunk,
                               impl=self.impl)


def _layer_spec(cfg: RWKVConfig):
    return {
        "ln1": layers.layernorm_spec(cfg.d_model, cfg.param_dtype),
        "ln2": layers.layernorm_spec(cfg.d_model, cfg.param_dtype),
        "tm": ssm.timemix_spec(cfg.tm(), cfg.param_dtype),
        "cm": ssm.channelmix_spec(cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def rwkv_spec(cfg: RWKVConfig):
    return {
        "embed": layers.embedding_spec(cfg.vocab, cfg.d_model, cfg.param_dtype),
        "ln_in": layers.layernorm_spec(cfg.d_model, cfg.param_dtype),
        "final_norm": layers.layernorm_spec(cfg.d_model, cfg.param_dtype),
        "body": _stack_spec(_layer_spec(cfg), cfg.n_layers),
        "head": layers.dense_spec(cfg.d_model, cfg.vocab, ("embed", "vocab"),
                                  dtype=cfg.param_dtype),
    }


def forward(params, cfg: RWKVConfig, tokens: jax.Array):
    x = layers.embedding(params["embed"], tokens, cfg.compute_dtype)
    x = layers.layernorm(params["ln_in"], x)

    def layer_fwd(x, p):
        h = layers.layernorm(p["ln1"], x)
        x = x + ssm.timemix(p["tm"], cfg.tm(), h, cfg.compute_dtype)
        h = layers.layernorm(p["ln2"], x)
        x = x + ssm.channelmix(p["cm"], h, compute_dtype=cfg.compute_dtype)
        return x, 0.0

    body = layer_fwd
    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["body"], unroll=cfg.scan_unroll)
    x = layers.layernorm(params["final_norm"], x)
    return x


def loss_fn(params, cfg: RWKVConfig, batch) -> jax.Array:
    hidden = forward(params, cfg, batch["tokens"])
    logits = layers.dense(params["head"], hidden, cfg.compute_dtype)
    return _xent(logits, batch["targets"])


def state_shapes(cfg: RWKVConfig, batch: int):
    tm = cfg.tm()
    h, hd = tm.n_heads, tm.head_dim
    per_layer = {
        "wkv": jax.ShapeDtypeStruct((batch, h, hd, hd), jnp.float32),
        "tm_x": jax.ShapeDtypeStruct((batch, cfg.d_model), jnp.bfloat16),
        "cm_x": jax.ShapeDtypeStruct((batch, cfg.d_model), jnp.bfloat16),
    }
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape,
                                                       s.dtype), per_layer)


def init_state(cfg: RWKVConfig, batch: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        state_shapes(cfg, batch))


def decode_step(params, cfg: RWKVConfig, state, token: jax.Array, pos: jax.Array):
    """O(1)-state decode. state: stacked per-layer recurrent carries."""
    x = layers.embedding(params["embed"], token, cfg.compute_dtype)
    x = layers.layernorm(params["ln_in"], x)

    def layer_step(x, scanned):
        p, st = scanned
        h = layers.layernorm(p["ln1"], x)
        tm_state = {"wkv": st["wkv"], "x_prev": st["tm_x"]}
        tm_state, y = ssm.timemix_step(p["tm"], cfg.tm(), tm_state, h,
                                       cfg.compute_dtype)
        x = x + y
        h = layers.layernorm(p["ln2"], x)
        y = ssm.channelmix(p["cm"], h[:, None, :], st["cm_x"],
                           compute_dtype=cfg.compute_dtype)[:, 0]
        x = x + y
        new_st = {"wkv": tm_state["wkv"], "tm_x": tm_state["x_prev"],
                  "cm_x": h.astype(jnp.bfloat16)}
        return x, new_st

    x, new_state = jax.lax.scan(layer_step, x, (params["body"], state),
                                unroll=cfg.scan_unroll)
    x = layers.layernorm(params["final_norm"], x)
    logits = layers.dense(params["head"], x, cfg.compute_dtype)
    return new_state, logits
