"""End-to-end NVSA: train the ResNet frontend on synthetic RAVEN panels,
then evaluate neuro-symbolic reasoning accuracy across precisions (Tab. IV).

Usage:
  PYTHONPATH=src python examples/train_nvsa_raven.py \
      [--steps 400] [--n-train 400] [--n-eval 128] [--out results/nvsa_tab4.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import raven
from repro.models import nvsa
from repro.nn import init as nninit
from repro.train import optimizer as opt_mod


def train_frontend(cfg: nvsa.NVSAConfig, steps: int, n_problems: int,
                   batch: int = 64, lr: float = 3e-3, log_every: int = 50):
    imgs, attrs = raven.panel_dataset(cfg.raven, seed=11, n_problems=n_problems)
    print(f"[nvsa] supervision set: {imgs.shape[0]} panels")
    params = nninit.materialize(nvsa.nvsa_spec(cfg), jax.random.PRNGKey(0))
    ocfg = opt_mod.AdamWConfig(lr=lr, warmup_steps=20, total_steps=steps,
                               weight_decay=1e-4)
    state = opt_mod.init_state(params, ocfg)

    @jax.jit
    def step_fn(params, state, bi, bl):
        (loss, bn_stats), grads = jax.value_and_grad(
            nvsa.frontend_loss, has_aux=True)(params, cfg, bi, bl)
        params, state, m = opt_mod.apply_updates(params, grads, state, ocfg)
        # fold this step's BN batch statistics into the running stats so
        # eval-mode BN (serving, nvsa.solve) sees trained statistics
        params = nvsa.frontend_apply_bn_stats(params, bn_stats, momentum=0.9)
        return params, state, loss

    rng = np.random.default_rng(0)
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, imgs.shape[0], batch)
        params, state, loss = step_fn(params, state, jnp.asarray(imgs[idx]),
                                      jnp.asarray(attrs[idx]))
        if s % log_every == 0 or s == steps - 1:
            print(f"[nvsa] step {s:4d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--n-train", type=int, default=400)
    ap.add_argument("--n-eval", type=int, default=128)
    ap.add_argument("--out", default="results/nvsa_tab4.json")
    args = ap.parse_args()

    base = nvsa.NVSAConfig()
    params = train_frontend(base, args.steps, args.n_train)

    results = {}
    for style in ("raven", "iraven", "pgm"):
        rcfg = dataclasses.replace(base.raven, style=style)
        batch = raven.generate_batch(rcfg, seed=777, n=args.n_eval)
        row = {}
        for label, nn_p, sy_p in [("fp32", "fp32", "fp32"),
                                  ("bf16", "bf16", "bf16"),
                                  ("int8", "int8", "int8"),
                                  ("mp", "int8", "int4"),
                                  ("int4", "int4", "int4")]:
            cfg = dataclasses.replace(base, raven=rcfg, nn_precision=nn_p,
                                      symb_precision=sy_p)
            codebooks = nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
            acc, racc = nvsa.accuracy(params, codebooks, cfg, batch)
            mem = nvsa.nvsa_memory_bytes(cfg, params)
            row[label] = {"answer_acc": acc, "rule_acc": racc, "memory_bytes": mem}
            print(f"[tab4] {style:7s} {label:5s} acc {acc:.3f} rule {racc:.3f} "
                  f"mem {mem/1e6:.2f} MB", flush=True)
        results[style] = row
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=1))
    print(f"[tab4] wrote {out}")


if __name__ == "__main__":
    main()
