"""Serving neuro-symbolic reasoning traffic — the NSFlow two-stream demo.

Part 1: NVSA RAVEN serving through the double-buffered ReasonEngine —
        a lazy request stream (problems are rendered as the pipeline pulls
        them) flows through the neural stage (ResNet -> attribute PMFs)
        and the symbolic stage (FPE codes -> VSA rule abduction -> rule
        execution by circular convolution), overlap vs sequential.
Part 2: symbolic-stream-only serving (oracle perception) — the engine's
        answer accuracy on unambiguous RAVEN grids is 1.0 by construction.
Part 3: PrAE on the same traffic — a different symbolic op mix
        (PMF-table shifts/correlations, no VSA algebra) behind the same
        engine interface, plus Tab. IV mixed precision on NVSA (nn int8
        through the Pallas qmatmul kernel, symbolic int4).

Run:  PYTHONPATH=src python examples/serve_reason.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.configs import base as cbase
from repro.data import raven
from repro.models import nvsa
from repro.nn import init as nninit
from repro.serve.reason import ReasonConfig, ReasonEngine, ReasonRequest

D = 64          # VSA block dim; >= 128 (pow2) would engage the Pallas kernel
N_PROBLEMS = 16
BATCH = 4


def request_stream(cfg, n, start=0):
    """Lazy request source: rendering runs inside the serving pipeline."""
    for i in range(n):
        p = raven.generate_problem(cfg.raven, seed=100 + start + i)
        yield ReasonRequest(
            uid=start + i, context=p["context"], candidates=p["candidates"],
            context_attrs=p["context_attrs"],
            candidate_attrs=p["candidate_attrs"])


def answers(cfg, n, start=0):
    return [raven.generate_problem(cfg.raven, seed=100 + start + i)["answer"]
            for i in range(n)]


def main():
    cfg = nvsa.NVSAConfig(d=D)
    params = nninit.materialize(nvsa.nvsa_spec(cfg), jax.random.PRNGKey(0))
    books = nvsa.nvsa_codebooks(cfg, jax.random.PRNGKey(1))
    neural, oracle, symbolic = cbase.reason_fns("nvsa", cfg)
    engine = ReasonEngine(neural, symbolic, ReasonConfig(batch_size=BATCH),
                          oracle_fn=oracle)

    # Part 1 — two-stream NVSA serving, overlap vs sequential
    engine.run(params, books, request_stream(cfg, BATCH))  # warm up compile
    engine.run(params, books, request_stream(cfg, BATCH),
               schedule="sequential")
    for sched in ("sequential", "overlap"):
        t0 = time.time()
        res = engine.run(params, books, request_stream(cfg, N_PROBLEMS),
                         schedule=sched)
        dt = time.time() - t0
        print(f"[serve_reason] nvsa/{sched}: {N_PROBLEMS} problems in "
              f"{dt:.2f}s ({N_PROBLEMS / dt:.1f} problems/s)")
    first = res[0]
    print(f"[serve_reason]   e.g. uid 0 (batch {first.batch}): answer "
          f"panel {first.answer}, logp {first.answer_logprobs.round(2)}")

    # Part 2 — symbolic stream only: oracle perception, accuracy 1.0
    res = engine.run(params, books, request_stream(cfg, N_PROBLEMS),
                     perception="oracle")
    acc = np.mean([res[i].answer == a
                   for i, a in enumerate(answers(cfg, N_PROBLEMS))])
    print(f"[serve_reason] oracle perception (symbolic stream only): "
          f"accuracy {acc:.3f}")

    # Part 3 — PrAE traffic + NVSA mixed precision on the same engine API
    pn, po, ps = cbase.reason_fns("prae", cfg)
    prae_eng = ReasonEngine(pn, ps, ReasonConfig(batch_size=BATCH),
                            oracle_fn=po)
    res = prae_eng.run(params, None, request_stream(cfg, N_PROBLEMS),
                       perception="oracle")
    acc = np.mean([res[i].answer == a
                   for i, a in enumerate(answers(cfg, N_PROBLEMS))])
    print(f"[serve_reason] prae (PMF-table symbolic stream): "
          f"accuracy {acc:.3f}")

    mp_cfg = dataclasses.replace(cfg, nn_precision="int8",
                                 symb_precision="int4", use_qmatmul=True)
    mn, mo, ms = cbase.reason_fns("nvsa", mp_cfg)
    mp_eng = ReasonEngine(mn, ms, ReasonConfig(batch_size=BATCH),
                          oracle_fn=mo)
    t0 = time.time()
    mp_eng.run(params, books, request_stream(cfg, N_PROBLEMS))
    print(f"[serve_reason] mixed precision nn=int8(qmatmul)/symb=int4: "
          f"{N_PROBLEMS} problems in {time.time() - t0:.2f}s "
          f"(memory {nvsa.nvsa_memory_bytes(cfg, params) / nvsa.nvsa_memory_bytes(mp_cfg, params):.1f}x smaller)")


if __name__ == "__main__":
    main()
