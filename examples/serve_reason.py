"""Serving neuro-symbolic reasoning traffic — the NSFlow pipeline demo.

Every workload in ``configs.base.REASON_WORKLOADS`` serves through the SAME
generic engine: its pipeline is compiled from declared stage functions into
a ``StagedSchedule`` (``serve.schedule``), and ``ReasonEngine`` executes
the schedule double-buffered so host ingest/staging of batch i+1 overlaps
batch i on the device.

Part 1: NVSA RAVEN serving — a lazy request stream flows through the
        compiled two-stage pipeline (ResNet frontend -> attribute PMFs;
        FPE codes -> VSA rule abduction -> circ-conv rule execution),
        overlap vs sequential + per-stage timing breakdown.
Part 2: symbolic-stream-only serving (oracle variant) — accuracy 1.0 on
        unambiguous RAVEN grids by construction.
Part 3: every registered workload through the same path — PrAE (PMF-table
        symbolic stream), MIMONet (bind -> shared NN trunk -> unbind/
        classify, K inputs per request), LVRF (learned-rule posterior ->
        posterior-weighted execution) — the model list derives from the
        registry, so a new workload shows up here by registration alone.
Part 4: Tab. IV mixed precision on NVSA (nn int8 through the Pallas
        qmatmul kernel, symbolic int4) behind the same engine.
Part 5: ONLINE mixed serving via ``repro.serve.deploy`` — an LM arch
        (stablelm-3b) and two NSAI workloads (nvsa + mimonet) deployed
        behind ONE deadline-batched, shape-bucketed front-door under
        Poisson arrivals.  The NSAI engines' serving knobs (batch
        buckets, in-flight depth, overlap-vs-sequential schedule) are
        DSE-derived from each workload's traced dataflow graph — the
        paper's generator -> architecture loop — and the report covers
        both request classes (tokens/s for LM rows, problems/s for NSAI
        rows) with per-request queue/service latency percentiles.

Run:  PYTHONPATH=src python examples/serve_reason.py
"""

import time

import jax

from repro.configs import base as cbase
from repro.models import nvsa
from repro.serve.reason import ReasonConfig

D = 64          # VSA block dim; >= 128 (pow2) would engage the Pallas kernel
N_PROBLEMS = 16
BATCH = 4


def main():
    entry = cbase.REASON_WORKLOADS["nvsa"]
    cfg = entry.make_config(d=D)
    consts = entry.make_consts(cfg, jax.random.PRNGKey(0))
    engine = cbase.reason_engine("nvsa", cfg, ReasonConfig(batch_size=BATCH),
                                 consts=consts)

    # Part 1 — compiled NVSA pipeline, overlap vs sequential
    print(f"[serve_reason] nvsa pipeline: "
          f"{engine.schedules['cnn'].describe()}")
    stream, truth = entry.make_requests(cfg, N_PROBLEMS, seed=100)
    warm, _ = entry.make_requests(cfg, BATCH, seed=0)
    engine.run(warm())  # warm up compile
    engine.run(warm(), schedule="sequential")
    for sched in ("sequential", "overlap"):
        t0 = time.time()
        res = engine.run(stream(), schedule=sched)
        dt = time.time() - t0
        print(f"[serve_reason] nvsa/{sched}: {N_PROBLEMS} problems in "
              f"{dt:.2f}s ({N_PROBLEMS / dt:.1f} problems/s)")
    for name, t in engine.stats["stage_time_s"]["cnn"].items():
        print(f"[serve_reason]   stage {name:10s} {t:.3f}s (sequential)")
    first = res[0]
    print(f"[serve_reason]   e.g. uid 0 (batch {first.batch}): answer "
          f"panel {first.answer}, logp {first.answer_logprobs.round(2)}")

    # Part 2 — symbolic stream only: oracle variant, accuracy 1.0
    res = engine.run(stream(), variant="oracle")
    print(f"[serve_reason] oracle variant (symbolic stream only): "
          f"accuracy {entry.score(res, truth()):.3f}")

    # Part 3 — every registered workload through the same generic engine
    for model, e in cbase.REASON_WORKLOADS.items():
        if model == "nvsa":
            continue
        mcfg = e.make_config(d=D)
        mconsts = e.make_consts(mcfg, jax.random.PRNGKey(0))
        variant = "oracle" if "oracle" in e.variants else e.variants[0]
        eng = cbase.reason_engine(model, mcfg, ReasonConfig(batch_size=BATCH),
                                  consts=mconsts, variants=(variant,))
        mstream, mtruth = e.make_requests(mcfg, N_PROBLEMS, seed=100)
        t0 = time.time()
        res = eng.run(mstream())
        dt = time.time() - t0
        print(f"[serve_reason] {model}/{variant}: "
              f"{eng.schedules[variant].describe()}")
        print(f"[serve_reason]   {N_PROBLEMS} problems in {dt:.2f}s "
              f"({N_PROBLEMS / dt:.1f} problems/s), accuracy "
              f"{e.score(res, mtruth()):.3f}")

    # Part 4 — Tab. IV mixed precision on the same engine API
    mp_cfg = entry.make_config(d=D, nn_precision="int8",
                               symb_precision="int4")
    mp_eng = cbase.reason_engine("nvsa", mp_cfg,
                                 ReasonConfig(batch_size=BATCH),
                                 consts=consts, variants=("cnn",))
    t0 = time.time()
    mp_eng.run(stream())
    print(f"[serve_reason] mixed precision nn=int8(qmatmul)/symb=int4: "
          f"{N_PROBLEMS} problems in {time.time() - t0:.2f}s (memory "
          f"{nvsa.nvsa_memory_bytes(cfg, consts['params']) / nvsa.nvsa_memory_bytes(mp_cfg, consts['params']):.1f}x smaller)")

    # Part 5 — online mixed LM + NSAI serving through deploy(): the DSE
    # reads each NSAI workload's traced dataflow graph and emits the
    # serving configuration; one front-door admits both request classes
    from repro.serve import Budget, Traffic, deploy

    deployment = deploy(
        ["stablelm-3b", "nvsa", "mimonet"],
        traffic=Traffic(rate_rps=40.0, deadline_s=0.02),
        budget=Budget(max_pes=4096, max_batch=BATCH, max_slots=2,
                      max_len=64, max_new_tokens=8),
        options={"nvsa": {"d": D}, "mimonet": {"d": D}})
    for line in deployment.summary().splitlines():
        print(f"[serve_reason] deploy: {line}")
    deployment.warmup()  # compile every serving shape before latencies
    arrivals, _ = deployment.synthetic_traffic(N_PROBLEMS)
    report = deployment.serve(arrivals)
    print(f"[serve_reason] front-door: poisson 40 req/s per model, "
          f"deadline 20ms — one report, both request classes:")
    for line in report.summary().splitlines():
        print(f"[serve_reason]   {line}")


if __name__ == "__main__":
    main()
