"""Continuous-batching LM serving + the enc-dec overlap demo.

Part 1: slot-based continuous batching on a smoke llama-family model —
         8 ragged requests stream through a 4-slot KV pool; retired slots
         are refilled from the queue mid-flight, decode runs in fused
         lax.scan blocks, and sampling is temperature/top-k driven.
Part 2: seamless-m4t-style enc-dec serving where encode(batch i+1) is
         issued alongside decode(batch i) — NSFlow's inter-loop overlap
         (paper Fig. 4 ③) mapped to serving.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs import base as cbase
from repro.nn import init as nninit
from repro.serve.engine import Engine, Request, ServeConfig


def serve_llama():
    arch = ARCHS["llama3.2-3b"]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg), jax.random.PRNGKey(0))
    step, init_caches = cbase.serve_fns(arch, cfg, max_len=64)
    # params are bound at construction: the engine implements the unified
    # runtime protocol (submit/drain_ready/drain_all), and run() is the
    # offline loop over it
    engine = Engine(step, init_caches,
                    ServeConfig(max_new_tokens=16, max_slots=4, max_len=64,
                                decode_block=8, temperature=0.7, top_k=32,
                                eos_id=1, seed=0), params=params)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab, (int(rng.integers(4, 14)),)
                                        ).astype(np.int32))
            for i in range(8)]
    t0 = time.time()
    results = engine.run(reqs)
    dt = time.time() - t0
    done = sum(1 for r in results.values())
    toks = sum(len(r.tokens) for r in results.values())
    print(f"[serve_lm] llama-smoke: {done} requests ({toks} tokens) through "
          f"a {engine.cfg.max_slots}-slot pool in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s)")
    print(f"[serve_lm] slot utilization {engine.utilization():.0%}, "
          f"requests per slot: {engine.stats['slots_served']}")
    for uid in sorted(results)[:3]:
        r = results[uid]
        print(f"[serve_lm]   req {uid}: prompt {r.prompt_len} -> "
              f"{r.tokens[:8].tolist()}{' (eos)' if r.finished_by_eos else ''}")


def serve_encdec_overlap():
    from repro.models import encdec

    arch = ARCHS["seamless-m4t-large-v2"]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    enc_fn = jax.jit(lambda p, f: encdec.encode(p, cfg, f))
    step_fn = jax.jit(lambda p, c, t, pos: encdec.decode_step(p, cfg, c, t, pos))

    def one_batch_frames():
        return jnp.asarray(rng.normal(size=(2, 24, cfg.d_model)), jnp.bfloat16)

    # software pipeline: encode(i+1) is dispatched before decode(i) finishes
    # (on a real mesh the encoder/decoder occupy disjoint device groups —
    # the folding analogue; here we demonstrate the schedule)
    n_batches, new_tokens = 3, 8
    t0 = time.time()
    enc_next = enc_fn(params, one_batch_frames())
    for i in range(n_batches):
        enc_cur = enc_next
        if i + 1 < n_batches:
            enc_next = enc_fn(params, one_batch_frames())  # overlapped encode
        caches = encdec.init_caches(params, cfg, enc_cur, max_len=32)
        tok = jnp.zeros((2,), jnp.int32)
        for t in range(new_tokens):
            caches, logits = step_fn(params, caches, tok, jnp.int32(t))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print(f"[serve_lm] enc-dec pipelined serving: {n_batches} batches x "
          f"{new_tokens} tokens in {time.time()-t0:.1f}s "
          f"(encode i+1 overlaps decode i)")


if __name__ == "__main__":
    serve_llama()
    serve_encdec_overlap()
