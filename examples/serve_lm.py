"""Batched LM serving with KV caches + the enc-dec overlap demo.

Part 1: greedy batched generation from a smoke llama-family model —
         prefill via scan-decode, then token-by-token with a ring of
         request slots.
Part 2: seamless-m4t-style enc-dec serving where encode(batch i+1) is
         issued alongside decode(batch i) — NSFlow's inter-loop overlap
         (paper Fig. 4 ③) mapped to serving.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs import base as cbase
from repro.configs.shapes import ShapeSpec
from repro.nn import init as nninit
from repro.serve.engine import Engine, ServeConfig


def serve_llama():
    arch = ARCHS["llama3.2-3b"]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg), jax.random.PRNGKey(0))
    shape = ShapeSpec("serve", "decode", 128, 4)

    def init_caches(batch):
        specs, _, _ = cbase.decode_state_specs(arch, cfg, shape)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)

    engine = Engine(cbase.decode_fn(arch, cfg), init_caches,
                    ServeConfig(max_new_tokens=16))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 12)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(params, prompts)
    print(f"[serve_lm] llama-smoke: 4 requests x 16 tokens in "
          f"{time.time()-t0:.1f}s -> {out.shape}")
    print(f"[serve_lm] greedy continuations: {out[:, :8].tolist()}")


def serve_encdec_overlap():
    from repro.models import encdec

    arch = ARCHS["seamless-m4t-large-v2"]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    enc_fn = jax.jit(lambda p, f: encdec.encode(p, cfg, f))
    step_fn = jax.jit(lambda p, c, t, pos: encdec.decode_step(p, cfg, c, t, pos))

    def one_batch_frames():
        return jnp.asarray(rng.normal(size=(2, 24, cfg.d_model)), jnp.bfloat16)

    # software pipeline: encode(i+1) is dispatched before decode(i) finishes
    # (on a real mesh the encoder/decoder occupy disjoint device groups —
    # the folding analogue; here we demonstrate the schedule)
    n_batches, new_tokens = 3, 8
    t0 = time.time()
    enc_next = enc_fn(params, one_batch_frames())
    for i in range(n_batches):
        enc_cur = enc_next
        if i + 1 < n_batches:
            enc_next = enc_fn(params, one_batch_frames())  # overlapped encode
        caches = encdec.init_caches(params, cfg, enc_cur, max_len=32)
        tok = jnp.zeros((2,), jnp.int32)
        for t in range(new_tokens):
            caches, logits = step_fn(params, caches, tok, jnp.int32(t))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print(f"[serve_lm] enc-dec pipelined serving: {n_batches} batches x "
          f"{new_tokens} tokens in {time.time()-t0:.1f}s "
          f"(encode i+1 overlaps decode i)")


if __name__ == "__main__":
    serve_llama()
    serve_encdec_overlap()
