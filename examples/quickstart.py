"""Quickstart: the full NSFlow pipeline on one NVSA reasoning task.

  1. build (or trace) the workload's operation graph,
  2. generate the dataflow graph (critical path + parallelism),
  3. run the two-phase DSE -> AdArray design + memory plan (paper Alg. 1),
  4. simulate NSFlow vs baselines (paper Fig. 5),
  5. run the actual JAX NVSA model end-to-end on a synthetic RAVEN problem
     (kernels included), untrained frontend replaced by oracle PMFs.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataflow, dse, simulator, trace, workloads
from repro.core.opgraph import format_trace
from repro.data import raven
from repro.models import nvsa


def main():
    print("=" * 70)
    print("1) Workload graph (paper-scale NVSA: ResNet-18 + VSA reasoning)")
    g = workloads.nvsa_graph()
    nn_f, vsa_f = g.total_flops("nn"), g.total_flops("vsa")
    print(f"   {len(g)} nodes | symbolic share of FLOPs: "
          f"{100 * vsa_f / (nn_f + vsa_f):.1f}% (paper Fig. 1: ~19%)")

    print("\n2) Dataflow graph")
    df = dataflow.build(g)
    print(f"   critical path: {len(df.critical_path)} nodes; "
          f"nn span {df.nn_span}, vsa span {df.vsa_span}")

    print("\n3) Two-phase DSE (Algorithm 1)")
    cfg = dse.explore(df, max_pes=16384)
    s = cfg.summary()
    print(f"   AdArray (H, W, N) = {s['AdArray (H, W, N)']}, partition "
          f"{s['partition']}, mode={s['mode']}")
    print(f"   MemA1 {s['MemA1']/1e6:.2f} MB | MemA2 {s['MemA2']/1e6:.2f} MB | "
          f"SIMD {s['SIMD']} lanes | searched {cfg.searched_points} points "
          f"(vs 10^60+ brute force)")

    print("\n4) Device comparison (paper Fig. 5)")
    ns = simulator.simulate_nsflow(g)
    print(f"   NSFlow: {ns.total * 1e3:.2f} ms/task")
    for dev in ("tx2", "rtx2080", "dpu"):
        r = simulator.simulate_generic(g, simulator.DEVICES[dev])
        print(f"   {r.device:18s}: {r.total * 1e3:8.2f} ms  "
              f"({r.total / ns.total:5.1f}x slower)")
    tpu = simulator.simulate_tpu_like(g)
    print(f"   {tpu.device:18s}: {tpu.total * 1e3:8.2f} ms  "
          f"({tpu.total / ns.total:5.1f}x slower)")

    print("\n5) Executable NVSA on a synthetic RAVEN problem (JAX + kernels)")
    ncfg = nvsa.NVSAConfig()
    batch = raven.generate_batch(ncfg.raven, seed=3, n=8)
    codebooks = nvsa.nvsa_codebooks(ncfg, jax.random.PRNGKey(1))
    ctx = [jnp.asarray(x) for x in nvsa.oracle_pmfs(
        ncfg, jnp.asarray(batch["context_attrs"]))]
    cand = [jnp.asarray(x) for x in nvsa.oracle_pmfs(
        ncfg, jnp.asarray(batch["candidate_attrs"]))]
    logp, rules = nvsa.reason(ncfg, codebooks, ctx, cand)
    acc = float(np.mean(np.argmax(np.asarray(logp), -1) == batch["answer"]))
    print(f"   answer accuracy (oracle perception): {acc:.2f} — symbolic "
          f"reasoning runs on the circ_conv Pallas kernels")

    print("\n6) Program trace extraction (paper Listing 1 analogue)")
    tg = trace.extract(lambda c1, c2: nvsa.reason(ncfg, codebooks, c1, c2),
                       ctx, cand)
    print(format_trace(tg, 6))
    kinds = {}
    for n in tg:
        kinds[n.kind] = kinds.get(n.kind, 0) + 1
    print(f"   traced {len(tg)} ops: {kinds}")


if __name__ == "__main__":
    main()
