"""End-to-end LM training driver: train a ~100M-param llama-family model on
the synthetic token stream for a few hundred steps, with checkpoint/restart.

Defaults are sized for CPU demonstration (~25M params, 200 steps); pass
``--width full100m`` for the ~100M configuration (same code path — slower
on CPU, the intended substrate is a TPU slice via the identical shardings).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax

from repro.data.tokens import SyntheticTokens, TokenPipelineConfig
from repro.models import lm
from repro.nn import init as nninit
from repro.train import optimizer as opt_mod
from repro.train.trainer import Trainer, TrainerConfig

WIDTHS = {
    # ~25M params — a few minutes of CPU
    "demo": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                 head_dim=64, d_ff=1024, vocab=8192),
    # ~100M params — the assignment's end-to-end scale
    "full100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                     head_dim=64, d_ff=2048, vocab=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", choices=list(WIDTHS), default="demo")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--out", default="results/train_lm_metrics.json")
    args = ap.parse_args()

    cfg = lm.LMConfig(name=f"lm-{args.width}", **WIDTHS[args.width])
    spec = lm.lm_spec(cfg)
    params = nninit.materialize(spec, jax.random.PRNGKey(0))
    print(f"[train_lm] {args.width}: {nninit.param_count(spec)/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    loader = SyntheticTokens(TokenPipelineConfig(
        vocab_size=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=0))
    trainer = Trainer(
        loss_fn=lambda p, b: lm.loss_fn(p, cfg, b), params=params,
        tcfg=TrainerConfig(total_steps=args.steps,
                           ckpt_every=max(25, args.steps // 4),
                           ckpt_dir=args.ckpt_dir),
        ocfg=opt_mod.AdamWConfig(lr=args.lr, warmup_steps=20,
                                 total_steps=args.steps),
        loader=loader)
    if trainer.try_restore():
        print(f"[train_lm] resumed from step {trainer.step}")
    t0 = time.time()
    hist = trainer.run()
    dt = time.time() - t0
    if not hist:
        print("[train_lm] nothing to do (checkpoint already at target step)")
        return
    print(f"[train_lm] loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({dt/max(1,len(hist)):.2f}s/step)")
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(
        {"width": args.width, "steps": len(hist),
         "losses": [h["loss"] for h in hist],
         "s_per_step": dt / max(1, len(hist))}, indent=1))


if __name__ == "__main__":
    main()
