"""Serving benchmark: fused-scan continuous batching vs the seed lockstep
loop (one XLA dispatch per token), on a 4-request llama-smoke batch.

Reports tokens/s for both engines plus slot utilization for a ragged
8-request / 4-slot run that exercises admission-on-retirement.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def _serve_setup(max_len: int = 64):
    from repro.configs import ARCHS
    from repro.configs import base as cbase
    from repro.nn import init as nninit

    arch = ARCHS["llama3.2-3b"]
    cfg = arch.make_smoke()
    params = nninit.materialize(cbase.model_spec(arch, cfg),
                                jax.random.PRNGKey(0))
    step, init_caches = cbase.serve_fns(arch, cfg, max_len=max_len)
    return cfg, params, step, init_caches


def bench_serve():
    from repro.serve.engine import Engine, LockstepEngine, Request, ServeConfig

    cfg, params, step, init_caches = _serve_setup()
    rows = []
    new, n_req, plen = 32, 4, 12
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (n_req, plen)).astype(np.int32)
    scfg = ServeConfig(max_new_tokens=new, max_slots=n_req, max_len=64,
                       decode_block=8)

    def _best_of(fn, iters=5):
        dts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn()
            dts.append(time.perf_counter() - t0)
        return out, min(dts)

    # seed-style lockstep: one dispatch per token
    lockstep = LockstepEngine(step, init_caches, scfg)
    lockstep.generate(params, prompts)  # warm up compile
    ref, dt_lock = _best_of(lambda: lockstep.generate(params, prompts))
    rows.append(("serve/lockstep_4x32/tok_s", n_req * new / dt_lock,
                 f"dispatches={new}"))

    # fused scan blocks (params bound: the engine implements the runtime
    # protocol; generate/run no longer take params)
    engine = Engine(step, init_caches, scfg, params=params)
    engine.generate(prompts)  # warm up compile
    out, dt_fused = _best_of(lambda: engine.generate(prompts))
    assert np.array_equal(out, ref), "fused decode diverged from lockstep"
    rows.append(("serve/fused_scan_4x32/tok_s", n_req * new / dt_fused,
                 f"dispatches={-(-new // scfg.decode_block)}"))
    rows.append(("serve/fused_vs_lockstep/speedup", dt_lock / dt_fused,
                 f"block={scfg.decode_block}"))

    # continuous batching: ragged 8-request queue through the 4-slot pool
    rng = np.random.default_rng(1)
    cb = Engine(step, init_caches, scfg, params=params)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, (int(rng.integers(4, 16)),)).astype(np.int32),
        max_new_tokens=int(rng.integers(8, new))) for i in range(8)]
    cb.run([Request(uid=99, prompt=reqs[0].prompt, max_new_tokens=4)])
    cb.stats.update(slot_steps=0, active_slot_steps=0)  # warm-up off the books
    t0 = time.perf_counter()
    results = cb.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results.values())
    rows.append(("serve/continuous_8req_4slot/tok_s", toks / dt,
                 f"utilization={cb.utilization():.2f}"))
    return rows


if __name__ == "__main__":
    import argparse
    import json
    import pathlib

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", type=pathlib.Path, default=None,
                    help="also write rows as JSON")
    args = ap.parse_args()
    rows = bench_serve()
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(
            [{"name": n, "value": v, "derived": str(d)}
             for n, v, d in rows], indent=1))
