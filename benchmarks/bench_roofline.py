"""Roofline table from the dry-run artifacts (results/dryrun/*.json)."""

from __future__ import annotations

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "results" / "dryrun"


def load_cells(mesh: str | None = "pod16x16"):
    cells = []
    if not DRYRUN.exists():
        return cells
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("tag"):
            continue  # perf A/B variants are reported in §Perf
        cells.append(r)
    return cells


def bench_roofline():
    rows = []
    ok = skip = err = 0
    for r in load_cells(mesh=None):
        cell = f"{r['arch']}__{r['shape']}__{r['mesh']}"
        if r["status"] == "skip":
            skip += 1
            continue
        if r["status"] != "ok":
            err += 1
            rows.append((f"roofline/{cell}/ERROR", 0.0, r.get("error", "?")[:60]))
            continue
        ok += 1
        t = r["roofline"]
        if r["mesh"] == "pod16x16":  # roofline table is single-pod (brief)
            rows.append((f"roofline/{cell}/dominant", 0.0, t["dominant"]))
            rows.append((f"roofline/{cell}/compute_ms", 0.0,
                         round(t["compute_s"] * 1e3, 2)))
            rows.append((f"roofline/{cell}/memory_ms", 0.0,
                         round(t["memory_s"] * 1e3, 2)))
            rows.append((f"roofline/{cell}/collective_ms", 0.0,
                         round(t["collective_s"] * 1e3, 3)))
            rows.append((f"roofline/{cell}/useful_flops_ratio", 0.0,
                         round(r["useful_flops_ratio"], 3)))
    rows.append(("roofline/cells_ok", 0.0, ok))
    rows.append(("roofline/cells_skipped_documented", 0.0, skip))
    rows.append(("roofline/cells_error", 0.0, err))
    return rows
