"""Paper-table benchmarks (Tab. II / III / IV, Fig. 1 / 5 / 6).

Each ``bench_*`` returns rows of (name, us_per_call, derived):
- ``us_per_call`` — wall-clock of producing that result (DSE runs, sim
  evals, kernel calls),
- ``derived``     — the headline number the paper's table/figure reports.
"""

from __future__ import annotations

import json
import pathlib
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results"


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e6, out


# -- Tab. II: search-space reduction -----------------------------------------


def bench_tab2_searchspace():
    from repro.core import dse, workloads

    rows = []
    for wname, builder in workloads.WORKLOADS.items():
        g = builder()
        n_nodes = len(g.nn_nodes()) + len(g.vsa_nodes())
        us, s = _timed(lambda: dse.search_space(10, n_nodes, 8,
                                                len(g.nn_nodes())))
        rows.append((f"tab2/{wname}/original_log10", us,
                     round(s["original_log10_total"], 1)))
        rows.append((f"tab2/{wname}/dag_points", 0.0, s["dag_total_points"]))
        rows.append((f"tab2/{wname}/reduction_log10", 0.0,
                     round(s["reduction_log10"], 1)))
    return rows


# -- Tab. III: DAG-generated design configurations ----------------------------


def bench_tab3_configs():
    from repro.core import dataflow, dse, workloads

    rows = []
    for wname, builder in workloads.WORKLOADS.items():
        g = builder()

        def run():
            df = dataflow.build(g)
            return dse.explore(df, max_pes=16384)

        us, cfg = _timed(run)
        s = cfg.summary()
        rows.append((f"tab3/{wname}/adarray_HWN", us, f"{cfg.H}x{cfg.W}x{cfg.N}"))
        rows.append((f"tab3/{wname}/partition", 0.0,
                     f"{cfg.nl_bar}:{cfg.nv_bar}" if cfg.mode == "parallel"
                     else "sequential"))
        rows.append((f"tab3/{wname}/simd_lanes", 0.0, s["SIMD"]))
        for m in ("MemA1", "MemA2", "MemB", "MemC", "cache"):
            rows.append((f"tab3/{wname}/{m}_MB", 0.0,
                         round(s[m] / 1e6, 2) if s[m] else 0))
        rows.append((f"tab3/{wname}/searched_points", 0.0, cfg.searched_points))
    return rows


# -- Tab. IV: mixed-precision accuracy/memory ---------------------------------


def bench_tab4_precision():
    path = RESULTS / "nvsa_tab4.json"
    rows = []
    if not path.exists():
        rows.append(("tab4/SKIPPED(run examples/train_nvsa_raven.py)", 0.0, 0))
        return rows
    data = json.loads(path.read_text())
    for style, per_prec in data.items():
        for prec, r in per_prec.items():
            rows.append((f"tab4/{style}/{prec}/answer_acc", 0.0,
                         round(r["answer_acc"], 3)))
        fp32_mem = per_prec["fp32"]["memory_bytes"]
        mp_mem = per_prec["mp"]["memory_bytes"]
        rows.append((f"tab4/{style}/memory_saving_fp32_over_mp", 0.0,
                     round(fp32_mem / mp_mem, 2)))
    return rows


# -- Fig. 1: workload characterization ----------------------------------------


def bench_fig1_characterization():
    from repro.core import simulator, workloads

    rows = []
    for wname, builder in workloads.WORKLOADS.items():
        g = builder()
        nn_f, vsa_f = g.total_flops("nn"), g.total_flops("vsa")
        us, r = _timed(lambda: simulator.simulate_generic(
            g, simulator.DEVICES["rtx2080"]))
        rows.append((f"fig1/{wname}/symbolic_flops_pct", us,
                     round(100 * vsa_f / (nn_f + vsa_f), 1)))
        rows.append((f"fig1/{wname}/symbolic_runtime_pct_gpu", 0.0,
                     round(100 * r.vsa / r.total, 1)))
    return rows


# -- Fig. 5: end-to-end runtime vs baselines ----------------------------------


def bench_fig5_runtime():
    from repro.core import simulator, workloads

    rows = []
    for wname, builder in workloads.WORKLOADS.items():
        g = builder()
        us, ns = _timed(lambda: simulator.simulate_nsflow(g))
        rows.append((f"fig5/{wname}/nsflow_ms", us, round(ns.total * 1e3, 2)))
        for dev in ("tx2", "nx", "xeon", "rtx2080", "coral", "dpu"):
            r = simulator.simulate_generic(g, simulator.DEVICES[dev])
            rows.append((f"fig5/{wname}/speedup_vs_{dev}", 0.0,
                         round(r.total / ns.total, 1)))
        tpu = simulator.simulate_tpu_like(g)
        rows.append((f"fig5/{wname}/speedup_vs_tpu_like", 0.0,
                     round(tpu.total / ns.total, 1)))
    return rows


# -- Fig. 6: scalability ablation ---------------------------------------------


def bench_fig6_ablation():
    from repro.core import simulator, workloads

    rows = []
    t0 = time.perf_counter()
    for scale in (1, 8, 24, 48, 96, 192, 384):
        g = workloads.nvsa_graph(symbolic_scale=scale)
        vsa_b = g.total_bytes("vsa")
        tot_b = g.total_bytes()
        pct = round(100 * vsa_b / tot_b, 1)
        full = simulator.simulate_nsflow(g)
        p1 = simulator.simulate_nsflow(g, phase2_enabled=False)
        seq = simulator.simulate_nsflow(g, force_mode="sequential")
        tpu = simulator.simulate_tpu_like(g)
        rows.append((f"fig6/symb{pct}pct/speedup_vs_tpu", 0.0,
                     round(tpu.total / full.total, 2)))
        rows.append((f"fig6/symb{pct}pct/phase2_gain_pct", 0.0,
                     round(100 * (p1.total / full.total - 1), 1)))
        rows.append((f"fig6/symb{pct}pct/folding_gain_pct", 0.0,
                     round(100 * (seq.total / full.total - 1), 1)))
    us = (time.perf_counter() - t0) * 1e6 / 21
    rows = [(n, us if i == 0 else u, d) for i, (n, u, d) in enumerate(rows)]
    # scalability claim: runtime growth when symbolic scales 150x
    g1 = workloads.nvsa_graph(symbolic_scale=2)
    g150 = workloads.nvsa_graph(symbolic_scale=300)
    r1 = simulator.simulate_nsflow(g1)
    r150 = simulator.simulate_nsflow(g150)
    rows.append(("fig6/runtime_growth_at_150x_symbolic", 0.0,
                 round(r150.total / r1.total, 2)))
    return rows
