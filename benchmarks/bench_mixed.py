"""Mixed LM + NSAI front-door benchmark: one admission layer, two classes.

Deploys an arbitrary mix of LM archs and NSAI workloads (``--models
stablelm-3b,nvsa``) through ``repro.serve.deploy`` — the NSAI engines'
serving knobs (batch buckets, in-flight depth, schedule) DSE-derived from
each workload's traced dataflow graph — and serves interleaved Poisson
arrival streams through ONE ``FrontDoor``.  Rows report, per model, the
class's own throughput unit (tokens/s for LM, problems/s for NSAI) plus
p50/p95 queueing and service latency out of the single shared
``FrontDoorReport``.

Run:  PYTHONPATH=src python benchmarks/bench_mixed.py
          [--models stablelm-3b,nvsa] [--requests 12] [--rate 4]
          [--json out.json] [--check]

``--check`` exits non-zero unless BOTH request classes are present in the
one report and every model's queue/service p50/p95 latencies are finite
(the CI gate for mixed serving).
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def bench_mixed(models, requests: int = 12, rate_rps: float = 4.0,
                deadline_ms: float = 20.0, max_pes: int = 4096,
                max_batch: int = 4, seed: int = 0,
                replicas: int | None = None):
    import jax

    from repro.serve import Budget, Traffic, deploy

    options = {m: {"d": 64} for m in models
               if deployment_class(m) == "reason"}
    deployment = deploy(
        models,
        traffic=Traffic(rate_rps=rate_rps, deadline_s=deadline_ms / 1e3),
        budget=Budget(max_pes=max_pes, max_batch=max_batch, max_slots=2,
                      max_len=64, max_new_tokens=8, replicas=replicas),
        options=options, seed=seed)
    for line in deployment.summary().splitlines():
        print(f"# deploy: {line}", file=sys.stderr)
    deployment.warmup()  # compile every serving shape before latencies
    arrivals, _ = deployment.synthetic_traffic(requests, seed=100 + seed)
    report = deployment.serve(arrivals)

    rows = []
    # every row records the device pool and the model's replica count, so
    # a BENCH measurement is attributable to the mesh it ran on
    ndev = jax.device_count()
    for m in models:
        design = deployment.designs[m]
        dse_tag = f"dse={design.tag()}" if design is not None else "dse=n/a"
        mesh_tag = (f"devices={ndev} "
                    f"replicas={deployment.replicas.get(m, 1)}")
        unit = report.work_unit(m)
        q = report.percentiles("queue_s", m)
        s = report.percentiles("service_s", m)
        t = report.percentiles("total_s", m)
        pre = f"serve/mixed/{m}"
        rows += [
            (f"{pre}/served", len(report.results[m]),
             f"class={deployment.classes[m]} {mesh_tag} {dse_tag}"),
            (f"{pre}/{'tok' if unit == 'tok' else 'problems'}_s",
             report.work_per_s(m), f"unit={unit} {mesh_tag} {dse_tag}"),
            (f"{pre}/queue_p50_ms", q["p50"] * 1e3,
             f"arrival->dispatch {mesh_tag}"),
            (f"{pre}/queue_p95_ms", q["p95"] * 1e3,
             f"arrival->dispatch {mesh_tag}"),
            (f"{pre}/queue_p99_ms", q["p99"] * 1e3,
             f"arrival->dispatch {mesh_tag}"),
            (f"{pre}/service_p50_ms", s["p50"] * 1e3,
             f"dispatch->done {mesh_tag}"),
            (f"{pre}/service_p95_ms", s["p95"] * 1e3,
             f"dispatch->done {mesh_tag}"),
            (f"{pre}/service_p99_ms", s["p99"] * 1e3,
             f"dispatch->done {mesh_tag}"),
            (f"{pre}/total_p99_ms", t["p99"] * 1e3,
             f"arrival->done {mesh_tag}"),
        ]
    return rows, report, deployment


def deployment_class(model: str) -> str:
    # same membership test deploy() itself uses (Deployment.classes is the
    # authoritative answer post-deploy; this is needed pre-deploy to build
    # the per-model options)
    from repro.configs.base import REASON_WORKLOADS

    return "reason" if model in REASON_WORKLOADS else "lm"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="stablelm-3b,nvsa",
                    help="comma list mixing LM archs and NSAI workloads")
    ap.add_argument("--requests", type=int, default=12,
                    help="Poisson arrivals per model")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="per-model offered load, req/s")
    ap.add_argument("--deadline-ms", type=float, default=20.0)
    ap.add_argument("--max-pes", type=int, default=4096)
    ap.add_argument("--replicas", type=int, default=None,
                    help="data-parallel engine replicas per model (default "
                         "1; fake devices via XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N)")
    ap.add_argument("--json", type=pathlib.Path, default=None,
                    help="also write rows as JSON")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless both request classes report finite "
                         "p50/p95 latencies in the one FrontDoorReport")
    args = ap.parse_args()

    models = [m.strip() for m in args.models.split(",") if m.strip()]
    rows, report, deployment = bench_mixed(
        models, requests=args.requests, rate_rps=args.rate,
        deadline_ms=args.deadline_ms, max_pes=args.max_pes,
        replicas=args.replicas)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(
            [{"name": n, "value": v, "derived": str(x)}
             for n, v, x in rows], indent=1))
    if args.check:
        classes = {deployment.classes[m] for m in models}
        if classes != {"lm", "reason"}:
            print(f"FAIL: mixed gate needs both classes in one report, "
                  f"got {sorted(classes)}", file=sys.stderr)
            return 1
        vals = {n: v for n, v, _ in rows}
        for m in models:
            if not vals[f"serve/mixed/{m}/served"] == args.requests:
                print(f"FAIL: {m} served "
                      f"{vals[f'serve/mixed/{m}/served']:.0f} of "
                      f"{args.requests} requests", file=sys.stderr)
                return 1
            for p in ("queue_p50_ms", "queue_p95_ms", "queue_p99_ms",
                      "service_p50_ms", "service_p95_ms", "service_p99_ms",
                      "total_p99_ms"):
                v = vals[f"serve/mixed/{m}/{p}"]
                if not math.isfinite(v):
                    print(f"FAIL: {m} {p} is not finite ({v})",
                          file=sys.stderr)
                    return 1
        missing = [n for n, _, x in rows
                   if "devices=" not in x or "replicas=" not in x]
        if missing:
            print(f"FAIL: rows missing devices=/replicas= provenance: "
                  f"{missing}", file=sys.stderr)
            return 1
        print("mixed front-door gate OK: both request classes finite "
              f"p50/p95 ({','.join(models)}), devices/replicas recorded")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
